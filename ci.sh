#!/usr/bin/env bash
# Tier-1 verify plus lint gates, as run by .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Release-mode run: exercises the blocked multi-RHS kernels with
# optimizations on (debug-only runs hide FMA/reassociation drift).
echo "== cargo test -q --release =="
cargo test -q --release

# Forced-scalar run: keeps the portable reference path covered on
# SIMD-capable runners (the default run above dispatches to AVX2/NEON
# when the host supports it).
echo "== cargo test -q (SNSOLVE_SIMD=scalar) =="
SNSOLVE_SIMD=scalar cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "CI OK"
