#!/usr/bin/env bash
# Tier-1 verify plus lint gates, as run by .github/workflows/ci.yml.
#
# `./ci.sh --lint` runs only the fast static gates — snsolve-lint, its
# self-tests, rustfmt and clippy — as the pre-push inner loop (seconds,
# not minutes).
set -euo pipefail
cd "$(dirname "$0")/rust"

lint_only=0
for arg in "$@"; do
  case "$arg" in
    --lint) lint_only=1 ;;
    *)
      echo "usage: ci.sh [--lint]" >&2
      exit 2
      ;;
  esac
done

run_lint_gates() {
  # Project lint first: it is the cheapest gate and its findings are the
  # most actionable (missing SAFETY comments, stray env reads, half-wired
  # knobs).
  echo "== snsolve-lint =="
  cargo run -q -p snsolve-lint

  echo "== snsolve-lint self-tests =="
  cargo test -q -p snsolve-lint

  echo "== cargo fmt --check =="
  cargo fmt --all --check

  echo "== cargo clippy -- -D warnings =="
  cargo clippy --workspace --all-targets -- -D warnings

  # Release-profile clippy too: cfg(debug_assertions)-gated code flips,
  # and optimizer-dependent lints (e.g. overflow checks) differ.
  echo "== cargo clippy --release -- -D warnings =="
  cargo clippy --workspace --all-targets --release -- -D warnings
}

if [[ $lint_only -eq 1 ]]; then
  run_lint_gates
  echo "LINT OK"
  exit 0
fi

echo "== cargo build --release =="
cargo build --release

# Benches only compiled when run by hand before this; keep them building.
echo "== cargo bench --no-run =="
cargo bench --no-run

echo "== cargo test -q =="
cargo test -q

# Release-mode run: exercises the blocked multi-RHS kernels with
# optimizations on (debug-only runs hide FMA/reassociation drift).
echo "== cargo test -q --release =="
cargo test -q --release

# Forced-scalar run: keeps the portable reference path covered on
# SIMD-capable runners (the default run above dispatches to
# AVX-512/AVX2/NEON when the host supports it).
echo "== cargo test -q (SNSOLVE_SIMD=scalar) =="
SNSOLVE_SIMD=scalar cargo test -q

# Forced-avx512 run: exercises the 8x8 zmm backend on hosts reporting
# avx512f. The step is skipped entirely when the host lacks the feature —
# a forced avx512 would just degrade to scalar there, duplicating the
# forced-scalar run above. (The in-process fallback still guarantees the
# knob is safe anywhere.)
if grep -q avx512f /proc/cpuinfo 2>/dev/null; then
  echo "== cargo test -q (SNSOLVE_SIMD=avx512) =="
  SNSOLVE_SIMD=avx512 cargo test -q
else
  echo "== skipping SNSOLVE_SIMD=avx512 run (host reports no avx512f) =="
fi

# Sketch-engine equivalence (blocked/fused FWHT, inverted scatter,
# workspaces) pinned explicitly under BOTH the portable reference backend
# and the detected-best backend (auto dispatch) — the full-suite runs
# above cover these too; the explicit runs keep the engine's bitwise
# contract loud in the CI log.
echo "== sketch engine equivalence (SNSOLVE_SIMD=scalar) =="
SNSOLVE_SIMD=scalar cargo test -q --test sketch_engine_equivalence --test workspace_reuse

echo "== sketch engine equivalence (detected-best backend) =="
cargo test -q --test sketch_engine_equivalence --test workspace_reuse

# Scheduler matrix: the determinism harness (including the steal-heavy
# adversarial sweep) under both worker-pool schedulers at awkward ambient
# pool sizes (7 divides nothing). The test drives its own thread/schedule
# sweeps internally; the env matrix additionally pins the ambient
# resolution each knob path must honor.
for sched in steal static; do
  for t in 2 7; do
    echo "== parallel determinism (SNSOLVE_SCHEDULE=$sched SNSOLVE_THREADS=$t) =="
    SNSOLVE_SCHEDULE=$sched SNSOLVE_THREADS=$t cargo test -q --test parallel_determinism
  done
done

# Serving-tier e2e under both wire clients: the suite's env-selected flow
# runs through the legacy v1 blocking client and the pipelined v2 client,
# alongside the always-on pipelining/regression tests.
for client in legacy pipelined; do
  echo "== service e2e (SNSOLVE_CLIENT=$client) =="
  SNSOLVE_CLIENT=$client cargo test -q --test service_e2e
done

# Cluster tier: three real serve processes behind the sharded failover
# router — kill-one mid-traffic, replica failover, restart + rebalance,
# seeded network-fault drill — under both worker-pool schedulers.
for sched in steal static; do
  echo "== cluster failover (SNSOLVE_SCHEDULE=$sched) =="
  SNSOLVE_SCHEDULE=$sched cargo test -q --test cluster_failover
done

# Robust-solving tier: the accuracy pins for the forward-stable ladder and
# the deterministic fault-injection drills (every ladder rung forced to
# fail, worker panic containment), under both worker-pool schedulers — the
# escalation path must hold regardless of how the sweeps are scheduled.
for sched in steal static; do
  echo "== solver stability + ladder faults (SNSOLVE_SCHEDULE=$sched) =="
  SNSOLVE_SCHEDULE=$sched cargo test -q --test solver_stability --test ladder_faults
done

# Front-end bench smoke: closed-loop serial vs pipelined sweep in quick
# mode; records BENCH_frontend_pipeline.{json,csv} with p50/p95/p99 + QPS.
echo "== frontend pipeline bench (quick) =="
SNSOLVE_BENCH_QUICK=1 cargo bench --bench coordinator_throughput -- --frontend

# Stability bench smoke: quick κ-sweep (forward error vs condition number
# per solver tier); records BENCH_solver_stability.{json,csv}.
echo "== solver stability bench (quick) =="
SNSOLVE_BENCH_QUICK=1 cargo bench --bench solver_stability

run_lint_gates

echo "CI OK"
