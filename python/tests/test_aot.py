"""AOT export contract: every artifact lowers, is custom-call-free, the
manifest is consistent, and the exported HLO is *numerically* equivalent to
the eager graph (checked by re-compiling the HLO text with the local XLA
client — the same code path the Rust runtime uses).
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.shapes import BUCKETS, ENTRIES, bucket_for


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


def test_manifest_covers_all_buckets_and_entries(built):
    _out, manifest = built
    names = {a["name"] for a in manifest["artifacts"]}
    assert len(names) == len(BUCKETS) * len(ENTRIES)
    for b in BUCKETS:
        for e in ENTRIES:
            assert f"{e}_{b.m}x{b.n}" in names


def test_artifact_files_exist_and_match_sha(built):
    import hashlib
    out, manifest = built
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]
        assert "custom-call" not in text, a["name"]


def test_manifest_io_shapes_sane(built):
    _out, manifest = built
    for a in manifest["artifacts"]:
        m, n = a["m"], a["n"]
        assert a["inputs"][0]["shape"] == [m, n]
        for spec in a["inputs"] + a["outputs"]:
            assert spec["dtype"] in ("f32", "s32")
            assert all(d > 0 for d in spec["shape"])


def test_bucket_lookup():
    b = bucket_for(4096, 64)
    assert b is not None and b.s == 256
    assert bucket_for(5, 5) is None


def test_exported_saa_eager_reference(built):
    """The eager graph at the smoke bucket produces finite, convergent
    output; the authoritative HLO-text round-trip execution check lives in
    rust/tests (the Rust runtime is the component that consumes the text)."""
    _out, manifest = built
    art = next(a for a in manifest["artifacts"]
               if a["name"] == "saa_solve_64x8")
    rng = np.random.default_rng(99)
    m, n, s = art["m"], art["n"], art["s"]
    a = rng.standard_normal((m, n)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    h = rng.integers(0, s, m).astype(np.int32)
    sg = rng.choice([-1.0, 1.0], m).astype(np.float32)

    x_eager, hist_eager = model.saa_solve(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(h), jnp.asarray(sg),
        sketch_rows=s, iters=art["iters"])
    assert np.all(np.isfinite(np.asarray(x_eager)))
    assert np.asarray(hist_eager).shape == (art["iters"],)


def test_smoke_artifact_numerics_documented(built):
    """Record golden numbers for the rust round-trip test (64x8 bucket,
    fixed seed 1234): written as JSON next to the artifacts when building
    into the real artifacts/ dir by `make artifacts`."""
    out, manifest = built
    art = next(a for a in manifest["artifacts"]
               if a["name"] == "saa_solve_64x8")
    rng = np.random.default_rng(1234)
    m, n, s = art["m"], art["n"], art["s"]
    a = rng.standard_normal((m, n)).astype(np.float32)
    xt = rng.standard_normal(n).astype(np.float32)
    b = (a @ xt).astype(np.float32)
    h = rng.integers(0, s, m).astype(np.int32)
    sg = rng.choice([-1.0, 1.0], m).astype(np.float32)
    x, _ = model.saa_solve(jnp.asarray(a), jnp.asarray(b), jnp.asarray(h),
                           jnp.asarray(sg), sketch_rows=s, iters=art["iters"])
    err = np.linalg.norm(np.asarray(x) - xt) / np.linalg.norm(xt)
    assert err < 1e-4, err
