"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the core
correctness signal required before anything is AOT-exported.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import countsketch, countsketch_vec, fht, gaussian_sketch
from compile.kernels.ref import (countsketch_ref, fwht_ref,
                                 gaussian_sketch_ref, mgs_qr_ref)

jax.config.update("jax_enable_x64", True)


def _tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else \
        dict(rtol=1e-12, atol=1e-12)


# ----------------------------------------------------------------------
# CountSketch
# ----------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([32, 64, 128, 512, 1000]),
    n=st.sampled_from([1, 3, 8, 32, 100]),
    s=st.sampled_from([8, 16, 64]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_countsketch_matches_ref(m, n, s, dtype, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, n)), dtype)
    h = jnp.asarray(rng.integers(0, s, m), jnp.int32)
    sg = jnp.asarray(rng.choice([-1.0, 1.0], m), dtype)
    got = countsketch(a, h, sg, s)
    want = countsketch_ref(a, h, sg, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(
    tile_m=st.sampled_from([16, 64, 256]),
    tile_n=st.sampled_from([4, 16, 128]),
)
def test_countsketch_tile_invariance(tile_m, tile_n):
    """Result must not depend on the VMEM tiling."""
    rng = np.random.default_rng(7)
    m, n, s = 512, 48, 32
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    h = jnp.asarray(rng.integers(0, s, m), jnp.int32)
    sg = jnp.asarray(rng.choice([-1.0, 1.0], m), jnp.float32)
    base = countsketch(a, h, sg, s)
    tiled = countsketch(a, h, sg, s, tile_m=tile_m, tile_n=tile_n)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled),
                               rtol=2e-5, atol=2e-5)


def test_countsketch_vec_matches_matrix_path():
    rng = np.random.default_rng(3)
    m, s = 1000, 64
    v = jnp.asarray(rng.standard_normal(m), jnp.float32)
    h = jnp.asarray(rng.integers(0, s, m), jnp.int32)
    sg = jnp.asarray(rng.choice([-1.0, 1.0], m), jnp.float32)
    got = countsketch_vec(v, h, sg, s)
    want = countsketch_ref(v[:, None], h, sg, s)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_countsketch_preserves_column_sums_up_to_sign():
    """Structural invariant: Σ_r B[r, j] = Σ_i sign[i]·A[i, j]."""
    rng = np.random.default_rng(5)
    m, n, s = 256, 10, 16
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float64)
    h = jnp.asarray(rng.integers(0, s, m), jnp.int32)
    sg = jnp.asarray(rng.choice([-1.0, 1.0], m), jnp.float64)
    b = countsketch(a, h, sg, s)
    np.testing.assert_allclose(np.asarray(b.sum(0)),
                               np.asarray((a * sg[:, None]).sum(0)),
                               rtol=1e-10, atol=1e-10)


# ----------------------------------------------------------------------
# Dense (Gaussian) sketch GEMM
# ----------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([8, 32, 128]),
    m=st.sampled_from([64, 256, 1000]),
    n=st.sampled_from([1, 16, 100]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gaussian_sketch_matches_ref(s, m, n, dtype, seed):
    rng = np.random.default_rng(seed)
    smat = jnp.asarray(rng.standard_normal((s, m)) / np.sqrt(s), dtype)
    a = jnp.asarray(rng.standard_normal((m, n)), dtype)
    got = gaussian_sketch(smat, a)
    want = gaussian_sketch_ref(smat, a)
    tol = dict(rtol=5e-4, atol=5e-4) if dtype == np.float32 else \
        dict(rtol=1e-11, atol=1e-11)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


@settings(max_examples=10, deadline=None)
@given(
    tm=st.sampled_from([8, 32, 128]),
    tk=st.sampled_from([16, 64, 256]),
)
def test_gaussian_sketch_tile_invariance(tm, tk):
    rng = np.random.default_rng(11)
    smat = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((512, 32)), jnp.float32)
    base = gaussian_sketch(smat, a)
    tiled = gaussian_sketch(smat, a, tm=tm, tk=tk)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled),
                               rtol=5e-4, atol=5e-4)


# ----------------------------------------------------------------------
# FWHT
# ----------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    logm=st.integers(0, 10),
    n=st.sampled_from([1, 3, 16, 64]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fht_matches_ref(logm, n, dtype, seed):
    m = 1 << logm
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, n)), dtype)
    got = fht(x)
    want = fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def test_fht_involution_and_parseval():
    rng = np.random.default_rng(13)
    m, n = 256, 8
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float64)
    hx = fht(x)
    # H(Hx) = m·x
    np.testing.assert_allclose(np.asarray(fht(hx)), m * np.asarray(x),
                               rtol=1e-11, atol=1e-11)
    # Parseval: ‖Hx‖² = m·‖x‖²
    np.testing.assert_allclose(float((hx**2).sum()), m * float((x**2).sum()),
                               rtol=1e-12)


def test_fht_rejects_non_power_of_two():
    x = jnp.zeros((6, 2), jnp.float32)
    with pytest.raises(AssertionError):
        fht(x)


# ----------------------------------------------------------------------
# MGS QR oracle sanity (used by the AOT graphs)
# ----------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([16, 48, 128]),
    n=st.sampled_from([4, 12, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mgs_qr_ref_invariants(s, n, seed):
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((s, n)), jnp.float64)
    q, r = mgs_qr_ref(b)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(n),
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(b),
                               rtol=0, atol=1e-12)
    assert np.allclose(np.tril(np.asarray(r), -1), 0.0)
