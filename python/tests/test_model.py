"""Layer-2 correctness: the AOT computation graphs.

Validates the custom-call-free QR/substitution building blocks against
scipy, the fused SAA-SAS graph against ground-truth planted problems, and
the LSQR scan against scipy.sparse.linalg.lsqr.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.linalg as sla
import scipy.sparse.linalg as spla
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_enable_x64", True)


def planted(m, n, resid, seed, dtype=np.float64, cond=None):
    """Small §5.1-style problem with known minimizer."""
    rng = np.random.default_rng(seed)
    if cond is None:
        a = rng.standard_normal((m, n))
    else:
        u, _ = np.linalg.qr(rng.standard_normal((m, n)))
        v, _ = np.linalg.qr(rng.standard_normal((n, n)))
        sig = np.logspace(0, -np.log10(cond), n)
        a = (u * sig) @ v.T
    x = rng.standard_normal(n)
    x /= np.linalg.norm(x)
    r = rng.standard_normal(m)
    r -= a @ np.linalg.lstsq(a, r, rcond=None)[0]
    r *= resid / np.linalg.norm(r)
    b = a @ x + r
    return a.astype(dtype), b.astype(dtype), x.astype(dtype)


def cw_hash(m, s, seed):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.integers(0, s, m), jnp.int32),
            jnp.asarray(rng.choice([-1.0, 1.0], m)))


# ----------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([24, 64]), n=st.sampled_from([4, 12, 24]),
       seed=st.integers(0, 2**31 - 1))
def test_mgs_qr_graph_invariants(s, n, seed):
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((s, n)))
    q, r = model.mgs_qr(b)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(n), atol=1e-12)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(b), atol=1e-12)
    assert np.allclose(np.tril(np.asarray(r), -1), 0.0)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([1, 5, 20, 64]), seed=st.integers(0, 2**31 - 1))
def test_triangular_solves_match_scipy(n, seed):
    rng = np.random.default_rng(seed)
    r = np.triu(rng.standard_normal((n, n))) + 3.0 * np.eye(n)
    z = rng.standard_normal(n)
    got_u = np.asarray(model.solve_upper(jnp.asarray(r), jnp.asarray(z)))
    np.testing.assert_allclose(got_u, sla.solve_triangular(r, z), rtol=1e-9)
    got_t = np.asarray(
        model.solve_upper_transpose(jnp.asarray(r), jnp.asarray(z)))
    np.testing.assert_allclose(got_t, sla.solve_triangular(r.T, z, lower=True),
                               rtol=1e-9)


def test_solve_upper_guards_zero_diagonal():
    r = jnp.asarray(np.diag([1.0, 0.0, 2.0]))
    x = model.solve_upper(r, jnp.ones(3))
    assert np.all(np.isfinite(np.asarray(x)))


# ----------------------------------------------------------------------
# LSQR scan
# ----------------------------------------------------------------------

def test_lsqr_scan_matches_scipy_lsqr():
    a, b, _x = planted(300, 20, 0.1, 42)
    aj = jnp.asarray(a)
    x, hist = model.lsqr_scan(lambda v: aj @ v, lambda u: aj.T @ u,
                              jnp.asarray(b), jnp.zeros(20), iters=40)
    ref = spla.lsqr(a, b, atol=0, btol=0, iter_lim=40)[0]
    np.testing.assert_allclose(np.asarray(x), ref, rtol=1e-6, atol=1e-8)
    # history is the monotone phibar sequence
    h = np.asarray(hist)
    assert np.all(np.diff(h) <= 1e-12)


def test_lsqr_scan_warm_start():
    a, b, x_true = planted(200, 10, 1e-8, 43)
    aj = jnp.asarray(a)
    x, hist = model.lsqr_scan(lambda v: aj @ v, lambda u: aj.T @ u,
                              jnp.asarray(b), jnp.asarray(x_true), iters=5)
    err = np.linalg.norm(np.asarray(x) - x_true)
    assert err < 1e-8, err


# ----------------------------------------------------------------------
# fused pipelines
# ----------------------------------------------------------------------

def test_saa_solve_recovers_planted_solution():
    m, n, s = 2048, 32, 128
    a, b, x_true = planted(m, n, 1e-6, 44)
    h, sg = cw_hash(m, s, 45)
    x, hist = model.saa_solve(jnp.asarray(a), jnp.asarray(b), h, sg,
                              sketch_rows=s, iters=20)
    err = np.linalg.norm(np.asarray(x) - x_true) / np.linalg.norm(x_true)
    assert err < 1e-8, err
    assert np.asarray(hist).shape == (20,)


def test_saa_solve_illconditioned_f64():
    m, n, s = 4096, 50, 200
    a, b, x_true = planted(m, n, 1e-10, 46, cond=1e8)
    h, sg = cw_hash(m, s, 47)
    x, _ = model.saa_solve(jnp.asarray(a), jnp.asarray(b), h, sg,
                           sketch_rows=s, iters=40)
    err = np.linalg.norm(np.asarray(x) - x_true) / np.linalg.norm(x_true)
    assert err < 1e-4, err


def test_saa_beats_baseline_iteration_for_iteration():
    m, n, s = 2048, 32, 128
    a, b, x_true = planted(m, n, 1e-4, 48, cond=1e6)
    h, sg = cw_hash(m, s, 49)
    iters = 15
    xs, _ = model.saa_solve(jnp.asarray(a), jnp.asarray(b), h, sg,
                            sketch_rows=s, iters=iters)
    xb, _ = model.lsqr_baseline(jnp.asarray(a), jnp.asarray(b), iters=iters)
    err_s = np.linalg.norm(np.asarray(xs) - x_true)
    err_b = np.linalg.norm(np.asarray(xb) - x_true)
    assert err_s < err_b, (err_s, err_b)


def test_sketch_and_solve_only_close_but_coarse():
    m, n, s = 2048, 32, 128
    a, b, x_true = planted(m, n, 0.01, 50)
    h, sg = cw_hash(m, s, 51)
    x = model.sketch_and_solve_only(jnp.asarray(a), jnp.asarray(b), h, sg,
                                    sketch_rows=s)
    err = np.linalg.norm(np.asarray(x) - x_true) / np.linalg.norm(x_true)
    assert err < 0.05, err


def test_sketch_only_matches_ref():
    from compile.kernels.ref import countsketch_ref
    m, n, s = 512, 16, 64
    rng = np.random.default_rng(52)
    a = jnp.asarray(rng.standard_normal((m, n)))
    h, sg = cw_hash(m, s, 53)
    got = model.sketch_only(a, h, sg, sketch_rows=s)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(countsketch_ref(a, h, sg, s)),
                               atol=1e-10)
