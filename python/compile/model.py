"""Layer 2: the SAA-SAS pipeline as JAX computation graphs.

These functions are lowered ONCE by ``aot.py`` to HLO text and executed from
the Rust coordinator via PJRT — Python never runs on the request path.

Constraint that shapes this file: the Rust PJRT CPU client has **no LAPACK
custom-call registry**, so ``jnp.linalg.qr`` / ``cholesky`` /
``lax.linalg.triangular_solve`` (which all lower to
``lapack_*`` custom-calls on CPU) are off-limits. Every factorization and
solve here is hand-written from matmul/scan/dynamic-slice — pure HLO ops
that any PJRT backend executes. ``python/tests/test_model.py`` asserts the
lowered modules are custom-call-free.

Numerics: the AOT path is f32 (XLA CPU). With MGS(2-pass) QR and
substitution solves the pipeline is accurate to ~κ(A)·ε_f32; the native f64
Rust path covers the paper's extreme κ = 10¹⁰ experiments, and the
integration tests compare the two at f32-appropriate tolerances.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.countsketch import countsketch, countsketch_vec


# ----------------------------------------------------------------------
# Custom-call-free dense building blocks
# ----------------------------------------------------------------------

def mgs_qr(b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two-pass modified Gram–Schmidt economy QR of ``(s, n)``, s ≥ n.

    Pure scan/matmul — lowers to an HLO while-loop, no custom calls.
    Two orthogonalization passes keep ‖QᵀQ − I‖ = O(ε) even for
    ill-conditioned B (Giraud et al. 2005), which SAA-SAS depends on.
    """
    s, n = b.shape
    dtype = b.dtype

    def step(carry, j):
        q, r = carry
        v = jax.lax.dynamic_slice(b, (0, j), (s, 1))[:, 0]
        proj_total = jnp.zeros((n,), dtype)
        for _ in range(2):  # two-pass re-orthogonalization
            proj = q.T @ v
            proj_total = proj_total + proj
            v = v - q @ proj
        norm = jnp.sqrt(jnp.sum(v * v))
        # Guard rank deficiency: if the column vanished, keep a zero column
        # (R gets a zero diagonal; downstream substitution guards too).
        safe = jnp.where(norm > 0, norm, jnp.asarray(1.0, dtype))
        qcol = v / safe
        q = jax.lax.dynamic_update_slice(q, qcol[:, None], (0, j))
        rcol = proj_total.at[j].set(norm)
        r = jax.lax.dynamic_update_slice(r, rcol[:, None], (0, j))
        return (q, r), None

    q0 = jnp.zeros((s, n), dtype)
    r0 = jnp.zeros((n, n), dtype)
    (q, r), _ = jax.lax.scan(step, (q0, r0), jnp.arange(n))
    return q, r


def mgs_qr_blocked(b: jnp.ndarray, panel: int = 32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Panel-blocked two-pass MGS QR — same math as [`mgs_qr`], restructured
    for AOT latency.

    Perf note (EXPERIMENTS.md §Perf-L2): the column-at-a-time scan costs one
    sequential HLO while-loop step *per column* (~1.3 ms dispatch each on
    XLA CPU → 340 ms at n = 256). Blocking processes `panel` columns per
    scan step: inter-panel orthogonalization is two GEMMs (CGS2), the
    within-panel factorization is an unrolled MGS over `panel` columns.
    n/panel = 8 sequential steps instead of 256.
    """
    s, n = b.shape
    dtype = b.dtype
    if n % panel != 0:
        panel = 1  # fallback: degenerate to column-at-a-time
    nblk = n // panel

    def step(carry, p):
        q, r = carry
        j0 = p * panel
        v = jax.lax.dynamic_slice(b, (0, j0), (s, panel))
        # CGS2 against all previously filled columns (unfilled are zero).
        proj_total = jnp.zeros((n, panel), dtype)
        for _ in range(2):
            proj = q.T @ v
            proj_total = proj_total + proj
            v = v - q @ proj
        r = jax.lax.dynamic_update_slice(
            r,
            jax.lax.dynamic_slice(r, (0, j0), (n, panel)) + proj_total,
            (0, j0),
        )
        # Within-panel MGS (unrolled: `panel` small).
        qp = jnp.zeros((s, panel), dtype)
        rp = jnp.zeros((panel, panel), dtype)
        for j in range(panel):
            col = v[:, j]
            acc = jnp.zeros((panel,), dtype)
            for _ in range(2):
                proj = qp.T @ col
                acc = acc + proj
                col = col - qp @ proj
            norm = jnp.sqrt(jnp.sum(col * col))
            safe = jnp.where(norm > 0, norm, jnp.asarray(1.0, dtype))
            qp = qp.at[:, j].set(col / safe)
            rp = rp.at[:, j].set(acc.at[j].set(norm))
        q = jax.lax.dynamic_update_slice(q, qp, (0, j0))
        r = jax.lax.dynamic_update_slice(
            r,
            jax.lax.dynamic_slice(r, (j0, j0), (panel, panel)) + rp,
            (j0, j0),
        )
        return (q, r), None

    q0 = jnp.zeros((s, n), dtype)
    r0 = jnp.zeros((n, n), dtype)
    (q, r), _ = jax.lax.scan(step, (q0, r0), jnp.arange(nblk))
    return q, r


def solve_upper(r: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Back substitution ``x = R⁻¹ z`` for upper-triangular R — pure scan."""
    n = r.shape[0]
    dtype = r.dtype

    def step(x, t):
        j = n - 1 - t
        rrow = jax.lax.dynamic_slice(r, (j, 0), (1, n))[0]
        # x[k] = 0 for k ≤ j (not yet assigned) and R[j,k] = 0 for k < j,
        # so the full dot picks up exactly the solved suffix.
        dot = jnp.sum(rrow * x)
        zj = jax.lax.dynamic_slice(z, (j,), (1,))[0]
        diag = jax.lax.dynamic_slice(r, (j, j), (1, 1))[0, 0]
        safe = jnp.where(jnp.abs(diag) > 0, diag, jnp.asarray(1.0, dtype))
        xj = (zj - dot) / safe
        x = jax.lax.dynamic_update_slice(x, xj[None], (j,))
        return x, None

    x0 = jnp.zeros((n,), dtype)
    x, _ = jax.lax.scan(step, x0, jnp.arange(n))
    return x


def solve_upper_transpose(r: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Forward substitution ``x = R⁻ᵀ z`` (lower-triangular Rᵀ) — pure scan."""
    n = r.shape[0]
    dtype = r.dtype

    def step(x, j):
        # Rᵀ[j, :] = R[:, j]; entries below diag of Rᵀ are R[k, j], k < j.
        rcol = jax.lax.dynamic_slice(r, (0, j), (n, 1))[:, 0]
        dot = jnp.sum(rcol * x)  # picks up solved prefix only
        zj = jax.lax.dynamic_slice(z, (j,), (1,))[0]
        diag = jax.lax.dynamic_slice(r, (j, j), (1, 1))[0, 0]
        safe = jnp.where(jnp.abs(diag) > 0, diag, jnp.asarray(1.0, dtype))
        xj = (zj - dot) / safe
        x = jax.lax.dynamic_update_slice(x, xj[None], (j,))
        return x, None

    x0 = jnp.zeros((n,), dtype)
    x, _ = jax.lax.scan(step, x0, jnp.arange(n))
    return x


def invert_upper(r: jnp.ndarray) -> jnp.ndarray:
    """Explicit ``R⁻¹`` by back substitution with matrix RHS — ONE n-step
    scan total, after which applying ``R⁻¹``/``R⁻ᵀ`` is a plain GEMV.

    Perf note (EXPERIMENTS.md §Perf-L2): the first AOT export applied
    `solve_upper` *inside every LSQR iteration*, costing two n-step
    sequential HLO while-loops per iteration (~15k loop-step dispatches per
    solve at n = 256). Materializing R⁻¹ once collapses each iteration to
    two fused GEMVs. Numerically this trades a substitution for an explicit
    inverse; κ(R) ≈ κ(A), acceptable on the f32 serving path whose router
    already bounds requested tolerance (RouterConfig::max_pjrt_tol).
    """
    n = r.shape[0]
    dtype = r.dtype
    eye = jnp.eye(n, dtype=dtype)

    def step(x, t):
        j = n - 1 - t
        rrow = jax.lax.dynamic_slice(r, (j, 0), (1, n))[0]
        # rows of x below j are solved; row j is still zero; R[j, k<j] = 0.
        dot = rrow @ x
        ej = jax.lax.dynamic_slice(eye, (j, 0), (1, n))[0]
        diag = jax.lax.dynamic_slice(r, (j, j), (1, 1))[0, 0]
        safe = jnp.where(jnp.abs(diag) > 0, diag, jnp.asarray(1.0, dtype))
        xrow = (ej - dot) / safe
        x = jax.lax.dynamic_update_slice(x, xrow[None, :], (j, 0))
        return x, None

    x0 = jnp.zeros((n, n), dtype)
    x, _ = jax.lax.scan(step, x0, jnp.arange(n))
    return x


# ----------------------------------------------------------------------
# LSQR as a fixed-trip scan
# ----------------------------------------------------------------------

class LsqrState(NamedTuple):
    x: jnp.ndarray
    u: jnp.ndarray
    v: jnp.ndarray
    w: jnp.ndarray
    alpha: jnp.ndarray
    rhobar: jnp.ndarray
    phibar: jnp.ndarray


def lsqr_scan(matvec, rmatvec, b: jnp.ndarray, x0: jnp.ndarray,
              iters: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Paige–Saunders LSQR, fixed ``iters`` trips (no early exit — HLO keeps
    a single fused while-loop; the Rust layer applies the convergence test
    to the returned residual history, mirroring Algorithm 1 line 7).

    Returns ``(x, resnorm_history)`` with history length ``iters``.
    """
    dtype = b.dtype

    def norm(x):
        return jnp.sqrt(jnp.sum(x * x))

    u = b - matvec(x0)
    beta = norm(u)
    u = u / jnp.where(beta > 0, beta, 1.0)
    v = rmatvec(u)
    alpha = norm(v)
    v = v / jnp.where(alpha > 0, alpha, 1.0)
    state = LsqrState(x=x0, u=u, v=v, w=v, alpha=alpha, rhobar=alpha,
                      phibar=beta)

    def step(st: LsqrState, _):
        u = matvec(st.v) - st.alpha * st.u
        beta = norm(u)
        u = u / jnp.where(beta > 0, beta, 1.0)
        v = rmatvec(u) - beta * st.v
        alpha = norm(v)
        v = v / jnp.where(alpha > 0, alpha, 1.0)

        rho = jnp.sqrt(st.rhobar * st.rhobar + beta * beta)
        c = st.rhobar / rho
        s = beta / rho
        theta = s * alpha
        rhobar = -c * alpha
        phi = c * st.phibar
        phibar = s * st.phibar

        x = st.x + (phi / rho) * st.w
        w = v - (theta / rho) * st.w
        new = LsqrState(x=x, u=u, v=v, w=w, alpha=alpha, rhobar=rhobar,
                        phibar=phibar)
        return new, phibar.astype(dtype)

    final, history = jax.lax.scan(step, state, None, length=iters)
    return final.x, history


# ----------------------------------------------------------------------
# Pipeline entry points (AOT-exported)
# ----------------------------------------------------------------------

def sketch_qr_precond(a: jnp.ndarray, b: jnp.ndarray, buckets: jnp.ndarray,
                      signs: jnp.ndarray, sketch_rows: int):
    """Algorithm 1 steps 2–5: returns ``(r, z0, c)``.

    ``B = S·A`` runs through the Layer-1 CountSketch Pallas kernel, so it
    lowers into the same HLO module. Tiles are set to the full block on the
    CPU/interpret path — the interpret-mode grid machinery costs ~10 ms per
    grid step, dwarfing the scatter itself (§Perf-L1); the TPU tiling story
    lives in the kernel's BlockSpecs and DESIGN.md.
    """
    m, n = a.shape
    b_sk = countsketch(a, buckets, signs, sketch_rows, tile_m=m, tile_n=n)
    c = countsketch_vec(b, buckets, signs, sketch_rows)
    q, r = mgs_qr_blocked(b_sk)
    z0 = q.T @ c
    return r, z0, c


@functools.partial(jax.jit, static_argnames=("sketch_rows", "iters"))
def saa_solve(a: jnp.ndarray, b: jnp.ndarray, buckets: jnp.ndarray,
              signs: jnp.ndarray, *, sketch_rows: int, iters: int):
    """Full SAA-SAS (Algorithm 1 lines 2–8, fallback decided by caller).

    The preconditioned operator ``Y = A·R⁻¹`` is applied as
    ``Y·v = A·(R⁻¹v)`` with an explicit, once-computed ``R⁻¹`` (see
    [`invert_upper`]) — every LSQR iteration is two fused GEMVs, no
    sequential inner loops, and the m×n dense ``Y`` is never formed.

    Returns ``(x, resnorm_history)``.
    """
    r, z0, _c = sketch_qr_precond(a, b, buckets, signs, sketch_rows)
    rinv = invert_upper(r)
    rinvt = rinv.T  # hoisted: transposes must never live inside the scan

    def matvec(z):
        return a @ (rinv @ z)

    def rmatvec(u):
        # (uᵀA)ᵀ instead of Aᵀu: row-major contraction, no m×n transpose
        # materialized per iteration (§Perf-L2: 20× on the 16384×256 bucket).
        return rinvt @ (u @ a)

    z, hist = lsqr_scan(matvec, rmatvec, b, z0, iters)
    x = rinv @ z
    return x, hist


@functools.partial(jax.jit, static_argnames=("iters",))
def lsqr_baseline(a: jnp.ndarray, b: jnp.ndarray, *, iters: int):
    """The deterministic baseline as a graph: LSQR directly on A.

    Returns ``(x, resnorm_history)``.
    """
    n = a.shape[1]
    x0 = jnp.zeros((n,), a.dtype)
    return lsqr_scan(lambda v: a @ v, lambda u: u @ a, b, x0, iters)


@functools.partial(jax.jit, static_argnames=("sketch_rows",))
def sketch_only(a: jnp.ndarray, buckets: jnp.ndarray, signs: jnp.ndarray, *,
                sketch_rows: int):
    """Standalone CountSketch application (microbenchmark artifact)."""
    return countsketch(a, buckets, signs, sketch_rows)


@functools.partial(jax.jit, static_argnames=("sketch_rows",))
def sketch_and_solve_only(a: jnp.ndarray, b: jnp.ndarray,
                          buckets: jnp.ndarray, signs: jnp.ndarray, *,
                          sketch_rows: int):
    """Classical one-shot sketch-and-solve ``x̂ = R⁻¹Qᵀ(Sb)`` (cheapest
    estimate; the ablation's accuracy floor)."""
    r, z0, _c = sketch_qr_precond(a, b, buckets, signs, sketch_rows)
    return invert_upper(r) @ z0
