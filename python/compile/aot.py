"""AOT lowering: JAX graphs → HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` crate) rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts

Writes ``<entry>_<m>x<n>.hlo.txt`` per (entry, bucket) plus
``manifest.json`` describing every artifact's I/O signature — the Rust
runtime's ground truth for literal packing.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .shapes import BUCKETS, ENTRIES, ShapeBucket


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to HLO text via stablehlo→XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_entry(entry: str, b: ShapeBucket):
    """Lower one entry point at one bucket; returns (lowered, ins, outs).

    ``ins``/``outs`` are manifest I/O descriptors: [{name, dtype, shape}].
    """
    a = _spec((b.m, b.n))
    vec = _spec((b.m,))
    h = _spec((b.m,), jnp.int32)
    sg = _spec((b.m,))

    if entry == "saa_solve":
        lowered = model.saa_solve.lower(a, vec, h, sg,
                                        sketch_rows=b.s, iters=b.iters)
        ins = [("a", "f32", [b.m, b.n]), ("b", "f32", [b.m]),
               ("buckets", "s32", [b.m]), ("signs", "f32", [b.m])]
        outs = [("x", "f32", [b.n]), ("history", "f32", [b.iters])]
    elif entry == "lsqr_baseline":
        lowered = model.lsqr_baseline.lower(a, vec, iters=b.baseline_iters)
        ins = [("a", "f32", [b.m, b.n]), ("b", "f32", [b.m])]
        outs = [("x", "f32", [b.n]), ("history", "f32", [b.baseline_iters])]
    elif entry == "sketch_only":
        lowered = model.sketch_only.lower(a, h, sg, sketch_rows=b.s)
        ins = [("a", "f32", [b.m, b.n]),
               ("buckets", "s32", [b.m]), ("signs", "f32", [b.m])]
        outs = [("b_sk", "f32", [b.s, b.n])]
    elif entry == "sketch_and_solve_only":
        lowered = model.sketch_and_solve_only.lower(a, vec, h, sg,
                                                    sketch_rows=b.s)
        ins = [("a", "f32", [b.m, b.n]), ("b", "f32", [b.m]),
               ("buckets", "s32", [b.m]), ("signs", "f32", [b.m])]
        outs = [("x", "f32", [b.n])]
    else:
        raise ValueError(f"unknown entry {entry!r}")

    ins = [{"name": nm, "dtype": dt, "shape": shp} for nm, dt, shp in ins]
    outs = [{"name": nm, "dtype": dt, "shape": shp} for nm, dt, shp in outs]
    return lowered, ins, outs


FORBIDDEN = ("custom-call",)


def check_no_custom_calls(name: str, hlo: str) -> None:
    """The Rust PJRT client has no LAPACK/FFI registry — refuse to ship an
    artifact that would fail at service startup."""
    for needle in FORBIDDEN:
        if needle in hlo:
            lines = [ln.strip() for ln in hlo.splitlines() if needle in ln]
            raise RuntimeError(
                f"{name}: lowered HLO contains {needle!r} "
                f"(unrunnable on the Rust PJRT CPU client):\n  "
                + "\n  ".join(lines[:5])
            )


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []
    for b in BUCKETS:
        for entry in ENTRIES:
            name = f"{entry}_{b.tag}"
            lowered, ins, outs = lower_entry(entry, b)
            hlo = to_hlo_text(lowered)
            check_no_custom_calls(name, hlo)
            fname = f"{name}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(hlo)
            artifacts.append({
                "name": name,
                "entry": entry,
                "file": fname,
                "m": b.m,
                "n": b.n,
                "s": b.s,
                "iters": b.iters if entry == "saa_solve" else (
                    b.baseline_iters if entry == "lsqr_baseline" else 0),
                "inputs": ins,
                "outputs": outs,
                "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
            })
            print(f"wrote {path} ({len(hlo)/1024:.0f} KiB)")
    manifest = {"version": 1, "artifacts": artifacts}
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(artifacts)} artifacts)")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None,
                   help="compat: single-file mode writes the smoke artifact")
    args = p.parse_args()
    if args.out:
        # Back-compat path used by the Makefile's stamp file.
        out_dir = os.path.dirname(args.out) or "."
        build(out_dir)
        return
    build(args.out_dir)


if __name__ == "__main__":
    main()
