"""Shape-bucket registry shared by aot.py, the Rust runtime and the tests.

The solve service compiles one executable per (entry, bucket). Buckets are
small-to-medium (the serving path); the huge Figure-3 shapes run on the
native Rust solvers. Keep this list short — every bucket costs XLA compile
time at `make artifacts` and at service startup.
"""

from __future__ import annotations

from typing import NamedTuple


class ShapeBucket(NamedTuple):
    """One compiled problem shape."""
    m: int        # rows
    n: int        # cols
    s: int        # sketch rows (CountSketch output)
    iters: int    # fixed LSQR trips in the fused SAA graph
    baseline_iters: int  # fixed LSQR trips in the baseline graph

    @property
    def tag(self) -> str:
        return f"{self.m}x{self.n}"


#: The buckets the service ships with. s = 4n (the SaaConfig default in
#: Rust), iters sized so the preconditioned solve converges with slack.
BUCKETS: list[ShapeBucket] = [
    ShapeBucket(m=64, n=8, s=32, iters=8, baseline_iters=16),       # smoke
    ShapeBucket(m=4096, n=64, s=256, iters=24, baseline_iters=128),
    ShapeBucket(m=8192, n=128, s=512, iters=24, baseline_iters=128),
    ShapeBucket(m=16384, n=256, s=1024, iters=30, baseline_iters=128),
]

#: Entry points exported per bucket (must match model.py function names).
ENTRIES = ("saa_solve", "lsqr_baseline", "sketch_only", "sketch_and_solve_only")


def bucket_for(m: int, n: int) -> ShapeBucket | None:
    """Exact-match bucket lookup."""
    for b in BUCKETS:
        if b.m == m and b.n == n:
            return b
    return None
