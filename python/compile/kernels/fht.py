"""Layer-1 Pallas kernel: fast Walsh–Hadamard transform (SRHT core).

The SRHT sketch needs ``H·(D·A)`` where H is the m̃×m̃ Hadamard matrix. A
GPU implementation would assign a threadblock per column stripe and run the
log₂(m̃) butterfly stages in shared memory; the TPU mapping keeps the same
decomposition but the stripe lives in **VMEM**:

* grid over column stripes of width TILE_N;
* the full (m̃ × TILE_N) stripe is resident per grid step (the butterfly
  is a permutation-heavy, matmul-free pattern — VPU work, not MXU);
* all log₂(m̃) stages run in-register/VMEM with no HBM round-trips, which
  is the entire point: HBM traffic is 2·m̃·TILE_N floats total regardless
  of depth.

VMEM/step (f32): m̃·TILE_N·4 B → with m̃ = 8192, TILE_N = 256 that is 8 MB;
the AOT shape registry keeps stripes under that budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_N = 128


def _largest_divisor_at_most(n: int, cap: int) -> int:
    cap = min(cap, n)
    for t in range(cap, 0, -1):
        if n % t == 0:
            return t
    return 1


def _fht_kernel(x_ref, o_ref, *, rows: int):
    """Full butterfly over the resident stripe (rows must be a power of 2)."""
    y = x_ref[...]
    n = y.shape[1]
    h = 1
    while h < rows:
        y = y.reshape(rows // (2 * h), 2, h, n)
        a = y[:, 0]
        b = y[:, 1]
        y = jnp.concatenate([a + b, a - b], axis=1).reshape(rows, n)
        h *= 2
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fht(x: jnp.ndarray, *, tile_n: int = DEFAULT_TILE_N,
        interpret: bool = True) -> jnp.ndarray:
    """Unnormalized FWHT along axis 0 of ``(m, n)``; ``m`` a power of two."""
    m, n = x.shape
    assert m & (m - 1) == 0, f"rows {m} must be a power of two"
    tile_n = _largest_divisor_at_most(n, tile_n)
    grid = (n // tile_n,)
    kernel = functools.partial(_fht_kernel, rows=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, tile_n), lambda cs: (0, cs))],
        out_specs=pl.BlockSpec((m, tile_n), lambda cs: (0, cs)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x)
