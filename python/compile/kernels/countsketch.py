"""Layer-1 Pallas kernel: Clarkson–Woodruff (CountSketch) application.

The paper's final algorithm sketches with CountSketch, whose application is
a *scatter*: row ``i`` of ``A`` is added (sign-flipped) into output row
``h[i]``. Scatters are hostile to TPU hardware — the systolic MXU wants
dense tiles and VMEM has no cross-lane atomics — so the kernel inverts the
loop structure instead of porting the scatter:

* the **grid runs over column stripes** of width ``TILE_N`` and row blocks
  of height ``TILE_M``;
* each grid step owns the **entire (s × TILE_N) output stripe in VMEM**
  (s is small: a few·n) and streams one (TILE_M × TILE_N) block of ``A``
  plus the matching slice of ``h``/``sign`` from HBM;
* within the block, rows are folded into the resident stripe with a
  one-hot-select accumulate — race-free by construction because no other
  grid step ever touches this stripe.

VMEM budget (f32): stripe ``s·TILE_N·4`` + block ``TILE_M·TILE_N·4``;
with s = 1024, TILE_N = 256, TILE_M = 512 that is 1.0 MB + 0.5 MB — well
under the ~16 MB/core envelope, leaving room for double buffering.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated through the interpret path and the
structure (BlockSpec schedule) is the TPU story. See DESIGN.md
§Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_N = 256
DEFAULT_TILE_M = 512


def _largest_divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``cap`` (tiles must tile exactly)."""
    cap = min(cap, n)
    for t in range(cap, 0, -1):
        if n % t == 0:
            return t
    return 1


def _countsketch_kernel(h_ref, sgn_ref, a_ref, o_ref, *, sketch_rows: int,
                        tile_m: int, fold: str):
    """One grid step: fold a (tile_m × tile_n) block of A into the stripe.

    Grid layout: (row_blocks, col_stripes); axis 0 is the *inner* sequential
    accumulation axis, so the output stripe (indexed only by axis 1) stays
    resident while row blocks stream through.

    Two fold strategies (DESIGN.md §Hardware-Adaptation):

    * ``"onehot"`` — the TPU-shaped variant: express the bucket fold as a
      (s × tile_m) one-hot matmul, feeding the MXU. Costs O(s·tile_m·tile_n)
      flops per block, but MXU flops are nearly free and the access pattern
      is purely dense.
    * ``"scatter"`` — the CPU/interpret-shaped variant: a scatter-add into
      the resident stripe, O(tile_m·tile_n) work (one pass over the block),
      which is what makes CountSketch the paper's O(nnz) winner.
    """
    rb = pl.program_id(0)

    # First row-block of each stripe zero-initializes the output.
    @pl.when(rb == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]            # (tile_m, tile_n)
    h = h_ref[...]            # (tile_m,) int32
    sgn = sgn_ref[...]        # (tile_m,) float
    signed = a * sgn[:, None]

    if fold == "onehot":
        onehot = jnp.equal(
            jnp.arange(sketch_rows, dtype=h.dtype)[:, None], h[None, :]
        ).astype(a.dtype)     # (s, tile_m)
        o_ref[...] += onehot @ signed
    else:
        stripe = jnp.zeros((sketch_rows, signed.shape[1]), a.dtype)
        o_ref[...] += stripe.at[h].add(signed)


@functools.partial(
    jax.jit,
    static_argnames=("sketch_rows", "tile_n", "tile_m", "interpret", "fold"))
def countsketch(a: jnp.ndarray, buckets: jnp.ndarray, signs: jnp.ndarray,
                sketch_rows: int, *, tile_n: int = DEFAULT_TILE_N,
                tile_m: int = DEFAULT_TILE_M,
                interpret: bool = True, fold: str = "scatter") -> jnp.ndarray:
    """``B = S·A`` for the CountSketch ``S`` defined by (buckets, signs).

    Args:
      a: ``(m, n)`` input matrix.
      buckets: ``(m,)`` int32, values in ``[0, sketch_rows)``.
      signs: ``(m,)`` ±1, same float dtype as ``a``.
      sketch_rows: ``s``, the sketch dimension.
      tile_n / tile_m: stripe width / row-block height (clamped to shape).
      interpret: keep True off-TPU.

    Returns:
      ``(sketch_rows, n)``.
    """
    m, n = a.shape
    assert buckets.shape == (m,), f"buckets {buckets.shape} vs m={m}"
    assert signs.shape == (m,), f"signs {signs.shape} vs m={m}"
    tile_n = _largest_divisor_at_most(n, tile_n)
    tile_m = _largest_divisor_at_most(m, tile_m)
    grid = (m // tile_m, n // tile_n)

    assert fold in ("scatter", "onehot"), f"unknown fold {fold!r}"
    kernel = functools.partial(
        _countsketch_kernel, sketch_rows=sketch_rows, tile_m=tile_m, fold=fold)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda rb, cs: (rb,)),          # buckets
            pl.BlockSpec((tile_m,), lambda rb, cs: (rb,)),          # signs
            pl.BlockSpec((tile_m, tile_n), lambda rb, cs: (rb, cs)),  # A block
        ],
        out_specs=pl.BlockSpec(
            (sketch_rows, tile_n), lambda rb, cs: (0, cs)),          # stripe
        out_shape=jax.ShapeDtypeStruct((sketch_rows, n), a.dtype),
        interpret=interpret,
    )(buckets, signs, a)


def countsketch_vec(b: jnp.ndarray, buckets: jnp.ndarray, signs: jnp.ndarray,
                    sketch_rows: int, *, interpret: bool = True) -> jnp.ndarray:
    """``c = S·b`` for a vector: the (m, 1) special case of the kernel.

    Full-block tiles: a vector sketch is one streaming pass; splitting it
    into grid steps only adds interpret-mode dispatch overhead.
    """
    out = countsketch(b[:, None], buckets, signs, sketch_rows,
                      tile_n=1, tile_m=b.shape[0], interpret=interpret)
    return out[:, 0]
