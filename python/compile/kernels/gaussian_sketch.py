"""Layer-1 Pallas kernel: dense (Gaussian/uniform) sketch application.

Dense sketching is a plain GEMM ``B = S @ A`` with a short-fat ``S``
(s × m, s ≪ m). This is the MXU-shaped member of the operator family — the
kernel is a classic three-level tiled matmul:

* grid = (s/TM, n/TN, m/TK), **K innermost** so the (TM × TN) accumulator
  tile stays register/VMEM-resident across the contraction;
* blocks of S (TM × TK) and A (TK × TN) stream HBM→VMEM per step — the
  BlockSpec index maps express exactly the HBM↔VMEM schedule a CUDA
  implementation would write with threadblock tiles;
* MXU-native tile sizes default to 128×128×128 (f32 accumulate; on real
  TPU the inputs would be bf16 with f32 accumulation).

VMEM/step: TM·TK + TK·TN + TM·TN floats = 3·128²·4 B = 192 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TM = 128
DEFAULT_TN = 128
DEFAULT_TK = 128


def _largest_divisor_at_most(n: int, cap: int) -> int:
    cap = min(cap, n)
    for t in range(cap, 0, -1):
        if n % t == 0:
            return t
    return 1


def _matmul_kernel(s_ref, a_ref, o_ref):
    """Accumulating tile matmul: o[i,j] += s[i,k] @ a[k,j], k innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(s_ref[...], a_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tm", "tn", "tk", "interpret"))
def gaussian_sketch(s_mat: jnp.ndarray, a: jnp.ndarray, *,
                    tm: int = DEFAULT_TM, tn: int = DEFAULT_TN,
                    tk: int = DEFAULT_TK,
                    interpret: bool = True) -> jnp.ndarray:
    """``B = S @ A`` with MXU-style tiling.

    Args:
      s_mat: ``(s, m)`` dense sketching matrix (Gaussian, uniform, ...).
      a: ``(m, n)`` input.

    Returns:
      ``(s, n)``.
    """
    s, m = s_mat.shape
    m2, n = a.shape
    assert m == m2, f"S is {s_mat.shape}, A is {a.shape}"
    tm = _largest_divisor_at_most(s, tm)
    tn = _largest_divisor_at_most(n, tn)
    tk = _largest_divisor_at_most(m, tk)
    grid = (s // tm, n // tn, m // tk)

    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), a.dtype),
        interpret=interpret,
    )(s_mat, a)
