"""Pure-jnp oracles for the Layer-1 Pallas kernels.

Every kernel in this package has a reference implementation here, written
with nothing but ``jax.numpy`` primitives. ``python/tests/test_kernels.py``
sweeps shapes and dtypes (hypothesis) asserting kernel == oracle; this is
the core correctness signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp


def countsketch_ref(a: jnp.ndarray, buckets: jnp.ndarray, signs: jnp.ndarray,
                    sketch_rows: int) -> jnp.ndarray:
    """Clarkson–Woodruff sketch: ``B[h[i], :] += sign[i] * A[i, :]``.

    Args:
      a: ``(m, n)`` input.
      buckets: ``(m,)`` int32 target rows in ``[0, sketch_rows)``.
      signs: ``(m,)`` float ±1.
      sketch_rows: output rows ``s``.

    Returns:
      ``(s, n)`` sketched matrix.
    """
    signed = a * signs[:, None]
    # segment-sum by bucket: a one-hot matmul keeps it pure-jnp and exact.
    onehot = jnp.equal(
        buckets[:, None], jnp.arange(sketch_rows, dtype=buckets.dtype)[None, :]
    ).astype(a.dtype)
    return onehot.T @ signed


def gaussian_sketch_ref(s_mat: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Dense sketch application: plain matmul ``S @ A``."""
    return s_mat @ a


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized fast Walsh–Hadamard transform along axis 0.

    ``x`` has shape ``(m, ...)`` with ``m`` a power of two.
    """
    m = x.shape[0]
    assert m & (m - 1) == 0, f"rows {m} must be a power of two"
    h = 1
    y = x
    while h < m:
        y = y.reshape(m // (2 * h), 2, h, *x.shape[1:])
        a, b = y[:, 0], y[:, 1]
        y = jnp.concatenate([a + b, a - b], axis=1).reshape(m, *x.shape[1:])
        h *= 2
    return y


def mgs_qr_ref(b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Modified Gram–Schmidt economy QR (two-pass) — pure jnp, no LAPACK.

    Oracle for the custom-call-free QR used in the AOT graphs (the CPU PJRT
    runtime in the Rust layer has no LAPACK custom-call registry, so
    ``jnp.linalg.qr`` is off-limits in exported HLO).
    """
    s, n = b.shape
    q = jnp.zeros((s, n), b.dtype)
    r = jnp.zeros((n, n), b.dtype)
    for j in range(n):
        v = b[:, j]
        for _ in range(2):  # re-orthogonalize: "twice is enough"
            proj = q.T @ v
            r = r.at[:, j].add(proj)
            v = v - q @ proj
        norm = jnp.linalg.norm(v)
        r = r.at[j, j].set(norm)
        q = q.at[:, j].set(v / norm)
    return q, r
