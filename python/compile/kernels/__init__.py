"""Layer-1 Pallas kernels (interpret=True off-TPU) + pure-jnp oracles."""

from . import ref  # noqa: F401
from .countsketch import countsketch, countsketch_vec  # noqa: F401
from .fht import fht  # noqa: F401
from .gaussian_sketch import gaussian_sketch  # noqa: F401
