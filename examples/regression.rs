//! ML scenario from the paper's introduction: large-scale linear regression.
//!
//! Fits a random-Fourier-feature ridge-free regression on a synthetic
//! nonlinear dataset (y = sin(3x₀) + x₁² + noise) with m = 50k samples and
//! n = 400 features, comparing SAA-SAS against LSQR on wall-clock and
//! held-out RMSE — the "machine learning" column of the paper's motivation.
//!
//! Run: `cargo run --release --example regression`

use snsolve::linalg::{DenseMatrix, Matrix};
use snsolve::rng::{GaussianSource, RngCore, Xoshiro256pp};
use snsolve::solvers::lsqr::{LsqrConfig, LsqrSolver};
use snsolve::solvers::saa::SaaSolver;
use snsolve::solvers::Solver;

/// Random Fourier features: φ(x) = cos(Wx + b) with W ~ N(0, γI).
struct Features {
    w: DenseMatrix, // n_feat × d
    b: Vec<f64>,
}

impl Features {
    fn new(d: usize, n_feat: usize, gamma: f64, seed: u64) -> Self {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let mut w = DenseMatrix::gaussian(n_feat, d, &mut g);
        w.scale(gamma);
        let b: Vec<f64> = (0..n_feat)
            .map(|_| g.rng_mut().next_f64() * std::f64::consts::TAU)
            .collect();
        Self { w, b }
    }

    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let wx = self.w.matvec(x);
        wx.iter()
            .zip(self.b.iter())
            .map(|(&v, &bi)| (v + bi).cos())
            .collect()
    }
}

fn target_fn(x: &[f64]) -> f64 {
    (3.0 * x[0]).sin() + x[1] * x[1]
}

fn make_dataset(
    m: usize,
    d: usize,
    feats: &Features,
    noise: f64,
    seed: u64,
) -> (DenseMatrix, Vec<f64>, Vec<Vec<f64>>) {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    let n_feat = feats.w.rows();
    let mut phi = DenseMatrix::zeros(m, n_feat);
    let mut y = vec![0.0; m];
    let mut raw = Vec::with_capacity(m);
    for i in 0..m {
        let x = g.gaussian_vec(d);
        let row = feats.apply(&x);
        phi.row_mut(i).copy_from_slice(&row);
        y[i] = target_fn(&x) + noise * g.next_gaussian();
        raw.push(x);
    }
    (phi, y, raw)
}

fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    (pred.iter().zip(truth.iter()).map(|(p, t)| (p - t) * (p - t)).sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

fn main() {
    let (m_train, m_test, d, n_feat) = (50_000, 5_000, 4, 400);
    println!("building random-Fourier-feature regression: {m_train} samples, {n_feat} features");
    let feats = Features::new(d, n_feat, 1.0, 1);
    let (phi_train, y_train, _) = make_dataset(m_train, d, &feats, 0.05, 2);
    let (phi_test, _y_test_noisy, raw_test) = make_dataset(m_test, d, &feats, 0.0, 3);
    let y_test: Vec<f64> = raw_test.iter().map(|x| target_fn(x)).collect();

    let a = Matrix::Dense(phi_train);
    let solvers: Vec<Box<dyn Solver>> = vec![
        Box::new(SaaSolver::default()),
        Box::new(LsqrSolver::new(LsqrConfig {
            atol: 1e-10,
            btol: 1e-10,
            conlim: 0.0,
            ..Default::default()
        })),
    ];

    println!(
        "\n{:<12} {:>10} {:>8} {:>12} {:>12}",
        "solver", "fit_time", "iters", "train_resid", "test_rmse"
    );
    for solver in solvers {
        let t0 = std::time::Instant::now();
        let sol = solver.solve(&a, &y_train).expect("fit");
        let dt = t0.elapsed().as_secs_f64();
        let pred = phi_test.matvec(&sol.x);
        println!(
            "{:<12} {:>9.3}s {:>8} {:>12.4e} {:>12.5}",
            solver.name(),
            dt,
            sol.iterations,
            sol.resnorm,
            rmse(&pred, &y_test)
        );
    }
    println!(
        "\nBoth reach the same held-out RMSE — the sketch does not degrade the\n\
         fit — while SAA-SAS needs far fewer LSQR iterations on the m >> n\n\
         feature matrix (the regime the paper's intro motivates)."
    );
}
