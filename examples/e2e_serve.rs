//! END-TO-END driver: the full three-layer system on a real workload.
//!
//! Starts the solve service (Rust coordinator, workers owning PJRT engines
//! with the AOT artifacts compiled from the JAX/Pallas layers), registers
//! design matrices at the compiled shape buckets, replays a bursty
//! synthetic request trace through the TCP front-end, and reports
//! throughput + latency percentiles + accuracy, split by execution route
//! (PJRT artifact vs native solver).
//!
//! Run: `make artifacts && cargo run --release --example e2e_serve`
//! (recorded in EXPERIMENTS.md §E2E)

use std::time::{Duration, Instant};

use snsolve::coordinator::tcp::{Client, TcpServer};
use snsolve::coordinator::{Service, ServiceConfig, SolverChoice};
use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::DenseMatrix;
use snsolve::problems::workload::WorkloadSpec;
use snsolve::rng::{GaussianSource, Xoshiro256pp};

fn main() {
    let artifact_dir = std::path::PathBuf::from(
        std::env::var("SNSOLVE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let have_artifacts = artifact_dir.join("manifest.json").exists();
    if !have_artifacts {
        eprintln!("warning: no artifacts/manifest.json — run `make artifacts` for the PJRT path; continuing native-only");
    }

    // --- service ---------------------------------------------------------
    let mut cfg = ServiceConfig { workers: 2, queue_capacity: 512, ..Default::default() };
    if have_artifacts {
        cfg.worker.artifact_dir = Some(artifact_dir);
    }
    cfg.batcher.max_batch = 16;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let service = Service::start(cfg);
    let server = TcpServer::serve(service.clone(), "127.0.0.1:0").expect("bind");
    println!("service up on {} (pjrt={})", server.addr(), have_artifacts);

    // --- problem set at the compiled buckets ------------------------------
    // Shapes match python/compile/shapes.py so requests route to PJRT.
    let buckets: Vec<(usize, usize)> = vec![(4096, 64), (8192, 128), (16384, 256)];
    let mut client = Client::connect(server.addr()).expect("connect");
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(7));
    let mut matrices = Vec::new();
    for &(m, n) in &buckets {
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let mut x_true = g.gaussian_vec(n);
        snsolve::linalg::norms::normalize(&mut x_true);
        let b = a.matvec(&x_true);
        let t0 = Instant::now();
        let id = client.register_dense(&a).expect("register");
        println!(
            "registered {}x{} as matrix {} ({:.1} MB, {:.0} ms)",
            m,
            n,
            id,
            (m * n * 8) as f64 / 1e6,
            t0.elapsed().as_secs_f64() * 1e3
        );
        matrices.push((id, x_true, b));
    }

    // --- warmup: trigger artifact compilation off the clock ---------------
    // (one request per bucket per worker; XLA compiles lazily on first use)
    print!("warmup (XLA compiles each bucket's executable) ...");
    let warm_t0 = Instant::now();
    for _ in 0..2 {
        for (id, _xt, b) in &matrices {
            let _ = client.solve(*id, b, SolverChoice::Saa, 1e-2).expect("warm solve");
        }
    }
    println!(" done in {:.1}s", warm_t0.elapsed().as_secs_f64());

    // --- replay a bursty trace -------------------------------------------
    let trace = WorkloadSpec {
        shapes: buckets.iter().map(|&(m, n)| (m, n, 1.0)).collect(),
        rate_per_sec: 60.0,
        count: 240,
        burstiness: 3.0,
        seed: 99,
    }
    .generate();
    println!("\nreplaying {} requests (bursty Poisson, ~60 rps nominal) ...", trace.len());

    let start = Instant::now();
    let mut latencies_us: Vec<u64> = Vec::with_capacity(trace.len());
    let mut max_err = 0.0f64;
    let mut route_pjrt = 0usize;
    for entry in &trace {
        // pace according to the trace
        let target = Duration::from_micros(entry.arrival_us);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        let (id, x_true, b) = &matrices[entry.shape_idx];
        let t0 = Instant::now();
        // tol 1e-2 keeps bucket-matching requests PJRT-eligible.
        let sol = client.solve(*id, b, SolverChoice::Saa, 1e-2).expect("solve");
        let lat = t0.elapsed().as_micros() as u64;
        latencies_us.push(lat);
        let err = nrm2_diff(&sol.x, x_true) / nrm2(x_true);
        max_err = max_err.max(err);
        // the wire doesn't carry the route; infer from the service metrics later
        let _ = &mut route_pjrt;
    }
    let wall = start.elapsed().as_secs_f64();

    // --- report -----------------------------------------------------------
    latencies_us.sort_unstable();
    let pct = |q: f64| latencies_us[((q * (latencies_us.len() - 1) as f64) as usize).min(latencies_us.len() - 1)];
    let mean: f64 = latencies_us.iter().map(|&v| v as f64).sum::<f64>() / latencies_us.len() as f64;
    println!("\n===== E2E RESULTS =====");
    println!("requests:        {}", latencies_us.len());
    println!("wall time:       {wall:.2} s");
    println!("throughput:      {:.1} solves/s", latencies_us.len() as f64 / wall);
    println!(
        "latency:         mean {:.1} ms | p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms | max {:.1} ms",
        mean / 1e3,
        pct(0.50) as f64 / 1e3,
        pct(0.95) as f64 / 1e3,
        pct(0.99) as f64 / 1e3,
        *latencies_us.last().unwrap() as f64 / 1e3
    );
    println!("max rel error:   {max_err:.3e}");
    println!("\n--- service metrics ---\n{}", client.metrics().expect("metrics"));

    server.stop();
    service.shutdown();

    // Exit code communicates success to `make e2e` / EXPERIMENTS.md.
    if max_err > 1e-2 {
        eprintln!("FAIL: accuracy out of tolerance");
        std::process::exit(1);
    }
    println!("\nE2E OK");
}
