//! Quickstart: generate an ill-conditioned least-squares problem (§5.1)
//! and solve it three ways — SAA-SAS (the paper's algorithm), the LSQR
//! baseline, and the one-shot sketch-and-solve estimate.
//!
//! Run: `cargo run --release --example quickstart`

use snsolve::problems::{generate_dense, DenseProblemSpec};
use snsolve::solvers::lsqr::{LsqrConfig, LsqrSolver};
use snsolve::solvers::saa::SaaSolver;
use snsolve::solvers::sas::SketchAndSolve;
use snsolve::solvers::Solver;

fn main() {
    // The paper's error-comparison instance (§5.1).
    let spec = DenseProblemSpec {
        m: 20_000,
        n: 100,
        cond: 1e10,        // κ = 10¹⁰  (paper §5.1)
        resid_norm: 1e-10, // β = 10⁻¹⁰
        seed: 42,
    };
    println!(
        "generating dense {}x{} problem with κ = {:.0e}, β = {:.0e} ...",
        spec.m, spec.n, spec.cond, spec.resid_norm
    );
    let p = generate_dense(&spec);

    let solvers: Vec<Box<dyn Solver>> = vec![
        Box::new(SaaSolver::default()),
        Box::new(LsqrSolver::new(LsqrConfig {
            atol: 1e-14,
            btol: 1e-14,
            conlim: 0.0,
            iter_lim: Some(400),
            ..Default::default()
        })),
        Box::new(SketchAndSolve::default()),
    ];

    println!(
        "\n{:<18} {:>10} {:>8} {:>12} {:>12} {:>10}",
        "solver", "time", "iters", "rel_err", "resid", "converged"
    );
    for solver in solvers {
        let t0 = std::time::Instant::now();
        let sol = solver.solve(&p.a, &p.b).expect("solve");
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<18} {:>9.3}s {:>8} {:>12.3e} {:>12.3e} {:>10}",
            solver.name(),
            dt,
            sol.iterations,
            p.relative_error(&sol.x),
            p.residual_norm(&sol.x),
            sol.converged
        );
    }
    println!(
        "\nSAA-SAS reaches LSQR-level error in a fraction of the iterations\n\
         because R from the sketched QR is a near-perfect right preconditioner\n\
         and z0 = Q'(Sb) already lands O(eps) from the solution (paper §4)."
    );
}
