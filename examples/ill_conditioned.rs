//! Conditioning study: how solver error degrades with κ(A) — sweeps the
//! §5.1 generator's condition number from 10² to 10¹⁴ and reports forward
//! error and residual suboptimality for SAA-SAS, LSQR and one-shot SAS,
//! including the regime where Algorithm 1's perturbation fallback matters.
//!
//! Run: `cargo run --release --example ill_conditioned`

use snsolve::problems::{generate_dense, DenseProblemSpec};
use snsolve::solvers::lsqr::{LsqrConfig, LsqrSolver};
use snsolve::solvers::saa::{SaaConfig, SaaSolver};
use snsolve::solvers::sas::SketchAndSolve;
use snsolve::solvers::Solver;

fn main() {
    let (m, n) = (8000, 80);
    println!("conditioning sweep on dense {m}x{n} (β = 1e-10):\n");
    println!(
        "{:>8} {:<14} {:>12} {:>14} {:>7} {:>9}",
        "κ", "solver", "rel_err", "resid_subopt", "iters", "fallback"
    );
    for exp in [2i32, 4, 6, 8, 10, 12, 14] {
        let cond = 10f64.powi(exp);
        let p = generate_dense(&DenseProblemSpec {
            m,
            n,
            cond,
            resid_norm: 1e-10,
            seed: 100 + exp as u64,
        });
        let solvers: Vec<Box<dyn Solver>> = vec![
            Box::new(SaaSolver::new(SaaConfig {
                lsqr: LsqrConfig { atol: 1e-14, btol: 1e-14, conlim: 0.0, ..Default::default() },
                ..Default::default()
            })),
            Box::new(LsqrSolver::new(LsqrConfig {
                atol: 1e-14,
                btol: 1e-14,
                conlim: 0.0,
                iter_lim: Some(4 * n),
                ..Default::default()
            })),
            Box::new(SketchAndSolve::default()),
        ];
        for solver in solvers {
            let sol = solver.solve(&p.a, &p.b).expect("solve");
            println!(
                "{:>8.0e} {:<14} {:>12.3e} {:>14.3e} {:>7} {:>9}",
                cond,
                solver.name(),
                p.relative_error(&sol.x),
                p.residual_suboptimality(&sol.x).abs(),
                sol.iterations,
                sol.fallback_used
            );
        }
        println!();
    }
    println!(
        "Reading the table: forward error grows ~κ·ε for all solvers (the\n\
         problem's intrinsic sensitivity); SAA-SAS tracks LSQR's accuracy with\n\
         far fewer iterations, and the one-shot estimate loses accuracy first —\n\
         the paper's §5.3 comparison, extended across the κ axis."
    );
}
