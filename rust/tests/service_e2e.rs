//! End-to-end service tests: TCP front-end, batching under load,
//! backpressure, PJRT-bucket routing when artifacts are present.

use std::sync::Arc;
use std::time::Duration;

use snsolve::coordinator::tcp::{Client, TcpServer};
use snsolve::coordinator::{
    Service, ServiceConfig, SolveRequest, SolverChoice,
};
use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::{DenseMatrix, Matrix};
use snsolve::rng::{GaussianSource, Xoshiro256pp};

fn planted(m: usize, n: usize, seed: u64) -> (DenseMatrix, Vec<f64>, Vec<f64>) {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let x = g.gaussian_vec(n);
    let b = a.matvec(&x);
    (a, x, b)
}

#[test]
fn tcp_register_solve_metrics_evict() {
    let svc = Service::start(ServiceConfig { workers: 2, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(300, 10, 42);
    let mut client = Client::connect(addr).expect("connect");
    let id = client.register_dense(&a).expect("register");
    let sol = client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
    assert!(sol.converged);
    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-8, "err {err}");

    let metrics = client.metrics().expect("metrics");
    assert!(metrics.contains("completed=1"), "{metrics}");

    assert!(client.evict(id).expect("evict"));
    assert!(!client.evict(id).expect("evict twice"));
    // Solving against the evicted matrix errors cleanly.
    let e = client.solve(id, &b, SolverChoice::Saa, 1e-10);
    assert!(e.is_err());

    server.stop();
    svc.shutdown();
}

#[test]
fn tcp_multiple_clients_interleaved() {
    let svc = Service::start(ServiceConfig { workers: 2, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(200, 8, 7);
    let mut c0 = Client::connect(addr).unwrap();
    let id = c0.register_dense(&a).unwrap();

    let handles: Vec<_> = (0..4)
        .map(|_| {
            let b = b.clone();
            let x_true = x_true.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for _ in 0..5 {
                    let sol = c.solve(id, &b, SolverChoice::Saa, 1e-10).unwrap();
                    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
                    assert!(err < 1e-8);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
    svc.shutdown();
}

#[test]
fn backpressure_rejects_when_overloaded() {
    // One slow worker + tiny queue + zero submit timeout → Overloaded.
    let svc = Service::start(ServiceConfig {
        workers: 1,
        queue_capacity: 2,
        submit_timeout: Duration::from_millis(0),
        ..Default::default()
    });
    let (a, _xt, b) = planted(1500, 100, 9); // slow enough to back up
    let id = svc.register_matrix(Matrix::Dense(a));
    let req = || SolveRequest {
        matrix: id,
        rhs: b.clone(),
        solver: SolverChoice::Lsqr,
        tol: 1e-14,
        deadline_us: 0,
    };
    let mut rejected = 0;
    let mut handles = Vec::new();
    for _ in 0..50 {
        match svc.submit(req()) {
            Ok(h) => handles.push(h),
            Err(snsolve::coordinator::ServiceError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(rejected > 0, "expected overload rejections");
    for h in handles {
        let _ = h.wait();
    }
    svc.shutdown();
}

#[test]
fn batching_coalesces_same_matrix_bursts() {
    let svc = Service::start(ServiceConfig {
        workers: 1,
        batcher: snsolve::coordinator::batcher::BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
        },
        ..Default::default()
    });
    let (a, _xt, b) = planted(400, 16, 11);
    let id = svc.register_matrix(Matrix::Dense(a));
    let handles: Vec<_> = (0..24)
        .map(|_| {
            svc.submit(SolveRequest {
                matrix: id,
                rhs: b.clone(),
                solver: SolverChoice::Saa,
                tol: 1e-10,
                deadline_us: 0,
            })
            .unwrap()
        })
        .collect();
    for h in handles {
        h.wait().unwrap().result.unwrap();
    }
    let m = svc.metrics();
    let batches = snsolve::coordinator::metrics::Metrics::get(&m.batches);
    assert!(batches < 24, "expected coalescing, got {batches} batches for 24 reqs");
    assert!(m.mean_batch_size() > 1.0, "mean batch {}", m.mean_batch_size());
    svc.shutdown();
}

#[test]
fn malformed_rhs_inside_batch_fails_alone() {
    // Regression for the hoisted shape validation: a wrong-length RHS that
    // lands in the middle of a coalesced batch must fail with its own
    // BadRequest while its batch-mates solve normally (previously only the
    // single-vector path validated shapes).
    let svc = Service::start(ServiceConfig {
        workers: 1,
        batcher: snsolve::coordinator::batcher::BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(30),
        },
        ..Default::default()
    });
    let (a, x_true, b) = planted(250, 12, 21);
    let id = svc.register_matrix(Matrix::Dense(a));
    let mk = |rhs: Vec<f64>| SolveRequest {
        matrix: id,
        rhs,
        solver: SolverChoice::Saa,
        tol: 1e-10,
        deadline_us: 0,
    };
    let handles = vec![
        svc.submit(mk(b.clone())).unwrap(),
        svc.submit(mk(vec![1.0, 2.0, 3.0])).unwrap(), // malformed
        svc.submit(mk(b.clone())).unwrap(),
        svc.submit(mk(b.clone())).unwrap(),
    ];
    let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert!(
        matches!(
            responses[1].result,
            Err(snsolve::coordinator::ServiceError::BadRequest(_))
        ),
        "malformed item: {:?}",
        responses[1].result
    );
    for j in [0usize, 2, 3] {
        let sol = responses[j].result.as_ref().unwrap();
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "batch-mate {j} err {err}");
    }
    svc.shutdown();
}

#[test]
fn blocked_batches_match_per_item_loop_results() {
    // Per-RHS equivalence end to end: a 16-deep same-matrix burst solved
    // through the blocked multi-RHS path returns exactly what the per-item
    // loop returns for the same requests.
    let run = |block_rhs: bool| -> Vec<Vec<f64>> {
        let mut cfg = ServiceConfig {
            workers: 1,
            batcher: snsolve::coordinator::batcher::BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(20),
            },
            ..Default::default()
        };
        cfg.worker.block_rhs = block_rhs;
        let svc = Service::start(cfg);
        let (a, _xt, b) = planted(300, 14, 23);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(24));
        let id = svc.register_matrix(Matrix::Dense(a));
        let handles: Vec<_> = (0..16)
            .map(|i| {
                // Vary the RHS per request so columns differ.
                let mut rhs = b.clone();
                if i % 2 == 1 {
                    for v in rhs.iter_mut() {
                        *v += 0.05 * g.next_gaussian();
                    }
                }
                svc.submit(SolveRequest {
                    matrix: id,
                    rhs,
                    solver: SolverChoice::Saa,
                    tol: 1e-10,
                    deadline_us: 0,
                })
                .unwrap()
            })
            .collect();
        let xs: Vec<Vec<f64>> = handles
            .into_iter()
            .map(|h| h.wait().unwrap().result.unwrap().x)
            .collect();
        if block_rhs {
            let blocked =
                snsolve::coordinator::metrics::Metrics::get(&svc.metrics().blocked_rhs);
            assert!(blocked >= 16, "expected all 16 RHS on the blocked path, got {blocked}");
        }
        svc.shutdown();
        xs
    };
    let blocked = run(true);
    let per_item = run(false);
    for (j, (xb, xs)) in blocked.iter().zip(per_item.iter()).enumerate() {
        assert_eq!(xb, xs, "request {j}: blocked and per-item solutions differ");
    }
}

#[test]
fn pjrt_bucket_routing_when_artifacts_present() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let mut cfg = ServiceConfig { workers: 1, ..Default::default() };
    cfg.worker.artifact_dir = Some(dir);
    let svc = Service::start(cfg);
    // 64x8 matches the smoke bucket exactly → PJRT route.
    let (a, x_true, b) = planted(64, 8, 13);
    let id = svc.register_matrix(Matrix::Dense(a));
    let resp = svc
        .solve_blocking(SolveRequest {
            matrix: id,
            rhs: b.clone(),
            solver: SolverChoice::Saa,
            tol: 1e-2, // loose → PJRT-eligible
            deadline_us: 0,
        })
        .unwrap();
    let sol = resp.result.unwrap();
    match &resp.executed_on {
        snsolve::coordinator::ExecutedOn::Pjrt(name) => {
            assert_eq!(name, "saa_solve_64x8");
        }
        other => panic!("expected PJRT route, got {other:?}"),
    }
    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-3, "err {err}");

    // Tight tolerance diverts to native (f64).
    let resp2 = svc
        .solve_blocking(SolveRequest {
            matrix: id,
            rhs: b,
            solver: SolverChoice::Saa,
            tol: 1e-12,
            deadline_us: 0,
        })
        .unwrap();
    assert_eq!(resp2.executed_on, snsolve::coordinator::ExecutedOn::Native);
    svc.shutdown();
}

#[test]
fn graceful_shutdown_drains() {
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let (a, _xt, b) = planted(200, 10, 17);
    let id = svc.register_matrix(Matrix::Dense(a));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            svc.submit(SolveRequest {
                matrix: id,
                rhs: b.clone(),
                solver: SolverChoice::Saa,
                tol: 1e-8,
                deadline_us: 0,
            })
            .unwrap()
        })
        .collect();
    let svc2: Arc<Service> = svc.clone();
    // Shutdown while work may be in flight: all responders must resolve.
    std::thread::spawn(move || svc2.shutdown());
    let mut ok = 0;
    for h in handles {
        if let Ok(resp) = h.wait() {
            if resp.result.is_ok() {
                ok += 1;
            }
        }
    }
    // Submitted before close: the dispatcher drains them.
    assert!(ok >= 1, "at least some requests must complete, got {ok}");
}
