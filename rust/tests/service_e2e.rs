//! End-to-end service tests: TCP front-end, batching under load,
//! backpressure, PJRT-bucket routing when artifacts are present.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use snsolve::coordinator::metrics::Metrics;
use snsolve::coordinator::protocol::{
    OP_ERROR, OP_HELLO, OP_OK_HELLO, OP_OK_SOLVE, OP_SOLVE, PROTO_V2, Reader, Writer,
};
use snsolve::coordinator::tcp::{Client, ClientError, PipelinedClient, TcpServer};
use snsolve::coordinator::{
    Service, ServiceConfig, SolveRequest, SolverChoice,
};
use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::{DenseMatrix, Matrix};
use snsolve::problems::{generate_dense, DenseProblemSpec};
use snsolve::rng::{GaussianSource, Xoshiro256pp};

fn planted(m: usize, n: usize, seed: u64) -> (DenseMatrix, Vec<f64>, Vec<f64>) {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let x = g.gaussian_vec(n);
    let b = a.matvec(&x);
    (a, x, b)
}

#[test]
fn tcp_register_solve_metrics_evict() {
    let svc = Service::start(ServiceConfig { workers: 2, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(300, 10, 42);
    let mut client = Client::connect(addr).expect("connect");
    let id = client.register_dense(&a).expect("register");
    let sol = client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
    assert!(sol.converged);
    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-8, "err {err}");

    let metrics = client.metrics().expect("metrics");
    assert!(metrics.contains("completed=1"), "{metrics}");

    assert!(client.evict(id).expect("evict"));
    assert!(!client.evict(id).expect("evict twice"));
    // Solving against the evicted matrix errors cleanly.
    let e = client.solve(id, &b, SolverChoice::Saa, 1e-10);
    assert!(e.is_err());

    server.stop();
    svc.shutdown();
}

#[test]
fn tcp_multiple_clients_interleaved() {
    let svc = Service::start(ServiceConfig { workers: 2, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(200, 8, 7);
    let mut c0 = Client::connect(addr).unwrap();
    let id = c0.register_dense(&a).unwrap();

    let handles: Vec<_> = (0..4)
        .map(|_| {
            let b = b.clone();
            let x_true = x_true.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for _ in 0..5 {
                    let sol = c.solve(id, &b, SolverChoice::Saa, 1e-10).unwrap();
                    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
                    assert!(err < 1e-8);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
    svc.shutdown();
}

#[test]
fn backpressure_rejects_when_overloaded() {
    // One slow worker + tiny queue + zero submit timeout → Overloaded.
    let svc = Service::start(ServiceConfig {
        workers: 1,
        queue_capacity: 2,
        submit_timeout: Duration::from_millis(0),
        ..Default::default()
    });
    let (a, _xt, b) = planted(1500, 100, 9); // slow enough to back up
    let id = svc.register_matrix(Matrix::Dense(a));
    let req = || SolveRequest {
        matrix: id,
        rhs: b.clone(),
        solver: SolverChoice::Lsqr,
        tol: 1e-14,
        deadline_us: 0,
        refine_iters: 0,
    };
    let mut rejected = 0;
    let mut handles = Vec::new();
    for _ in 0..50 {
        match svc.submit(req()) {
            Ok(h) => handles.push(h),
            Err(snsolve::coordinator::ServiceError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(rejected > 0, "expected overload rejections");
    for h in handles {
        let _ = h.wait();
    }
    svc.shutdown();
}

#[test]
fn batching_coalesces_same_matrix_bursts() {
    let svc = Service::start(ServiceConfig {
        workers: 1,
        batcher: snsolve::coordinator::batcher::BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
        },
        ..Default::default()
    });
    let (a, _xt, b) = planted(400, 16, 11);
    let id = svc.register_matrix(Matrix::Dense(a));
    let handles: Vec<_> = (0..24)
        .map(|_| {
            svc.submit(SolveRequest {
                matrix: id,
                rhs: b.clone(),
                solver: SolverChoice::Saa,
                tol: 1e-10,
                deadline_us: 0,
                refine_iters: 0,
            })
            .unwrap()
        })
        .collect();
    for h in handles {
        h.wait().unwrap().result.unwrap();
    }
    let m = svc.metrics();
    let batches = snsolve::coordinator::metrics::Metrics::get(&m.batches);
    assert!(batches < 24, "expected coalescing, got {batches} batches for 24 reqs");
    assert!(m.mean_batch_size() > 1.0, "mean batch {}", m.mean_batch_size());
    svc.shutdown();
}

#[test]
fn malformed_rhs_inside_batch_fails_alone() {
    // Regression for the hoisted shape validation: a wrong-length RHS that
    // lands in the middle of a coalesced batch must fail with its own
    // BadRequest while its batch-mates solve normally (previously only the
    // single-vector path validated shapes).
    let svc = Service::start(ServiceConfig {
        workers: 1,
        batcher: snsolve::coordinator::batcher::BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(30),
        },
        ..Default::default()
    });
    let (a, x_true, b) = planted(250, 12, 21);
    let id = svc.register_matrix(Matrix::Dense(a));
    let mk = |rhs: Vec<f64>| SolveRequest {
        matrix: id,
        rhs,
        solver: SolverChoice::Saa,
        tol: 1e-10,
        deadline_us: 0,
        refine_iters: 0,
    };
    let handles = vec![
        svc.submit(mk(b.clone())).unwrap(),
        svc.submit(mk(vec![1.0, 2.0, 3.0])).unwrap(), // malformed
        svc.submit(mk(b.clone())).unwrap(),
        svc.submit(mk(b.clone())).unwrap(),
    ];
    let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert!(
        matches!(
            responses[1].result,
            Err(snsolve::coordinator::ServiceError::BadRequest(_))
        ),
        "malformed item: {:?}",
        responses[1].result
    );
    for j in [0usize, 2, 3] {
        let sol = responses[j].result.as_ref().unwrap();
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "batch-mate {j} err {err}");
    }
    svc.shutdown();
}

#[test]
fn blocked_batches_match_per_item_loop_results() {
    // Per-RHS equivalence end to end: a 16-deep same-matrix burst solved
    // through the blocked multi-RHS path returns exactly what the per-item
    // loop returns for the same requests.
    let run = |block_rhs: bool| -> Vec<Vec<f64>> {
        let mut cfg = ServiceConfig {
            workers: 1,
            batcher: snsolve::coordinator::batcher::BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(20),
            },
            ..Default::default()
        };
        cfg.worker.block_rhs = block_rhs;
        let svc = Service::start(cfg);
        let (a, _xt, b) = planted(300, 14, 23);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(24));
        let id = svc.register_matrix(Matrix::Dense(a));
        let handles: Vec<_> = (0..16)
            .map(|i| {
                // Vary the RHS per request so columns differ.
                let mut rhs = b.clone();
                if i % 2 == 1 {
                    for v in rhs.iter_mut() {
                        *v += 0.05 * g.next_gaussian();
                    }
                }
                svc.submit(SolveRequest {
                    matrix: id,
                    rhs,
                    solver: SolverChoice::Saa,
                    tol: 1e-10,
                    deadline_us: 0,
                    refine_iters: 0,
                })
                .unwrap()
            })
            .collect();
        let xs: Vec<Vec<f64>> = handles
            .into_iter()
            .map(|h| h.wait().unwrap().result.unwrap().x)
            .collect();
        if block_rhs {
            let blocked =
                snsolve::coordinator::metrics::Metrics::get(&svc.metrics().blocked_rhs);
            assert!(blocked >= 16, "expected all 16 RHS on the blocked path, got {blocked}");
        }
        svc.shutdown();
        xs
    };
    let blocked = run(true);
    let per_item = run(false);
    for (j, (xb, xs)) in blocked.iter().zip(per_item.iter()).enumerate() {
        assert_eq!(xb, xs, "request {j}: blocked and per-item solutions differ");
    }
}

#[test]
fn pjrt_bucket_routing_when_artifacts_present() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let mut cfg = ServiceConfig { workers: 1, ..Default::default() };
    cfg.worker.artifact_dir = Some(dir);
    let svc = Service::start(cfg);
    // 64x8 matches the smoke bucket exactly → PJRT route.
    let (a, x_true, b) = planted(64, 8, 13);
    let id = svc.register_matrix(Matrix::Dense(a));
    let resp = svc
        .solve_blocking(SolveRequest {
            matrix: id,
            rhs: b.clone(),
            solver: SolverChoice::Saa,
            tol: 1e-2, // loose → PJRT-eligible
            deadline_us: 0,
            refine_iters: 0,
        })
        .unwrap();
    let sol = resp.result.unwrap();
    match &resp.executed_on {
        snsolve::coordinator::ExecutedOn::Pjrt(name) => {
            assert_eq!(name, "saa_solve_64x8");
        }
        other => panic!("expected PJRT route, got {other:?}"),
    }
    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-3, "err {err}");

    // Tight tolerance diverts to native (f64).
    let resp2 = svc
        .solve_blocking(SolveRequest {
            matrix: id,
            rhs: b,
            solver: SolverChoice::Saa,
            tol: 1e-12,
            deadline_us: 0,
            refine_iters: 0,
        })
        .unwrap();
    assert_eq!(resp2.executed_on, snsolve::coordinator::ExecutedOn::Native);
    svc.shutdown();
}

#[test]
fn graceful_shutdown_drains() {
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let (a, _xt, b) = planted(200, 10, 17);
    let id = svc.register_matrix(Matrix::Dense(a));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            svc.submit(SolveRequest {
                matrix: id,
                rhs: b.clone(),
                solver: SolverChoice::Saa,
                tol: 1e-8,
                deadline_us: 0,
                refine_iters: 0,
            })
            .unwrap()
        })
        .collect();
    let svc2: Arc<Service> = svc.clone();
    // Shutdown while work may be in flight: all responders must resolve.
    std::thread::spawn(move || svc2.shutdown());
    let mut ok = 0;
    for h in handles {
        if let Ok(resp) = h.wait() {
            if resp.result.is_ok() {
                ok += 1;
            }
        }
    }
    // Submitted before close: the dispatcher drains them.
    assert!(ok >= 1, "at least some requests must complete, got {ok}");
}

// ---------------------------------------------------------------------------
// Pipelined front-end (protocol v2) and serving-tier regression tests
// ---------------------------------------------------------------------------

/// Read one length-prefixed frame from a raw socket (test-side decoder).
fn read_frame_raw(s: &mut TcpStream) -> Vec<u8> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).expect("frame length");
    let n = u32::from_le_bytes(len) as usize;
    let mut p = vec![0u8; n];
    s.read_exact(&mut p).expect("frame payload");
    p
}

#[test]
fn pipelined_16_inflight_out_of_order() {
    // The acceptance pin for the multiplexed front-end: one socket holds
    // >= 16 concurrent in-flight solves (witnessed by the server-side peak
    // gauge), and a slow request submitted *first* completes *after* the 16
    // fast ones behind it — completion order inverts submission order.
    let svc = Service::start(ServiceConfig {
        workers: 2,
        batcher: snsolve::coordinator::batcher::BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(30),
        },
        ..Default::default()
    });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(300, 12, 31);
    // Inconsistent system + tol 0 => LSQR runs its full iteration budget,
    // so the heavy request deterministically outlives the fast batch.
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(32));
    let heavy = DenseMatrix::gaussian(2000, 400, &mut g);
    let heavy_rhs = g.gaussian_vec(2000);

    let mut pc = PipelinedClient::connect(addr).expect("connect v2");
    let id = pc.register_dense(&a).expect("register");
    let heavy_id = pc.register_dense(&heavy).expect("register heavy");

    let slow = pc
        .submit_solve(heavy_id, &heavy_rhs, SolverChoice::Lsqr, 0.0, 0)
        .expect("submit slow");
    let fast: Vec<_> = (0..16)
        .map(|i| {
            let c = (i + 1) as f64;
            let rhs: Vec<f64> = b.iter().map(|v| c * v).collect();
            pc.submit_solve(id, &rhs, SolverChoice::Saa, 1e-10, 0).expect("submit fast")
        })
        .collect();

    // Harvest in reverse submission order: each ticket resolves on its own,
    // and linearity (rhs scaled by c => solution scaled by c) proves every
    // response was routed to the request that asked for it.
    let mut last_fast_arrival = None;
    for (i, t) in fast.into_iter().enumerate().rev() {
        let c = (i + 1) as f64;
        let (sol, at) = t.wait_timed().expect("fast solve");
        assert!(sol.converged, "fast {i} did not converge");
        let scaled: Vec<f64> = x_true.iter().map(|v| c * v).collect();
        let err = nrm2_diff(&sol.x, &scaled) / nrm2(&scaled);
        assert!(err < 1e-8, "fast {i} err {err}");
        let latest = last_fast_arrival.unwrap_or(at);
        last_fast_arrival = Some(latest.max(at));
    }
    // The slow head-of-line request finishes after every fast one.
    let (sol, slow_at) = slow.wait_timed().expect("slow solve");
    assert_eq!(sol.x.len(), 400);
    assert!(
        slow_at > last_fast_arrival.unwrap(),
        "slow response should arrive after all fast responses"
    );

    let peak = Metrics::get(&svc.metrics().frontend_peak_inflight);
    assert!(peak >= 16, "expected >=16 concurrent in-flight solves, saw peak {peak}");

    server.stop();
    svc.shutdown();
}

#[test]
fn pipelined_malformed_frame_errors_only_that_request() {
    // A malformed frame in the middle of a pipeline must error only its own
    // request id; the well-formed requests around it still complete.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(250, 10, 33);
    let mut reg = Client::connect(addr).expect("connect v1");
    let id = reg.register_dense(&a).expect("register");

    let mut s = TcpStream::connect(addr).expect("connect raw");
    s.write_all(&Writer::new(OP_HELLO).u8(PROTO_V2).frame()).unwrap();
    let hello = read_frame_raw(&mut s);
    assert_eq!(hello[0], OP_OK_HELLO);
    assert_eq!(hello[1], PROTO_V2);

    let solve_frame = |rid: u64, solver: u8| {
        Writer::new(OP_SOLVE)
            .u64(rid)
            .u64(id)
            .u8(solver)
            .f64(1e-10)
            .u64(0)
            .u32(b.len() as u32)
            .f64_slice(&b)
            .frame()
    };
    // Three pipelined requests; the middle one has an invalid solver byte.
    let mut burst = Vec::new();
    burst.extend_from_slice(&solve_frame(1, 0));
    burst.extend_from_slice(&solve_frame(2, 99));
    burst.extend_from_slice(&solve_frame(3, 0));
    s.write_all(&burst).unwrap();

    let mut ok = 0;
    let mut errored_id = 0;
    for _ in 0..3 {
        let p = read_frame_raw(&mut s);
        let mut r = Reader::new(&p);
        let op = r.u8().unwrap();
        let rid = r.u64().unwrap();
        if op == OP_ERROR {
            errored_id = rid;
            continue;
        }
        assert_eq!(op, OP_OK_SOLVE, "request {rid}");
        let n = r.u32().unwrap() as usize;
        let x = r.f64_vec(n).unwrap();
        let err = nrm2_diff(&x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "request {rid} err {err}");
        ok += 1;
    }
    assert_eq!(ok, 2, "both well-formed requests must succeed");
    assert_eq!(errored_id, 2, "only the malformed request may error");

    server.stop();
    svc.shutdown();
}

#[test]
fn legacy_pipelining_stays_fifo() {
    // v1 has no request ids: a client that writes several requests before
    // reading must get responses back in submission order even though the
    // server completes work out of order internally.
    let svc = Service::start(ServiceConfig { workers: 2, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(220, 9, 35);
    let mut reg = Client::connect(addr).expect("connect");
    let id = reg.register_dense(&a).expect("register");

    let mut s = TcpStream::connect(addr).expect("connect raw");
    let mut burst = Vec::new();
    for i in 0..4u32 {
        let c = (i + 1) as f64;
        let rhs: Vec<f64> = b.iter().map(|v| c * v).collect();
        let f = Writer::new(OP_SOLVE)
            .u64(id)
            .u8(0)
            .f64(1e-10)
            .u64(0)
            .u32(rhs.len() as u32)
            .f64_slice(&rhs)
            .frame();
        burst.extend_from_slice(&f);
    }
    s.write_all(&burst).unwrap();
    for i in 0..4u32 {
        let c = (i + 1) as f64;
        let p = read_frame_raw(&mut s);
        let mut r = Reader::new(&p);
        assert_eq!(r.u8().unwrap(), OP_OK_SOLVE, "response {i}");
        let n = r.u32().unwrap() as usize;
        let x = r.f64_vec(n).unwrap();
        let scaled: Vec<f64> = x_true.iter().map(|v| c * v).collect();
        let err = nrm2_diff(&x, &scaled) / nrm2(&scaled);
        assert!(err < 1e-8, "response {i} out of order or corrupt (err {err})");
    }
    server.stop();
    svc.shutdown();
}

#[test]
fn accept_loop_survives_transient_errors() {
    // Regression: transient accept() failures used to kill the accept loop,
    // leaving the service running but permanently unreachable.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    for kind in [
        std::io::ErrorKind::ConnectionAborted,
        std::io::ErrorKind::ConnectionReset,
        std::io::ErrorKind::Interrupted,
    ] {
        server.inject_accept_error(std::io::Error::new(kind, "synthetic"));
    }
    server.inject_accept_error(std::io::Error::from_raw_os_error(24)); // EMFILE

    // New connections still get served after the errors are consumed.
    let (a, x_true, b) = planted(200, 8, 37);
    let mut client = Client::connect(server.addr()).expect("connect after errors");
    let id = client.register_dense(&a).expect("register");
    let sol = client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
    let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-8, "err {err}");

    // Every injected failure was counted.
    let deadline = Instant::now() + Duration::from_secs(5);
    while Metrics::get(&svc.metrics().accept_errors) < 4 {
        assert!(Instant::now() < deadline, "accept_errors never reached 4");
        std::thread::sleep(Duration::from_millis(10));
    }
    server.stop();
    svc.shutdown();
}

#[test]
fn accept_loop_fatal_error_stops_listening() {
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();
    // Sanity: reachable before the fatal error (held open across it).
    let _pre = Client::connect(addr).expect("connect before");
    server.inject_accept_error(std::io::Error::new(
        std::io::ErrorKind::PermissionDenied,
        "synthetic fatal",
    ));
    // The accept thread exits and drops the listener, so new connections
    // are refused (retry until the injected error is consumed).
    let deadline = Instant::now() + Duration::from_secs(5);
    while TcpStream::connect(addr).is_ok() {
        assert!(Instant::now() < deadline, "listener still accepting after fatal error");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(Metrics::get(&svc.metrics().accept_errors) >= 1);
    server.stop();
    svc.shutdown();
}

#[test]
fn stop_closes_live_connections_and_refuses_new() {
    // Regression: stop() used to strand detached per-connection threads
    // blocked in read; now it shuts every live socket down and joins all
    // server threads before returning.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(200, 8, 39);
    let mut client = Client::connect(addr).expect("connect");
    let id = client.register_dense(&a).expect("register");
    let sol = client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
    assert!(nrm2_diff(&sol.x, &x_true) / nrm2(&x_true) < 1e-8);

    server.stop(); // joins accept, readers and all connection writers

    // The live connection was shut down server-side: further calls fail.
    assert!(client.metrics().is_err(), "call on a closed connection must error");
    // And the port no longer accepts.
    assert!(TcpStream::connect(addr).is_err(), "post-stop connect must be refused");
    svc.shutdown();
}

#[test]
fn client_deadline_is_transmitted_and_enforced() {
    // Regression: Client::solve used to hardcode deadline_us = 0, so no
    // deadline ever reached the server. solve_with_deadline must transmit
    // it, and a 1µs budget is always blown by queue time alone.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();
    let (a, x_true, b) = planted(200, 8, 41);

    let mut legacy = Client::connect(addr).expect("connect v1");
    let id = legacy.register_dense(&a).expect("register");
    match legacy.solve_with_deadline(id, &b, SolverChoice::Saa, 1e-10, 1) {
        Err(ClientError::Server(msg)) => {
            assert!(msg.to_lowercase().contains("deadline"), "unexpected error: {msg}");
        }
        Err(e) => panic!("wrong error kind over v1: {e}"),
        Ok(_) => panic!("expected a deadline error over v1"),
    }
    // Without a deadline the same request succeeds.
    let sol = legacy.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
    assert!(nrm2_diff(&sol.x, &x_true) / nrm2(&x_true) < 1e-8);

    let mut pipe = PipelinedClient::connect(addr).expect("connect v2");
    match pipe.solve_with_deadline(id, &b, SolverChoice::Saa, 1e-10, 1) {
        Err(ClientError::Server(msg)) => {
            assert!(msg.to_lowercase().contains("deadline"), "unexpected error: {msg}");
        }
        Err(e) => panic!("wrong error kind over v2: {e}"),
        Ok(_) => panic!("expected a deadline error over v2"),
    }
    server.stop();
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// Robust-solving tier: wire-level input validation and the stable solver
// ---------------------------------------------------------------------------

#[test]
fn non_finite_inputs_are_rejected_at_the_wire() {
    // A NaN smuggled into a registration would corrupt the cached
    // factorization for every later solve against that matrix; a NaN rhs
    // would propagate into the answer. Both must die at the decode boundary
    // with a typed error frame — and the connection must stay usable.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (a, x_true, b) = planted(200, 8, 45);
    let mut client = Client::connect(addr).expect("connect");

    let mut poisoned = a.clone();
    poisoned.data_mut()[3] = f64::NAN;
    match client.register_dense(&poisoned) {
        Err(ClientError::Server(msg)) => assert!(msg.contains("non-finite"), "{msg}"),
        Err(e) => panic!("wrong error kind: {e}"),
        Ok(_) => panic!("poisoned register must be rejected"),
    }

    let id = client.register_dense(&a).expect("register clean");
    let mut bad_rhs = b.clone();
    bad_rhs[0] = f64::INFINITY;
    match client.solve(id, &bad_rhs, SolverChoice::Saa, 1e-10) {
        Err(ClientError::Server(msg)) => assert!(msg.contains("non-finite"), "{msg}"),
        Err(e) => panic!("wrong error kind: {e}"),
        Ok(_) => panic!("non-finite rhs must be rejected"),
    }
    match client.solve(id, &b, SolverChoice::Saa, f64::NAN) {
        Err(ClientError::Server(msg)) => assert!(msg.contains("tolerance"), "{msg}"),
        Err(e) => panic!("wrong error kind: {e}"),
        Ok(_) => panic!("NaN tolerance must be rejected"),
    }

    // The connection survived all three rejections.
    let sol = client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve after errors");
    assert!(nrm2_diff(&sol.x, &x_true) / nrm2(&x_true) < 1e-8);
    server.stop();
    svc.shutdown();
}

#[test]
fn stable_solver_round_trips_over_tcp() {
    // `--solver stable` through the whole stack: protocol solver code 3,
    // worker ladder path, per-stage counters visible in the wire metrics.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let p = generate_dense(&DenseProblemSpec {
        m: 400,
        n: 16,
        cond: 1e10,
        resid_norm: 1e-10,
        seed: 47,
    });
    let ad = p.a.to_dense();
    let mut c = PipelinedClient::connect(addr).expect("connect v2");
    let id = c.register_dense(&ad).expect("register");
    let sol = c.solve(id, &p.b, SolverChoice::Stable, 1e-10).expect("stable solve");
    let err = nrm2_diff(&sol.x, &p.x_true) / nrm2(&p.x_true);
    assert!(err < 1e-4, "κ=1e10 stable-over-TCP err {err:.3e}");

    // κ = 1e10 defeats the one-shot stage, so the escalation counters moved
    // — and they are wire-visible through OP_METRICS.
    let wire = c.metrics().expect("metrics");
    assert!(wire.contains("ladder: "), "{wire}");
    assert!(Metrics::get(&svc.metrics().ladder_escalations) >= 1);
    server.stop();
    svc.shutdown();
}

#[test]
fn client_flow_selected_by_env() {
    // CI runs this suite twice with SNSOLVE_CLIENT=legacy|pipelined; the
    // same register/solve/evict flow must pass through either client.
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();
    let (a, x_true, b) = planted(240, 10, 43);
    let choice = std::env::var("SNSOLVE_CLIENT").unwrap_or_default();
    let (x, evicted, metrics) = if choice == "pipelined" {
        let mut c = PipelinedClient::connect(addr).expect("connect v2");
        let id = c.register_dense(&a).expect("register");
        let sol = c.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
        (sol.x, c.evict(id).expect("evict"), c.metrics().expect("metrics"))
    } else {
        let mut c = Client::connect(addr).expect("connect v1");
        let id = c.register_dense(&a).expect("register");
        let sol = c.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
        (sol.x, c.evict(id).expect("evict"), c.metrics().expect("metrics"))
    };
    let err = nrm2_diff(&x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-8, "err {err} (client {choice:?})");
    assert!(evicted);
    assert!(metrics.contains("completed="), "{metrics}");
    server.stop();
    svc.shutdown();
}
