//! Bitwise equivalence suite for the sketch-application engine (PR 5):
//!
//! (a) the blocked, stage-fused FWHT (`fwht_columns_with_radix` /
//!     `fwht_with_radix` at radix 2/4/8) is **bitwise identical** to the
//!     stage-per-pass baseline (radix 1) at every SIMD backend the host
//!     supports and at thread counts {1, 2, 4, 7} — the fused radix
//!     kernels compute exactly the cascaded radix-2 adds/subs, and tiling
//!     only reorders independent (element, stage) work;
//!
//! (b) the inverted-hash scatter layout of CountSketch / SparseSign /
//!     UniformSparse is **bitwise identical** to the band-rescan baseline
//!     (and to the serial streaming pass) on the dense and CSR paths, at
//!     every thread count and backend — each output row accumulates its
//!     input rows in the same serial order under every layout;
//!
//! (c) the `--fwht-radix` / config knob round-trips: forcing radix 1
//!     through the global knob reproduces the baseline bitwise.
//!
//! Everything lives in ONE test function: the pool size, the SIMD backend,
//! the FWHT radix and the scatter layout are process-wide settings, and
//! keeping the sweep single-threaded at the test level makes the
//! `set_threads`/`set_choice`/`set_fwht_radix`/`set_inverted_scatter`
//! transitions race-free (the same rule as `tests/parallel_determinism`).
//! The pure-computation radix checks (no globals) get their own function.

use snsolve::linalg::sparse::CooBuilder;
use snsolve::linalg::{hadamard, DenseMatrix};
use snsolve::rng::{GaussianSource, RngCore, Xoshiro256pp};
use snsolve::sketch::{self, SketchKind, SketchOperator};

/// Thread counts the engine acceptance criteria call out (7 is
/// deliberately not a divisor of anything).
const SWEEP: [usize; 4] = [1, 2, 4, 7];

const RADICES: [usize; 4] = [1, 2, 4, 8];

#[test]
fn sketch_engine_paths_bitwise_identical_across_knobs() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(9001));

    // --- FWHT fixtures ---------------------------------------------------
    // Column transform: 4096 × 33 clears the parallel floor (135k elems),
    // splits into ≥ 4 column bands (ceil(33/8) = 5), and at band widths
    // ~6-33 the L2 tile is smaller than 4096 rows — so phase A (in-tile
    // stages), phase B (cross-tile fused stages) and ragged vector tails
    // all execute.
    let (frows, fcols) = (4096usize, 33usize);
    let fdata = g.gaussian_vec(frows * fcols);
    // Vector transform: 2^18 elements = 8 tiles of 32768, so phase B runs
    // a full radix-8 fused pass (3 cross-tile stages).
    let fvec = g.gaussian_vec(1 << 18);

    // --- scatter fixtures ------------------------------------------------
    let (sm, sn, ss) = (4096usize, 24usize, 96usize);
    let sa_dense = DenseMatrix::gaussian(sm, sn, &mut g);
    let sa_csr = {
        let mut rng = Xoshiro256pp::seed_from_u64(9002);
        let mut bld = CooBuilder::with_capacity(sm, sn, sm * 4);
        for i in 0..sm {
            for _ in 0..4 {
                bld.push(i, rng.next_bounded(sn as u64) as usize, g.next_gaussian());
            }
        }
        bld.build()
    };
    let scatter_kinds =
        [SketchKind::CountSketch, SketchKind::SparseSign, SketchKind::UniformSparse];

    // --- FWHT references: stage-per-pass, scalar backend, 1 thread -------
    // The butterfly cascade is adds/subs only, so these references are
    // valid bitwise targets for EVERY backend; the scatter operators'
    // accumulation instead goes through the dispatched axpy (whose FMA
    // contraction re-rounds per backend), so their serial references are
    // rebuilt per backend below.
    snsolve::parallel::set_threads(1);
    snsolve::simd::set_choice(snsolve::simd::SimdChoice::Scalar);
    let cols_ref = {
        let mut d = fdata.clone();
        hadamard::fwht_columns_with_radix(&mut d, frows, fcols, 1).unwrap();
        d
    };
    let vec_ref = {
        let mut x = fvec.clone();
        hadamard::fwht_with_radix(&mut x, 1).unwrap();
        x
    };

    for backend in snsolve::simd::available() {
        snsolve::simd::set_choice(backend.as_choice());
        assert_eq!(snsolve::simd::active(), backend, "backend failed to activate");
        let name = backend.name();

        // Per-backend serial scatter reference (threads = 1 streams rows;
        // no layout branch on the serial path).
        snsolve::parallel::set_threads(1);
        let scatter_ref: Vec<(SketchKind, DenseMatrix, DenseMatrix)> = scatter_kinds
            .iter()
            .map(|&kind| {
                let op = sketch::build(kind, ss, sm, 4242);
                (kind, op.apply_dense(&sa_dense), op.apply_csr(&sa_csr))
            })
            .collect();

        for &t in &SWEEP {
            snsolve::parallel::set_threads(t);

            // (a) every radix — including the radix-1 baseline itself —
            // reproduces the scalar/1-thread/stage-per-pass bits.
            for radix in RADICES {
                let mut d = fdata.clone();
                hadamard::fwht_columns_with_radix(&mut d, frows, fcols, radix).unwrap();
                assert_eq!(
                    d, cols_ref,
                    "{name}: fwht_columns radix {radix} not bitwise at {t} threads"
                );
                let mut x = fvec.clone();
                hadamard::fwht_with_radix(&mut x, radix).unwrap();
                assert_eq!(x, vec_ref, "{name}: fwht radix {radix} not bitwise at {t} threads");
            }

            // (b) inverted scatter vs band-rescan vs the serial reference,
            // dense and CSR paths.
            for (kind, dense_ref, csr_ref) in &scatter_ref {
                let op = sketch::build(*kind, ss, sm, 4242);
                sketch::set_inverted_scatter(Some(false));
                let d_rescan = op.apply_dense(&sa_dense);
                let c_rescan = op.apply_csr(&sa_csr);
                sketch::set_inverted_scatter(Some(true));
                let d_inv = op.apply_dense(&sa_dense);
                let c_inv = op.apply_csr(&sa_csr);
                sketch::set_inverted_scatter(None);
                assert_eq!(
                    &d_rescan,
                    dense_ref,
                    "{name}: {} rescan dense differs at {t} threads",
                    kind.name()
                );
                assert_eq!(
                    d_inv, d_rescan,
                    "{name}: {} inverted dense not bitwise at {t} threads",
                    kind.name()
                );
                assert_eq!(
                    &c_rescan,
                    csr_ref,
                    "{name}: {} rescan csr differs at {t} threads",
                    kind.name()
                );
                assert_eq!(
                    c_inv, c_rescan,
                    "{name}: {} inverted csr not bitwise at {t} threads",
                    kind.name()
                );
            }
        }
    }

    // (c) the global radix knob round-trips: forcing the baseline through
    // the knob reproduces the reference via the default-dispatch entry
    // points, and every forced radix agrees.
    snsolve::parallel::set_threads(2);
    for radix in RADICES {
        hadamard::set_fwht_radix(Some(radix));
        assert_eq!(hadamard::fwht_radix_in_use(), radix);
        let mut d = fdata.clone();
        hadamard::fwht_columns_inplace(&mut d, frows, fcols).unwrap();
        assert_eq!(d, cols_ref, "knob radix {radix}: fwht_columns_inplace not bitwise");
        let mut x = fvec.clone();
        hadamard::fwht_inplace(&mut x).unwrap();
        assert_eq!(x, vec_ref, "knob radix {radix}: fwht_inplace not bitwise");
    }
    hadamard::set_fwht_radix(None);

    // Restore the ambient configuration for other test binaries.
    snsolve::parallel::set_threads(0);
    snsolve::simd::clear_choice();
}

/// Pure-computation radix equivalence across sizes (no process-global
/// knobs touched: explicit-radix entry points only, and the FWHT is
/// adds/subs — invariant to whichever backend/thread settings the sweep
/// above has installed at any instant).
#[test]
fn fwht_radix_equivalence_across_sizes() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(9003));
    for rows in [1usize, 2, 4, 8, 16, 64, 512, 2048] {
        let x = g.gaussian_vec(rows);
        let mut base = x.clone();
        hadamard::fwht_with_radix(&mut base, 1).unwrap();
        for radix in [2usize, 4, 8] {
            let mut y = x.clone();
            hadamard::fwht_with_radix(&mut y, radix).unwrap();
            assert_eq!(y, base, "vector rows={rows} radix={radix}");
        }
        for cols in [1usize, 3, 8, 17] {
            let data = g.gaussian_vec(rows * cols);
            let mut cbase = data.clone();
            hadamard::fwht_columns_with_radix(&mut cbase, rows, cols, 1).unwrap();
            for radix in [2usize, 4, 8] {
                let mut d = data.clone();
                hadamard::fwht_columns_with_radix(&mut d, rows, cols, radix).unwrap();
                assert_eq!(d, cbase, "columns rows={rows} cols={cols} radix={radix}");
            }
        }
    }
    // The blocked engine still matches the O(n²) reference transform.
    let x = g.gaussian_vec(256);
    let reference = hadamard::wht_reference(&x);
    for radix in [2usize, 4, 8] {
        let mut y = x.clone();
        hadamard::fwht_with_radix(&mut y, radix).unwrap();
        for (u, v) in y.iter().zip(reference.iter()) {
            assert!((u - v).abs() < 1e-9, "radix {radix} vs reference");
        }
    }
}

/// The SRHT silent-clamp regression at the integration level: a sketch
/// dimension beyond the padded Hadamard order must hard-error instead of
/// returning an operator whose trailing rows are silently zero.
#[test]
fn srht_rejects_sketch_dim_beyond_padded_order() {
    let r = std::panic::catch_unwind(|| sketch::SrhtSketch::new(200, 100, 7));
    assert!(r.is_err(), "s=200 > m̃=128 must panic");
    let op = sketch::SrhtSketch::new(120, 100, 7);
    assert_eq!(op.sketch_dim(), 120);
    // Materialized S has no all-zero row (every sampled Hadamard row is a
    // ±1 pattern times the sign flip).
    let s_mat = op.materialize();
    for r in 0..120 {
        let nonzero = s_mat.row(r).iter().filter(|v| **v != 0.0).count();
        assert!(nonzero > 0, "row {r} of S is all-zero");
    }
}
