//! Blocked compact-WY QR equivalence suite (the PR-4 tentpole contract):
//!
//! (a) blocked and unblocked `qr_compact` agree within 1e-12 (in units of
//!     the matrix/column scale) on R and on `q_transpose_vec`/`q_vec`
//!     outputs, across NB ∈ {1, 8, 32, full} and shapes that cross panel
//!     boundaries;
//! (b) the agreement survives the ill-conditioned column scalings the
//!     `qr.rs` unit suite uses;
//! (c) `nb ≥ n` is bit-for-bit the unblocked sweep, and every NB yields a
//!     factorization whose materialized Q/R satisfy the QR invariants;
//! (d) the blocked appliers stay per-row bitwise against the single-vector
//!     path (the contract the batched serving layer leans on).

use snsolve::linalg::qr::{qr_compact_blocked, qr_compact_unblocked, QrCompact};
use snsolve::linalg::DenseMatrix;
use snsolve::rng::{GaussianSource, Xoshiro256pp};

const TOL: f64 = 1e-12;

/// NBs the acceptance criteria call out; `usize::MAX` stands in for
/// "full" (clamped by the factorization to one panel).
const NBS: [usize; 4] = [1, 8, 32, usize::MAX];

fn rand_matrix(s: usize, n: usize, seed: u64) -> DenseMatrix {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    DenseMatrix::gaussian(s, n, &mut g)
}

/// Column norms of `a` — the scale R's column j lives at.
fn col_norms(a: &DenseMatrix) -> Vec<f64> {
    let (s, n) = a.shape();
    let mut out = vec![0.0; n];
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for i in 0..s {
            acc += a[(i, j)] * a[(i, j)];
        }
        *o = acc.sqrt().max(1e-300);
    }
    out
}

fn assert_r_close(blocked: &QrCompact, reference: &QrCompact, scales: &[f64], label: &str) {
    let rb = blocked.r();
    let ru = reference.r();
    let n = scales.len();
    for i in 0..n {
        for j in i..n {
            let d = (rb[(i, j)] - ru[(i, j)]).abs();
            assert!(
                d <= TOL * scales[j],
                "{label}: R[{i},{j}] {} vs {} (col scale {})",
                rb[(i, j)],
                ru[(i, j)],
                scales[j]
            );
        }
    }
}

fn assert_vec_close(a: &[f64], b: &[f64], scale: f64, label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length");
    for (i, (u, v)) in a.iter().zip(b.iter()).enumerate() {
        assert!((u - v).abs() <= TOL * scale, "{label}[{i}]: {u} vs {v}");
    }
}

/// (a) + (c): blocked-vs-unblocked agreement over the NB sweep, shapes
/// chosen to cross panel boundaries (n not a multiple of NB, n == NB,
/// n < NB, square).
#[test]
fn blocked_matches_unblocked_across_nb_and_shapes() {
    let shapes = [
        (40usize, 10usize, 1u64),
        (100, 33, 2),
        (200, 64, 3),
        (129, 65, 4),
        (64, 64, 5),
        (260, 96, 6),
    ];
    for (s, n, seed) in shapes {
        let a = rand_matrix(s, n, seed);
        let scales = col_norms(&a);
        let reference = qr_compact_unblocked(&a).unwrap();
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed + 100));
        let c = g.gaussian_vec(s);
        let z = g.gaussian_vec(n);
        let c_norm = c.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0);
        let z_ref = reference.q_transpose_vec(&c);
        // Q has orthonormal columns, so ‖Qz‖ = ‖z‖ is the output scale.
        let z_scale = z.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0);
        let y_ref = reference.q_vec(&z);
        for nb in NBS {
            let blocked = qr_compact_blocked(&a, nb).unwrap();
            let label = format!("{s}x{n} nb={nb}");
            assert_r_close(&blocked, &reference, &scales, &label);
            assert_vec_close(&blocked.q_transpose_vec(&c), &z_ref, c_norm, &label);
            assert_vec_close(&blocked.q_vec(&z), &y_ref, z_scale, &label);
            if nb >= n {
                // Full-width panel IS the unblocked sweep, bit for bit.
                assert_eq!(blocked, reference, "{label}: full panel not bitwise");
            }
        }
    }
}

/// Every NB yields a valid factorization on its own terms: R triangular,
/// QᵀQ = I, QR = A.
#[test]
fn every_nb_satisfies_qr_invariants() {
    let a = rand_matrix(150, 47, 7);
    for nb in NBS {
        let f = qr_compact_blocked(&a, nb).unwrap();
        let q = f.q();
        let r = f.r();
        for i in 0..47 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0, "nb={nb}: R not triangular at ({i},{j})");
            }
        }
        let qtq = q.transpose().matmul(&q).unwrap();
        let dev = qtq.fro_distance(&DenseMatrix::eye(47));
        assert!(dev < 1e-11, "nb={nb}: QtQ dev {dev}");
        let rel = q.matmul(&r).unwrap().fro_distance(&a) / a.fro_norm();
        assert!(rel < TOL, "nb={nb}: QR != A rel {rel}");
    }
}

/// (b) the ill-conditioned column scalings from the `qr.rs` unit suite:
/// blocked and unblocked must still agree column-by-column at each
/// column's own scale.
#[test]
fn blocked_matches_unblocked_on_illconditioned_columns() {
    let mut a = rand_matrix(80, 12, 8);
    for j in 0..12 {
        let scale = 10f64.powi(-(2 * j as i32 % 15));
        for i in 0..80 {
            a[(i, j)] *= scale;
        }
    }
    let scales = col_norms(&a);
    let reference = qr_compact_unblocked(&a).unwrap();
    for nb in [1usize, 8, 32] {
        let blocked = qr_compact_blocked(&a, nb).unwrap();
        assert_r_close(&blocked, &reference, &scales, &format!("illcond nb={nb}"));
    }
}

/// (d) the blocked factorization's `q_transpose_mat` keeps the per-row
/// bitwise contract against `q_transpose_vec` — the batched serving
/// equivalence, now on blocked reflectors.
#[test]
fn blocked_q_transpose_mat_matches_per_row_bitwise() {
    let a = rand_matrix(96, 30, 9);
    let f = qr_compact_blocked(&a, 8).unwrap();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(10));
    let c = DenseMatrix::gaussian(6, 96, &mut g);
    let z = f.q_transpose_mat(&c);
    for r in 0..6 {
        assert_eq!(z.row(r), &f.q_transpose_vec(c.row(r))[..], "row {r}");
    }
}
