//! NaN/Inf propagation contract for the kernel layer (the satellite
//! bugfixes of the SIMD PR):
//!
//! * GEMM must not skip zero operands, so `0·NaN = 0·Inf = NaN` reaches C
//!   identically whether the element lands in a full register tile or a
//!   ragged edge tile — C's non-finite propagation must not depend on the
//!   matrix shape (the old `micro_edge` dropped `av == 0.0` terms).
//! * `matvec_t` (dense and CSR) and the blocked `apply_transpose_mat` must
//!   not skip zero coefficients for the same reason.
//! * `norm_inf` must propagate NaN (`f64::max` swallows it — a vector of
//!   NaNs reported ∞-norm 0.0, so a diverged solve could be reported as
//!   converged), and `nrm2`'s zero-skip must not swallow NaN/Inf either.
//!
//! Everything here must hold on every SIMD backend; the suite runs under
//! the ambient backend (CI covers `SNSOLVE_SIMD=scalar` explicitly).

use snsolve::linalg::sparse::CooBuilder;
use snsolve::linalg::{gemm, norms, DenseMatrix, LinearOperator};
use snsolve::rng::{GaussianSource, Xoshiro256pp};

/// Both-NaN or bitwise-equal — `assert_eq!` alone can't compare NaNs.
fn same_value(u: f64, v: f64) -> bool {
    u.to_bits() == v.to_bits() || (u.is_nan() && v.is_nan())
}

/// `0 · NaN` and `0 · Inf` in B poison the matching C columns for every
/// tile the element can land in. A is all-zero, so the old edge-kernel
/// `av == 0.0` skip made exactly the edge-tile entries (shape-dependent!)
/// come out 0.0 instead of NaN.
#[test]
fn gemm_zero_times_nonfinite_poisons_full_and_edge_tiles() {
    // 9 rows: two full MR=4 tiles + 1 edge row. 13 cols: a full register
    // tile plus a ragged remainder for both the scalar/NEON (NR=8) and
    // AVX2 (NR=12) tile widths. Column 0 is always in a full tile, column
    // 12 always in an edge tile.
    let (m, k, n) = (9usize, 5usize, 13usize);
    let a = DenseMatrix::zeros(m, k);
    let mut b = DenseMatrix::zeros(k, n);
    b[(2, 0)] = f64::NAN;
    b[(3, 5)] = f64::INFINITY;
    b[(4, n - 1)] = f64::NAN;
    let c = gemm::matmul(&a, &b).unwrap();
    for i in 0..m {
        assert!(c[(i, 0)].is_nan(), "0*NaN lost in full tile, row {i}");
        assert!(c[(i, 5)].is_nan(), "0*Inf lost, row {i}");
        assert!(c[(i, n - 1)].is_nan(), "0*NaN lost in edge tile, row {i}");
        assert_eq!(c[(i, 1)], 0.0, "clean column polluted, row {i}");
    }
}

/// NaN in A poisons the matching C rows — full-height and edge-height
/// tiles alike.
#[test]
fn gemm_nan_in_a_poisons_rows() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(71));
    let (m, k, n) = (10usize, 7usize, 9usize);
    let mut a = DenseMatrix::gaussian(m, k, &mut g);
    a[(0, 3)] = f64::NAN; // full-tile row
    a[(m - 1, 2)] = f64::NAN; // edge-tile row
    let b = DenseMatrix::gaussian(k, n, &mut g);
    let c = gemm::matmul(&a, &b).unwrap();
    for j in 0..n {
        assert!(c[(0, j)].is_nan(), "NaN lost in full-tile row, col {j}");
        assert!(c[(m - 1, j)].is_nan(), "NaN lost in edge-tile row, col {j}");
        assert!(c[(1, j)].is_finite(), "clean row polluted, col {j}");
    }
}

#[test]
fn matvec_propagates_nonfinite_x() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(72));
    let a = DenseMatrix::gaussian(6, 4, &mut g);
    let mut x = vec![1.0; 4];
    x[2] = f64::NAN;
    for yi in a.matvec(&x) {
        assert!(yi.is_nan());
    }
    // Inf against a column with a zero entry → 0·Inf = NaN in that row.
    let mut az = DenseMatrix::gaussian(3, 2, &mut g);
    az[(1, 0)] = 0.0;
    let y = az.matvec(&[f64::INFINITY, 1.0]);
    assert!(y[1].is_nan());
}

/// `matvec_t` must not skip zero coefficients: `x[i] == 0` against a row
/// of A holding NaN/Inf still contributes `0·NaN = NaN`.
#[test]
fn matvec_t_zero_coefficient_propagates_nonfinite_rows() {
    let mut a = DenseMatrix::zeros(4, 3);
    a[(1, 0)] = f64::NAN;
    a[(2, 1)] = f64::INFINITY;
    let x = vec![1.0, 0.0, 0.0, 1.0]; // zero weight on the NaN/Inf rows
    let y = a.matvec_t(&x);
    assert!(y[0].is_nan(), "0·NaN dropped");
    assert!(y[1].is_nan(), "0·Inf dropped");
    assert_eq!(y[2], 0.0);
}

#[test]
fn csr_matvec_t_zero_coefficient_propagates_nonfinite_rows() {
    let mut bld = CooBuilder::new(3, 2);
    bld.push(0, 0, 1.0);
    bld.push(1, 1, f64::NAN);
    bld.push(2, 1, f64::INFINITY);
    let s = bld.build();
    let y = s.matvec_t(&[2.0, 0.0, 0.0]);
    assert_eq!(y[0], 2.0);
    assert!(y[1].is_nan(), "CSR 0·NaN / 0·Inf dropped");
}

/// The blocked transpose apply keeps the same IEEE contract as the vector
/// kernel — rows match `matvec_t` even when the coefficients are zero and
/// A holds non-finite entries.
#[test]
fn apply_transpose_mat_matches_matvec_t_under_nonfinite() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(73));
    let (m, n, k) = (12usize, 5usize, 3usize);
    let mut a = DenseMatrix::gaussian(m, n, &mut g);
    a[(4, 1)] = f64::NAN;
    a[(7, 3)] = f64::INFINITY;
    let mut x = DenseMatrix::gaussian(k, m, &mut g);
    x[(0, 4)] = 0.0; // zero weight on the NaN row
    x[(1, 7)] = 0.0; // zero weight on the Inf row
    let mut y = DenseMatrix::zeros(k, n);
    a.apply_transpose_mat(&x, &mut y);
    for r in 0..k {
        let expect = a.matvec_t(x.row(r));
        for (j, (&u, &v)) in y.row(r).iter().zip(expect.iter()).enumerate() {
            assert!(same_value(u, v), "row {r} col {j}: blocked {u} vs vector {v}");
        }
        assert!(expect[1].is_nan(), "row {r}: NaN row of A never reached y");
    }
}

/// Packed-panel GEMM (PR 4): the pack zero-pads ragged edge strips/panels
/// and masks their write-back. The padding must never swallow `0·NaN` /
/// `0·Inf` arising from **real** data, and it must never leak into clean
/// outputs. Shapes chosen above the packed-path floor (`PACK_MIN_FLOPS`)
/// and ragged in every dimension for every backend tile (MR ∈ {4, 8},
/// NR ∈ {8, 12}).
///
/// ONE test (not several): the packing knob is process-global and the
/// tests in this binary run concurrently — a second knob-flipping test
/// could silently route this test's "unpacked" baseline through the
/// packed path between the flip and the matmul, making the comparison
/// vacuous (same reason the gemm.rs unit suite keeps a single knob test).
#[test]
fn packed_gemm_zero_padding_preserves_nonfinite_and_stays_clean() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(74));
    let (m, k, n) = (41usize, 48, 37);
    let mut a = DenseMatrix::gaussian(m, k, &mut g);
    let mut b = DenseMatrix::gaussian(k, n, &mut g);
    a[(m - 1, 3)] = f64::NAN; // last row: always an edge strip row
    b[(2, 0)] = f64::NAN; // first column: always a full-panel column
    b[(5, n - 1)] = f64::INFINITY; // last column: always an edge panel column
    snsolve::linalg::gemm::set_packing(Some(true));
    let cp = gemm::matmul(&a, &b).unwrap();
    snsolve::linalg::gemm::set_packing(Some(false));
    let cu = gemm::matmul(&a, &b).unwrap();
    snsolve::linalg::gemm::set_packing(None);
    for j in 0..n {
        assert!(cp[(m - 1, j)].is_nan(), "NaN row lost in packed edge strip, col {j}");
    }
    for i in 0..m - 1 {
        assert!(cp[(i, 0)].is_nan(), "NaN column lost in packed full panel, row {i}");
        assert!(!cp[(i, n - 1)].is_finite(), "Inf col finite in packed edge panel, row {i}");
        assert!(cp[(i, 1)].is_finite(), "clean column polluted by pack padding, row {i}");
    }
    // Elementwise: packed and unpacked agree on non-finite placement
    // exactly, and on finite values within rounding (edge tiles round
    // differently between the two paths).
    let scale = 1e-12
        * cu.data().iter().filter(|v| v.is_finite()).fold(1.0f64, |acc, &v| acc.max(v.abs()));
    for (i, (u, p)) in cu.data().iter().zip(cp.data().iter()).enumerate() {
        if u.is_nan() || p.is_nan() {
            assert!(u.is_nan() && p.is_nan(), "NaN placement differs at flat index {i}");
        } else if !u.is_finite() || !p.is_finite() {
            assert_eq!(u, p, "Inf placement differs at flat index {i}");
        } else {
            assert!((u - p).abs() <= scale, "finite divergence at flat index {i}: {u} vs {p}");
        }
    }

    // All-zero A against non-finite B through the packed path — the
    // padded accumulator rows compute `0·NaN` too, but only the masked
    // write-back decides what reaches C: real rows get NaN, the clean
    // column stays 0.
    let (m, k, n) = (33usize, 64, 29); // ≥ PACK_MIN_FLOPS, ragged everywhere
    let az = DenseMatrix::zeros(m, k);
    let mut bz = DenseMatrix::zeros(k, n);
    bz[(1, 0)] = f64::NAN;
    bz[(k - 1, n - 1)] = f64::INFINITY;
    snsolve::linalg::gemm::set_packing(Some(true));
    let cz = gemm::matmul(&az, &bz).unwrap();
    snsolve::linalg::gemm::set_packing(None);
    for i in 0..m {
        assert!(cz[(i, 0)].is_nan(), "packed 0·NaN lost, row {i}");
        assert!(cz[(i, n - 1)].is_nan(), "packed 0·Inf lost in edge panel, row {i}");
        assert_eq!(cz[(i, 1)], 0.0, "clean column polluted, row {i}");
    }
}

#[test]
fn norms_propagate_nonfinite() {
    assert!(norms::norm_inf(&[f64::NAN; 3]).is_nan());
    assert!(norms::norm_inf(&[5.0, f64::NAN]).is_nan());
    assert_eq!(norms::norm_inf(&[-3.0, 1.0]), 3.0);
    assert_eq!(norms::norm_inf(&[f64::NEG_INFINITY, 1.0]), f64::INFINITY);
    assert!(norms::nrm2(&[0.0, f64::NAN]).is_nan());
    assert!(norms::nrm2(&[3.0, f64::NAN, 4.0]).is_nan());
    assert_eq!(norms::nrm2(&[f64::INFINITY, 1.0]), f64::INFINITY);
}
