//! Property tests pinning the blocked multi-RHS solve to the per-RHS path.
//!
//! The serving guarantee the batcher rides on (cf. Epperly 2311.04362 and
//! Meier et al. 2302.07202 on where sketch-and-precondition accuracy
//! lives): solving k right-hand sides as one `lsqr_block` — shared operator
//! applies, per-column scalar recurrences, per-column convergence masking —
//! must match k independent `lsqr` calls. Pinned here to ≤ 1e-10 per
//! column *and* to identical per-column stop reasons / iteration counts,
//! for k ∈ {1, 2, 5, 16}, on well- and ill-conditioned problems, with and
//! without warm starts, including mixed-convergence batches where some
//! columns finish early.

use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::operator::PreconditionedOperator;
use snsolve::linalg::qr::qr_compact;
use snsolve::linalg::triangular::right_solve_upper_multi;
use snsolve::linalg::DenseMatrix;
use snsolve::prop_assert;
use snsolve::sketch::{CountSketch, SketchOperator};
use snsolve::solvers::lsqr::{lsqr, lsqr_block, LsqrConfig, StopReason};
use snsolve::testing::{forall_cases, PropRng};

const BLOCK_SIZES: [usize; 4] = [1, 2, 5, 16];

/// Max per-column deviation the acceptance criteria allow. (In practice the
/// blocked path is bitwise per column; the tolerance guards the contract,
/// the istop/itn equality below guards the trajectory.)
const COL_TOL: f64 = 1e-10;

/// Random m×n problem matrix; `ill` grades column scales over ~6 decades.
fn problem_matrix(rng: &mut PropRng, m: usize, n: usize, ill: bool) -> DenseMatrix {
    let mut a = DenseMatrix::from_vec(m, n, rng.gaussian_vec(m * n)).unwrap();
    if ill {
        let decades = 6.0 / (n.max(2) - 1) as f64;
        for j in 0..n {
            let s = 10f64.powf(-decades * j as f64);
            for i in 0..m {
                a[(i, j)] *= s;
            }
        }
    }
    a
}

/// A batch of k RHS of deliberately mixed difficulty: consistent systems,
/// noisy (inconsistent) ones, rescaled ones, and the occasional zero vector
/// — so columns converge at different iterations within one block.
fn rhs_batch(rng: &mut PropRng, a: &DenseMatrix, k: usize) -> DenseMatrix {
    let (m, n) = a.shape();
    let mut b = DenseMatrix::from_fn(k, m, |_, _| 0.0);
    for j in 0..k {
        let style = rng.usize_in(0, 3);
        let row = match style {
            0 => a.matvec(&rng.gaussian_vec(n)), // consistent
            1 => {
                // consistent + residual component
                let mut r = a.matvec(&rng.gaussian_vec(n));
                for ri in r.iter_mut() {
                    *ri += 0.5 * rng.gaussian();
                }
                r
            }
            2 => {
                let scale = 10f64.powf(rng.f64_in(-4.0, 3.0));
                a.matvec(&rng.gaussian_vec(n)).iter().map(|v| v * scale).collect()
            }
            _ => vec![0.0; m], // trivial column
        };
        b.row_mut(j).copy_from_slice(&row);
    }
    b
}

fn assert_columns_match(
    block: &[snsolve::solvers::lsqr::LsqrResult],
    a: &impl snsolve::linalg::LinearOperator,
    b: &DenseMatrix,
    x0: Option<&DenseMatrix>,
    cfg: &LsqrConfig,
) -> Result<(), String> {
    for (j, bres) in block.iter().enumerate() {
        let x0j: Option<Vec<f64>> = x0.map(|m| m.row(j).to_vec());
        let solo = lsqr(a, b.row(j), x0j.as_deref(), cfg);
        prop_assert!(
            bres.istop == solo.istop,
            "col {j}: istop {:?} vs solo {:?}",
            bres.istop,
            solo.istop
        );
        prop_assert!(bres.itn == solo.itn, "col {j}: itn {} vs solo {}", bres.itn, solo.itn);
        let scale = nrm2(&solo.x).max(1.0);
        let dev = nrm2_diff(&bres.x, &solo.x) / scale;
        prop_assert!(dev <= COL_TOL, "col {j}: x deviates by {dev:.3e} (tol {COL_TOL:.0e})");
    }
    Ok(())
}

#[test]
fn blocked_lsqr_matches_independent_solves() {
    forall_cases("lsqr_block == k independent lsqr", 24, |rng| {
        let k = *rng.choose(&BLOCK_SIZES);
        let ill = rng.usize_in(0, 1) == 1;
        let n = rng.usize_in(4, 10);
        let m = rng.usize_in(3 * n, 8 * n);
        let a = problem_matrix(rng, m, n, ill);
        let b = rhs_batch(rng, &a, k);
        let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() };
        let block = lsqr_block(&a, &b, None, &cfg);
        prop_assert!(block.len() == k, "expected {k} results, got {}", block.len());
        assert_columns_match(&block, &a, &b, None, &cfg)
    });
}

#[test]
fn blocked_lsqr_matches_with_warm_starts() {
    forall_cases("warm-started lsqr_block == solo", 16, |rng| {
        let k = *rng.choose(&BLOCK_SIZES);
        let ill = rng.usize_in(0, 1) == 1;
        let n = rng.usize_in(4, 9);
        let m = rng.usize_in(3 * n, 7 * n);
        let a = problem_matrix(rng, m, n, ill);
        let b = rhs_batch(rng, &a, k);
        // Warm starts of mixed quality (one exact-ish, rest random).
        let mut x0 = DenseMatrix::from_fn(k, n, |_, _| 0.0);
        for j in 0..k {
            let row = rng.gaussian_vec(n);
            x0.row_mut(j).copy_from_slice(&row);
        }
        let cfg = LsqrConfig { atol: 1e-11, btol: 1e-11, ..Default::default() };
        let block = lsqr_block(&a, &b, Some(&x0), &cfg);
        assert_columns_match(&block, &a, &b, Some(&x0), &cfg)
    });
}

/// The SAA serving shape: right-preconditioned operator + sketched warm
/// start, exactly what `Worker::execute_batch` runs against the factor
/// cache.
#[test]
fn blocked_preconditioned_solve_matches_serving_path() {
    forall_cases("preconditioned lsqr_block == solo", 12, |rng| {
        let k = *rng.choose(&BLOCK_SIZES);
        let n = rng.usize_in(4, 8);
        let m = rng.usize_in(6 * n, 12 * n);
        let a = problem_matrix(rng, m, n, rng.usize_in(0, 1) == 1);
        let b = rhs_batch(rng, &a, k);
        let s_rows = (4 * n).min(m);
        let sketch = CountSketch::new(s_rows, m, rng.case_seed ^ 0xBEEF);
        let b_sk = sketch.apply_dense(&a);
        let qr = qr_compact(&b_sk).map_err(|e| e.to_string())?;
        let r = qr.r();
        let y = right_solve_upper_multi(&a, &r).map_err(|e| e.to_string())?;
        // Warm starts z0 = Qᵀ S b, blocked exactly like the worker.
        let z0 = qr.q_transpose_mat(&sketch.apply_mat(&b));
        let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, conlim: 0.0, ..Default::default() };
        let block_y = lsqr_block(&y, &b, Some(&z0), &cfg);
        assert_columns_match(&block_y, &y, &b, Some(&z0), &cfg)?;
        // And through the implicit operator (the CSR-path shape).
        let op = PreconditionedOperator::new(&a, &r);
        let block_op = lsqr_block(&op, &b, Some(&z0), &cfg);
        assert_columns_match(&block_op, &op, &b, Some(&z0), &cfg)
    });
}

/// Deterministic mixed-convergence batch: a trivial (zero) column, a
/// warm-started-at-the-solution column and two cold columns stop at
/// different iterations — masking must keep every column identical to its
/// solo run, bit for bit.
#[test]
fn mixed_convergence_batch_masks_early_columns() {
    use snsolve::rng::{GaussianSource, Xoshiro256pp};
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(0xD00D));
    let (m, n, k) = (60, 8, 4);
    let a = DenseMatrix::from_vec(m, n, g.gaussian_vec(m * n)).unwrap();
    let x_true = g.gaussian_vec(n);
    let easy = a.matvec(&x_true);
    let mut hard = easy.clone();
    for h in hard.iter_mut() {
        *h += 2.0 * g.next_gaussian();
    }
    let mut b = DenseMatrix::zeros(k, m);
    // row 0 stays zero: trivial column.
    b.row_mut(1).copy_from_slice(&easy); // warm-started at x_true below
    b.row_mut(2).copy_from_slice(&easy); // cold consistent
    b.row_mut(3).copy_from_slice(&hard); // cold inconsistent
    let mut x0 = DenseMatrix::zeros(k, n);
    x0.row_mut(1).copy_from_slice(&x_true);
    let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() };
    let block = lsqr_block(&a, &b, Some(&x0), &cfg);
    assert_eq!(block[0].istop, StopReason::TrivialSolution);
    assert_eq!(block[0].itn, 0);
    assert!(block[1].itn <= 1, "warm column itn {}", block[1].itn);
    assert!(block[2].itn > block[1].itn, "cold column must outlast the warm one");
    assert!(block[3].itn >= 1);
    for j in 0..k {
        let x0j = x0.row(j).to_vec();
        let solo = lsqr(&a, b.row(j), Some(&x0j), &cfg);
        assert_eq!(block[j].istop, solo.istop, "col {j}");
        assert_eq!(block[j].itn, solo.itn, "col {j}");
        assert_eq!(block[j].x, solo.x, "col {j}");
    }
}
