//! Deterministic fault-injection drills for the escalation ladder and the
//! serving tier: force each ladder stage to fail (or hand back poisoned
//! iterates), and assert the escalation order, final accuracy, and counter
//! movement — the fallback path must be exercisable on demand, not only on
//! matrices that happen to be nasty. Also drills the worker's
//! `catch_unwind` panic containment end to end.

use std::sync::{Arc, Mutex};

use snsolve::coordinator::{
    Service, ServiceConfig, ServiceError, SolveRequest, SolverChoice,
};
use snsolve::coordinator::metrics::Metrics;
use snsolve::linalg::DenseMatrix;
use snsolve::problems::{generate_dense, DenseProblemSpec, Problem};
use snsolve::solvers::ladder::Stage;
use snsolve::solvers::lsqr::SolveWorkspace;
use snsolve::solvers::{SolverError, StableSolver};
use snsolve::testing::{FaultGuard, FaultPlan};

/// Serializes the tests that install the process-global fault plan (the
/// plan is process-wide; unserialised they would fault each other's
/// workers). `into_inner` recovers from a panicked holder.
static GLOBAL_FAULTS: Mutex<()> = Mutex::new(());

fn instance(kappa: f64) -> Problem {
    generate_dense(&DenseProblemSpec { m: 400, n: 16, cond: kappa, resid_norm: 1e-10, seed: 42 })
}

/// Run the ladder on one RHS with an explicit fault plan; returns
/// (final stage, escalations, forward error).
fn run_with(p: &Problem, plan: FaultPlan) -> Result<(Stage, u64, f64), SolverError> {
    let m = p.a.shape().0;
    let mut rhs = DenseMatrix::zeros(1, m);
    rhs.row_mut(0).copy_from_slice(&p.b);
    let mut ws = SolveWorkspace::new();
    let out = StableSolver::default().solve_block(&p.a, &rhs, &mut ws, Some(&plan))?;
    Ok((out.stage_of[0], out.escalations, p.relative_error(&out.x.row(0).to_vec())))
}

#[test]
fn stage_failures_escalate_in_order_and_stay_accurate() {
    let p = instance(1e4);
    // Clean run: lands on one of the two iterative sketch stages.
    let (clean_stage, _, clean_err) = run_with(&p, FaultPlan::new()).unwrap();
    assert!(clean_stage <= Stage::PrecondLsqr, "clean run landed on {clean_stage:?}");
    assert!(clean_err < 1e-8, "clean err {clean_err:.3e}");

    // Each failed stage pushes the answer one rung down — never up — and
    // the final answer stays at tolerance regardless of which rung it is.
    let cases: &[(FaultPlan, Stage)] = &[
        (FaultPlan::new().fail("sas"), Stage::PrecondLsqr),
        (FaultPlan::new().fail("sas").fail("lsqr"), Stage::Refine),
        (FaultPlan::new().fail("sas").fail("lsqr").fail("refine"), Stage::DenseQr),
    ];
    for (plan, min_stage) in cases {
        let (stage, escalations, err) = run_with(&p, plan.clone()).unwrap();
        assert!(stage >= *min_stage, "expected ≥ {min_stage:?}, got {stage:?}");
        assert!(err < 1e-8, "{min_stage:?}: err {err:.3e}");
        assert!(
            escalations >= (*min_stage as u64),
            "{min_stage:?}: escalations {escalations} is vacuous"
        );
    }
}

#[test]
fn poisoned_iterates_are_caught_by_the_evidence() {
    let p = instance(1e4);
    // A poisoned stage completes with large finite garbage: only the
    // forward-error evidence can reject it. The ladder must never *accept*
    // a poisoned iterate.
    for (plan, label) in [
        (FaultPlan::new().poison("sas"), "poison sas"),
        (FaultPlan::new().poison("lsqr"), "poison lsqr"),
        (FaultPlan::new().fail("sas").fail("lsqr").poison("refine"), "poison refine"),
    ] {
        let (stage, escalations, err) = run_with(&p, plan).unwrap();
        assert!(err < 1e-8, "{label}: accepted a bad iterate (err {err:.3e}, {stage:?})");
        assert!(escalations >= 1, "{label}: escalations {escalations} is vacuous");
    }
}

#[test]
fn every_stage_failing_still_answers_via_dense_qr() {
    // The acceptance gate: all three sketch-based stages sabotaged, the
    // terminal dense stage still produces a certified answer.
    let p = instance(1e4);
    let plan = FaultPlan::new().fail("sas").fail("lsqr").fail("refine");
    let (stage, escalations, err) = run_with(&p, plan).unwrap();
    assert_eq!(stage, Stage::DenseQr);
    assert!(escalations >= 3);
    assert!(err < 1e-8, "dense terminal err {err:.3e}");
}

#[test]
fn dense_stage_failure_is_a_typed_error() {
    let p = instance(1e4);
    let plan = FaultPlan::new().fail("sas").fail("lsqr").fail("refine").fail("dense");
    match run_with(&p, plan) {
        Err(SolverError::NoConvergence(_)) => {}
        other => panic!("expected NoConvergence, got {other:?}"),
    }
}

#[test]
fn poisoned_dense_stage_is_rejected_not_returned() {
    // The terminal stage has no rung below it, so a poisoned dense iterate
    // must become a typed error — never a silently-wrong answer.
    let p = instance(1e4);
    let plan = FaultPlan::new().fail("sas").fail("lsqr").fail("refine").poison("dense");
    match run_with(&p, plan) {
        Err(SolverError::NoConvergence(_)) => {}
        other => panic!("expected NoConvergence, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Serving tier: global plan through the worker path
// ---------------------------------------------------------------------

fn test_service() -> (Arc<Service>, snsolve::coordinator::MatrixId, Vec<f64>, Vec<f64>) {
    let svc = Service::start(ServiceConfig { workers: 1, ..Default::default() });
    let p = instance(1e10);
    let (x_true, b) = (p.x_true.clone(), p.b.clone());
    let id = svc.register_matrix(p.a);
    (svc, id, x_true, b)
}

fn req(id: snsolve::coordinator::MatrixId, b: &[f64]) -> SolveRequest {
    SolveRequest {
        matrix: id,
        rhs: b.to_vec(),
        solver: SolverChoice::Stable,
        tol: 1e-10,
        deadline_us: 0,
        refine_iters: 0,
    }
}

#[test]
fn injected_worker_panic_is_contained_and_service_keeps_serving() {
    let _serial = GLOBAL_FAULTS.lock().unwrap_or_else(|e| e.into_inner());
    let (svc, id, _x_true, b) = test_service();
    {
        let _guard = FaultGuard::install(FaultPlan::new().panic_in("worker"));
        let resp = svc.solve_blocking(req(id, &b)).unwrap();
        match resp.result {
            Err(ServiceError::Solver(msg)) => assert!(msg.contains("panic"), "msg: {msg}"),
            other => panic!("expected a contained panic error, got {other:?}"),
        }
        assert_eq!(Metrics::get(&svc.metrics().worker_panics), 1);
    }
    // Plan cleared: the same worker thread must still be alive and solving.
    let resp = svc.solve_blocking(req(id, &b)).unwrap();
    assert!(resp.result.is_ok(), "service stopped serving after a contained panic");
    assert_eq!(Metrics::get(&svc.metrics().worker_panics), 1);
}

#[test]
fn ladder_escalation_counters_move_through_the_worker_path() {
    let _serial = GLOBAL_FAULTS.lock().unwrap_or_else(|e| e.into_inner());
    let (svc, id, x_true, b) = test_service();
    let resp = svc.solve_blocking(req(id, &b)).unwrap();
    let sol = resp.result.unwrap();
    let err = snsolve::linalg::norms::nrm2_diff(&sol.x, &x_true)
        / snsolve::linalg::norms::nrm2(&x_true);
    assert!(err < 1e-4, "κ=1e10 served err {err:.3e}");
    let m = svc.metrics();
    let answered = Metrics::get(&m.ladder_sas)
        + Metrics::get(&m.ladder_lsqr)
        + Metrics::get(&m.ladder_refine)
        + Metrics::get(&m.ladder_dense);
    assert_eq!(answered, 1, "every served RHS lands on exactly one rung");
    // κ = 1e10 defeats the one-shot stage, so at least one escalation
    // happened — the counter is non-vacuous.
    assert!(Metrics::get(&m.ladder_escalations) >= 1);
    // And the escalation shows up in the protocol-visible report.
    assert!(m.report().contains("ladder: "));
}
