//! The authoritative AOT round-trip test: HLO text written by
//! `python -m compile.aot` is loaded, compiled and executed through the
//! PJRT CPU client, and its numerics are checked against the native f64
//! solvers on the *same* problem with the *same* CountSketch.
//!
//! Requires `artifacts/` (run `make artifacts` first); tests skip with a
//! message when it is missing so `cargo test` stays green pre-build.

use std::path::PathBuf;

use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::DenseMatrix;
use snsolve::rng::{GaussianSource, Xoshiro256pp};
use snsolve::runtime::{Engine, Tensor};
use snsolve::sketch::{CountSketch, SketchOperator};

fn artifact_dir() -> Option<PathBuf> {
    let dir = std::env::var("SNSOLVE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: no artifacts at {} (run `make artifacts`)",
            dir.display()
        );
        None
    }
}

/// Build a small consistent problem in f32-friendly conditioning.
fn planted(m: usize, n: usize, seed: u64) -> (DenseMatrix, Vec<f64>, Vec<f64>) {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let mut x = g.gaussian_vec(n);
    snsolve::linalg::norms::normalize(&mut x);
    let b = a.matvec(&x);
    (a, x, b)
}

fn saa_inputs(
    a: &DenseMatrix,
    b: &[f64],
    sketch: &CountSketch,
) -> Vec<Tensor> {
    let (m, n) = a.shape();
    let (buckets, signs) = sketch.hash_arrays();
    vec![
        Tensor::from_f64(a.data(), vec![m, n]),
        Tensor::from_f64(b, vec![m]),
        Tensor::i32(buckets.iter().map(|&v| v as i32).collect(), vec![m]),
        Tensor::f32(signs.iter().map(|&v| v as f32).collect(), vec![m]),
    ]
}

#[test]
fn manifest_loads_and_buckets_exist() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    assert_eq!(engine.platform(), "cpu");
    let manifest = engine.manifest();
    assert!(manifest.artifacts.len() >= 8);
    assert!(manifest.find_shape("saa_solve", 64, 8).is_some());
    assert!(manifest.find_shape("lsqr_baseline", 4096, 64).is_some());
}

#[test]
fn saa_solve_smoke_artifact_recovers_planted_solution() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    let spec = engine.manifest().find("saa_solve_64x8").expect("smoke artifact").clone();
    let (a, x_true, b) = planted(spec.m, spec.n, 1234);
    let sketch = CountSketch::new(spec.s, spec.m, 99);
    let out = engine
        .execute(&spec.name, &saa_inputs(&a, &b, &sketch))
        .expect("execute");
    assert_eq!(out.len(), 2);
    let x = out[0].to_f64();
    let hist = out[1].to_f64();
    assert_eq!(x.len(), spec.n);
    assert_eq!(hist.len(), spec.iters);
    let err = nrm2_diff(&x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-4, "pjrt saa err {err}");
    // history decreasing
    for w in hist.windows(2) {
        assert!(w[1] <= w[0] + 1e-6, "history not monotone: {hist:?}");
    }
}

#[test]
fn pjrt_matches_native_saa_with_same_sketch() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    let spec = engine.manifest().find("saa_solve_64x8").expect("artifact").clone();
    let (a, _x_true, mut b) = planted(spec.m, spec.n, 777);
    // make it inconsistent so the LSQR refinement matters
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(778));
    for v in b.iter_mut() {
        *v += 1e-3 * g.next_gaussian();
    }
    let sketch = CountSketch::new(spec.s, spec.m, 31);

    // PJRT result.
    let out = engine.execute(&spec.name, &saa_inputs(&a, &b, &sketch)).expect("execute");
    let x_pjrt = out[0].to_f64();

    // Native result using the same sketch + same fixed iterations.
    let b_sk = sketch.apply_dense(&a);
    let c = sketch.apply_vec(&b);
    let f = snsolve::linalg::qr::qr_compact(&b_sk).unwrap();
    let r = f.r();
    let z0 = f.q_transpose_vec(&c);
    let y = snsolve::linalg::triangular::right_solve_upper(&a, &r).unwrap();
    let cfg = snsolve::solvers::lsqr::LsqrConfig {
        atol: 0.0,
        btol: 0.0,
        conlim: 0.0,
        iter_lim: Some(spec.iters),
        ..Default::default()
    };
    let res = snsolve::solvers::lsqr::lsqr(&y, &b, Some(&z0), &cfg);
    let x_native = snsolve::linalg::triangular::solve_upper(&r, &res.x).unwrap();

    let rel = nrm2_diff(&x_pjrt, &x_native) / nrm2(&x_native).max(1e-300);
    // f32 artifact vs f64 native: agreement bounded by f32 rounding through
    // ~30 iterations; observed ~1e-5.
    assert!(rel < 5e-3, "pjrt vs native rel diff {rel}");
}

#[test]
fn lsqr_baseline_artifact_runs() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    let spec = engine.manifest().find("lsqr_baseline_64x8").expect("artifact").clone();
    let (a, x_true, b) = planted(spec.m, spec.n, 555);
    let out = engine
        .execute(
            &spec.name,
            &[
                Tensor::from_f64(a.data(), vec![spec.m, spec.n]),
                Tensor::from_f64(&b, vec![spec.m]),
            ],
        )
        .expect("execute");
    let x = out[0].to_f64();
    let err = nrm2_diff(&x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-3, "baseline err {err}");
}

#[test]
fn sketch_only_artifact_matches_native_countsketch() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    let spec = engine.manifest().find("sketch_only_64x8").expect("artifact").clone();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(444));
    let a = DenseMatrix::gaussian(spec.m, spec.n, &mut g);
    let sketch = CountSketch::new(spec.s, spec.m, 17);
    let (buckets, signs) = sketch.hash_arrays();
    let out = engine
        .execute(
            &spec.name,
            &[
                Tensor::from_f64(a.data(), vec![spec.m, spec.n]),
                Tensor::i32(buckets.iter().map(|&v| v as i32).collect(), vec![spec.m]),
                Tensor::f32(signs.iter().map(|&v| v as f32).collect(), vec![spec.m]),
            ],
        )
        .expect("execute");
    let b_pjrt = out[0].to_f64();
    let b_native = sketch.apply_dense(&a);
    let mut max_err = 0.0f64;
    for (i, &v) in b_pjrt.iter().enumerate() {
        let (r, c) = (i / spec.n, i % spec.n);
        max_err = max_err.max((v - b_native[(r, c)]).abs());
    }
    assert!(max_err < 1e-4, "sketch mismatch {max_err}");
}

#[test]
fn input_validation_rejected() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    // Wrong input count.
    let err = engine.execute("saa_solve_64x8", &[]).unwrap_err();
    assert!(err.to_string().contains("expected"), "{err}");
    // Wrong shape.
    let bad = vec![
        Tensor::f32(vec![0.0; 64 * 8], vec![8, 64]), // transposed dims
        Tensor::f32(vec![0.0; 64], vec![64]),
        Tensor::i32(vec![0; 64], vec![64]),
        Tensor::f32(vec![1.0; 64], vec![64]),
    ];
    assert!(engine.execute("saa_solve_64x8", &bad).is_err());
    // Unknown artifact.
    assert!(engine.execute("nope", &[]).is_err());
}

#[test]
fn medium_bucket_end_to_end() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::load(&dir).expect("engine");
    let Some(spec) = engine.manifest().find("saa_solve_4096x64").cloned() else {
        eprintln!("skipping: 4096x64 bucket not present");
        return;
    };
    let (a, x_true, b) = planted(spec.m, spec.n, 9);
    let sketch = CountSketch::new(spec.s, spec.m, 5);
    let t0 = std::time::Instant::now();
    let out = engine.execute(&spec.name, &saa_inputs(&a, &b, &sketch)).expect("execute");
    let dt = t0.elapsed();
    let x = out[0].to_f64();
    let err = nrm2_diff(&x, &x_true) / nrm2(&x_true);
    assert!(err < 1e-3, "err {err}");
    eprintln!("saa_solve_4096x64 executed in {dt:?} (err {err:.2e})");
}
