//! Accuracy pins for the forward-stable solver tier (the acceptance gates
//! for `--solver stable`): across κ(A) ∈ {10⁶, 10¹⁰, 10¹⁴} the ladder's
//! forward error must stay within 10× of dense QR, while one-shot
//! sketch-and-solve demonstrably degrades. The numeric floors per κ come
//! from the recorded `BENCH_solver_stability` sweeps (m = 800, n = 25,
//! β = 10⁻¹⁰, seeds 42–44).

use snsolve::problems::{generate_dense, DenseProblemSpec, Problem};
use snsolve::solvers::direct::DirectQr;
use snsolve::solvers::{SketchAndSolve, Solver, StableSolver};

fn instance(kappa: f64, seed: u64) -> Problem {
    generate_dense(&DenseProblemSpec { m: 800, n: 25, cond: kappa, resid_norm: 1e-10, seed })
}

fn forward_error(p: &Problem, s: &dyn Solver) -> f64 {
    let sol = s.solve(&p.a, &p.b).expect("solve");
    p.relative_error(&sol.x)
}

/// err_stable ≤ 10 · err_qr + floor, per seed. The additive floor absorbs
/// lucky QR draws (QR landing at 5e-12 must not fail a 5e-13 stable run's
/// seed-mate at 4e-12); at κ = 10¹⁴ the 10 · err_qr term dominates and no
/// floor is needed.
fn assert_stable_tracks_qr(kappa: f64, floor: f64) {
    for seed in [42, 43, 44] {
        let p = instance(kappa, seed);
        let err_qr = forward_error(&p, &DirectQr);
        let err_stable = forward_error(&p, &StableSolver::default());
        assert!(
            err_stable <= 10.0 * err_qr + floor,
            "κ={kappa:.0e} seed={seed}: stable {err_stable:.3e} vs qr {err_qr:.3e}"
        );
    }
}

#[test]
fn stable_tracks_dense_qr_at_kappa_1e6() {
    assert_stable_tracks_qr(1e6, 1e-8);
}

#[test]
fn stable_tracks_dense_qr_at_kappa_1e10() {
    assert_stable_tracks_qr(1e10, 1e-6);
}

#[test]
fn stable_tracks_dense_qr_at_kappa_1e14() {
    assert_stable_tracks_qr(1e14, 0.0);
}

#[test]
fn one_shot_sketch_and_solve_demonstrably_degrades() {
    // At κ = 10¹⁰ the one-shot estimate has O(κ·ε)-scale forward error
    // (~0.04–0.13 here) where the ladder holds ~1e-8: three orders of
    // magnitude apart, per seed — the gap the fallback ladder exists for.
    for seed in [42, 43, 44] {
        let p = instance(1e10, seed);
        let err_sas = forward_error(&p, &SketchAndSolve::default());
        let err_stable = forward_error(&p, &StableSolver::default());
        assert!(
            err_sas >= 1e-4,
            "seed={seed}: sketch-and-solve unexpectedly accurate ({err_sas:.3e})"
        );
        assert!(
            err_sas >= 1e3 * err_stable,
            "seed={seed}: sas {err_sas:.3e} not ≥ 1e3× stable {err_stable:.3e}"
        );
    }
}
