//! Multi-process cluster drill: three real `snsolve serve` shard
//! processes behind an in-process [`ShardRouter`] with replication 2.
//!
//! The drill checks the tentpole robustness claims end to end:
//! (a) no in-flight solve is ever lost — every pipelined request gets a
//!     real response (a solution or the typed retryable error) even when
//!     a shard is killed under it;
//! (b) matrices whose shard died keep solving through replica failover;
//! (c) a restarted shard is re-seeded by the rebalance path and serves
//!     its matrices again, with the membership epoch and the router
//!     counters visible over `OP_METRICS`;
//! plus a deterministic seeded network-fault drill (every `OP_SOLVE`
//! frame to the known primary dropped) driving the retry → failover
//! ladder without any process dying.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use snsolve::coordinator::protocol::OP_SOLVE;
use snsolve::coordinator::tcp::{Client, ClientError, PipelinedClient, WireSolution};
use snsolve::coordinator::{MatrixId, ShardMap, ShardRouter, ShardRouterConfig, SolverChoice};
use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::DenseMatrix;
use snsolve::rng::{GaussianSource, Xoshiro256pp};
use snsolve::testing::{FaultGuard, FaultPlan, NetFaultAction};

fn planted(m: usize, n: usize, seed: u64) -> (DenseMatrix, Vec<f64>, Vec<f64>) {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let x = g.gaussian_vec(n);
    let b = a.matvec(&x);
    (a, x, b)
}

fn check(x: &[f64], x_true: &[f64]) {
    let err = nrm2_diff(x, x_true) / nrm2(x_true);
    assert!(err < 1e-6, "relative error {err}");
}

/// One shard: a real `snsolve serve` child process. Spawned on an
/// ephemeral port (`127.0.0.1:0`), the actual address is parsed from the
/// startup announcement; restarts reuse the recorded address verbatim.
struct ShardProc {
    child: Child,
    addr: String,
}

impl ShardProc {
    fn spawn(addr: &str) -> ShardProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_snsolve"))
            .args(["serve", "--addr", addr, "--workers", "2", "--threads", "1"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn shard process");
        let stdout = child.stdout.take().expect("stdout piped");
        let mut lines = BufReader::new(stdout).lines();
        let line = loop {
            match lines.next() {
                Some(Ok(l)) if l.contains("listening on") => break l,
                Some(Ok(_)) => continue,
                other => panic!("shard never announced its address: {other:?}"),
            }
        };
        let addr = line.rsplit(' ').next().expect("address token").to_string();
        ShardProc { child, addr }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Solve through the router, retrying the typed retryable error (the
/// honest "resend later" answer during failure windows). Anything else —
/// a fatal error or a lost (never-answered) request — fails the test.
fn solve_until_ok(c: &mut PipelinedClient, id: u64, b: &[f64]) -> WireSolution {
    let t0 = Instant::now();
    loop {
        let mut t = c.submit_solve(id, b, SolverChoice::Saa, 1e-10, 2_000_000).expect("submit");
        match t.wait_timeout(Duration::from_secs(10)) {
            Some(Ok(sol)) => return sol,
            Some(Err(ClientError::Retryable(_))) => {
                assert!(
                    t0.elapsed() < Duration::from_secs(30),
                    "solve {id} still retryable after 30s"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
            Some(Err(e)) => panic!("solve {id} failed fatally: {e}"),
            None => panic!("in-flight solve {id} lost: no response within 10s"),
        }
    }
}

/// First integer right after `key` in the router's metrics report.
fn counter(report: &str, key: &str) -> u64 {
    let at = report.find(key).unwrap_or_else(|| panic!("{key:?} missing in:\n{report}"));
    report[at + key.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or(0)
}

/// Poll the router's aggregated metrics until `pred` holds.
fn wait_for_metrics(c: &mut PipelinedClient, what: &str, pred: impl Fn(&str) -> bool) -> String {
    let t0 = Instant::now();
    loop {
        let m = c.metrics().expect("metrics");
        if pred(&m) {
            return m;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "timeout waiting for {what}; last report:\n{m}"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

#[test]
fn router_serves_legacy_v1_client() {
    let shard = ShardProc::spawn("127.0.0.1:0");
    let mut rcfg = ShardRouterConfig::new(vec![shard.addr.clone()], 2);
    rcfg.heartbeat_ms = 100;
    let router = ShardRouter::serve("127.0.0.1:0", rcfg).expect("router bind");

    let (a, x_true, b) = planted(150, 6, 99);
    let mut c = Client::connect(router.addr()).expect("connect v1");
    let id = c.register_dense(&a).expect("register");
    let sol = c.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
    assert!(sol.converged);
    check(&sol.x, &x_true);
    let m = c.metrics().expect("metrics");
    assert!(m.contains("router: shards=1 alive=1"), "{m}");
    assert!(c.evict(id).expect("evict"));
    router.stop();
}

#[test]
fn cluster_kill_one_shard_failover_and_rebalance() {
    let mut shards: Vec<ShardProc> = (0..3).map(|_| ShardProc::spawn("127.0.0.1:0")).collect();
    let addrs: Vec<String> = shards.iter().map(|s| s.addr.clone()).collect();

    let mut rcfg = ShardRouterConfig::new(addrs.clone(), 2);
    rcfg.heartbeat_ms = 100;
    rcfg.attempt_timeout_ms = 150;
    let router = ShardRouter::serve("127.0.0.1:0", rcfg).expect("router bind");
    let mut client = PipelinedClient::connect(router.addr()).expect("connect router");

    // Register a fleet of planted problems; the router allocates the ids
    // and replicates each matrix to both of its ring owners.
    let mut problems: Vec<(u64, Vec<f64>, Vec<f64>)> = Vec::new();
    for seed in 0..8u64 {
        let (a, x, b) = planted(200, 8, seed);
        let id = client.register_dense(&a).expect("register");
        problems.push((id, x, b));
    }
    for (id, x, b) in &problems {
        check(&solve_until_ok(&mut client, *id, b).x, x);
    }

    // The router's placement is a pure function of (addresses,
    // replication), so an identical local ShardMap tells the test which
    // shard is the primary for problem 0 — no API peeking needed.
    let map = ShardMap::new(addrs.clone(), 2);
    let (id0, x0, b0) = {
        let p = &problems[0];
        (p.0, p.1.clone(), p.2.clone())
    };
    let primary = map.primary(MatrixId(id0)).expect("primary owner");

    // Seeded network-fault drill: drop every OP_SOLVE frame the router
    // sends to the primary. The attempt timeout fires, same-shard retries
    // burn down, the request fails over to the replica and still
    // succeeds — all deterministic under the installed plan.
    {
        let _g = FaultGuard::install(FaultPlan::new().net_fault(
            &addrs[primary],
            Some(OP_SOLVE),
            0,
            u64::MAX,
            NetFaultAction::Drop,
        ));
        check(&solve_until_ok(&mut client, id0, &b0).x, &x0);
    }
    let m = client.metrics().expect("metrics");
    assert!(m.contains("router: shards=3 alive=3"), "{m}");
    assert!(counter(&m, "retries=") >= 1, "no same-shard retries recorded:\n{m}");
    assert!(counter(&m, "failovers=") >= 1, "no failover recorded:\n{m}");

    // Kill the primary mid-traffic: a pipelined burst is in flight when
    // the process dies. Every single request must still get a response —
    // a solution or the typed retryable error — never silence.
    let mut tickets = Vec::new();
    for _round in 0..4 {
        for (id, _x, b) in &problems {
            let t = client
                .submit_solve(*id, b, SolverChoice::Saa, 1e-10, 5_000_000)
                .expect("submit burst");
            tickets.push((*id, t));
        }
    }
    shards[primary].kill();
    let mut answered_ok = 0usize;
    let mut answered_retryable = 0usize;
    for (id, mut t) in tickets {
        match t.wait_timeout(Duration::from_secs(15)) {
            Some(Ok(sol)) => {
                let (_, x, _) = problems.iter().find(|p| p.0 == id).expect("known id");
                check(&sol.x, x);
                answered_ok += 1;
            }
            Some(Err(ClientError::Retryable(_))) => answered_retryable += 1,
            Some(Err(e)) => panic!("in-flight solve {id} failed fatally: {e}"),
            None => panic!("in-flight solve {id} lost during shard death"),
        }
    }
    assert_eq!(answered_ok + answered_retryable, 4 * problems.len());
    assert!(answered_ok >= 1, "burst produced no successful solves");

    // (b) Dead-primary matrices keep solving via their surviving replica.
    for (id, x, b) in &problems {
        check(&solve_until_ok(&mut client, *id, b).x, x);
    }
    let m = wait_for_metrics(&mut client, "death detection", |m| m.contains("alive=2"));
    assert!(counter(&m, "epoch=") >= 1, "death must bump the epoch:\n{m}");

    // (c) Restart the shard on its old address: the heartbeat marks it
    // alive, the rebalance path streams its matrices back from the
    // surviving replicas, and the whole fleet serves again.
    shards[primary] = ShardProc::spawn(&addrs[primary]);
    let m = wait_for_metrics(&mut client, "revival + rebalance", |m| {
        m.contains("alive=3") && counter(m, "rebalance_matrices=") >= 1
    });
    assert!(counter(&m, "epoch=") >= 2, "revival must bump the epoch again:\n{m}");
    for (id, x, b) in &problems {
        check(&solve_until_ok(&mut client, *id, b).x, x);
    }

    // Registration still works against the healed cluster.
    let (a, x, b) = planted(200, 8, 77);
    let id = client.register_dense(&a).expect("register after heal");
    check(&solve_until_ok(&mut client, id, &b).x, &x);

    router.stop();
}
