//! Parallel-execution invariants (the contract `src/parallel` promises):
//!
//! (a) the parallel GEMM / FWHT / sketch-apply paths match the serial
//!     (1-thread) results within 1e-12 at thread counts {1, 2, 4, 7}, and
//!     are deterministic run-to-run at a fixed thread count;
//! (b) the blocked multi-RHS paths (`apply_mat` on every sketch operator
//!     and on dense operators, `right_solve_upper_multi`,
//!     `solve_upper_block`, `q_transpose_mat`) are **bitwise identical**
//!     across thread counts — they shard rows over the pool and run the
//!     serial vector kernels per row, matching the guarantees PR 1
//!     established for the vector paths;
//! (c) every sketch operator preserves norms in expectation,
//!     `E[‖Sx‖²] ≈ ‖x‖²`, checked through the in-tree property harness.
//!
//! (d) at every SIMD backend the host supports — including avx512 where
//!     the host reports `avx512f`; elsewhere the forced choice degrades to
//!     scalar so the sweep skips it gracefully — the parallel kernels stay
//!     **bitwise identical** across thread counts (panel boundaries are
//!     MR-aligned per backend), SIMD-vs-scalar agreement is ≤ 1e-12
//!     relative, and the FWHT butterfly (adds/subs only) is bitwise
//!     identical to scalar on every backend.
//!
//! (e) the parallel `matvec`/`matvec_t` (row shards / aligned column
//!     stripes, PR 4) are **bitwise identical** to the serial chains at
//!     every thread count and on every backend.
//!
//! (f) the work-stealing scheduler (PR 6) is **bitwise identical** to the
//!     static range-sharded baseline on every kernel — GEMM, FWHT,
//!     matvec/matvec_t, every sketch apply, the blocked triangular solve
//!     and the LSQR block loop — at thread counts {1, 2, 4, 7}, both at
//!     the auto grain and under an adversarial grain-1 decomposition that
//!     maximizes stealing. Ordered reduction + alignment-quantized unit
//!     boundaries make the steal interleaving unobservable.
//!
//! The thread-count and SIMD-backend sweeps live in ONE test function: the
//! pool size and the kernel backend are process-wide settings, and keeping
//! the sweeps single-threaded at the test level makes the
//! `set_threads`/`set_choice` transitions race-free.

use snsolve::bench_harness::max_abs_dev;
use snsolve::linalg::qr::qr_compact;
use snsolve::linalg::sparse::CooBuilder;
use snsolve::linalg::triangular::{right_solve_upper_multi, solve_upper_block};
use snsolve::linalg::{gemm, hadamard, DenseMatrix, LinearOperator};
use snsolve::prop_assert;
use snsolve::rng::{GaussianSource, RngCore, Xoshiro256pp};
use snsolve::sketch::{self, SketchKind, SketchOperator};
use snsolve::testing::forall_cases;

/// Thread counts the acceptance criteria call out (7 is deliberately not a
/// divisor of anything).
const SWEEP: [usize; 4] = [1, 2, 4, 7];

/// Tolerance for parallel-vs-serial agreement.
const TOL: f64 = 1e-12;

/// Sizes must clear the kernels' serial-below-this floors
/// (`parallel::PAR_MIN_ELEMS`) or the sweep would never leave the serial
/// path. GEMM: m·k·n = 256·96·64 ≈ 1.6M; FWHT: 256·300 = 76.8k;
/// sketches: m·n = 4096·24 ≈ 98k element-ops.
#[test]
fn parallel_paths_match_serial_across_thread_counts() {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(7001));

    // --- GEMM -----------------------------------------------------------
    let (gm, gk, gn) = (256usize, 96usize, 64usize);
    let ga = DenseMatrix::gaussian(gm, gk, &mut g);
    let gb = DenseMatrix::gaussian(gk, gn, &mut g);

    // --- FWHT columns ---------------------------------------------------
    let (frows, fcols) = (256usize, 300usize);
    let fdata: Vec<f64> = g.gaussian_vec(frows * fcols);

    // --- parallel matvec fixtures (m·n above PAR_MIN_ELEMS so the row
    // shards / column stripes actually engage) --------------------------
    let (mvm, mvn) = (600usize, 130usize);
    let mva = DenseMatrix::gaussian(mvm, mvn, &mut g);
    let mvx = g.gaussian_vec(mvn);
    let mvu = g.gaussian_vec(mvm);

    // --- sketch inputs --------------------------------------------------
    let (sm, sn, ss) = (4096usize, 24usize, 96usize);
    let sa_dense = DenseMatrix::gaussian(sm, sn, &mut g);
    let sa_csr = {
        let mut rng = Xoshiro256pp::seed_from_u64(7002);
        let mut bld = CooBuilder::with_capacity(sm, sn, sm * 4);
        for i in 0..sm {
            for _ in 0..4 {
                bld.push(i, rng.next_bounded(sn as u64) as usize, g.next_gaussian());
            }
        }
        bld.build()
    };

    // --- blocked multi-RHS inputs (k×m row blocks, PR 2) ----------------
    // Sizes chosen to clear the kernels' serial floors so the sweep
    // actually exercises the sharded paths.
    let k_rhs = 16usize;
    let sketch_blk = DenseMatrix::gaussian(k_rhs, sm, &mut g); // k·m = 64k
    let x_blk = DenseMatrix::gaussian(k_rhs, gk, &mut g); // vs ga (gm×gk)
    let u_blk = DenseMatrix::gaussian(k_rhs, gm, &mut g);
    let rtri = {
        let n = 48usize;
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = g.next_gaussian();
            }
            let d = r[(i, i)];
            r[(i, i)] = d + if d >= 0.0 { 3.0 } else { -3.0 };
        }
        r
    };
    let a_rs = DenseMatrix::gaussian(1000, 48, &mut g); // right-solve input
    let z_blk = DenseMatrix::gaussian(64, 48, &mut g); // back-substitution
    let qrc = qr_compact(&DenseMatrix::gaussian(96, 24, &mut g)).unwrap();
    let c_blk = DenseMatrix::gaussian(32, 96, &mut g); // Qᵀ block input

    // Serial references at 1 thread.
    snsolve::parallel::set_threads(1);
    let gemm_ref = gemm::matmul(&ga, &gb).unwrap();
    let mv_ref = mva.matvec(&mvx);
    let mvt_ref = mva.matvec_t(&mvu);
    let fwht_ref = {
        let mut d = fdata.clone();
        hadamard::fwht_columns_inplace(&mut d, frows, fcols).unwrap();
        d
    };
    let sketch_ref: Vec<(SketchKind, DenseMatrix, DenseMatrix)> = SketchKind::ALL
        .iter()
        .map(|&kind| {
            let op = sketch::build(kind, ss, sm, 4242);
            (kind, op.apply_dense(&sa_dense), op.apply_csr(&sa_csr))
        })
        .collect();
    let sketch_mat_ref: Vec<(SketchKind, DenseMatrix)> = SketchKind::ALL
        .iter()
        .map(|&kind| (kind, sketch::build(kind, ss, sm, 4242).apply_mat(&sketch_blk)))
        .collect();
    let apply_mat_ref = {
        let mut y = DenseMatrix::zeros(k_rhs, gm);
        ga.apply_mat(&x_blk, &mut y);
        y
    };
    let apply_tmat_ref = {
        let mut v = DenseMatrix::zeros(k_rhs, gk);
        ga.apply_transpose_mat(&u_blk, &mut v);
        v
    };
    let rsm_ref = right_solve_upper_multi(&a_rs, &rtri).unwrap();
    let sub_ref = solve_upper_block(&rtri, &z_blk).unwrap();
    let qtm_ref = qrc.q_transpose_mat(&c_blk);

    for &t in &SWEEP {
        snsolve::parallel::set_threads(t);

        // GEMM: disjoint C panels — bitwise-stable, asserted at 1e-12.
        let c1 = gemm::matmul(&ga, &gb).unwrap();
        let c2 = gemm::matmul(&ga, &gb).unwrap();
        assert_eq!(c1, c2, "gemm not deterministic at {t} threads");
        let dev = max_abs_dev(c1.data(), gemm_ref.data());
        assert!(dev <= TOL, "gemm dev {dev} at {t} threads");

        // matvec (row shards) and matvec_t (aligned column stripes):
        // bitwise identical to the serial chains at every thread count.
        assert_eq!(mva.matvec(&mvx), mv_ref, "matvec differs at {t} threads");
        assert_eq!(mva.matvec_t(&mvu), mvt_ref, "matvec_t differs at {t} threads");

        // FWHT: disjoint column bands.
        let mut d1 = fdata.clone();
        hadamard::fwht_columns_inplace(&mut d1, frows, fcols).unwrap();
        let mut d2 = fdata.clone();
        hadamard::fwht_columns_inplace(&mut d2, frows, fcols).unwrap();
        assert_eq!(d1, d2, "fwht not deterministic at {t} threads");
        let dev = max_abs_dev(&d1, &fwht_ref);
        assert!(dev <= TOL, "fwht dev {dev} at {t} threads");

        // Every sketch operator, dense and CSR paths.
        for (kind, dense_ref, csr_ref) in &sketch_ref {
            let op = sketch::build(*kind, ss, sm, 4242);
            let b1 = op.apply_dense(&sa_dense);
            let b2 = op.apply_dense(&sa_dense);
            assert_eq!(b1, b2, "{}: apply_dense not deterministic at {t} threads", kind.name());
            let dev = max_abs_dev(b1.data(), dense_ref.data());
            assert!(dev <= TOL, "{}: apply_dense dev {dev} at {t} threads", kind.name());

            let c1 = op.apply_csr(&sa_csr);
            let dev = max_abs_dev(c1.data(), csr_ref.data());
            assert!(dev <= TOL, "{}: apply_csr dev {dev} at {t} threads", kind.name());
        }

        // Blocked multi-RHS paths: bitwise identical to the 1-thread
        // reference (rows run the serial vector kernels, so not even fp
        // re-association is allowed here).
        for (kind, mat_ref) in &sketch_mat_ref {
            let op = sketch::build(*kind, ss, sm, 4242);
            let m1 = op.apply_mat(&sketch_blk);
            assert_eq!(&m1, mat_ref, "{}: apply_mat differs at {t} threads", kind.name());
        }
        {
            let mut y = DenseMatrix::zeros(k_rhs, gm);
            ga.apply_mat(&x_blk, &mut y);
            assert_eq!(y, apply_mat_ref, "dense apply_mat differs at {t} threads");
            let mut v = DenseMatrix::zeros(k_rhs, gk);
            ga.apply_transpose_mat(&u_blk, &mut v);
            assert_eq!(v, apply_tmat_ref, "dense apply_transpose_mat differs at {t} threads");
        }
        assert_eq!(
            right_solve_upper_multi(&a_rs, &rtri).unwrap(),
            rsm_ref,
            "right_solve_upper_multi differs at {t} threads"
        );
        assert_eq!(
            solve_upper_block(&rtri, &z_blk).unwrap(),
            sub_ref,
            "solve_upper_block differs at {t} threads"
        );
        assert_eq!(
            qrc.q_transpose_mat(&c_blk),
            qtm_ref,
            "q_transpose_mat differs at {t} threads"
        );
    }

    // --- SIMD backend sweep (d) -----------------------------------------
    // Scalar references at 1 thread; the vectors reuse the GEMM/FWHT
    // fixtures above plus dot/axpy-shaped matvec inputs.
    let xv = g.gaussian_vec(gk);
    let uv = g.gaussian_vec(gm);
    snsolve::simd::set_choice(snsolve::simd::SimdChoice::Scalar);
    snsolve::parallel::set_threads(1);
    let gemm_scalar = gemm::matmul(&ga, &gb).unwrap();
    let gemm_scale = gemm_scalar.max_abs().max(1e-300);
    let fwht_scalar = {
        let mut d = fdata.clone();
        hadamard::fwht_columns_inplace(&mut d, frows, fcols).unwrap();
        d
    };
    let mv_scalar = ga.matvec(&xv);
    let mvt_scalar = ga.matvec_t(&uv);

    // The sweep covers every backend the host actually supports — on an
    // avx512f host `available()` includes the 8x8 zmm backend and the loop
    // below runs the full bitwise/1e-12 battery on it; elsewhere a forced
    // avx512 resolves to scalar (pinned by the simd unit tests), so the
    // entry is skipped gracefully rather than silently testing the wrong
    // kernels.
    for backend in snsolve::simd::available() {
        snsolve::simd::set_choice(backend.as_choice());
        assert_eq!(snsolve::simd::active(), backend, "backend failed to activate");
        let name = backend.name();

        // Within the backend: bitwise identical across the thread sweep.
        snsolve::parallel::set_threads(1);
        let c1 = gemm::matmul(&ga, &gb).unwrap();
        let f1 = {
            let mut d = fdata.clone();
            hadamard::fwht_columns_inplace(&mut d, frows, fcols).unwrap();
            d
        };
        let mv1 = mva.matvec(&mvx);
        let mvt1 = mva.matvec_t(&mvu);
        for &t in &SWEEP {
            snsolve::parallel::set_threads(t);
            let ct = gemm::matmul(&ga, &gb).unwrap();
            assert_eq!(ct, c1, "{name}: gemm not bitwise across threads at {t}");
            let mut dt = fdata.clone();
            hadamard::fwht_columns_inplace(&mut dt, frows, fcols).unwrap();
            assert_eq!(dt, f1, "{name}: fwht not bitwise across threads at {t}");
            assert_eq!(mva.matvec(&mvx), mv1, "{name}: matvec not bitwise at {t}");
            assert_eq!(mva.matvec_t(&mvu), mvt1, "{name}: matvec_t not bitwise at {t}");
        }
        snsolve::parallel::set_threads(1);

        // Across backends: ≤ 1e-12 relative vs the scalar reference.
        let dev = max_abs_dev(c1.data(), gemm_scalar.data()) / gemm_scale;
        assert!(dev <= TOL, "{name}: gemm vs scalar rel dev {dev}");
        let mv = ga.matvec(&xv);
        let dev = max_abs_dev(&mv, &mv_scalar);
        assert!(dev <= TOL, "{name}: matvec vs scalar dev {dev}");
        let mvt = ga.matvec_t(&uv);
        let dev = max_abs_dev(&mvt, &mvt_scalar);
        assert!(dev <= TOL, "{name}: matvec_t vs scalar dev {dev}");

        // The FWHT butterfly is adds/subs only — bitwise on every backend.
        assert_eq!(f1, fwht_scalar, "{name}: fwht not bitwise vs scalar");

        // Blocked multi-RHS stays bitwise-per-row under this backend too.
        let mut y = DenseMatrix::zeros(k_rhs, gm);
        ga.apply_mat(&x_blk, &mut y);
        let mut v_out = DenseMatrix::zeros(k_rhs, gk);
        ga.apply_transpose_mat(&u_blk, &mut v_out);
        for r in 0..k_rhs {
            assert_eq!(y.row(r), &ga.apply_vec(x_blk.row(r))[..], "{name}: apply row {r}");
            assert_eq!(
                v_out.row(r),
                &ga.apply_transpose_vec(u_blk.row(r))[..],
                "{name}: transpose row {r}"
            );
        }
    }

    // --- scheduler sweep (f) --------------------------------------------
    // The work-stealing pool must be bitwise identical to the static
    // range-sharded baseline on every kernel, at every thread count, and
    // under an adversarial steal-heavy decomposition (grain 1: every unit
    // is one alignment quantum, so almost everything a worker runs beyond
    // its first unit was stolen or contended). The LSQR block loop rides
    // along because its per-column recurrences shard over the same pool.
    snsolve::simd::clear_choice();
    let lsqr_a = DenseMatrix::gaussian(900, 40, &mut g);
    let lsqr_b = {
        let mut rhs = DenseMatrix::zeros(12, 900);
        for r in 0..12 {
            let xs = g.gaussian_vec(40);
            rhs.row_mut(r).copy_from_slice(&lsqr_a.matvec(&xs));
        }
        rhs
    };
    let lsqr_cfg = snsolve::solvers::lsqr::LsqrConfig {
        atol: 1e-10,
        btol: 1e-10,
        ..Default::default()
    };
    // Static references at each thread count (grain irrelevant: the static
    // schedule never splits below one range per worker).
    snsolve::parallel::set_schedule(Some(snsolve::parallel::Schedule::Static));
    let static_ref: Vec<_> = SWEEP
        .iter()
        .map(|&t| {
            snsolve::parallel::set_threads(t);
            let gemm_s = gemm::matmul(&ga, &gb).unwrap();
            let mut fwht_s = fdata.clone();
            hadamard::fwht_columns_inplace(&mut fwht_s, frows, fcols).unwrap();
            let mv_s = mva.matvec(&mvx);
            let mvt_s = mva.matvec_t(&mvu);
            let sketches: Vec<DenseMatrix> = SketchKind::ALL
                .iter()
                .map(|&kind| sketch::build(kind, ss, sm, 4242).apply_dense(&sa_dense))
                .collect();
            let rsm_s = right_solve_upper_multi(&a_rs, &rtri).unwrap();
            let lsqr_s = snsolve::solvers::lsqr::lsqr_block(&lsqr_a, &lsqr_b, None, &lsqr_cfg);
            (gemm_s, fwht_s, mv_s, mvt_s, sketches, rsm_s, lsqr_s)
        })
        .collect();
    // All static schedules agree with each other (and with the pre-refactor
    // 1-thread references asserted bitwise above).
    for (i, &t) in SWEEP.iter().enumerate() {
        assert_eq!(static_ref[i].0, static_ref[0].0, "static gemm differs at {t} threads");
        assert_eq!(static_ref[i].6.len(), static_ref[0].6.len());
    }
    snsolve::parallel::set_schedule(Some(snsolve::parallel::Schedule::Steal));
    for grain in [None, Some(1)] {
        snsolve::parallel::set_steal_grain(grain);
        for (i, &t) in SWEEP.iter().enumerate() {
            snsolve::parallel::set_threads(t);
            let label = if grain.is_some() { "steal/adversarial" } else { "steal/auto" };
            let (gemm_s, fwht_s, mv_s, mvt_s, sketches, rsm_s, lsqr_s) = &static_ref[i];
            assert_eq!(
                &gemm::matmul(&ga, &gb).unwrap(),
                gemm_s,
                "{label}: gemm != static at {t} threads"
            );
            let mut d = fdata.clone();
            hadamard::fwht_columns_inplace(&mut d, frows, fcols).unwrap();
            assert_eq!(&d, fwht_s, "{label}: fwht != static at {t} threads");
            assert_eq!(&mva.matvec(&mvx), mv_s, "{label}: matvec != static at {t} threads");
            assert_eq!(&mva.matvec_t(&mvu), mvt_s, "{label}: matvec_t != static at {t} threads");
            for (kind, sref) in SketchKind::ALL.iter().zip(sketches.iter()) {
                assert_eq!(
                    &sketch::build(*kind, ss, sm, 4242).apply_dense(&sa_dense),
                    sref,
                    "{label}: {} != static at {t} threads",
                    kind.name()
                );
            }
            assert_eq!(
                &right_solve_upper_multi(&a_rs, &rtri).unwrap(),
                rsm_s,
                "{label}: right_solve_upper_multi != static at {t} threads"
            );
            let lsqr_t = snsolve::solvers::lsqr::lsqr_block(&lsqr_a, &lsqr_b, None, &lsqr_cfg);
            assert_eq!(lsqr_t.len(), lsqr_s.len());
            for (r, (got, want)) in lsqr_t.iter().zip(lsqr_s.iter()).enumerate() {
                assert_eq!(got.x, want.x, "{label}: lsqr_block x[{r}] != static at {t} threads");
                assert_eq!(
                    got.itn, want.itn,
                    "{label}: lsqr_block itn[{r}] != static at {t} threads"
                );
            }
            // Steal executions actually happened under the adversarial
            // decomposition at multi-thread counts (the property above is
            // vacuous if everything ran serially).
            if grain.is_some() && t >= 4 {
                let stats = snsolve::parallel::pool_stats();
                assert!(
                    stats.executed > 0 && stats.max_depth > 1,
                    "adversarial sweep never queued multiple units per worker"
                );
            }
        }
    }
    snsolve::parallel::set_steal_grain(None);

    // Restore the ambient (auto) configuration for other tests.
    snsolve::parallel::set_threads(0);
    snsolve::parallel::set_schedule(None);
    snsolve::simd::clear_choice();
}

/// (c) `E[‖Sx‖²] ≈ ‖x‖²` for every operator family — the approximate
/// isometry the solvers rely on, via the in-tree property harness.
#[test]
fn sketch_operators_preserve_norms_in_expectation() {
    forall_cases("expected_isometry_all_operators", 3, |rng| {
        let (s, m) = (32usize, 128usize);
        let mut x = rng.gaussian_vec(m);
        snsolve::linalg::norms::normalize(&mut x);
        for kind in SketchKind::ALL {
            let trials = 150u64;
            let mut acc = 0.0;
            for t in 0..trials {
                let op = sketch::build(kind, s, m, rng.case_seed ^ (t.wrapping_mul(7919)));
                let sx = op.apply_vec(&x);
                acc += sx.iter().map(|v| v * v).sum::<f64>();
            }
            let mean = acc / trials as f64;
            prop_assert!(
                (mean - 1.0).abs() < 0.15,
                "{}: E[||Sx||^2] = {mean} (expected ~1)",
                kind.name()
            );
        }
        Ok(())
    });
}
