//! Property-based tests on coordinator invariants: routing determinism,
//! batching bounds, queue FIFO/backpressure, histogram sanity.

use std::time::{Duration, Instant};

use snsolve::coordinator::batcher::{Batch, BatchKey, Batcher, BatcherConfig};
use snsolve::coordinator::metrics::LatencyHistogram;
use snsolve::coordinator::queue::{BoundedQueue, PopError};
use snsolve::coordinator::registry::MatrixId;
use snsolve::coordinator::router::{Route, Router, RouterConfig};
use snsolve::coordinator::SolverChoice;
use snsolve::linalg::{DenseMatrix, Matrix};
use snsolve::runtime::Manifest;
use snsolve::testing::{forall, forall_cases};

fn manifest() -> Manifest {
    let json = r#"{"version":1,"artifacts":[
      {"name":"saa_solve_64x8","entry":"saa_solve","file":"f","m":64,"n":8,
       "s":32,"iters":8,"inputs":[],"outputs":[]},
      {"name":"saa_solve_128x16","entry":"saa_solve","file":"f","m":128,"n":16,
       "s":64,"iters":8,"inputs":[],"outputs":[]}
    ]}"#;
    Manifest::parse(std::path::Path::new("."), json).unwrap()
}

#[test]
fn prop_router_deterministic_and_bucket_exact() {
    let m = manifest();
    let router = Router::new(Some(&m), RouterConfig::default());
    forall("router_determinism", |rng| {
        let rows = rng.usize_in(8, 256);
        let cols = rng.usize_in(1, rows.min(32));
        let a = Matrix::Dense(DenseMatrix::zeros(rows, cols));
        let solver = *rng.choose(&[
            SolverChoice::Saa,
            SolverChoice::Lsqr,
            SolverChoice::SketchOnly,
        ]);
        let tol = 10f64.powf(-(rng.usize_in(1, 12) as f64));
        let r1 = router.route(&a, solver, tol);
        let r2 = router.route(&a, solver, tol);
        if r1 != r2 {
            return Err("routing not deterministic".to_string());
        }
        match &r1 {
            Route::Artifact(name) => {
                // Artifact routes only for exact buckets and loose tol.
                let is_bucket = (rows, cols) == (64, 8) || (rows, cols) == (128, 16);
                if !is_bucket {
                    return Err(format!("non-bucket shape routed to {name}"));
                }
                if tol < 1e-3 {
                    return Err("tight tolerance must go native".to_string());
                }
                if !name.contains(&format!("{rows}x{cols}")) {
                    return Err(format!("artifact {name} doesn't match {rows}x{cols}"));
                }
            }
            Route::Native => {}
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_never_exceeds_max_and_loses_nothing() {
    forall_cases("batcher_bounds", 30, |rng| {
        let max_batch = rng.usize_in(1, 10);
        let cfg = BatcherConfig { max_batch, max_wait: Duration::from_secs(100) };
        let mut b: Batcher<u64> = Batcher::new(cfg);
        let n_items = rng.usize_in(1, 200);
        let n_keys = rng.usize_in(1, 5) as u64;
        let now = Instant::now();
        let mut emitted: Vec<Batch<u64>> = Vec::new();
        for i in 0..n_items {
            let key = BatchKey {
                matrix: MatrixId(rng.usize_in(0, n_keys as usize - 1) as u64),
                solver: SolverChoice::Saa,
            };
            if let Some(full) = b.offer(key, i as u64, now) {
                emitted.push(full);
            }
        }
        emitted.extend(b.flush_all());
        let mut all: Vec<u64> = emitted
            .iter()
            .flat_map(|batch| batch.items.iter().copied())
            .collect();
        for batch in &emitted {
            if batch.items.len() > max_batch {
                return Err(format!(
                    "batch size {} exceeds max {max_batch}",
                    batch.items.len()
                ));
            }
            // all items in a batch share the key by construction; verify
            // per-batch item uniqueness instead (no duplication).
        }
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_items as u64).collect();
        if all != expect {
            return Err(format!("lost/duplicated items: {} of {}", all.len(), n_items));
        }
        Ok(())
    });
}

#[test]
fn prop_queue_fifo_under_interleaving() {
    forall_cases("queue_fifo", 20, |rng| {
        let cap = rng.usize_in(1, 16);
        let q: BoundedQueue<u32> = BoundedQueue::new(cap);
        let mut next_push = 0u32;
        let mut next_pop = 0u32;
        for _ in 0..rng.usize_in(10, 100) {
            if rng.usize_in(0, 1) == 0 {
                if q.try_push(next_push).is_ok() {
                    next_push += 1;
                }
            } else if let Ok(v) = q.pop_timeout(Duration::from_millis(1)) {
                if v != next_pop {
                    return Err(format!("FIFO violated: got {v}, want {next_pop}"));
                }
                next_pop += 1;
            }
            if q.len() > cap {
                return Err("capacity exceeded".to_string());
            }
        }
        // Drain and re-check order.
        while let Ok(v) = q.pop_timeout(Duration::from_millis(1)) {
            if v != next_pop {
                return Err(format!("FIFO violated on drain: {v} vs {next_pop}"));
            }
            next_pop += 1;
        }
        if next_pop != next_push {
            return Err("items lost".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_percentiles_monotone_and_bounding() {
    forall_cases("histogram_props", 25, |rng| {
        let h = LatencyHistogram::new();
        let n = rng.usize_in(1, 500);
        let mut max_val = 0u64;
        for _ in 0..n {
            let v = rng.usize_in(1, 1_000_000) as u64;
            max_val = max_val.max(v);
            h.record(v);
        }
        if h.count() != n as u64 {
            return Err("count mismatch".to_string());
        }
        let p50 = h.percentile_us(0.5);
        let p90 = h.percentile_us(0.9);
        let p99 = h.percentile_us(0.99);
        if !(p50 <= p90 && p90 <= p99) {
            return Err(format!("percentiles not monotone: {p50} {p90} {p99}"));
        }
        // log2 bucketing over-estimates by ≤2×.
        if p99 > max_val.next_power_of_two() * 2 {
            return Err(format!("p99 {p99} way above max {max_val}"));
        }
        Ok(())
    });
}

#[test]
fn queue_closed_drains_then_stops() {
    let q: BoundedQueue<u8> = BoundedQueue::new(4);
    q.try_push(1).unwrap();
    q.try_push(2).unwrap();
    q.close();
    assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), 1);
    assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), 2);
    assert_eq!(q.pop_timeout(Duration::from_millis(1)), Err(PopError::Closed));
}
