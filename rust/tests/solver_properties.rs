//! Property-based tests over the solver stack (via the in-crate `testing`
//! mini-framework — proptest is unavailable offline).

use snsolve::linalg::norms::{nrm2, nrm2_diff};
use snsolve::linalg::qr::qr_compact;
use snsolve::linalg::{triangular, DenseMatrix, Matrix};
use snsolve::problems::{generate_dense, DenseProblemSpec};
use snsolve::sketch::{self, SketchKind, SketchOperator};
use snsolve::solvers::direct::DirectQr;
use snsolve::solvers::lsqr::{lsqr, LsqrConfig};
use snsolve::solvers::saa::{SaaConfig, SaaSolver};
use snsolve::solvers::Solver;
use snsolve::testing::{forall, forall_cases};

#[test]
fn prop_qr_reconstructs_and_orthonormal() {
    forall("qr_invariants", |rng| {
        let n = rng.usize_in(2, 24);
        let s = n + rng.usize_in(1, 40);
        let data = rng.gaussian_vec(s * n);
        let a = DenseMatrix::from_vec(s, n, data).unwrap();
        let f = qr_compact(&a).map_err(|e| e.to_string())?;
        let q = f.q();
        let r = f.r();
        let qr = q.matmul(&r).unwrap();
        let rel = qr.fro_distance(&a) / a.fro_norm().max(1e-300);
        if rel > 1e-11 {
            return Err(format!("QR != A: rel {rel} (s={s}, n={n})"));
        }
        let qtq = q.transpose().matmul(&q).unwrap();
        let dist = qtq.fro_distance(&DenseMatrix::eye(n));
        if dist > 1e-11 * n as f64 {
            return Err(format!("QtQ != I: {dist}"));
        }
        Ok(())
    });
}

#[test]
fn prop_triangular_solve_inverts() {
    forall("triangular_roundtrip", |rng| {
        let n = rng.usize_in(1, 32);
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = rng.gaussian();
            }
            r[(i, i)] += 2.0 * r[(i, i)].signum();
            if r[(i, i)] == 0.0 {
                r[(i, i)] = 2.0;
            }
        }
        let x_true = rng.gaussian_vec(n);
        let b = r.matvec(&x_true);
        let x = triangular::solve_upper(&r, &b).map_err(|e| e.to_string())?;
        let err = nrm2_diff(&x, &x_true) / nrm2(&x_true).max(1e-300);
        if err > 1e-8 {
            return Err(format!("solve_upper err {err} (n={n})"));
        }
        Ok(())
    });
}

#[test]
fn prop_lsqr_matches_direct_on_wellconditioned() {
    forall_cases("lsqr_vs_direct", 20, |rng| {
        let n = rng.usize_in(2, 16);
        let m = n + rng.usize_in(8, 120);
        let a = DenseMatrix::from_vec(m, n, rng.gaussian_vec(m * n)).unwrap();
        let b = rng.gaussian_vec(m);
        let am = Matrix::Dense(a);
        let direct = DirectQr.solve(&am, &b).map_err(|e| e.to_string())?;
        let cfg = LsqrConfig { atol: 1e-13, btol: 1e-13, conlim: 0.0, ..Default::default() };
        let res = lsqr(am.as_operator(), &b, None, &cfg);
        let err = nrm2_diff(&res.x, &direct.x) / nrm2(&direct.x).max(1e-300);
        if err > 1e-7 {
            return Err(format!("lsqr vs direct err {err} (m={m}, n={n})"));
        }
        Ok(())
    });
}

#[test]
fn prop_saa_matches_direct_all_operators() {
    forall_cases("saa_vs_direct_operators", 18, |rng| {
        let n = rng.usize_in(4, 20);
        let m = 8 * n + rng.usize_in(0, 200);
        let a = DenseMatrix::from_vec(m, n, rng.gaussian_vec(m * n)).unwrap();
        let b = rng.gaussian_vec(m);
        let am = Matrix::Dense(a);
        let kind = *rng.choose(&SketchKind::ALL);
        let direct = DirectQr.solve(&am, &b).map_err(|e| e.to_string())?;
        let saa = SaaSolver::new(SaaConfig {
            sketch: kind,
            seed: rng.case_seed,
            ..Default::default()
        });
        let sol = saa.solve(&am, &b).map_err(|e| e.to_string())?;
        let err = nrm2_diff(&sol.x, &direct.x) / nrm2(&direct.x).max(1e-300);
        if err > 1e-6 {
            return Err(format!(
                "saa({}) vs direct err {err} (m={m}, n={n})",
                kind.name()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_sketch_embedding_preserves_residual_ordering() {
    // If ‖Ax₁−b‖ ≪ ‖Ax₂−b‖ then the sketched residuals keep the order —
    // the property sketch-and-solve correctness rests on.
    forall_cases("sketch_preserves_order", 20, |rng| {
        let n = rng.usize_in(3, 12);
        let m = 40 * n;
        let s = 8 * n;
        let a = DenseMatrix::from_vec(m, n, rng.gaussian_vec(m * n)).unwrap();
        let x_good = rng.gaussian_vec(n);
        let b = a.matvec(&x_good); // residual 0 at x_good
        let mut x_bad = x_good.clone();
        for v in x_bad.iter_mut() {
            *v += rng.gaussian();
        }
        let kind = *rng.choose(&SketchKind::ALL);
        let op = sketch::build(kind, s, m, rng.case_seed);
        let resid = |x: &[f64]| {
            let ax = a.matvec(x);
            let r: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
            op.apply_vec(&r).iter().map(|v| v * v).sum::<f64>().sqrt()
        };
        let r_good = resid(&x_good);
        let r_bad = resid(&x_bad);
        if r_good > r_bad * 0.5 {
            return Err(format!(
                "{}: sketched residual ordering broken: good {r_good} vs bad {r_bad}",
                kind.name()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_generated_problems_have_planted_minimizer() {
    forall_cases("generator_plants_minimizer", 15, |rng| {
        let n = rng.usize_in(4, 24);
        let m = 10 * n + rng.usize_in(0, 100);
        let cond = 10f64.powi(rng.usize_in(0, 8) as i32);
        let beta = 10f64.powf(-(rng.usize_in(2, 10) as f64));
        let p = generate_dense(&DenseProblemSpec {
            m,
            n,
            cond,
            resid_norm: beta,
            seed: rng.case_seed,
        });
        // Perturbing x* in any direction must not reduce the residual.
        let base = p.residual_norm(&p.x_true);
        for _ in 0..3 {
            let mut xp = p.x_true.clone();
            let dir = rng.gaussian_vec(n);
            for (v, d) in xp.iter_mut().zip(dir.iter()) {
                *v += 1e-3 * d;
            }
            let perturbed = p.residual_norm(&xp);
            if perturbed + 1e-12 < base {
                return Err(format!(
                    "x* not a minimizer: base {base}, perturbed {perturbed} (cond {cond})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_saa_deterministic_in_seed() {
    forall_cases("saa_deterministic", 10, |rng| {
        let n = rng.usize_in(4, 12);
        let m = 20 * n;
        let a = DenseMatrix::from_vec(m, n, rng.gaussian_vec(m * n)).unwrap();
        let b = rng.gaussian_vec(m);
        let am = Matrix::Dense(a);
        let cfg = SaaConfig { seed: rng.case_seed, ..Default::default() };
        let s1 = SaaSolver::new(cfg.clone()).solve(&am, &b).map_err(|e| e.to_string())?;
        let s2 = SaaSolver::new(cfg).solve(&am, &b).map_err(|e| e.to_string())?;
        if s1.x != s2.x {
            return Err("same seed produced different solutions".to_string());
        }
        Ok(())
    });
}
