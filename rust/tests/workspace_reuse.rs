//! Workspace-reuse equivalence (PR 5): every `_ws` entry point — the
//! sketch operators' `apply_dense_ws`/`apply_csr_ws`/`apply_mat_ws` and
//! the solvers' `lsqr_ws`/`lsqr_block_ws` — must be **bitwise identical**
//! to its fresh-allocation twin, across repeated applies through ONE
//! reused workspace (recycled buffers are re-zeroed by the pool, so reuse
//! can never leak state between requests). This is the guarantee that
//! makes the worker's zero-allocation steady-state serving loop safe.
//!
//! This file deliberately touches no process-global knobs (threads, SIMD
//! backend, radix, scatter layout), so its bitwise assertions cannot race
//! another test's sweep — globals-flipping sweeps live in
//! `tests/sketch_engine_equivalence.rs` and `tests/parallel_determinism.rs`.

use snsolve::linalg::sparse::CooBuilder;
use snsolve::linalg::DenseMatrix;
use snsolve::rng::{GaussianSource, RngCore, Xoshiro256pp};
use snsolve::sketch::{self, SketchKind, SketchOperator, SketchWorkspace};
use snsolve::solvers::lsqr::{lsqr, lsqr_block, lsqr_block_ws, lsqr_ws, LsqrConfig, SolveWorkspace};

#[test]
fn sketch_workspace_reuse_bitwise_identical() {
    let (s, m, n, k) = (64usize, 600usize, 9usize, 6usize);
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(1201));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let blk = DenseMatrix::gaussian(k, m, &mut g);
    let sp = {
        let mut rng = Xoshiro256pp::seed_from_u64(1202);
        let mut bld = CooBuilder::with_capacity(m, n, m * 3);
        for i in 0..m {
            for _ in 0..3 {
                bld.push(i, rng.next_bounded(n as u64) as usize, g.next_gaussian());
            }
        }
        bld.build()
    };
    // ONE workspace shared by every operator and every repeat — buffer
    // sizes differ per operator, so the pool's recycle/re-zero logic is
    // genuinely exercised.
    let mut ws = SketchWorkspace::new();
    for kind in SketchKind::ALL {
        let op = sketch::build(kind, s, m, 3131);
        let d_ref = op.apply_dense(&a);
        let c_ref = op.apply_csr(&sp);
        let m_ref = op.apply_mat(&blk);
        for trial in 0..3 {
            assert_eq!(
                op.apply_dense_ws(&a, &mut ws),
                d_ref,
                "{} dense trial {trial}",
                kind.name()
            );
            assert_eq!(op.apply_csr_ws(&sp, &mut ws), c_ref, "{} csr trial {trial}", kind.name());
            assert_eq!(op.apply_mat_ws(&blk, &mut ws), m_ref, "{} mat trial {trial}", kind.name());
        }
    }
}

#[test]
fn solve_workspace_reuse_bitwise_identical() {
    let (m, n) = (160usize, 24usize);
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(1203));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let x_true = g.gaussian_vec(n);
    let b = a.matvec(&x_true);
    let mut noisy = b.clone();
    for bi in noisy.iter_mut() {
        *bi += 0.4 * g.next_gaussian();
    }
    let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, track_history: true, ..Default::default() };

    let fresh_b = lsqr(&a, &b, None, &cfg);
    let fresh_noisy = lsqr(&a, &noisy, Some(&x_true), &cfg);
    let mut ws = SolveWorkspace::new();
    // Alternating problems through one workspace: consistent, then noisy
    // warm-started, repeatedly — every result must match fresh allocation
    // bitwise (x, stop reason, iteration count, residual history).
    for trial in 0..3 {
        let r1 = lsqr_ws(&a, &b, None, &cfg, &mut ws);
        assert_eq!(r1.x, fresh_b.x, "trial {trial}");
        assert_eq!(r1.itn, fresh_b.itn, "trial {trial}");
        assert_eq!(r1.istop, fresh_b.istop, "trial {trial}");
        assert_eq!(r1.history, fresh_b.history, "trial {trial}");
        let r2 = lsqr_ws(&a, &noisy, Some(&x_true), &cfg, &mut ws);
        assert_eq!(r2.x, fresh_noisy.x, "trial {trial}");
        assert_eq!(r2.itn, fresh_noisy.itn, "trial {trial}");
    }

    // Blocked path: mixed batch (consistent + noisy + zero RHS) with warm
    // starts, through the same (already warm) workspace.
    let mut rhs = DenseMatrix::zeros(3, m);
    rhs.row_mut(0).copy_from_slice(&b);
    rhs.row_mut(1).copy_from_slice(&noisy);
    let mut x0 = DenseMatrix::zeros(3, n);
    x0.row_mut(1).copy_from_slice(&x_true);
    let fresh_blk = lsqr_block(&a, &rhs, Some(&x0), &cfg);
    for trial in 0..3 {
        let blk = lsqr_block_ws(&a, &rhs, Some(&x0), &cfg, &mut ws);
        assert_eq!(blk.len(), fresh_blk.len());
        for (col, (rb, rf)) in blk.iter().zip(fresh_blk.iter()).enumerate() {
            assert_eq!(rb.x, rf.x, "trial {trial} col {col}");
            assert_eq!(rb.itn, rf.itn, "trial {trial} col {col}");
            assert_eq!(rb.istop, rf.istop, "trial {trial} col {col}");
            assert_eq!(rb.history, rf.history, "trial {trial} col {col}");
        }
    }

    // And the solo path again after blocked solves resized the pool.
    let r = lsqr_ws(&a, &b, None, &cfg, &mut ws);
    assert_eq!(r.x, fresh_b.x);
}
