//! `snsolve-lint` — dependency-free static analysis for the snsolve tree.
//!
//! The crate grew a large hand-written unsafe/concurrency surface (three
//! SIMD intrinsic backends, a CAS-packed work-stealing deque, `SendPtr`
//! output sharding, raw `poll(2)` FFI) plus nine `SNSOLVE_*` knobs that
//! must stay coherent across env var, `--flag`, config key and
//! `SolveConfig` field. Nothing machine-checked those invariants; this
//! tool does, with a small hand-rolled lexer (strings, raw strings,
//! nested block comments — no `syn`, std only per the repo's no-deps
//! rule) and five rules:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `unsafe-needs-safety` | every `unsafe` occurrence is immediately preceded by a `// SAFETY:` comment (or a `# Safety` doc section) |
//! | `intrinsics-behind-dispatch` | `core::arch` / `#[target_feature]` only under `src/simd/`, so illegal instructions can't bypass runtime dispatch |
//! | `determinism-hazards` | no `HashMap`/`HashSet`/`Instant`/`SystemTime`/thread-id logic in kernel paths; `thread::spawn` confined to `parallel/` + `coordinator/` |
//! | `knob-coherence` | every `SNSOLVE_*` knob is fully wired (env read + CLI flag + config key + config field) or exempted with a rationale |
//! | `env-reads-behind-config` | `env::var` only in `config/` or at designated (annotated) knob-resolution sites |
//!
//! Any finding is suppressible at its site with
//! `// snsolve-lint: allow(<rule>) — <rationale>` on the same line or in
//! the contiguous comment/attribute block directly above it.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Rule names with one-line descriptions (for `--list-rules`).
pub const RULES: &[(&str, &str)] = &[
    (
        "unsafe-needs-safety",
        "every `unsafe` block/fn/impl must be immediately preceded by a `// SAFETY:` comment",
    ),
    (
        "intrinsics-behind-dispatch",
        "core::arch intrinsics and #[target_feature] are allowed only under src/simd/",
    ),
    (
        "determinism-hazards",
        "HashMap/HashSet/Instant/SystemTime/thread-id logic forbidden in kernel paths; \
         thread::spawn confined to parallel/ and coordinator/",
    ),
    (
        "knob-coherence",
        "every SNSOLVE_* env knob must be fully wired: env read + --flag + config key + \
         SolveConfig field (or exempted with a rationale)",
    ),
    (
        "env-reads-behind-config",
        "env::var only in config/ or at annotated knob-resolution sites",
    ),
];

/// One lint finding, anchored to a file and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: PathBuf,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.message)
    }
}

/// Per-line view of a lexed source file: `code` is the line with comments
/// removed and string/char literal contents blanked (delimiters kept);
/// `comment` concatenates the comment text appearing on the line.
#[derive(Debug, Default, Clone)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

/// A lexed source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub lines: Vec<Line>,
    /// `(0-based start line, content)` of every string literal
    /// (plain, byte, raw and raw-byte forms).
    pub strings: Vec<(usize, String)>,
}

/// A scanned source file: path relative to the scan root (with `/`
/// separators, used for path-scoped rules) plus the lexed view.
#[derive(Debug)]
pub struct Source {
    pub rel: String,
    pub path: PathBuf,
    pub lx: Lexed,
}

#[derive(Clone, Copy)]
enum Mode {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Tokenize Rust source into per-line code/comment views. Handles line
/// and (nested) block comments, plain/byte strings with escapes, raw
/// strings with any `#` count, and char literals vs lifetimes.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines: Vec<Line> = vec![Line::default()];
    let mut strings: Vec<(usize, String)> = Vec::new();
    let mut mode = Mode::Code;
    let mut cur = 0usize;
    let mut sbuf = String::new();
    let mut sline = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(Line::default());
            cur += 1;
            match mode {
                Mode::LineComment => mode = Mode::Code,
                Mode::Str | Mode::RawStr(_) => sbuf.push('\n'),
                _ => {}
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    lines[cur].code.push('"');
                    sbuf.clear();
                    sline = cur;
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b' || c == 'c') && !prev_is_ident(&chars, i) {
                    // Possible raw/byte/C-string prefix: r", r#", b", br#", c", cr#".
                    let mut j = i + 1;
                    let mut raw = c == 'r';
                    if !raw && j < n && chars[j] == 'r' {
                        raw = true;
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while raw && j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' && (raw || j == i + 1) {
                        for k in i..j {
                            lines[cur].code.push(chars[k]);
                        }
                        lines[cur].code.push('"');
                        sbuf.clear();
                        sline = cur;
                        mode = if raw { Mode::RawStr(hashes) } else { Mode::Str };
                        i = j + 1;
                    } else {
                        lines[cur].code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime/loop label.
                    if i + 1 < n && chars[i + 1] == '\\' {
                        // Escaped char literal: skip quote, backslash and the
                        // escape head, then scan to the closing quote (the
                        // head skip makes '\'' terminate correctly).
                        let mut j = i + 3;
                        while j < n && chars[j] != '\'' {
                            j += 1;
                        }
                        lines[cur].code.push_str("''");
                        i = (j + 1).min(n);
                    } else if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                        lines[cur].code.push_str("''");
                        i += 3;
                    } else {
                        lines[cur].code.push('\'');
                        i += 1;
                    }
                } else {
                    lines[cur].code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                lines[cur].comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    lines[cur].comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    sbuf.push(c);
                    if i + 1 < n {
                        sbuf.push(chars[i + 1]);
                    }
                    i += 2;
                } else if c == '"' {
                    lines[cur].code.push('"');
                    strings.push((sline, std::mem::take(&mut sbuf)));
                    mode = Mode::Code;
                    i += 1;
                } else {
                    sbuf.push(c);
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut k = 0u32;
                    let mut j = i + 1;
                    while k < hashes && j < n && chars[j] == '#' {
                        k += 1;
                        j += 1;
                    }
                    if k == hashes {
                        lines[cur].code.push('"');
                        strings.push((sline, std::mem::take(&mut sbuf)));
                        mode = Mode::Code;
                        i = j;
                    } else {
                        sbuf.push(c);
                        i += 1;
                    }
                } else {
                    sbuf.push(c);
                    i += 1;
                }
            }
        }
    }
    Lexed { lines, strings }
}

/// Whole-word substring search (identifier boundaries on both sides).
pub fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(p) = code[start..].find(word) {
        let at = start + p;
        let end = at + word.len();
        let before_ok =
            at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        let after_ok =
            end >= bytes.len() || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_');
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Walk back from `idx` through the contiguous comment/attribute block
/// directly above it (a fully blank line or a code line ends the block),
/// returning true if any comment in the block — or on `idx` itself —
/// contains `needle`.
fn comment_block_contains(lx: &Lexed, idx: usize, needles: &[&str]) -> bool {
    let hit = |c: &str| needles.iter().any(|n| c.contains(n));
    if hit(&lx.lines[idx].comment) {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let l = &lx.lines[k];
        let code = l.code.trim();
        let is_attr = code.starts_with("#[") || code.starts_with("#![");
        if !code.is_empty() && !is_attr {
            return false;
        }
        if code.is_empty() && l.comment.is_empty() {
            return false;
        }
        if hit(&l.comment) {
            return true;
        }
    }
    false
}

/// Is a finding of `rule` at (0-based) `idx` suppressed by a
/// `snsolve-lint: allow(<rule>)` comment on the line or directly above it?
pub fn suppressed(lx: &Lexed, idx: usize, rule: &str) -> bool {
    let needle = format!("snsolve-lint: allow({rule})");
    comment_block_contains(lx, idx, &[needle.as_str()])
}

/// Is the `unsafe` at (0-based) `idx` covered by a `SAFETY:` comment (or
/// a `# Safety` doc section) directly above or on the line?
pub fn safety_documented(lx: &Lexed, idx: usize) -> bool {
    comment_block_contains(lx, idx, &["SAFETY:", "# Safety"])
}

/// One fully-wired `SNSOLVE_*` knob: the env var, its CLI `--flag`, its
/// config `[section] key`, and the config-struct field. Names cannot be
/// derived from each other (`SNSOLVE_GEMM_PACK` ↔ `--pack`), so the table
/// is the single declarative source of truth the tree is checked against.
pub struct Knob {
    pub env: &'static str,
    pub flag: &'static str,
    pub section: &'static str,
    pub key: &'static str,
    pub field: &'static str,
}

/// The knob table. Adding an `SNSOLVE_*` env var to the tree without
/// adding it here (or to [`ENV_EXEMPT`]) is an `unknown knob` finding;
/// listing it here without all four legs wired is a `half-wired` finding.
pub const KNOBS: &[Knob] = &[
    Knob {
        env: "SNSOLVE_THREADS",
        flag: "threads",
        section: "parallel",
        key: "threads",
        field: "threads",
    },
    Knob { env: "SNSOLVE_SIMD", flag: "simd", section: "parallel", key: "simd", field: "simd" },
    Knob {
        env: "SNSOLVE_GEMM_PACK",
        flag: "pack",
        section: "parallel",
        key: "pack",
        field: "pack",
    },
    Knob { env: "SNSOLVE_QR_NB", flag: "qr-nb", section: "parallel", key: "qr_nb", field: "qr_nb" },
    Knob {
        env: "SNSOLVE_FWHT_RADIX",
        flag: "fwht-radix",
        section: "parallel",
        key: "fwht_radix",
        field: "fwht_radix",
    },
    Knob {
        env: "SNSOLVE_SCHEDULE",
        flag: "schedule",
        section: "parallel",
        key: "schedule",
        field: "schedule",
    },
    Knob {
        env: "SNSOLVE_SKETCH_INVERT",
        flag: "sketch-invert",
        section: "parallel",
        key: "sketch_invert",
        field: "sketch_invert",
    },
    Knob {
        env: "SNSOLVE_READERS",
        flag: "readers",
        section: "service",
        key: "readers",
        field: "readers",
    },
    Knob {
        env: "SNSOLVE_SOLVER",
        flag: "solver",
        section: "solver",
        key: "solver",
        field: "solver",
    },
    Knob {
        env: "SNSOLVE_REFINE_ITERS",
        flag: "refine-iters",
        section: "solver",
        key: "refine_iters",
        field: "refine_iters",
    },
    Knob {
        env: "SNSOLVE_SHARDS",
        flag: "shards",
        section: "cluster",
        key: "shards",
        field: "shards",
    },
    Knob {
        env: "SNSOLVE_REPLICATION",
        flag: "replication",
        section: "cluster",
        key: "replication",
        field: "replication",
    },
];

/// `SNSOLVE_*` vars that are deliberately not user-facing solve/service
/// knobs, with the rationale for exempting them from full wiring.
pub const ENV_EXEMPT: &[(&str, &str)] = &[
    ("SNSOLVE_PROP_SEED", "property-test shrink-seed override; test-only (testing/)"),
    ("SNSOLVE_BENCH_QUICK", "bench-harness quick mode; bench-only (bench_harness/)"),
    ("SNSOLVE_REPORT_DIR", "bench report output directory; bench-only (bench_harness/)"),
    ("SNSOLVE_CLIENT", "service_e2e wire-client selector; test-only (rust/tests/)"),
];

const KERNEL_DIRS: &[&str] = &["linalg/", "sketch/", "solvers/", "parallel/"];
const SPAWN_DIRS: &[&str] = &["parallel/", "coordinator/"];
const HAZARD_WORDS: &[&str] = &["HashMap", "HashSet", "Instant", "SystemTime", "ThreadId"];

/// Recursively collect and lex every `.rs` file under `root`, sorted by
/// path for deterministic output.
pub fn scan_root(root: &Path) -> io::Result<Vec<Source>> {
    let mut files: Vec<PathBuf> = Vec::new();
    walk(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let text = fs::read_to_string(&f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        out.push(Source { rel, path: f, lx: lex(&text) });
    }
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run every rule over the scanned tree.
pub fn check_tree(sources: &[Source]) -> Vec<Finding> {
    let mut out = Vec::new();
    for s in sources {
        check_unsafe(s, &mut out);
        check_intrinsics(s, &mut out);
        check_determinism(s, &mut out);
        check_env_reads(s, &mut out);
    }
    check_knobs(sources, &mut out);
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

fn push(s: &Source, idx: usize, rule: &'static str, message: String, out: &mut Vec<Finding>) {
    if !suppressed(&s.lx, idx, rule) {
        out.push(Finding { file: s.path.clone(), line: idx + 1, rule, message });
    }
}

fn check_unsafe(s: &Source, out: &mut Vec<Finding>) {
    for (idx, line) in s.lx.lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        if safety_documented(&s.lx, idx) {
            continue;
        }
        push(
            s,
            idx,
            "unsafe-needs-safety",
            "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            out,
        );
    }
}

fn check_intrinsics(s: &Source, out: &mut Vec<Finding>) {
    if s.rel.starts_with("simd/") {
        return;
    }
    for (idx, line) in s.lx.lines.iter().enumerate() {
        for pat in ["core::arch", "std::arch", "target_feature(enable"] {
            if line.code.contains(pat) {
                push(
                    s,
                    idx,
                    "intrinsics-behind-dispatch",
                    format!("`{pat}` outside src/simd/ bypasses the runtime-dispatch layer"),
                    out,
                );
                break;
            }
        }
    }
}

fn check_determinism(s: &Source, out: &mut Vec<Finding>) {
    let kernel = KERNEL_DIRS.iter().any(|d| s.rel.starts_with(d));
    let spawn_ok = SPAWN_DIRS.iter().any(|d| s.rel.starts_with(d));
    for (idx, line) in s.lx.lines.iter().enumerate() {
        if kernel {
            for w in HAZARD_WORDS {
                if has_word(&line.code, w) {
                    push(
                        s,
                        idx,
                        "determinism-hazards",
                        format!("`{w}` in a kernel path threatens bitwise determinism"),
                        out,
                    );
                    break;
                }
            }
            if line.code.contains("thread::current") {
                push(
                    s,
                    idx,
                    "determinism-hazards",
                    "thread-identity logic in a kernel path threatens determinism".to_string(),
                    out,
                );
            }
        }
        if !spawn_ok && line.code.contains("thread::spawn") {
            push(
                s,
                idx,
                "determinism-hazards",
                "`thread::spawn` outside parallel/ and coordinator/".to_string(),
                out,
            );
        }
    }
}

fn check_env_reads(s: &Source, out: &mut Vec<Finding>) {
    if s.rel.starts_with("config/") {
        return;
    }
    for (idx, line) in s.lx.lines.iter().enumerate() {
        if line.code.contains("env::var") {
            push(
                s,
                idx,
                "env-reads-behind-config",
                "`env::var` outside config/ (annotate designated knob-resolution sites)"
                    .to_string(),
                out,
            );
        }
    }
}

/// Extract `SNSOLVE_[A-Z0-9_]+` tokens from a string-literal body.
pub fn extract_env_tokens(content: &str) -> Vec<String> {
    let bytes = content.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while let Some(p) = content[i..].find("SNSOLVE_") {
        let at = i + p;
        let boundary =
            at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        let mut j = at + "SNSOLVE_".len();
        while j < bytes.len()
            && (bytes[j].is_ascii_uppercase() || bytes[j].is_ascii_digit() || bytes[j] == b'_')
        {
            j += 1;
        }
        if boundary && j > at + "SNSOLVE_".len() {
            out.push(content[at..j].to_string());
        }
        i = j.max(at + 1);
    }
    out
}

fn check_knobs(sources: &[Source], out: &mut Vec<Finding>) {
    // Discovery: every SNSOLVE_* literal anywhere must be a table entry or
    // an exemption — the catch for knobs added without wiring.
    for s in sources {
        for (line, content) in &s.lx.strings {
            for tok in extract_env_tokens(content) {
                let known = KNOBS.iter().any(|k| k.env == tok)
                    || ENV_EXEMPT.iter().any(|(e, _)| *e == tok);
                if !known {
                    push(
                        s,
                        *line,
                        "knob-coherence",
                        format!(
                            "unknown knob `{tok}`: not in the snsolve-lint knob table or \
                             exemption list"
                        ),
                        out,
                    );
                }
            }
        }
    }
    // Wiring: needs the real config/CLI entry points to be in the tree.
    let config = sources.iter().find(|s| s.rel == "config/mod.rs");
    let main = sources.iter().find(|s| s.rel == "main.rs");
    let (config, main) = match (config, main) {
        (Some(c), Some(m)) => (c, m),
        _ => return,
    };
    for k in KNOBS {
        let mut missing: Vec<String> = Vec::new();
        if !sources.iter().any(|s| s.lx.strings.iter().any(|(_, c)| c.contains(k.env))) {
            missing.push(format!("no source reads `{}`", k.env));
        }
        if !main.lx.strings.iter().any(|(_, c)| c.as_str() == k.flag) {
            missing.push(format!("`--{}` flag not declared in main.rs", k.flag));
        }
        let key_ok = config.lx.strings.iter().any(|(_, c)| c.as_str() == k.key)
            && config.lx.strings.iter().any(|(_, c)| c.as_str() == k.section);
        if !key_ok {
            missing.push(format!("`[{}] {}` key not parsed in config/mod.rs", k.section, k.key));
        }
        if !config.lx.lines.iter().any(|l| has_word(&l.code, k.field)) {
            missing.push(format!("field `{}` absent from config/mod.rs", k.field));
        }
        if !missing.is_empty() {
            push(
                config,
                0,
                "knob-coherence",
                format!("{} is half-wired: {}", k.env, missing.join("; ")),
                out,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comment_inside_string_is_code() {
        let lx = lex("let s = \"http://example\"; // real comment\n");
        assert!(!lx.lines[0].code.contains("http"));
        assert!(lx.lines[0].code.contains("let s"));
        assert!(lx.lines[0].comment.contains("real comment"));
        assert_eq!(lx.strings.len(), 1);
        assert_eq!(lx.strings[0].1, "http://example");
    }

    #[test]
    fn raw_strings_swallow_comment_markers() {
        let lx = lex("let r = r#\"// not \"a\" comment\"#; let x = 1;\n");
        assert!(lx.lines[0].comment.is_empty());
        assert!(lx.lines[0].code.contains("let x = 1"));
        assert_eq!(lx.strings[0].1, "// not \"a\" comment");
    }

    #[test]
    fn nested_block_comments() {
        let lx = lex("/* a /* b */ c */ let y = 2;\n");
        assert!(lx.lines[0].code.contains("let y = 2"));
        for frag in ["a", "b", "c"] {
            assert!(lx.lines[0].comment.contains(frag));
        }
        assert!(!lx.lines[0].code.contains('a'), "code: {}", lx.lines[0].code);
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let lx = lex("/* first\nsecond */ let z = 3;\n");
        assert!(lx.lines[0].comment.contains("first"));
        assert!(lx.lines[1].comment.contains("second"));
        assert!(lx.lines[1].code.contains("let z"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let lx =
            lex("fn f<'a>(x: &'a str) -> char { '\\'' }\nlet c = 'x'; let d = '\\u{1F600}';\n");
        assert!(lx.lines[0].code.contains("fn f<'a>"));
        assert!(lx.lines[1].code.contains("let c = ''"));
        assert!(lx.lines[1].code.contains("let d = ''"));
    }

    #[test]
    fn byte_and_multiline_strings() {
        let lx = lex("let b = b\"ab\"; let r = br#\"cd\"#;\nlet m = \"one\ntwo\";\n");
        assert_eq!(lx.strings[0].1, "ab");
        assert_eq!(lx.strings[1].1, "cd");
        assert_eq!(lx.strings[2].1, "one\ntwo");
        assert_eq!(lx.strings[2].0, 1);
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(has_word("pub unsafe fn x()", "unsafe"));
        assert!(!has_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(!has_word("InstantCoffee", "Instant"));
        assert!(has_word("Instant::now()", "Instant"));
    }

    #[test]
    fn safety_comment_detection() {
        let ok = lex("// SAFETY: ptr is valid for len elements.\nunsafe { *p }\n");
        assert!(safety_documented(&ok, 1));
        let with_attr =
            lex("// SAFETY: feature checked at dispatch.\n#[inline]\nunsafe fn g() {}\n");
        assert!(safety_documented(&with_attr, 2));
        let doc = lex("/// # Safety\n/// caller upholds the contract.\npub unsafe fn h() {}\n");
        assert!(safety_documented(&doc, 2));
        let blank_gap = lex("// SAFETY: stale.\n\nunsafe { *p }\n");
        assert!(!safety_documented(&blank_gap, 2));
        let none = lex("let a = 1;\nunsafe { *p }\n");
        assert!(!safety_documented(&none, 1));
    }

    #[test]
    fn suppression_detection() {
        let lx = lex(
            "// snsolve-lint: allow(determinism-hazards) — bench timing only\nlet t = Instant::now();\n",
        );
        assert!(suppressed(&lx, 1, "determinism-hazards"));
        assert!(!suppressed(&lx, 1, "unsafe-needs-safety"));
    }

    #[test]
    fn env_token_extraction() {
        assert_eq!(
            extract_env_tokens("read SNSOLVE_THREADS then SNSOLVE_ and SNSOLVE_QR_NB."),
            vec!["SNSOLVE_THREADS".to_string(), "SNSOLVE_QR_NB".to_string()]
        );
        assert!(extract_env_tokens("XSNSOLVE_FOO").is_empty());
    }
}
