//! CLI for `snsolve-lint`: scan source roots, print findings, exit
//! non-zero when any survive. `cargo run -p snsolve-lint` from the
//! workspace root (or `rust/`) lints the real tree.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--list-rules" => {
                for (name, desc) in snsolve_lint::RULES {
                    println!("{name}: {desc}");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!(
                    "usage: snsolve-lint [--list-rules] [ROOT...]\n\n\
                     Lints every .rs file under each ROOT (default: rust/src or src).\n\
                     Suppress a finding with `// snsolve-lint: allow(<rule>) — <rationale>`."
                );
                return ExitCode::SUCCESS;
            }
            other => roots.push(PathBuf::from(other)),
        }
    }
    if roots.is_empty() {
        for cand in ["rust/src", "src"] {
            if Path::new(cand).is_dir() {
                roots.push(PathBuf::from(cand));
                break;
            }
        }
    }
    if roots.is_empty() {
        eprintln!("snsolve-lint: no scan root found (expected rust/src or src)");
        return ExitCode::FAILURE;
    }
    let mut total = 0usize;
    for root in &roots {
        let sources = match snsolve_lint::scan_root(root) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("snsolve-lint: scanning {}: {e}", root.display());
                return ExitCode::FAILURE;
            }
        };
        let findings = snsolve_lint::check_tree(&sources);
        for f in &findings {
            println!("{f}");
        }
        total += findings.len();
    }
    if total == 0 {
        eprintln!("snsolve-lint: clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("snsolve-lint: {total} finding(s)");
        ExitCode::FAILURE
    }
}
