//! Integration tests: every seeded fixture violation is caught, the
//! clean fixture tree and the real `rust/src` tree lint clean.

use std::path::{Path, PathBuf};

use snsolve_lint::{check_tree, scan_root, Finding};

fn lint(dir: &str) -> Vec<Finding> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir);
    let sources = scan_root(&root).expect("scan fixture root");
    check_tree(&sources)
}

fn hits<'a>(findings: &'a [Finding], rule: &str, file_frag: &str) -> Vec<&'a Finding> {
    findings
        .iter()
        .filter(|f| f.rule == rule && f.file.to_string_lossy().contains(file_frag))
        .collect()
}

#[test]
fn bad_tree_catches_every_seeded_violation() {
    let findings = lint("fixtures/bad");

    // unsafe-needs-safety: the undocumented unsafe fn and unsafe block.
    assert!(hits(&findings, "unsafe-needs-safety", "kernels.rs").len() >= 2);

    // intrinsics-behind-dispatch: `use core::arch` + `#[target_feature]`
    // outside simd/.
    assert!(hits(&findings, "intrinsics-behind-dispatch", "intrinsics.rs").len() >= 2);

    // determinism-hazards: HashMap + Instant in linalg/, plus the rogue
    // thread::spawn in util/.
    let hazards = hits(&findings, "determinism-hazards", "kernels.rs");
    assert!(hazards.iter().any(|f| f.message.contains("HashMap")));
    assert!(hazards.iter().any(|f| f.message.contains("Instant")));
    assert_eq!(hits(&findings, "determinism-hazards", "spawner.rs").len(), 1);

    // env-reads-behind-config: the un-annotated env::var in linalg/.
    assert_eq!(hits(&findings, "env-reads-behind-config", "kernels.rs").len(), 1);

    // knob-coherence: the unknown knob literal plus half-wired reports
    // for every table entry (the fixture config/main wire nothing).
    let knobs = hits(&findings, "knob-coherence", "kernels.rs");
    assert!(knobs.iter().any(|f| f.message.contains("SNSOLVE_BOGUS")));
    let half_wired = hits(&findings, "knob-coherence", "config/mod.rs");
    assert_eq!(half_wired.len(), snsolve_lint::KNOBS.len());
    assert!(half_wired.iter().all(|f| f.message.contains("half-wired")));
}

#[test]
fn clean_tree_has_no_findings() {
    let findings = lint("fixtures/clean");
    assert!(findings.is_empty(), "expected clean, got:\n{findings:?}");
}

#[test]
fn real_tree_is_clean() {
    // tools/snsolve-lint -> ../../src is the crate's real source tree.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../src");
    assert!(Path::new(&root).is_dir(), "rust/src not found at {}", root.display());
    let sources = scan_root(&root).expect("scan rust/src");
    let findings = check_tree(&sources);
    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(findings.is_empty(), "rust/src must lint clean:\n{}", rendered.join("\n"));
}
