//! Fixture: intrinsics leaking out of the dispatch layer (this file is
//! outside simd/, so both lines below must be flagged).

use core::arch::x86_64::__m256d;

// SAFETY: irrelevant — the violation is the location, not the safety doc.
#[target_feature(enable = "avx2")]
pub unsafe fn leaked(_x: __m256d) {}
