//! Fixture: a config module that wires none of the knob table, so every
//! table entry is reported half-wired.

pub struct SolveConfig {
    pub nothing: usize,
}
