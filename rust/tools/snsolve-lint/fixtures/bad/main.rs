//! Fixture: a main that declares no knob flags.

fn main() {
    println!("no flags here");
}
