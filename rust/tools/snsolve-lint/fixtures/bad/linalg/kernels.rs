//! Fixture: seeded violations in a kernel path. Never compiled — lexed
//! only by the snsolve-lint integration tests.

use std::collections::HashMap;
use std::time::Instant;

pub fn lookup(map: &HashMap<u32, f64>) -> f64 {
    map.values().sum()
}

pub fn elapsed_nondeterminism() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub unsafe fn raw_read(p: *const f64) -> f64 {
    *p
}

pub fn undocumented_block(p: *const f64) -> f64 {
    unsafe { *p }
}

pub fn stray_env_read() -> bool {
    std::env::var("SNSOLVE_BOGUS").is_ok()
}
