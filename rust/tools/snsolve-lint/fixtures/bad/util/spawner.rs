//! Fixture: `thread::spawn` outside parallel/ and coordinator/.

pub fn rogue_thread() {
    std::thread::spawn(|| {}).join().unwrap();
}
