//! Fixture: the negative cases — intrinsics under simd/, documented
//! unsafe, suppressed findings. Must lint clean.

use core::arch::x86_64::{__m256d, _mm256_setzero_pd};

// SAFETY: callers reach this only through the dispatch layer, which has
// verified AVX2 support on the running CPU.
#[target_feature(enable = "avx2")]
pub unsafe fn documented(_x: __m256d) -> __m256d {
    // SAFETY: the intrinsic has no memory-safety obligations beyond the
    // AVX2 requirement guaranteed by the enclosing target_feature fn.
    unsafe { _mm256_setzero_pd() }
}

/// # Safety
/// The pointer must be valid for reads of one f64.
pub unsafe fn doc_section_counts(p: *const f64) -> f64 {
    // SAFETY: contract forwarded verbatim from the caller.
    unsafe { *p }
}

pub fn string_mentions_are_not_code() -> &'static str {
    "unsafe { thread::spawn } // core::arch inside a string is fine"
}
