//! Fixture: allowed patterns in parallel/ — spawn is confined here, and
//! an explicitly suppressed hazard stays suppressed.

pub fn spawn_is_fine_here() {
    std::thread::spawn(|| {}).join().unwrap();
}

pub fn timed_scope() -> f64 {
    // snsolve-lint: allow(determinism-hazards) — wall-clock feeds a stats
    // counter only, never a kernel result.
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn annotated_env_read() -> usize {
    // snsolve-lint: allow(env-reads-behind-config) — designated knob
    // resolution site for SNSOLVE_THREADS.
    std::env::var("SNSOLVE_THREADS").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}
