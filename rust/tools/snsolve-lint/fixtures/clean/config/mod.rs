//! Fixture: a config module that fully wires every knob in the table —
//! the key/section literals, the struct fields, and (config/ being the
//! designated env layer) the env reads.

pub struct SolveConfig {
    pub threads: usize,
    pub simd: u8,
    pub pack: bool,
    pub qr_nb: usize,
    pub fwht_radix: usize,
    pub schedule: u8,
    pub sketch_invert: bool,
    pub solver: u8,
    pub refine_iters: usize,
}

pub struct FrontendConfig {
    pub readers: usize,
}

pub struct ClusterConfig {
    pub shards: Vec<String>,
    pub replication: usize,
}

pub fn keys() -> [(&'static str, &'static str); 12] {
    [
        ("parallel", "threads"),
        ("parallel", "simd"),
        ("parallel", "pack"),
        ("parallel", "qr_nb"),
        ("parallel", "fwht_radix"),
        ("parallel", "schedule"),
        ("parallel", "sketch_invert"),
        ("service", "readers"),
        ("solver", "solver"),
        ("solver", "refine_iters"),
        ("cluster", "shards"),
        ("cluster", "replication"),
    ]
}

pub fn env_overrides() -> Vec<String> {
    [
        "SNSOLVE_THREADS",
        "SNSOLVE_SIMD",
        "SNSOLVE_GEMM_PACK",
        "SNSOLVE_QR_NB",
        "SNSOLVE_FWHT_RADIX",
        "SNSOLVE_SCHEDULE",
        "SNSOLVE_SKETCH_INVERT",
        "SNSOLVE_READERS",
        "SNSOLVE_SOLVER",
        "SNSOLVE_REFINE_ITERS",
        "SNSOLVE_SHARDS",
        "SNSOLVE_REPLICATION",
    ]
    .iter()
    .filter_map(|k| std::env::var(k).ok())
    .collect()
}
