//! Fixture: a main that declares every knob flag from the table.

const FLAGS: &[&str] = &[
    "threads",
    "simd",
    "pack",
    "qr-nb",
    "fwht-radix",
    "schedule",
    "sketch-invert",
    "readers",
    "solver",
    "refine-iters",
    "shards",
    "replication",
];

fn main() {
    for f in FLAGS {
        println!("--{f}");
    }
}
