//! Bench: coordinator serving performance — requests/s and latency through
//! the full queue→batcher→worker path, the factor-cache ablation
//! (cache ON vs OFF is the batching win), raw dispatch overhead vs a
//! direct in-thread solve, and the blocked multi-RHS sweep
//! (`--block-rhs` runs only that sweep): 16-RHS same-matrix batches solved
//! by one `lsqr_block` vs the per-item loop, reporting solves/sec and the
//! speedup ratio. `--frontend` runs only the TCP front-end sweep: closed-loop
//! load through a serial v1 client vs a pipelined v2 client at depth 16,
//! with client-side p50/p95/p99 latency, saved as `BENCH_frontend_pipeline`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use snsolve::bench_harness::report::Table;
use snsolve::coordinator::batcher::BatcherConfig;
use snsolve::coordinator::metrics::Metrics;
use snsolve::coordinator::tcp::{Client, PipelinedClient, TcpServer};
use snsolve::coordinator::{Service, ServiceConfig, SolveRequest, SolverChoice};
use snsolve::linalg::{DenseMatrix, Matrix};
use snsolve::rng::{GaussianSource, Xoshiro256pp};
use snsolve::solvers::saa::SaaSolver;
use snsolve::solvers::Solver;

/// Run `requests` same-matrix SAA solves through a 1-worker service with
/// 16-deep batches; returns (wall seconds, blocked-RHS count).
fn run_block_config(
    a: &DenseMatrix,
    b: &[f64],
    requests: usize,
    block_rhs: bool,
) -> (f64, u64) {
    let mut cfg = ServiceConfig {
        workers: 1,
        queue_capacity: 1024,
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(500) },
        ..Default::default()
    };
    cfg.worker.block_rhs = block_rhs;
    let svc = Service::start(cfg);
    let id = svc.register_matrix(Matrix::Dense(a.clone()));
    // Warm the factor cache outside the timed window.
    svc.solve_blocking(SolveRequest {
        matrix: id,
        rhs: b.to_vec(),
        solver: SolverChoice::Saa,
        tol: 1e-10,
        deadline_us: 0,
        refine_iters: 0,
    })
    .expect("warmup")
    .result
    .expect("warmup solution");
    let blocked_before = Metrics::get(&svc.metrics().blocked_rhs);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            svc.submit(SolveRequest {
                matrix: id,
                rhs: b.to_vec(),
                solver: SolverChoice::Saa,
                tol: 1e-10,
                deadline_us: 0,
                refine_iters: 0,
            })
            .expect("submit")
        })
        .collect();
    for h in handles {
        h.wait().expect("resp").result.expect("solution");
    }
    let wall = t0.elapsed().as_secs_f64();
    // Delta over the warmup so the column counts only timed requests.
    let blocked = Metrics::get(&svc.metrics().blocked_rhs) - blocked_before;
    svc.shutdown();
    (wall, blocked)
}

/// The `--block-rhs` sweep: blocked multi-RHS batches vs the per-item loop.
fn block_rhs_sweep(a: &DenseMatrix, b: &[f64], requests: usize) {
    let mut table = Table::new(
        "coordinator — blocked multi-RHS (16-deep same-matrix batches)",
        &["config", "requests", "wall_s", "solves_per_s", "blocked_rhs"],
    );
    let mut rates = Vec::new();
    for block in [false, true] {
        let (wall, blocked) = run_block_config(a, b, requests, block);
        let rate = requests as f64 / wall;
        rates.push(rate);
        table.row(vec![
            if block { "block-rhs=on (lsqr_block)" } else { "block-rhs=off (per-item)" }.into(),
            requests.to_string(),
            format!("{wall:.3}"),
            format!("{rate:.1}"),
            blocked.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "block-rhs speedup: {:.2}x solves/sec over the per-item loop (16-RHS batches)",
        rates[1] / rates[0]
    );
    let _ = table.save("coordinator_block_rhs");
}

/// Exact percentile over a pre-sorted latency vector (nearest-rank).
fn pctl(sorted_us: &[u64], q: f64) -> u64 {
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx]
}

/// The `--frontend` sweep: closed-loop load through the TCP front-end,
/// one blocking v1 client vs one pipelined v2 client at depth 16, with
/// client-side latency percentiles. RTT and batcher wait dominate on the
/// small matrix, so the pipelined client's amortization is what's measured.
fn frontend_sweep(requests: usize) {
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(6));
    let a = DenseMatrix::gaussian(256, 16, &mut g);
    let b = a.matvec(&g.gaussian_vec(16));

    let svc = Service::start(ServiceConfig {
        workers: 2,
        queue_capacity: 1024,
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(500) },
        ..Default::default()
    });
    let server = TcpServer::serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let mut table = Table::new(
        "coordinator — TCP front-end: serial vs pipelined (depth 16)",
        &["mode", "requests", "wall_s", "qps", "p50_us", "p95_us", "p99_us"],
    );

    // Serial: one request in flight at a time. Each solo request also ages
    // out of the batcher alone, so it pays the full max_wait.
    let mut client = Client::connect(addr).expect("connect v1");
    let id = client.register_dense(&a).expect("register");
    client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("warmup");
    let mut lat = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for _ in 0..requests {
        let s = Instant::now();
        client.solve(id, &b, SolverChoice::Saa, 1e-10).expect("solve");
        lat.push(s.elapsed().as_micros() as u64);
    }
    let serial_wall = t0.elapsed().as_secs_f64();
    lat.sort_unstable();
    table.row(vec![
        "serial (v1 blocking)".into(),
        requests.to_string(),
        format!("{serial_wall:.3}"),
        format!("{:.1}", requests as f64 / serial_wall),
        pctl(&lat, 0.50).to_string(),
        pctl(&lat, 0.95).to_string(),
        pctl(&lat, 0.99).to_string(),
    ]);

    // Pipelined: keep 16 requests in flight on one socket; harvest the
    // oldest ticket and immediately refill the window.
    let depth = 16usize;
    let mut pc = PipelinedClient::connect(addr).expect("connect v2");
    let mut lat = Vec::with_capacity(requests);
    let mut window = VecDeque::new();
    let mut submitted = 0usize;
    let t0 = Instant::now();
    while lat.len() < requests {
        while submitted < requests && window.len() < depth {
            let s = Instant::now();
            let t = pc.submit_solve(id, &b, SolverChoice::Saa, 1e-10, 0).expect("submit");
            window.push_back((s, t));
            submitted += 1;
        }
        let (s, t) = window.pop_front().expect("window nonempty");
        let (_sol, at) = t.wait_timed().expect("pipelined solve");
        lat.push(at.duration_since(s).as_micros() as u64);
    }
    let pipe_wall = t0.elapsed().as_secs_f64();
    lat.sort_unstable();
    table.row(vec![
        format!("pipelined (v2 depth {depth})"),
        requests.to_string(),
        format!("{pipe_wall:.3}"),
        format!("{:.1}", requests as f64 / pipe_wall),
        pctl(&lat, 0.50).to_string(),
        pctl(&lat, 0.95).to_string(),
        pctl(&lat, 0.99).to_string(),
    ]);

    println!("{}", table.render());
    let speedup = serial_wall / pipe_wall;
    println!("frontend pipelining speedup at depth {depth}: {speedup:.2}x QPS over serial");
    match table.save("BENCH_frontend_pipeline") {
        Ok(p) => println!("saved {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }

    server.stop();
    svc.shutdown();
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let only_block = argv.iter().any(|a| a == "--block-rhs");
    let only_frontend = argv.iter().any(|a| a == "--frontend");
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (m, n, requests) = if quick { (2048, 64, 60) } else { (8192, 128, 200) };
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(5));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let b = a.matvec(&g.gaussian_vec(n));

    if only_block {
        block_rhs_sweep(&a, &b, requests);
        return;
    }
    if only_frontend {
        frontend_sweep(requests);
        return;
    }

    let mut table = Table::new(
        "coordinator — serving throughput and dispatch overhead",
        &["config", "requests", "wall_s", "req_per_s", "p50_us", "p99_us", "mean_batch", "cache_miss"],
    );

    // Direct solve (no service) — the baseline the dispatch overhead is
    // measured against. Factor reuse OFF: full SAA each time.
    {
        let solver = SaaSolver::default();
        let am = Matrix::Dense(a.clone());
        let t0 = std::time::Instant::now();
        let reps = requests / 4;
        for _ in 0..reps {
            snsolve::bench_harness::black_box(solver.solve(&am, &b).unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            "direct (no cache)".into(),
            reps.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", reps as f64 / wall),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }

    // Service configurations.
    for (label, cache_cap, max_batch) in [
        ("service cache=off batch=1", 0usize, 1usize),
        ("service cache=on  batch=1", 4, 1),
        ("service cache=on  batch=16", 4, 16),
    ] {
        let mut cfg = ServiceConfig {
            workers: 2,
            queue_capacity: 1024,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_micros(500),
            },
            ..Default::default()
        };
        cfg.worker.factor_cache_cap = cache_cap.max(1);
        // cache "off": cap 1 but evict by reusing a fresh matrix id per
        // request is awkward; emulate by cap 1 + alternating two matrices.
        let svc = Service::start(cfg);
        let id0 = svc.register_matrix(Matrix::Dense(a.clone()));
        let id1 = svc.register_matrix(Matrix::Dense(a.clone()));
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..requests)
            .map(|i| {
                let matrix = if cache_cap == 0 {
                    // alternate matrices to defeat the (cap-1) cache
                    if i % 2 == 0 { id0 } else { id1 }
                } else {
                    id0
                };
                svc.submit(SolveRequest {
                    matrix,
                    rhs: b.clone(),
                    solver: SolverChoice::Saa,
                    tol: 1e-10,
                    deadline_us: 0,
                    refine_iters: 0,
                })
                .expect("submit")
            })
            .collect();
        for h in handles {
            h.wait().expect("resp").result.expect("solution");
        }
        let wall = t0.elapsed().as_secs_f64();
        let met = svc.metrics();
        let (_c, _mean, p50, p99, _max) = met.e2e_latency.snapshot();
        table.row(vec![
            label.into(),
            requests.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", requests as f64 / wall),
            p50.to_string(),
            p99.to_string(),
            format!("{:.2}", met.mean_batch_size()),
            snsolve::coordinator::metrics::Metrics::get(&met.factor_cache_misses).to_string(),
        ]);
        svc.shutdown();
    }

    println!("{}", table.render());
    let _ = table.save("coordinator_throughput");

    // Kernel worker-pool scheduler counters accumulated across the runs
    // above — the same numbers the OP_METRICS frame reports to clients.
    let pool = snsolve::parallel::pool_stats();
    println!(
        "pool: schedule={} regions={} units={} stolen={} steal_rate={:.3} max_depth={}",
        snsolve::parallel::active_schedule().name(),
        pool.regions,
        pool.executed,
        pool.stolen,
        pool.steal_rate(),
        pool.max_depth,
    );

    block_rhs_sweep(&a, &b, requests);
    frontend_sweep(requests);
}
