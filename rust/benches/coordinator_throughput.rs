//! Bench: coordinator serving performance — requests/s and latency through
//! the full queue→batcher→worker path, the factor-cache ablation
//! (cache ON vs OFF is the batching win), raw dispatch overhead vs a
//! direct in-thread solve, and the blocked multi-RHS sweep
//! (`--block-rhs` runs only that sweep): 16-RHS same-matrix batches solved
//! by one `lsqr_block` vs the per-item loop, reporting solves/sec and the
//! speedup ratio.

use std::time::Duration;

use snsolve::bench_harness::report::Table;
use snsolve::coordinator::batcher::BatcherConfig;
use snsolve::coordinator::metrics::Metrics;
use snsolve::coordinator::{Service, ServiceConfig, SolveRequest, SolverChoice};
use snsolve::linalg::{DenseMatrix, Matrix};
use snsolve::rng::{GaussianSource, Xoshiro256pp};
use snsolve::solvers::saa::SaaSolver;
use snsolve::solvers::Solver;

/// Run `requests` same-matrix SAA solves through a 1-worker service with
/// 16-deep batches; returns (wall seconds, blocked-RHS count).
fn run_block_config(
    a: &DenseMatrix,
    b: &[f64],
    requests: usize,
    block_rhs: bool,
) -> (f64, u64) {
    let mut cfg = ServiceConfig {
        workers: 1,
        queue_capacity: 1024,
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(500) },
        ..Default::default()
    };
    cfg.worker.block_rhs = block_rhs;
    let svc = Service::start(cfg);
    let id = svc.register_matrix(Matrix::Dense(a.clone()));
    // Warm the factor cache outside the timed window.
    svc.solve_blocking(SolveRequest {
        matrix: id,
        rhs: b.to_vec(),
        solver: SolverChoice::Saa,
        tol: 1e-10,
        deadline_us: 0,
    })
    .expect("warmup")
    .result
    .expect("warmup solution");
    let blocked_before = Metrics::get(&svc.metrics().blocked_rhs);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            svc.submit(SolveRequest {
                matrix: id,
                rhs: b.to_vec(),
                solver: SolverChoice::Saa,
                tol: 1e-10,
                deadline_us: 0,
            })
            .expect("submit")
        })
        .collect();
    for h in handles {
        h.wait().expect("resp").result.expect("solution");
    }
    let wall = t0.elapsed().as_secs_f64();
    // Delta over the warmup so the column counts only timed requests.
    let blocked = Metrics::get(&svc.metrics().blocked_rhs) - blocked_before;
    svc.shutdown();
    (wall, blocked)
}

/// The `--block-rhs` sweep: blocked multi-RHS batches vs the per-item loop.
fn block_rhs_sweep(a: &DenseMatrix, b: &[f64], requests: usize) {
    let mut table = Table::new(
        "coordinator — blocked multi-RHS (16-deep same-matrix batches)",
        &["config", "requests", "wall_s", "solves_per_s", "blocked_rhs"],
    );
    let mut rates = Vec::new();
    for block in [false, true] {
        let (wall, blocked) = run_block_config(a, b, requests, block);
        let rate = requests as f64 / wall;
        rates.push(rate);
        table.row(vec![
            if block { "block-rhs=on (lsqr_block)" } else { "block-rhs=off (per-item)" }.into(),
            requests.to_string(),
            format!("{wall:.3}"),
            format!("{rate:.1}"),
            blocked.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "block-rhs speedup: {:.2}x solves/sec over the per-item loop (16-RHS batches)",
        rates[1] / rates[0]
    );
    let _ = table.save("coordinator_block_rhs");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let only_block = argv.iter().any(|a| a == "--block-rhs");
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (m, n, requests) = if quick { (2048, 64, 60) } else { (8192, 128, 200) };
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(5));
    let a = DenseMatrix::gaussian(m, n, &mut g);
    let b = a.matvec(&g.gaussian_vec(n));

    if only_block {
        block_rhs_sweep(&a, &b, requests);
        return;
    }

    let mut table = Table::new(
        "coordinator — serving throughput and dispatch overhead",
        &["config", "requests", "wall_s", "req_per_s", "p50_us", "p99_us", "mean_batch", "cache_miss"],
    );

    // Direct solve (no service) — the baseline the dispatch overhead is
    // measured against. Factor reuse OFF: full SAA each time.
    {
        let solver = SaaSolver::default();
        let am = Matrix::Dense(a.clone());
        let t0 = std::time::Instant::now();
        let reps = requests / 4;
        for _ in 0..reps {
            snsolve::bench_harness::black_box(solver.solve(&am, &b).unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            "direct (no cache)".into(),
            reps.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", reps as f64 / wall),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }

    // Service configurations.
    for (label, cache_cap, max_batch) in [
        ("service cache=off batch=1", 0usize, 1usize),
        ("service cache=on  batch=1", 4, 1),
        ("service cache=on  batch=16", 4, 16),
    ] {
        let mut cfg = ServiceConfig {
            workers: 2,
            queue_capacity: 1024,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_micros(500),
            },
            ..Default::default()
        };
        cfg.worker.factor_cache_cap = cache_cap.max(1);
        // cache "off": cap 1 but evict by reusing a fresh matrix id per
        // request is awkward; emulate by cap 1 + alternating two matrices.
        let svc = Service::start(cfg);
        let id0 = svc.register_matrix(Matrix::Dense(a.clone()));
        let id1 = svc.register_matrix(Matrix::Dense(a.clone()));
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..requests)
            .map(|i| {
                let matrix = if cache_cap == 0 {
                    // alternate matrices to defeat the (cap-1) cache
                    if i % 2 == 0 { id0 } else { id1 }
                } else {
                    id0
                };
                svc.submit(SolveRequest {
                    matrix,
                    rhs: b.clone(),
                    solver: SolverChoice::Saa,
                    tol: 1e-10,
                    deadline_us: 0,
                })
                .expect("submit")
            })
            .collect();
        for h in handles {
            h.wait().expect("resp").result.expect("solution");
        }
        let wall = t0.elapsed().as_secs_f64();
        let met = svc.metrics();
        let (_c, _mean, p50, p99, _max) = met.e2e_latency.snapshot();
        table.row(vec![
            label.into(),
            requests.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", requests as f64 / wall),
            p50.to_string(),
            p99.to_string(),
            format!("{:.2}", met.mean_batch_size()),
            snsolve::coordinator::metrics::Metrics::get(&met.factor_cache_misses).to_string(),
        ]);
        svc.shutdown();
    }

    println!("{}", table.render());
    let _ = table.save("coordinator_throughput");

    // Kernel worker-pool scheduler counters accumulated across the runs
    // above — the same numbers the OP_METRICS frame reports to clients.
    let pool = snsolve::parallel::pool_stats();
    println!(
        "pool: schedule={} regions={} units={} stolen={} steal_rate={:.3} max_depth={}",
        snsolve::parallel::active_schedule().name(),
        pool.regions,
        pool.executed,
        pool.stolen,
        pool.steal_rate(),
        pool.max_depth,
    );

    block_rhs_sweep(&a, &b, requests);
}
