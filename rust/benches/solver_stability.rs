//! Bench: accuracy vs κ(A) for the forward-stable ladder.
//!
//! Sweeps the condition number up to 10¹⁵ on dense instances with a small
//! true residual and records, per solver, the relative forward error
//! ‖x̂ − x*‖/‖x*‖, the residual suboptimality, and wall time:
//!
//! * `qr`     — dense Householder QR (the forward-stable oracle),
//! * `sas`    — one-shot sketch-and-solve (degrades fast with κ),
//! * `sap`    — sketch-and-precondition LSQR baseline,
//! * `stable` — the escalation ladder (`--solver stable`), plus which
//!              stage finally answered and how many escalations it took.
//!
//! `SNSOLVE_BENCH_QUICK=1` shrinks the instance, seed count and κ grid.
//! Output: console table + target/bench-reports/BENCH_solver_stability.*

use std::time::Instant;

use snsolve::bench_harness::report::Table;
use snsolve::linalg::DenseMatrix;
use snsolve::problems::{generate_dense, DenseProblemSpec, Problem};
use snsolve::solvers::direct::DirectQr;
use snsolve::solvers::lsqr::SolveWorkspace;
use snsolve::solvers::{SapSolver, SketchAndSolve, Solver, StableSolver};

fn main() {
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (m, n, seeds): (usize, usize, &[u64]) =
        if quick { (400, 16, &[42]) } else { (2000, 50, &[42, 43, 44]) };
    let kappas: &[f64] = if quick {
        &[1e2, 1e6, 1e10, 1e14]
    } else {
        &[1e2, 1e4, 1e6, 1e8, 1e10, 1e12, 1e14, 1e15]
    };
    eprintln!("solver_stability: {m}x{n}, {} seeds, κ up to 1e15 (quick={quick})", seeds.len());

    let mut t = Table::new(
        "solver stability: forward error vs condition number",
        &[
            "kappa", "m", "n", "seed", "solver", "rel_err", "subopt", "time_ms", "stage",
            "escalations",
        ],
    );
    for &kappa in kappas {
        for &seed in seeds {
            let p = generate_dense(&DenseProblemSpec {
                m,
                n,
                cond: kappa,
                resid_norm: 1e-10,
                seed,
            });
            run_solver(&mut t, &p, kappa, seed, "qr", &DirectQr);
            run_solver(&mut t, &p, kappa, seed, "sas", &SketchAndSolve::default());
            run_solver(&mut t, &p, kappa, seed, "sap", &SapSolver::default());
            run_stable(&mut t, &p, kappa, seed);
        }
    }
    println!("{}", t.render());
    match t.save("BENCH_solver_stability") {
        Ok(p) => eprintln!("saved {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}

fn run_solver(t: &mut Table, p: &Problem, kappa: f64, seed: u64, name: &str, s: &dyn Solver) {
    let t0 = Instant::now();
    let (err, subopt) = match s.solve(&p.a, &p.b) {
        Ok(sol) => (p.relative_error(&sol.x), p.residual_suboptimality(&sol.x)),
        Err(_) => (f64::NAN, f64::NAN),
    };
    push(t, p, kappa, seed, name, err, subopt, t0.elapsed().as_secs_f64() * 1e3, "-", "-");
}

fn run_stable(t: &mut Table, p: &Problem, kappa: f64, seed: u64) {
    let m = p.a.shape().0;
    let mut rhs = DenseMatrix::zeros(1, m);
    rhs.row_mut(0).copy_from_slice(&p.b);
    let mut ws = SolveWorkspace::new();
    let t0 = Instant::now();
    match StableSolver::default().solve_block(&p.a, &rhs, &mut ws, None) {
        Ok(out) => {
            let x = out.x.row(0).to_vec();
            push(
                t,
                p,
                kappa,
                seed,
                "stable",
                p.relative_error(&x),
                p.residual_suboptimality(&x),
                t0.elapsed().as_secs_f64() * 1e3,
                out.stage_of[0].name(),
                &out.escalations.to_string(),
            );
        }
        Err(_) => push(
            t,
            p,
            kappa,
            seed,
            "stable",
            f64::NAN,
            f64::NAN,
            t0.elapsed().as_secs_f64() * 1e3,
            "error",
            "-",
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn push(
    t: &mut Table,
    p: &Problem,
    kappa: f64,
    seed: u64,
    solver: &str,
    err: f64,
    subopt: f64,
    ms: f64,
    stage: &str,
    escalations: &str,
) {
    let (m, n) = p.a.shape();
    t.row(vec![
        format!("{kappa:.0e}"),
        m.to_string(),
        n.to_string(),
        seed.to_string(),
        solver.to_string(),
        format!("{err:.6e}"),
        format!("{subopt:.6e}"),
        format!("{ms:.2}"),
        stage.to_string(),
        escalations.to_string(),
    ]);
}
