//! Bench: regenerate **Figure 4** — relative forward error of SAA-SAS vs
//! LSQR on the dense m = 20000, n = 100, κ = 10¹⁰, β = 10⁻¹⁰ instance,
//! plus the **T-sap** paradigm ablation (SAP-SAS vs SAA-SAS vs LSQR) and
//! the one-shot sketch-and-solve accuracy floor.
//!
//! `SNSOLVE_BENCH_QUICK=1` shrinks the instance and trial count.
//! Output: console table + target/bench-reports/figure4_error.{csv,json}.

use snsolve::bench_harness::figures::{run_figure4, Figure4Config};

fn main() {
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = if quick { Figure4Config::smoke() } else { Figure4Config::paper() };
    eprintln!(
        "figure4: {}x{} κ={:.0e} β={:.0e} trials={} (quick={quick})",
        cfg.m, cfg.n, cfg.cond, cfg.beta, cfg.trials
    );
    let t = run_figure4(&cfg);
    println!("{}", t.render());
    // Aggregate per-solver medians for the summary EXPERIMENTS.md quotes.
    summarize(&t);
    match t.save("figure4_error") {
        Ok(p) => eprintln!("saved {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}

fn summarize(t: &snsolve::bench_harness::report::Table) {
    let mut by_solver: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    for row in &t.rows {
        by_solver.entry(row[1].clone()).or_default().push(row[2].parse().unwrap_or(f64::NAN));
    }
    println!("median relative error by solver:");
    for (solver, mut errs) in by_solver {
        errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!("  {solver:<14} {:.3e}", errs[errs.len() / 2]);
    }
}
