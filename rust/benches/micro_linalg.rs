//! Microbenchmarks of the L3 hot paths with achieved-vs-roofline context:
//! blocked GEMM (GFLOP/s), Householder QR, FWHT, CountSketch apply
//! (GB/s — bandwidth-bound), CSR matvec (the LSQR inner loop), and the
//! Y = A·R⁻¹ right solve. These drive the §Perf iteration log.
//!
//! `--threads 1,2,4` (or `--threads N`; default sweep {1, 2, 4}) also runs
//! the parallel-scaling sweep: GEMM and SRHT apply at each pool size, with
//! wall-clock speedup over the 1-thread baseline and the max deviation from
//! the serial result (must stay ≤ 1e-12).
//!
//! `--simd scalar|avx2|avx512|neon|auto` forces the kernel backend for the
//! main table; the per-backend sweep at the end always times every backend
//! the host supports (GEMM/dot/axpy/FWHT GFLOP/s per backend) and
//! cross-checks each against the scalar reference (≤ 1e-12 relative; FWHT
//! bitwise).
//!
//! The final sweeps time packed vs unpacked GEMM and blocked vs unblocked
//! Householder QR (the PR-4 tentpole, saved as
//! `BENCH_micro_linalg.{json,csv}`) and static vs work-stealing scheduling
//! on skewed workloads (the PR-6 tentpole, saved as
//! `BENCH_pool_schedule.{json,csv}` with bitwise agreement asserted).

use snsolve::bench_harness::report::Table;
use snsolve::bench_harness::{
    bench, config_from_env, max_abs_dev, parse_simd_arg, parse_threads_arg, simd_in_use,
    threads_in_use, BenchConfig,
};
use snsolve::linalg::sparse::CooBuilder;
use snsolve::linalg::{gemm, hadamard, qr, triangular, DenseMatrix};
use snsolve::rng::{GaussianSource, RngCore, Xoshiro256pp};
use snsolve::sketch::{CountSketch, SketchOperator, SrhtSketch};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Some(choice) = parse_simd_arg(&argv) {
        snsolve::simd::set_choice(choice);
    }
    let cfg = config_from_env();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(1));
    let mut table = Table::new(
        "micro — L3 hot paths (achieved throughput)",
        &["kernel", "shape", "threads", "simd", "median_s", "throughput", "unit"],
    );
    let threads_now = threads_in_use().to_string();
    let simd_now = simd_in_use().to_string();

    // GEMM: C = A·B, classic compute-bound kernel.
    for n in [256usize, 512, 1024] {
        let a = DenseMatrix::gaussian(n, n, &mut g);
        let b = DenseMatrix::gaussian(n, n, &mut g);
        let st = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
        let gflops = 2.0 * (n as f64).powi(3) / st.median / 1e9;
        table.row(vec![
            "gemm".into(),
            format!("{n}x{n}x{n}"),
            threads_now.clone(),
            simd_now.clone(),
            format!("{:.6}", st.median),
            format!("{gflops:.2}"),
            "GFLOP/s".into(),
        ]);
    }

    // Householder QR at sketch scale (s = 4n).
    for n in [128usize, 256] {
        let s = 4 * n;
        let a = DenseMatrix::gaussian(s, n, &mut g);
        let st = bench(&cfg, || qr::qr_compact(&a).unwrap());
        // flops ≈ 2·s·n² − (2/3)n³
        let fl = 2.0 * s as f64 * (n as f64).powi(2) - 2.0 / 3.0 * (n as f64).powi(3);
        table.row(vec![
            "hhqr".into(),
            format!("{s}x{n}"),
            threads_now.clone(),
            simd_now.clone(),
            format!("{:.6}", st.median),
            format!("{:.2}", fl / st.median / 1e9),
            "GFLOP/s".into(),
        ]);
    }

    // FWHT: bandwidth/latency bound butterfly.
    for logm in [16usize, 20] {
        let m = 1usize << logm;
        let x = g.gaussian_vec(m);
        let st = bench(&cfg, || {
            let mut y = x.clone();
            hadamard::fwht_inplace(&mut y).unwrap();
            y
        });
        let mops = (m as f64 * logm as f64) / st.median / 1e9;
        table.row(vec![
            "fwht".into(),
            format!("2^{logm}"),
            threads_now.clone(),
            simd_now.clone(),
            format!("{:.6}", st.median),
            format!("{mops:.2}"),
            "Gop/s".into(),
        ]);
    }

    // CountSketch apply: must run at streaming bandwidth (reads A once).
    for (m, n) in [(1usize << 16, 256usize), (1 << 18, 128)] {
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let op = CountSketch::new(4 * n, m, 7);
        let st = bench(&cfg, || op.apply_dense(&a));
        let gbs = (m * n * 8) as f64 / st.median / 1e9;
        table.row(vec![
            "countsketch".into(),
            format!("{m}x{n}"),
            threads_now.clone(),
            simd_now.clone(),
            format!("{:.6}", st.median),
            format!("{gbs:.2}"),
            "GB/s".into(),
        ]);
    }

    // CSR matvec: the LSQR inner loop on Figure-3 workloads.
    {
        let (m, n, per_row) = (1usize << 18, 1000usize, 5usize);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut bld = CooBuilder::with_capacity(m, n, m * per_row);
        for i in 0..m {
            for _ in 0..per_row {
                bld.push(i, rng.next_bounded(n as u64) as usize, 1.0);
            }
        }
        let a = bld.build();
        let x = g.gaussian_vec(n);
        let mut y = vec![0.0; m];
        let st = bench(&cfg, || a.matvec_into(&x, &mut y));
        let gbs = (a.nnz() * 12) as f64 / st.median / 1e9;
        table.row(vec![
            "csr_matvec".into(),
            format!("{m}x{n} nnz={}", a.nnz()),
            threads_now.clone(),
            simd_now.clone(),
            format!("{:.6}", st.median),
            format!("{gbs:.2}"),
            "GB/s".into(),
        ]);
    }

    // Right solve Y = A·R⁻¹ (SAA step 4) at service scale.
    {
        let (m, n) = (16384usize, 256usize);
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let f = qr::qr_compact(&DenseMatrix::gaussian(4 * n, n, &mut g)).unwrap();
        let r = f.r();
        let st = bench(&cfg, || triangular::right_solve_upper(&a, &r).unwrap());
        let fl = (m * n * n) as f64; // n²/2 MACs per row ≈ n² flops
        table.row(vec![
            "right_solve".into(),
            format!("{m}x{n}"),
            threads_now.clone(),
            simd_now.clone(),
            format!("{:.6}", st.median),
            format!("{:.2}", fl / st.median / 1e9),
            "GFLOP/s".into(),
        ]);
    }

    println!("{}", table.render());
    let _ = table.save("micro_linalg");

    // ---- parallel scaling sweep: GEMM + SRHT apply ----------------------
    let sweep = parse_threads_arg(&argv).unwrap_or_else(|| vec![1, 2, 4]);
    let sweep_table = run_threads_sweep(&sweep);
    println!("{}", sweep_table.render());
    let _ = sweep_table.save("micro_linalg_threads");

    // ---- SIMD backend sweep: every backend vs the scalar reference ------
    let simd_table = run_simd_sweep();
    println!("{}", simd_table.render());
    let _ = simd_table.save("micro_linalg_simd");

    // ---- packed vs unpacked GEMM + blocked vs unblocked QR --------------
    // The PR-4 perf record: saved as BENCH_micro_linalg.{json,csv} so the
    // trajectory (GFLOP/s packed vs unpacked at 2048³, blocked vs
    // unblocked QR at Figure-3 scale) is captured run over run.
    let tent_table = run_packed_blocked_sweep();
    println!("{}", tent_table.render());
    let _ = tent_table.save("BENCH_micro_linalg");

    // ---- static vs work-stealing scheduler on skewed workloads ----------
    // The PR-6 tentpole record: saved as BENCH_pool_schedule.{json,csv}.
    let pool_table = run_pool_schedule_sweep();
    println!("{}", pool_table.render());
    let _ = pool_table.save("BENCH_pool_schedule");

    // Restore the ambient thread/backend/packing/scheduler configuration.
    snsolve::parallel::set_threads(0);
    snsolve::parallel::set_schedule(None);
    snsolve::simd::clear_choice();
    snsolve::linalg::gemm::set_packing(None);
}

/// GEMM with and without BLIS-style packing (acceptance: packed ≥ 1.5x
/// unpacked GFLOP/s at 2048³) and Householder QR blocked vs unblocked
/// (acceptance: blocked ≥ 2x faster at Figure-3 scale, s=4000 n=1000),
/// at the ambient thread count and backend, with agreement cross-checks.
fn run_packed_blocked_sweep() -> Table {
    let mut table = Table::new(
        "packed panels & blocked QR — PR-4 tentpole record",
        &["kernel", "shape", "threads", "simd", "median_s", "gflops", "speedup", "max_rel_dev"],
    );
    let cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(41));
    let threads_now = threads_in_use().to_string();
    let simd_now = simd_in_use().to_string();

    // GEMM: packed vs unpacked, 2048³ is the acceptance point.
    for n in [512usize, 1024, 2048] {
        let a = DenseMatrix::gaussian(n, n, &mut g);
        let b = DenseMatrix::gaussian(n, n, &mut g);
        let flops = 2.0 * (n as f64).powi(3);
        snsolve::linalg::gemm::set_packing(Some(false));
        let c_unpacked = gemm::matmul(&a, &b).unwrap();
        let st_u = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
        snsolve::linalg::gemm::set_packing(Some(true));
        let c_packed = gemm::matmul(&a, &b).unwrap();
        let st_p = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
        snsolve::linalg::gemm::set_packing(None);
        let dev = max_abs_dev(c_packed.data(), c_unpacked.data())
            / c_unpacked.max_abs().max(1e-300);
        assert!(dev <= 1e-12, "packed vs unpacked rel dev {dev} at {n}");
        for (label, st, speedup) in [
            ("gemm_unpacked", &st_u, 1.0),
            ("gemm_packed", &st_p, st_u.median / st_p.median),
        ] {
            table.row(vec![
                label.into(),
                format!("{n}x{n}x{n}"),
                threads_now.clone(),
                simd_now.clone(),
                format!("{:.6}", st.median),
                format!("{:.2}", flops / st.median / 1e9),
                format!("{speedup:.2}"),
                format!("{dev:.2e}"),
            ]);
        }
    }

    // QR: blocked (NB=32) vs unblocked, up to Figure-3 scale.
    for (s, n) in [(1024usize, 256usize), (4000, 1000)] {
        let a = DenseMatrix::gaussian(s, n, &mut g);
        let fl = 2.0 * s as f64 * (n as f64).powi(2) - 2.0 / 3.0 * (n as f64).powi(3);
        let unblocked = qr::qr_compact_unblocked(&a).unwrap();
        let st_u = bench(&cfg, || qr::qr_compact_unblocked(&a).unwrap());
        let blocked = qr::qr_compact_blocked(&a, 32).unwrap();
        let st_b = bench(&cfg, || qr::qr_compact_blocked(&a, 32).unwrap());
        let ru = unblocked.r();
        let rb = blocked.r();
        let dev = max_abs_dev(rb.data(), ru.data()) / ru.max_abs().max(1e-300);
        assert!(dev <= 1e-11, "blocked vs unblocked R rel dev {dev} at {s}x{n}");
        for (label, st, speedup) in [
            ("hhqr_unblocked", &st_u, 1.0),
            ("hhqr_blocked_nb32", &st_b, st_u.median / st_b.median),
        ] {
            table.row(vec![
                label.into(),
                format!("{s}x{n}"),
                threads_now.clone(),
                simd_now.clone(),
                format!("{:.6}", st.median),
                format!("{:.2}", fl / st.median / 1e9),
                format!("{speedup:.2}"),
                format!("{dev:.2e}"),
            ]);
        }
    }
    table
}

/// Static vs work-stealing scheduler on skewed workloads — the PR-6
/// tentpole record. Two sweeps at pool sizes {2, 4, 7}:
///
/// * **Skewed CSR SpMV**: every heavy row (64 nnz) lands in the first
///   static band while the rest carry 4 nnz, so the static schedule
///   serializes on worker 0 and stealing rebalances.
/// * **Tall-skinny GEMM**: uniform per-row cost — the control where both
///   schedules should tie (and must still agree bitwise).
///
/// Each row records measured GFLOP/s under both schedules plus a
/// `model_speedup` column: the static schedule's critical-path work
/// divided by the balanced critical path `max(total/threads, heaviest
/// unit)` over the actual steal-unit decomposition. That ratio is the
/// machine-independent record of the imbalance — wall-clock speedup
/// converges to it when that many cores are actually idle, while a
/// single-core CI runner still verifies the bitwise static==steal
/// contract (asserted on every output). Acceptance: model_speedup ≥ 1.2
/// on the skewed sweep at 4+ threads.
fn run_pool_schedule_sweep() -> Table {
    use snsolve::parallel::{partition, plan_units, Schedule};
    let mut table = Table::new(
        "pool schedule — static vs work-stealing on skewed workloads",
        &[
            "kernel",
            "shape",
            "threads",
            "schedule",
            "median_s",
            "gflops",
            "speedup_vs_static",
            "model_speedup",
            "agreement",
        ],
    );
    let cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(53));
    let sweep = [2usize, 4, 7];

    // Skewed CSR SpMV: heavy head band, light tail.
    {
        let (m, n) = (1usize << 17, 512usize);
        let heavy = m / 8;
        let mut rng = Xoshiro256pp::seed_from_u64(54);
        let mut bld = CooBuilder::with_capacity(m, n, heavy * 64 + (m - heavy) * 4);
        for i in 0..m {
            let per_row = if i < heavy { 64 } else { 4 };
            for _ in 0..per_row {
                bld.push(i, rng.next_bounded(n as u64) as usize, g.next_gaussian());
            }
        }
        let a = bld.build();
        let x = g.gaussian_vec(n);
        let row_cost: Vec<f64> = (0..m).map(|i| a.row(i).0.len() as f64 + 1.0).collect();
        let total_cost: f64 = row_cost.iter().sum();
        let flops = 2.0 * a.nnz() as f64;

        for &t in &sweep {
            snsolve::parallel::set_threads(t);
            let static_crit = partition(m, t)
                .into_iter()
                .map(|r| row_cost[r].iter().sum::<f64>())
                .fold(0.0f64, f64::max);
            // Same auto-grain formula the steal planner uses.
            let grain = (m / (t * 8)).max(1);
            let max_unit = plan_units(m, t, grain, 1)
                .units
                .iter()
                .map(|u| row_cost[u.clone()].iter().sum::<f64>())
                .fold(0.0f64, f64::max);
            let model = static_crit / (total_cost / t as f64).max(max_unit);

            snsolve::parallel::set_schedule(Some(Schedule::Static));
            let mut y_static = vec![0.0; m];
            a.matvec_into(&x, &mut y_static);
            let st_static = bench(&cfg, || {
                let mut y = vec![0.0; m];
                a.matvec_into(&x, &mut y);
                y
            });
            snsolve::parallel::set_schedule(Some(Schedule::Steal));
            let mut y_steal = vec![0.0; m];
            a.matvec_into(&x, &mut y_steal);
            assert_eq!(y_static, y_steal, "skewed csr: steal != static bitwise at {t} threads");
            let st_steal = bench(&cfg, || {
                let mut y = vec![0.0; m];
                a.matvec_into(&x, &mut y);
                y
            });
            if t >= 4 {
                assert!(
                    model >= 1.2,
                    "skewed sweep model speedup {model:.2} < 1.2 at {t} threads"
                );
            }
            for (schedule, st, speedup) in [
                ("static", &st_static, 1.0),
                ("steal", &st_steal, st_static.median / st_steal.median),
            ] {
                table.row(vec![
                    "csr_matvec_skewed".into(),
                    format!("{m}x{n} nnz={} head-heavy", a.nnz()),
                    t.to_string(),
                    schedule.into(),
                    format!("{:.6}", st.median),
                    format!("{:.2}", flops / st.median / 1e9),
                    format!("{speedup:.2}"),
                    format!("{model:.2}"),
                    "bitwise".into(),
                ]);
            }
        }
    }

    // Tall-skinny GEMM: uniform per-row work — the no-imbalance control.
    {
        let (m, k, n) = (8192usize, 96usize, 64usize);
        let a = DenseMatrix::gaussian(m, k, &mut g);
        let b = DenseMatrix::gaussian(k, n, &mut g);
        let flops = 2.0 * (m * k * n) as f64;
        for &t in &sweep {
            snsolve::parallel::set_threads(t);
            // Uniform cost: the static critical path is the largest part.
            let static_crit =
                partition(m, t).into_iter().map(|r| r.len() as f64).fold(0.0f64, f64::max);
            let model = static_crit / (m as f64 / t as f64);

            snsolve::parallel::set_schedule(Some(Schedule::Static));
            let c_static = gemm::matmul(&a, &b).unwrap();
            let st_static = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
            snsolve::parallel::set_schedule(Some(Schedule::Steal));
            let c_steal = gemm::matmul(&a, &b).unwrap();
            assert_eq!(c_static, c_steal, "gemm: steal != static bitwise at {t} threads");
            let st_steal = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
            for (schedule, st, speedup) in [
                ("static", &st_static, 1.0),
                ("steal", &st_steal, st_static.median / st_steal.median),
            ] {
                table.row(vec![
                    "gemm_tall_skinny".into(),
                    format!("{m}x{k}x{n}"),
                    t.to_string(),
                    schedule.into(),
                    format!("{:.6}", st.median),
                    format!("{:.2}", flops / st.median / 1e9),
                    format!("{speedup:.2}"),
                    format!("{model:.2}"),
                    "bitwise".into(),
                ]);
            }
        }
    }

    snsolve::parallel::set_schedule(None);
    table
}

/// Time GEMM (m = 4096) and SRHT apply (m = 16384) at each pool size,
/// reporting speedup over a measured 1-thread baseline and max |dev| from
/// the serial result.
fn run_threads_sweep(sweep: &[usize]) -> Table {
    let mut table = Table::new(
        "threads sweep — parallel kernels vs 1-thread baseline",
        &["kernel", "shape", "threads", "median_s", "speedup_vs_1t", "max_abs_dev"],
    );
    let cfg = snsolve::bench_harness::BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(17));

    // GEMM at m = 4096 (acceptance: ≥2x at 4 threads).
    {
        let (m, k, n) = (4096usize, 256usize, 256usize);
        let a = DenseMatrix::gaussian(m, k, &mut g);
        let b = DenseMatrix::gaussian(k, n, &mut g);
        snsolve::parallel::set_threads(1);
        let reference = gemm::matmul(&a, &b).unwrap();
        let base = bench(&cfg, || gemm::matmul(&a, &b).unwrap()).median;
        for &t in sweep {
            snsolve::parallel::set_threads(t);
            let st = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
            let out = gemm::matmul(&a, &b).unwrap();
            let dev = max_abs_dev(reference.data(), out.data());
            assert!(dev <= 1e-12, "gemm parallel deviation {dev} at {t} threads");
            table.row(vec![
                "gemm".into(),
                format!("{m}x{k}x{n}"),
                t.to_string(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }

    // SRHT apply at m = 16384 (acceptance: ≥2x at 4 threads).
    {
        let (m, n, s) = (16384usize, 256usize, 1024usize);
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let op = SrhtSketch::new(s, m, 23);
        snsolve::parallel::set_threads(1);
        let reference = op.apply_dense(&a);
        let base = bench(&cfg, || op.apply_dense(&a)).median;
        for &t in sweep {
            snsolve::parallel::set_threads(t);
            let st = bench(&cfg, || op.apply_dense(&a));
            let out = op.apply_dense(&a);
            let dev = max_abs_dev(reference.data(), out.data());
            assert!(dev <= 1e-12, "srht parallel deviation {dev} at {t} threads");
            table.row(vec![
                "srht_apply".into(),
                format!("{m}x{n} s={s}"),
                t.to_string(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }

    table
}

/// Time the dispatched kernels (GEMM, dot, axpy, FWHT) at 1 thread on each
/// backend this host supports, reporting GFLOP/s, speedup over the scalar
/// backend, and the relative deviation from the scalar reference — the
/// cross-check line the SIMD determinism contract promises (≤ 1e-12;
/// FWHT must be bitwise).
fn run_simd_sweep() -> Table {
    let mut table = Table::new(
        "simd sweep — kernel backends vs scalar reference (1 thread)",
        &["kernel", "shape", "backend", "median_s", "gflops", "speedup_vs_scalar", "rel_dev"],
    );
    let cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(29));
    snsolve::parallel::set_threads(1);

    let n = 512usize;
    let a = DenseMatrix::gaussian(n, n, &mut g);
    let b = DenseMatrix::gaussian(n, n, &mut g);
    let len = 1usize << 20;
    let xv = g.gaussian_vec(len);
    let yv = g.gaussian_vec(len);

    // Scalar references and baseline timings.
    snsolve::simd::set_choice(snsolve::simd::SimdChoice::Scalar);
    let gemm_ref = gemm::matmul(&a, &b).unwrap();
    let gemm_scale = gemm_ref.max_abs().max(1e-300);
    let gemm_base = bench(&cfg, || gemm::matmul(&a, &b).unwrap()).median;
    let dot_ref = gemm::dot(&xv, &yv);
    let dot_scale: f64 = xv.iter().zip(yv.iter()).map(|(x, y)| (x * y).abs()).sum();
    let dot_base = bench(&cfg, || gemm::dot(&xv, &yv)).median;
    let axpy_ref = {
        let mut y = yv.clone();
        gemm::axpy(0.37, &xv, &mut y);
        y
    };
    let axpy_scale = axpy_ref.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1e-300);
    let axpy_base = bench(&cfg, || {
        let mut y = yv.clone();
        gemm::axpy(0.37, &xv, &mut y);
        y
    })
    .median;
    let fwht_ref = {
        let mut y = xv.clone();
        hadamard::fwht_inplace(&mut y).unwrap();
        y
    };
    let fwht_base = bench(&cfg, || {
        let mut y = xv.clone();
        hadamard::fwht_inplace(&mut y).unwrap();
        y
    })
    .median;

    for backend in snsolve::simd::available() {
        snsolve::simd::set_choice(backend.as_choice());
        assert_eq!(snsolve::simd::active(), backend, "backend failed to activate");

        // GEMM.
        let out = gemm::matmul(&a, &b).unwrap();
        let dev = max_abs_dev(out.data(), gemm_ref.data()) / gemm_scale;
        assert!(dev <= 1e-12, "{}: gemm rel dev {dev}", backend.name());
        let st = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
        table.row(vec![
            "gemm".into(),
            format!("{n}x{n}x{n}"),
            backend.name().into(),
            format!("{:.6}", st.median),
            format!("{:.2}", 2.0 * (n as f64).powi(3) / st.median / 1e9),
            format!("{:.2}", gemm_base / st.median),
            format!("{dev:.2e}"),
        ]);

        // dot.
        let d = gemm::dot(&xv, &yv);
        let dev = (d - dot_ref).abs() / dot_scale.max(1e-300);
        assert!(dev <= 1e-12, "{}: dot rel dev {dev}", backend.name());
        let st = bench(&cfg, || gemm::dot(&xv, &yv));
        table.row(vec![
            "dot".into(),
            "2^20".into(),
            backend.name().into(),
            format!("{:.6}", st.median),
            format!("{:.2}", 2.0 * len as f64 / st.median / 1e9),
            format!("{:.2}", dot_base / st.median),
            format!("{dev:.2e}"),
        ]);

        // axpy.
        let mut y = yv.clone();
        gemm::axpy(0.37, &xv, &mut y);
        let dev = max_abs_dev(&y, &axpy_ref) / axpy_scale;
        assert!(dev <= 1e-12, "{}: axpy rel dev {dev}", backend.name());
        let st = bench(&cfg, || {
            let mut y = yv.clone();
            gemm::axpy(0.37, &xv, &mut y);
            y
        });
        table.row(vec![
            "axpy".into(),
            "2^20".into(),
            backend.name().into(),
            format!("{:.6}", st.median),
            format!("{:.2}", 2.0 * len as f64 / st.median / 1e9),
            format!("{:.2}", axpy_base / st.median),
            format!("{dev:.2e}"),
        ]);

        // FWHT — adds/subs only: bitwise identical on every backend.
        let mut y = xv.clone();
        hadamard::fwht_inplace(&mut y).unwrap();
        assert_eq!(y, fwht_ref, "{}: fwht not bitwise vs scalar", backend.name());
        let st = bench(&cfg, || {
            let mut y = xv.clone();
            hadamard::fwht_inplace(&mut y).unwrap();
            y
        });
        table.row(vec![
            "fwht".into(),
            "2^20".into(),
            backend.name().into(),
            format!("{:.6}", st.median),
            format!("{:.2}", len as f64 * 20.0 / st.median / 1e9),
            format!("{:.2}", fwht_base / st.median),
            "0.0e0 (bitwise)".into(),
        ]);
    }
    table
}
