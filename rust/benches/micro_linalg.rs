//! Microbenchmarks of the L3 hot paths with achieved-vs-roofline context:
//! blocked GEMM (GFLOP/s), Householder QR, FWHT, CountSketch apply
//! (GB/s — bandwidth-bound), CSR matvec (the LSQR inner loop), and the
//! Y = A·R⁻¹ right solve. These drive the §Perf iteration log.
//!
//! `--threads 1,2,4` (or `--threads N`; default sweep {1, 2, 4}) also runs
//! the parallel-scaling sweep: GEMM and SRHT apply at each pool size, with
//! wall-clock speedup over the 1-thread baseline and the max deviation from
//! the serial result (must stay ≤ 1e-12).

use snsolve::bench_harness::report::Table;
use snsolve::bench_harness::{bench, config_from_env, max_abs_dev, parse_threads_arg, threads_in_use};
use snsolve::linalg::sparse::CooBuilder;
use snsolve::linalg::{gemm, hadamard, qr, triangular, DenseMatrix};
use snsolve::rng::{GaussianSource, RngCore, Xoshiro256pp};
use snsolve::sketch::{CountSketch, SketchOperator, SrhtSketch};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cfg = config_from_env();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(1));
    let mut table = Table::new(
        "micro — L3 hot paths (achieved throughput)",
        &["kernel", "shape", "threads", "median_s", "throughput", "unit"],
    );
    let threads_now = threads_in_use().to_string();

    // GEMM: C = A·B, classic compute-bound kernel.
    for n in [256usize, 512, 1024] {
        let a = DenseMatrix::gaussian(n, n, &mut g);
        let b = DenseMatrix::gaussian(n, n, &mut g);
        let st = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
        let gflops = 2.0 * (n as f64).powi(3) / st.median / 1e9;
        table.row(vec![
            "gemm".into(),
            format!("{n}x{n}x{n}"),
            threads_now.clone(),
            format!("{:.6}", st.median),
            format!("{gflops:.2}"),
            "GFLOP/s".into(),
        ]);
    }

    // Householder QR at sketch scale (s = 4n).
    for n in [128usize, 256] {
        let s = 4 * n;
        let a = DenseMatrix::gaussian(s, n, &mut g);
        let st = bench(&cfg, || qr::qr_compact(&a).unwrap());
        // flops ≈ 2·s·n² − (2/3)n³
        let fl = 2.0 * s as f64 * (n as f64).powi(2) - 2.0 / 3.0 * (n as f64).powi(3);
        table.row(vec![
            "hhqr".into(),
            format!("{s}x{n}"),
            threads_now.clone(),
            format!("{:.6}", st.median),
            format!("{:.2}", fl / st.median / 1e9),
            "GFLOP/s".into(),
        ]);
    }

    // FWHT: bandwidth/latency bound butterfly.
    for logm in [16usize, 20] {
        let m = 1usize << logm;
        let x = g.gaussian_vec(m);
        let st = bench(&cfg, || {
            let mut y = x.clone();
            hadamard::fwht_inplace(&mut y).unwrap();
            y
        });
        let mops = (m as f64 * logm as f64) / st.median / 1e9;
        table.row(vec![
            "fwht".into(),
            format!("2^{logm}"),
            threads_now.clone(),
            format!("{:.6}", st.median),
            format!("{mops:.2}"),
            "Gop/s".into(),
        ]);
    }

    // CountSketch apply: must run at streaming bandwidth (reads A once).
    for (m, n) in [(1usize << 16, 256usize), (1 << 18, 128)] {
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let op = CountSketch::new(4 * n, m, 7);
        let st = bench(&cfg, || op.apply_dense(&a));
        let gbs = (m * n * 8) as f64 / st.median / 1e9;
        table.row(vec![
            "countsketch".into(),
            format!("{m}x{n}"),
            threads_now.clone(),
            format!("{:.6}", st.median),
            format!("{gbs:.2}"),
            "GB/s".into(),
        ]);
    }

    // CSR matvec: the LSQR inner loop on Figure-3 workloads.
    {
        let (m, n, per_row) = (1usize << 18, 1000usize, 5usize);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut bld = CooBuilder::with_capacity(m, n, m * per_row);
        for i in 0..m {
            for _ in 0..per_row {
                bld.push(i, rng.next_bounded(n as u64) as usize, 1.0);
            }
        }
        let a = bld.build();
        let x = g.gaussian_vec(n);
        let mut y = vec![0.0; m];
        let st = bench(&cfg, || a.matvec_into(&x, &mut y));
        let gbs = (a.nnz() * 12) as f64 / st.median / 1e9;
        table.row(vec![
            "csr_matvec".into(),
            format!("{m}x{n} nnz={}", a.nnz()),
            threads_now.clone(),
            format!("{:.6}", st.median),
            format!("{gbs:.2}"),
            "GB/s".into(),
        ]);
    }

    // Right solve Y = A·R⁻¹ (SAA step 4) at service scale.
    {
        let (m, n) = (16384usize, 256usize);
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let f = qr::qr_compact(&DenseMatrix::gaussian(4 * n, n, &mut g)).unwrap();
        let r = f.r();
        let st = bench(&cfg, || triangular::right_solve_upper(&a, &r).unwrap());
        let fl = (m * n * n) as f64; // n²/2 MACs per row ≈ n² flops
        table.row(vec![
            "right_solve".into(),
            format!("{m}x{n}"),
            threads_now.clone(),
            format!("{:.6}", st.median),
            format!("{:.2}", fl / st.median / 1e9),
            "GFLOP/s".into(),
        ]);
    }

    println!("{}", table.render());
    let _ = table.save("micro_linalg");

    // ---- parallel scaling sweep: GEMM + SRHT apply ----------------------
    let sweep = parse_threads_arg(&argv).unwrap_or_else(|| vec![1, 2, 4]);
    let sweep_table = run_threads_sweep(&sweep);
    println!("{}", sweep_table.render());
    let _ = sweep_table.save("micro_linalg_threads");
    // Restore the ambient thread configuration.
    snsolve::parallel::set_threads(0);
}

/// Time GEMM (m = 4096) and SRHT apply (m = 16384) at each pool size,
/// reporting speedup over a measured 1-thread baseline and max |dev| from
/// the serial result.
fn run_threads_sweep(sweep: &[usize]) -> Table {
    let mut table = Table::new(
        "threads sweep — parallel kernels vs 1-thread baseline",
        &["kernel", "shape", "threads", "median_s", "speedup_vs_1t", "max_abs_dev"],
    );
    let cfg = snsolve::bench_harness::BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(17));

    // GEMM at m = 4096 (acceptance: ≥2x at 4 threads).
    {
        let (m, k, n) = (4096usize, 256usize, 256usize);
        let a = DenseMatrix::gaussian(m, k, &mut g);
        let b = DenseMatrix::gaussian(k, n, &mut g);
        snsolve::parallel::set_threads(1);
        let reference = gemm::matmul(&a, &b).unwrap();
        let base = bench(&cfg, || gemm::matmul(&a, &b).unwrap()).median;
        for &t in sweep {
            snsolve::parallel::set_threads(t);
            let st = bench(&cfg, || gemm::matmul(&a, &b).unwrap());
            let out = gemm::matmul(&a, &b).unwrap();
            let dev = max_abs_dev(reference.data(), out.data());
            assert!(dev <= 1e-12, "gemm parallel deviation {dev} at {t} threads");
            table.row(vec![
                "gemm".into(),
                format!("{m}x{k}x{n}"),
                t.to_string(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }

    // SRHT apply at m = 16384 (acceptance: ≥2x at 4 threads).
    {
        let (m, n, s) = (16384usize, 256usize, 1024usize);
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let op = SrhtSketch::new(s, m, 23);
        snsolve::parallel::set_threads(1);
        let reference = op.apply_dense(&a);
        let base = bench(&cfg, || op.apply_dense(&a)).median;
        for &t in sweep {
            snsolve::parallel::set_threads(t);
            let st = bench(&cfg, || op.apply_dense(&a));
            let out = op.apply_dense(&a);
            let dev = max_abs_dev(reference.data(), out.data());
            assert!(dev <= 1e-12, "srht parallel deviation {dev} at {t} threads");
            table.row(vec![
                "srht_apply".into(),
                format!("{m}x{n} s={s}"),
                t.to_string(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }

    table
}
