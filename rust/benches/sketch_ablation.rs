//! Bench: the **T-op** operator ablation (§2.2–2.3 — all six sketching
//! operators: apply time, subspace-embedding distortion, end-to-end SAA
//! time/error) and the **T-s** sketch-size sweep (s/n ratio).
//!
//! `--threads 1,2,4` (default {1, 2, 4}) additionally sweeps the sketch
//! *apply* kernels over pool sizes, asserting the parallel outputs match
//! the serial path within 1e-12; `--simd scalar|avx2|avx512|neon|auto` forces the
//! kernel backend for the main tables, and a final per-backend sweep times
//! every operator's apply on each backend the host supports with a scalar
//! cross-check line (GFLOP/s + relative deviation ≤ 1e-12).
//!
//! Output: console tables + target/bench-reports/
//! {sketch_operator_ablation, sketch_size_ablation, sketch_apply_threads,
//! sketch_apply_simd}.{csv,json}.

use snsolve::bench_harness::figures::{
    run_sketch_ablation, run_sketch_size_ablation, AblationConfig,
};
use snsolve::bench_harness::report::Table;
use snsolve::bench_harness::{
    bench, max_abs_dev, parse_simd_arg, parse_threads_arg, simd_in_use, threads_in_use,
    BenchConfig,
};
use snsolve::linalg::DenseMatrix;
use snsolve::rng::{GaussianSource, Xoshiro256pp};
use snsolve::sketch::{self, SketchKind, SketchOperator};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Some(choice) = parse_simd_arg(&argv) {
        snsolve::simd::set_choice(choice);
    }
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = if quick {
        AblationConfig { m: 4096, n: 128, ..Default::default() }
    } else {
        AblationConfig::default()
    };
    eprintln!(
        "ablation workload: {}x{} κ={:.0e} (quick={quick}, threads={}, simd={})",
        cfg.m,
        cfg.n,
        cfg.cond,
        threads_in_use(),
        simd_in_use()
    );
    let t1 = run_sketch_ablation(&cfg);
    println!("{}", t1.render());
    let _ = t1.save("sketch_operator_ablation");
    let t2 = run_sketch_size_ablation(&cfg);
    println!("{}", t2.render());
    let _ = t2.save("sketch_size_ablation");

    // ---- sketch-apply thread sweep --------------------------------------
    let sweep = parse_threads_arg(&argv).unwrap_or_else(|| vec![1, 2, 4]);
    let t3 = run_apply_threads_sweep(&cfg, &sweep);
    println!("{}", t3.render());
    let _ = t3.save("sketch_apply_threads");

    // ---- sketch-apply SIMD backend sweep --------------------------------
    let t4 = run_apply_simd_sweep(&cfg);
    println!("{}", t4.render());
    let _ = t4.save("sketch_apply_simd");
    snsolve::parallel::set_threads(0);
    snsolve::simd::clear_choice();
}

/// Time every operator's `apply_dense` at 1 thread on each backend this
/// host supports; speedup and the relative-deviation cross-check line are
/// against the scalar backend (≤ 1e-12 — the SIMD determinism contract).
fn run_apply_simd_sweep(cfg: &AblationConfig) -> Table {
    let mut table = Table::new(
        "T-simd — sketch apply time per kernel backend",
        &["operator", "shape", "backend", "apply_s", "speedup_vs_scalar", "rel_dev"],
    );
    let bench_cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(cfg.seed));
    let a = DenseMatrix::gaussian(cfg.m, cfg.n, &mut g);
    let s_rows = 4 * cfg.n;
    snsolve::parallel::set_threads(1);
    for kind in SketchKind::ALL {
        let op = sketch::build(kind, s_rows, cfg.m, cfg.seed ^ 0xAB);
        snsolve::simd::set_choice(snsolve::simd::SimdChoice::Scalar);
        let reference = op.apply_dense(&a);
        let scale = reference.max_abs().max(1e-300);
        let base = bench(&bench_cfg, || op.apply_dense(&a)).median;
        for backend in snsolve::simd::available() {
            snsolve::simd::set_choice(backend.as_choice());
            let out = op.apply_dense(&a);
            let dev = max_abs_dev(out.data(), reference.data()) / scale;
            assert!(dev <= 1e-12, "{}: rel dev {dev} on {}", kind.name(), backend.name());
            let st = bench(&bench_cfg, || op.apply_dense(&a));
            table.row(vec![
                kind.name().to_string(),
                format!("{}x{}", cfg.m, cfg.n),
                backend.name().into(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }
    table
}

/// Time every operator's `apply_dense` at each pool size; speedup is over
/// a measured 1-thread baseline, and outputs are checked against serial.
fn run_apply_threads_sweep(cfg: &AblationConfig, sweep: &[usize]) -> Table {
    let mut table = Table::new(
        "T-threads — sketch apply time vs pool size",
        &["operator", "shape", "threads", "apply_s", "speedup_vs_1t", "max_abs_dev"],
    );
    let bench_cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(cfg.seed));
    let a = DenseMatrix::gaussian(cfg.m, cfg.n, &mut g);
    let s_rows = 4 * cfg.n;
    for kind in SketchKind::ALL {
        let op = sketch::build(kind, s_rows, cfg.m, cfg.seed ^ 0xAB);
        snsolve::parallel::set_threads(1);
        let reference = op.apply_dense(&a);
        let base = bench(&bench_cfg, || op.apply_dense(&a)).median;
        for &t in sweep {
            snsolve::parallel::set_threads(t);
            let st = bench(&bench_cfg, || op.apply_dense(&a));
            let out = op.apply_dense(&a);
            let dev = max_abs_dev(out.data(), reference.data());
            assert!(
                dev <= 1e-12,
                "{}: parallel deviation {dev} at {t} threads",
                kind.name()
            );
            table.row(vec![
                kind.name().to_string(),
                format!("{}x{}", cfg.m, cfg.n),
                t.to_string(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }
    table
}
