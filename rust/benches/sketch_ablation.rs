//! Bench: the **T-op** operator ablation (§2.2–2.3 — all six sketching
//! operators: apply time, subspace-embedding distortion, end-to-end SAA
//! time/error) and the **T-s** sketch-size sweep (s/n ratio).
//!
//! Output: console tables + target/bench-reports/
//! {sketch_operator_ablation, sketch_size_ablation}.{csv,json}.

use snsolve::bench_harness::figures::{
    run_sketch_ablation, run_sketch_size_ablation, AblationConfig,
};

fn main() {
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = if quick {
        AblationConfig { m: 4096, n: 128, ..Default::default() }
    } else {
        AblationConfig::default()
    };
    eprintln!("ablation workload: {}x{} κ={:.0e} (quick={quick})", cfg.m, cfg.n, cfg.cond);
    let t1 = run_sketch_ablation(&cfg);
    println!("{}", t1.render());
    let _ = t1.save("sketch_operator_ablation");
    let t2 = run_sketch_size_ablation(&cfg);
    println!("{}", t2.render());
    let _ = t2.save("sketch_size_ablation");
}
