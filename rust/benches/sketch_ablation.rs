//! Bench: the **T-op** operator ablation (§2.2–2.3 — all six sketching
//! operators: apply time, subspace-embedding distortion, end-to-end SAA
//! time/error) and the **T-s** sketch-size sweep (s/n ratio).
//!
//! `--threads 1,2,4` (default {1, 2, 4}) additionally sweeps the sketch
//! *apply* kernels over pool sizes, asserting the parallel outputs match
//! the serial path within 1e-12; `--simd scalar|avx2|avx512|neon|auto` forces the
//! kernel backend for the main tables, and a final per-backend sweep times
//! every operator's apply on each backend the host supports with a scalar
//! cross-check line (GFLOP/s + relative deviation ≤ 1e-12).
//!
//! The **sketch-engine sweep** (the PR-5 tentpole record) times every
//! operator's apply with effective GB/s alongside GFLOP/s, the stage-fused
//! blocked FWHT (radix 2/4/8) against the stage-per-pass baseline at
//! m̃ ∈ {2¹⁶, 2¹⁸, 2²⁰}, and the inverted-hash scatter against the
//! band-rescan baseline at 1/4 threads — every comparison **bitwise**
//! cross-checked — and saves `BENCH_sketch_apply.{json,csv}` so the
//! sketch-stage perf trajectory is tracked like `BENCH_micro_linalg`.
//!
//! Output: console tables + target/bench-reports/
//! {sketch_operator_ablation, sketch_size_ablation, sketch_apply_threads,
//! sketch_apply_simd, BENCH_sketch_apply}.{csv,json}.

use snsolve::bench_harness::figures::{
    run_sketch_ablation, run_sketch_size_ablation, AblationConfig,
};
use snsolve::bench_harness::report::Table;
use snsolve::bench_harness::{
    bench, max_abs_dev, parse_simd_arg, parse_threads_arg, simd_in_use, threads_in_use,
    BenchConfig, Stats,
};
use snsolve::linalg::{hadamard, DenseMatrix};
use snsolve::rng::{GaussianSource, Xoshiro256pp};
use snsolve::sketch::{self, SketchKind, SketchOperator};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Some(choice) = parse_simd_arg(&argv) {
        snsolve::simd::set_choice(choice);
    }
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = if quick {
        AblationConfig { m: 4096, n: 128, ..Default::default() }
    } else {
        AblationConfig::default()
    };
    eprintln!(
        "ablation workload: {}x{} κ={:.0e} (quick={quick}, threads={}, simd={})",
        cfg.m,
        cfg.n,
        cfg.cond,
        threads_in_use(),
        simd_in_use()
    );
    let t1 = run_sketch_ablation(&cfg);
    println!("{}", t1.render());
    let _ = t1.save("sketch_operator_ablation");
    let t2 = run_sketch_size_ablation(&cfg);
    println!("{}", t2.render());
    let _ = t2.save("sketch_size_ablation");

    // ---- sketch-apply thread sweep --------------------------------------
    let sweep = parse_threads_arg(&argv).unwrap_or_else(|| vec![1, 2, 4]);
    let t3 = run_apply_threads_sweep(&cfg, &sweep);
    println!("{}", t3.render());
    let _ = t3.save("sketch_apply_threads");

    // ---- sketch-apply SIMD backend sweep --------------------------------
    let t4 = run_apply_simd_sweep(&cfg);
    println!("{}", t4.render());
    let _ = t4.save("sketch_apply_simd");

    // ---- sketch-engine sweep (PR-5 tentpole record) ---------------------
    // Reset to the ambient pool size / dispatched backend first so the
    // record reflects the default engine configuration.
    snsolve::parallel::set_threads(0);
    snsolve::simd::clear_choice();
    if let Some(choice) = parse_simd_arg(&argv) {
        snsolve::simd::set_choice(choice);
    }
    let t5 = run_sketch_engine_sweep(&cfg, quick);
    println!("{}", t5.render());
    let _ = t5.save("BENCH_sketch_apply");

    snsolve::parallel::set_threads(0);
    snsolve::simd::clear_choice();
    snsolve::linalg::hadamard::set_fwht_radix(None);
    snsolve::sketch::set_inverted_scatter(None);
}

/// The sketch-engine perf record: (a) every operator's `apply_dense` with
/// effective GB/s (bytes moved / wall time — input + output traffic)
/// alongside GFLOP/s; (b) the stage-fused blocked FWHT at radix 2/4/8 vs
/// the stage-per-pass baseline (acceptance: fused beats baseline at
/// m̃ ≥ 2¹⁸); (c) the inverted-hash scatter vs the band-rescan baseline
/// for the three sparse operators at 1 and 4 threads (acceptance:
/// inverted wins at ≥ 4 threads). Every compared pair is asserted
/// **bitwise identical** — the engine's structural guarantee.
fn run_sketch_engine_sweep(cfg: &AblationConfig, quick: bool) -> Table {
    let mut table = Table::new(
        "T-engine — sketch engine: fused FWHT, inverted scatter, GB/s",
        &["kernel", "shape", "threads", "variant", "median_s", "gflops", "gbs", "speedup_vs_baseline", "bitwise"],
    );
    let bench_cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(cfg.seed ^ 0x5E11));

    // (a) per-operator apply with GB/s next to GFLOP/s (ambient threads).
    let a = DenseMatrix::gaussian(cfg.m, cfg.n, &mut g);
    let s_rows = 4 * cfg.n;
    let threads_now = threads_in_use().to_string();
    for kind in SketchKind::ALL {
        let op = sketch::build(kind, s_rows, cfg.m, cfg.seed ^ 0xAB);
        let st = bench(&bench_cfg, || op.apply_dense(&a));
        let flops = op.flops_estimate(cfg.n, cfg.m * cfg.n);
        let bytes = ((cfg.m + s_rows) * cfg.n * 8) as f64;
        table.row(vec![
            format!("apply_{}", kind.name()),
            format!("{}x{}", cfg.m, cfg.n),
            threads_now.clone(),
            "engine".into(),
            format!("{:.6}", st.median),
            format!("{:.2}", flops / st.median / 1e9),
            format!("{:.2}", bytes / st.median / 1e9),
            "1.00".into(),
            "-".into(),
        ]);
    }

    // (b) stage-fused blocked FWHT vs the stage-per-pass baseline.
    let fwht_logs: &[usize] = if quick { &[14, 16] } else { &[16, 18, 20] };
    let fwht_cols = 32usize;
    for &logm in fwht_logs {
        let rows = 1usize << logm;
        let data = g.gaussian_vec(rows * fwht_cols);
        let mut base_out = data.clone();
        hadamard::fwht_columns_with_radix(&mut base_out, rows, fwht_cols, 1).unwrap();
        let st_base = bench(&bench_cfg, || {
            let mut d = data.clone();
            hadamard::fwht_columns_with_radix(&mut d, rows, fwht_cols, 1).unwrap();
            d
        });
        // clone cost is shared by every variant; report the ops rate over
        // the butterfly work m̃·n·log₂ m̃.
        let ops = (rows * fwht_cols * logm) as f64;
        let bytes = (rows * fwht_cols * 8) as f64 * logm as f64 * 2.0;
        table.row(vec![
            "fwht_columns".into(),
            format!("2^{logm}x{fwht_cols}"),
            threads_now.clone(),
            "stagewise(r1)".into(),
            format!("{:.6}", st_base.median),
            format!("{:.2}", ops / st_base.median / 1e9),
            format!("{:.2}", bytes / st_base.median / 1e9),
            "1.00".into(),
            "ref".into(),
        ]);
        for radix in [2usize, 4, 8] {
            let mut out = data.clone();
            hadamard::fwht_columns_with_radix(&mut out, rows, fwht_cols, radix).unwrap();
            assert_eq!(out, base_out, "fused radix-{radix} FWHT not bitwise at 2^{logm}");
            let st = bench(&bench_cfg, || {
                let mut d = data.clone();
                hadamard::fwht_columns_with_radix(&mut d, rows, fwht_cols, radix).unwrap();
                d
            });
            // Fused passes touch the buffer fewer times; keep the
            // baseline's byte model so the column stays comparable.
            table.row(vec![
                "fwht_columns".into(),
                format!("2^{logm}x{fwht_cols}"),
                threads_now.clone(),
                format!("fused(r{radix})"),
                format!("{:.6}", st.median),
                format!("{:.2}", ops / st.median / 1e9),
                format!("{:.2}", bytes / st.median / 1e9),
                format!("{:.2}", st_base.median / st.median),
                "bitwise".into(),
            ]);
        }
    }

    // (c) inverted-hash scatter vs band-rescan, sparse operators only. At
    // 1 thread the serial streaming pass never consults the layout flag,
    // so it is recorded once as the `serial` baseline; the rescan and
    // inverted variants are measured where they actually diverge (4
    // threads). `speedup_vs_baseline` is vs serial for the rescan row and
    // vs rescan for the inverted row (the acceptance comparison).
    let sparse_kinds =
        [SketchKind::CountSketch, SketchKind::SparseSign, SketchKind::UniformSparse];
    for kind in sparse_kinds {
        let op = sketch::build(kind, s_rows, cfg.m, cfg.seed ^ 0xAB);
        let flops = op.flops_estimate(cfg.n, cfg.m * cfg.n);
        let bytes = ((cfg.m + s_rows) * cfg.n * 8) as f64;
        let mut scatter_row = |threads: usize, variant: String, st: &Stats, speedup: f64| {
            table.row(vec![
                format!("scatter_{}", kind.name()),
                format!("{}x{}", cfg.m, cfg.n),
                threads.to_string(),
                variant,
                format!("{:.6}", st.median),
                format!("{:.2}", flops / st.median / 1e9),
                format!("{:.2}", bytes / st.median / 1e9),
                format!("{speedup:.2}"),
                "bitwise".into(),
            ]);
        };
        snsolve::parallel::set_threads(1);
        let serial_out = op.apply_dense(&a);
        let st_serial = bench(&bench_cfg, || op.apply_dense(&a));
        scatter_row(1, "serial".into(), &st_serial, 1.0);

        snsolve::parallel::set_threads(4);
        snsolve::sketch::set_inverted_scatter(Some(false));
        let rescan_out = op.apply_dense(&a);
        let st_rescan = bench(&bench_cfg, || op.apply_dense(&a));
        snsolve::sketch::set_inverted_scatter(Some(true));
        let inv_out = op.apply_dense(&a);
        let st_inv = bench(&bench_cfg, || op.apply_dense(&a));
        snsolve::sketch::set_inverted_scatter(None);
        assert_eq!(
            rescan_out.data(),
            serial_out.data(),
            "{}: rescan not bitwise vs serial at 4 threads",
            kind.name()
        );
        assert_eq!(
            inv_out.data(),
            rescan_out.data(),
            "{}: inverted scatter not bitwise at 4 threads",
            kind.name()
        );
        scatter_row(4, "rescan".into(), &st_rescan, st_serial.median / st_rescan.median);
        scatter_row(4, "inverted".into(), &st_inv, st_rescan.median / st_inv.median);
    }
    snsolve::parallel::set_threads(0);
    table
}

/// Time every operator's `apply_dense` at 1 thread on each backend this
/// host supports; speedup and the relative-deviation cross-check line are
/// against the scalar backend (≤ 1e-12 — the SIMD determinism contract).
fn run_apply_simd_sweep(cfg: &AblationConfig) -> Table {
    let mut table = Table::new(
        "T-simd — sketch apply time per kernel backend",
        &["operator", "shape", "backend", "apply_s", "speedup_vs_scalar", "rel_dev"],
    );
    let bench_cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(cfg.seed));
    let a = DenseMatrix::gaussian(cfg.m, cfg.n, &mut g);
    let s_rows = 4 * cfg.n;
    snsolve::parallel::set_threads(1);
    for kind in SketchKind::ALL {
        let op = sketch::build(kind, s_rows, cfg.m, cfg.seed ^ 0xAB);
        snsolve::simd::set_choice(snsolve::simd::SimdChoice::Scalar);
        let reference = op.apply_dense(&a);
        let scale = reference.max_abs().max(1e-300);
        let base = bench(&bench_cfg, || op.apply_dense(&a)).median;
        for backend in snsolve::simd::available() {
            snsolve::simd::set_choice(backend.as_choice());
            let out = op.apply_dense(&a);
            let dev = max_abs_dev(out.data(), reference.data()) / scale;
            assert!(dev <= 1e-12, "{}: rel dev {dev} on {}", kind.name(), backend.name());
            let st = bench(&bench_cfg, || op.apply_dense(&a));
            table.row(vec![
                kind.name().to_string(),
                format!("{}x{}", cfg.m, cfg.n),
                backend.name().into(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }
    table
}

/// Time every operator's `apply_dense` at each pool size; speedup is over
/// a measured 1-thread baseline, and outputs are checked against serial.
fn run_apply_threads_sweep(cfg: &AblationConfig, sweep: &[usize]) -> Table {
    let mut table = Table::new(
        "T-threads — sketch apply time vs pool size",
        &["operator", "shape", "threads", "apply_s", "speedup_vs_1t", "max_abs_dev"],
    );
    let bench_cfg = BenchConfig::quick();
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(cfg.seed));
    let a = DenseMatrix::gaussian(cfg.m, cfg.n, &mut g);
    let s_rows = 4 * cfg.n;
    for kind in SketchKind::ALL {
        let op = sketch::build(kind, s_rows, cfg.m, cfg.seed ^ 0xAB);
        snsolve::parallel::set_threads(1);
        let reference = op.apply_dense(&a);
        let base = bench(&bench_cfg, || op.apply_dense(&a)).median;
        for &t in sweep {
            snsolve::parallel::set_threads(t);
            let st = bench(&bench_cfg, || op.apply_dense(&a));
            let out = op.apply_dense(&a);
            let dev = max_abs_dev(out.data(), reference.data());
            assert!(
                dev <= 1e-12,
                "{}: parallel deviation {dev} at {t} threads",
                kind.name()
            );
            table.row(vec![
                kind.name().to_string(),
                format!("{}x{}", cfg.m, cfg.n),
                t.to_string(),
                format!("{:.6}", st.median),
                format!("{:.2}", base / st.median),
                format!("{dev:.2e}"),
            ]);
        }
    }
    table
}
