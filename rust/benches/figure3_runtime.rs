//! Bench: regenerate **Figure 3** — runtime of SAA-SAS vs deterministic
//! LSQR on sparse problems with m ∈ logspace(2¹², 2²⁰), n = 1000.
//!
//! `cargo bench --bench figure3_runtime` runs the paper sweep;
//! `SNSOLVE_BENCH_QUICK=1` (or `make bench-smoke`) runs a reduced sweep.
//! Output: console table + target/bench-reports/figure3_runtime.{csv,json}.

use snsolve::bench_harness::figures::{run_figure3, Figure3Config};

fn main() {
    let quick = std::env::var("SNSOLVE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = if quick { Figure3Config::smoke() } else { Figure3Config::paper() };
    eprintln!(
        "figure3: {} sizes in [{}, {}], n = {} (quick={quick})",
        cfg.sizes.len(),
        cfg.sizes.first().unwrap(),
        cfg.sizes.last().unwrap(),
        cfg.n
    );
    let t = run_figure3(&cfg);
    println!("{}", t.render());
    match t.save("figure3_runtime") {
        Ok(p) => eprintln!("saved {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}
