//! Portable scalar reference kernels.
//!
//! These are the seed implementations (the pre-SIMD `gemm.rs` microkernel
//! and unrolled BLAS-1 loops), kept as the IEEE ground truth the SIMD
//! backends are cross-checked against and as the fallback on hosts without
//! AVX2/NEON (or under `SNSOLVE_SIMD=scalar`).

use super::{Backend, SimdKernels};

const MR: usize = 4;
const NR: usize = 8;

pub struct ScalarKernels;

impl SimdKernels for ScalarKernels {
    fn backend(&self) -> Backend {
        Backend::Scalar
    }

    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    /// Full 4x8 register-tile microkernel; the compiler maps the 32 live
    /// accumulators onto vector registers on its own.
    #[allow(clippy::too_many_arguments)]
    fn gemm_tile(
        &self,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        k: usize,
        n: usize,
        i0: usize,
        j0: usize,
        pc: usize,
        kc: usize,
    ) {
        let mut acc = [[0.0f64; NR]; MR];
        let a0 = i0 * k + pc;
        let a1 = (i0 + 1) * k + pc;
        let a2 = (i0 + 2) * k + pc;
        let a3 = (i0 + 3) * k + pc;
        for p in 0..kc {
            let bp = (pc + p) * n + j0;
            let brow = &b[bp..bp + NR];
            let av = [a[a0 + p], a[a1 + p], a[a2 + p], a[a3 + p]];
            for (r, &ar) in av.iter().enumerate() {
                for (s, &bv) in brow.iter().enumerate() {
                    acc[r][s] += ar * bv;
                }
            }
        }
        for (r, row) in acc.iter().enumerate() {
            let cp = (i0 + r) * n + j0;
            for (s, &v) in row.iter().enumerate() {
                c[cp + s] += v;
            }
        }
    }

    /// Packed 4x8 tile: same 32 live accumulators and the same ascending-p
    /// element order as `gemm_tile` — only the operand addressing changes
    /// (contiguous strip/panel instead of strided rows), so full tiles are
    /// bitwise identical to the direct tile.
    #[allow(clippy::too_many_arguments)]
    fn gemm_tile_packed(
        &self,
        ap: &[f64],
        bp: &[f64],
        c: &mut [f64],
        ldc: usize,
        i0: usize,
        j0: usize,
        kc: usize,
        mr: usize,
        nr: usize,
    ) {
        debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
        let mut acc = [[0.0f64; NR]; MR];
        for p in 0..kc {
            let av = &ap[p * MR..p * MR + MR];
            let bv = &bp[p * NR..p * NR + NR];
            for (r, &ar) in av.iter().enumerate() {
                for (s, &bs) in bv.iter().enumerate() {
                    acc[r][s] += ar * bs;
                }
            }
        }
        for (r, row) in acc.iter().enumerate().take(mr) {
            let cp = (i0 + r) * ldc + j0;
            for (s, &v) in row.iter().enumerate().take(nr) {
                c[cp + s] += v;
            }
        }
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in chunks * 4..n {
            s += a[i] * b[i];
        }
        s
    }

    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4;
        for c in 0..chunks {
            let i = c * 4;
            y[i] += alpha * x[i];
            y[i + 1] += alpha * x[i + 1];
            y[i + 2] += alpha * x[i + 2];
            y[i + 3] += alpha * x[i + 3];
        }
        for i in chunks * 4..n {
            y[i] += alpha * x[i];
        }
    }

    fn scal(&self, alpha: f64, x: &mut [f64]) {
        for v in x.iter_mut() {
            *v *= alpha;
        }
    }

    fn butterfly(&self, a: &mut [f64], b: &mut [f64]) {
        debug_assert_eq!(a.len(), b.len());
        for (x, y) in a.iter_mut().zip(b.iter_mut()) {
            let u = *x;
            let v = *y;
            *x = u + v;
            *y = u - v;
        }
    }

    fn butterfly4(&self, r0: &mut [f64], r1: &mut [f64], r2: &mut [f64], r3: &mut [f64]) {
        debug_assert!(r0.len() == r1.len() && r1.len() == r2.len() && r2.len() == r3.len());
        for i in 0..r0.len() {
            let (o0, o1, o2, o3) = super::butterfly4_lane(r0[i], r1[i], r2[i], r3[i]);
            r0[i] = o0;
            r1[i] = o1;
            r2[i] = o2;
            r3[i] = o3;
        }
    }

    fn butterfly8(&self, r: [&mut [f64]; 8]) {
        let n = r[0].len();
        debug_assert!(r.iter().all(|s| s.len() == n));
        let [r0, r1, r2, r3, r4, r5, r6, r7] = r;
        for i in 0..n {
            let o = super::butterfly8_lane([
                r0[i], r1[i], r2[i], r3[i], r4[i], r5[i], r6[i], r7[i],
            ]);
            r0[i] = o[0];
            r1[i] = o[1];
            r2[i] = o[2];
            r3[i] = o[3];
            r4[i] = o[4];
            r5[i] = o[5];
            r6[i] = o[6];
            r7[i] = o[7];
        }
    }
}
