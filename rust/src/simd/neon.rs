//! aarch64 NEON kernels.
//!
//! NEON is architecturally mandatory on aarch64, so there is no runtime
//! probe — compile-time cfg is the detection. 128-bit vectors hold two
//! doubles; the 4x8 GEMM tile uses 16 q-register accumulators (4 rows ×
//! 4 vectors) out of the 32 available, leaving room for the B row and the
//! A broadcast.
//!
//! Accumulation order matches the scalar reference (ascending depth,
//! per-lane); divergence from scalar is FMA contraction / lane-partitioned
//! partial sums only — ≤ 1e-12 relative on the tested workloads.

use core::arch::aarch64::{
    float64x2_t, vaddq_f64, vaddvq_f64, vdupq_n_f64, vfmaq_f64, vld1q_f64, vmulq_f64, vst1q_f64,
    vsubq_f64,
};

use super::{Backend, SimdKernels};

const MR: usize = 4;
const NR: usize = 8;

pub struct NeonKernels;

impl SimdKernels for NeonKernels {
    fn backend(&self) -> Backend {
        Backend::Neon
    }

    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_tile(
        &self,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        k: usize,
        n: usize,
        i0: usize,
        j0: usize,
        pc: usize,
        kc: usize,
    ) {
        // SAFETY: NEON is always present on aarch64; bounds are checked
        // inside (safe panic, never OOB).
        unsafe { gemm_tile_neon(a, b, c, k, n, i0, j0, pc, kc) }
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_tile_packed(
        &self,
        ap: &[f64],
        bp: &[f64],
        c: &mut [f64],
        ldc: usize,
        i0: usize,
        j0: usize,
        kc: usize,
        mr: usize,
        nr: usize,
    ) {
        // SAFETY: NEON is always present on aarch64; bounds are checked
        // inside (safe panic, never OOB).
        unsafe { gemm_tile_packed_neon(ap, bp, c, ldc, i0, j0, kc, mr, nr) }
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        // SAFETY: NEON is always present on aarch64.
        unsafe { dot_neon(a, b) }
    }

    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), y.len());
        // SAFETY: NEON is always present on aarch64.
        unsafe { axpy_neon(alpha, x, y) }
    }

    fn scal(&self, alpha: f64, x: &mut [f64]) {
        // SAFETY: NEON is always present on aarch64.
        unsafe { scal_neon(alpha, x) }
    }

    fn butterfly(&self, a: &mut [f64], b: &mut [f64]) {
        assert_eq!(a.len(), b.len());
        // SAFETY: NEON is always present on aarch64.
        unsafe { butterfly_neon(a, b) }
    }

    fn butterfly4(&self, r0: &mut [f64], r1: &mut [f64], r2: &mut [f64], r3: &mut [f64]) {
        assert!(r0.len() == r1.len() && r1.len() == r2.len() && r2.len() == r3.len());
        // SAFETY: NEON is always present on aarch64.
        unsafe { butterfly4_neon(r0, r1, r2, r3) }
    }

    fn butterfly8(&self, r: [&mut [f64]; 8]) {
        let n = r[0].len();
        assert!(r.iter().all(|s| s.len() == n));
        // SAFETY: NEON is always present on aarch64.
        unsafe { butterfly8_neon(r) }
    }
}

/// 4x8 register-tile `C += A·B` over `kc` depth steps.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn gemm_tile_neon(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    pc: usize,
    kc: usize,
) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        assert!(kc > 0 && (i0 + MR - 1) * k + pc + kc <= a.len());
        assert!((pc + kc - 1) * n + j0 + NR <= b.len());
        assert!((i0 + MR - 1) * n + j0 + NR <= c.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let zero: float64x2_t = vdupq_n_f64(0.0);
        let mut acc = [[zero; 4]; MR];
        let a_off = [i0 * k + pc, (i0 + 1) * k + pc, (i0 + 2) * k + pc, (i0 + 3) * k + pc];
        for p in 0..kc {
            let brow = bp.add((pc + p) * n + j0);
            let b0 = vld1q_f64(brow);
            let b1 = vld1q_f64(brow.add(2));
            let b2 = vld1q_f64(brow.add(4));
            let b3 = vld1q_f64(brow.add(6));
            for r in 0..MR {
                let ar = vdupq_n_f64(*ap.add(a_off[r] + p));
                acc[r][0] = vfmaq_f64(acc[r][0], ar, b0);
                acc[r][1] = vfmaq_f64(acc[r][1], ar, b1);
                acc[r][2] = vfmaq_f64(acc[r][2], ar, b2);
                acc[r][3] = vfmaq_f64(acc[r][3], ar, b3);
            }
        }
        for (r, row) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i0 + r) * n + j0);
            for (s, &v) in row.iter().enumerate() {
                let cp = crow.add(2 * s);
                vst1q_f64(cp, vaddq_f64(vld1q_f64(cp), v));
            }
        }
    }
}

/// Packed 4x8 tile: identical FMA sequence to `gemm_tile_neon` (ascending
/// depth, four q-register columns per row), reading the contiguous pack
/// strip / panel — full tiles are bitwise identical to the direct tile.
/// Ragged tiles (zero-padded in the pack) spill and mask the write-back.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn gemm_tile_packed_neon(
    ap: &[f64],
    bp: &[f64],
    c: &mut [f64],
    ldc: usize,
    i0: usize,
    j0: usize,
    kc: usize,
    mr: usize,
    nr: usize,
) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        assert!(kc > 0 && mr <= MR && nr <= NR);
        assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
        assert!((i0 + mr - 1) * ldc + j0 + nr <= c.len());
        let app = ap.as_ptr();
        let bpp = bp.as_ptr();
        let zero: float64x2_t = vdupq_n_f64(0.0);
        let mut acc = [[zero; 4]; MR];
        for p in 0..kc {
            let brow = bpp.add(p * NR);
            let b0 = vld1q_f64(brow);
            let b1 = vld1q_f64(brow.add(2));
            let b2 = vld1q_f64(brow.add(4));
            let b3 = vld1q_f64(brow.add(6));
            let arow = app.add(p * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let ar = vdupq_n_f64(*arow.add(r));
                accr[0] = vfmaq_f64(accr[0], ar, b0);
                accr[1] = vfmaq_f64(accr[1], ar, b1);
                accr[2] = vfmaq_f64(accr[2], ar, b2);
                accr[3] = vfmaq_f64(accr[3], ar, b3);
            }
        }
        if mr == MR && nr == NR {
            for (r, row) in acc.iter().enumerate() {
                let crow = c.as_mut_ptr().add((i0 + r) * ldc + j0);
                for (s, &v) in row.iter().enumerate() {
                    let cp = crow.add(2 * s);
                    vst1q_f64(cp, vaddq_f64(vld1q_f64(cp), v));
                }
            }
        } else {
            // Spill and mask: the padded accumulator rows/columns never reach C.
            let mut spill = [0.0f64; MR * NR];
            for (r, row) in acc.iter().enumerate() {
                for (s, &v) in row.iter().enumerate() {
                    vst1q_f64(spill.as_mut_ptr().add(r * NR + 2 * s), v);
                }
            }
            for r in 0..mr {
                let crow = (i0 + r) * ldc + j0;
                for s in 0..nr {
                    c[crow + s] += spill[r * NR + s];
                }
            }
        }
    }
}

/// Dot product: 4 vector accumulators (stride 8), combined pairwise like
/// the scalar kernel's partial sums, scalar tail.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f64], b: &[f64]) -> f64 {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut s0 = vdupq_n_f64(0.0);
        let mut s1 = vdupq_n_f64(0.0);
        let mut s2 = vdupq_n_f64(0.0);
        let mut s3 = vdupq_n_f64(0.0);
        let chunks = n / 8;
        for ch in 0..chunks {
            let i = ch * 8;
            s0 = vfmaq_f64(s0, vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i)));
            s1 = vfmaq_f64(s1, vld1q_f64(ap.add(i + 2)), vld1q_f64(bp.add(i + 2)));
            s2 = vfmaq_f64(s2, vld1q_f64(ap.add(i + 4)), vld1q_f64(bp.add(i + 4)));
            s3 = vfmaq_f64(s3, vld1q_f64(ap.add(i + 6)), vld1q_f64(bp.add(i + 6)));
        }
        let t = vaddq_f64(vaddq_f64(s0, s1), vaddq_f64(s2, s3));
        let mut s = vaddvq_f64(t);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }
}

/// `y += alpha · x`, two vectors per iteration, scalar tail.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(alpha: f64, x: &[f64], y: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = x.len();
        let va = vdupq_n_f64(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let chunks = n / 4;
        for ch in 0..chunks {
            let i = ch * 4;
            let y0 = vfmaq_f64(vld1q_f64(yp.add(i)), va, vld1q_f64(xp.add(i)));
            let y1 = vfmaq_f64(vld1q_f64(yp.add(i + 2)), va, vld1q_f64(xp.add(i + 2)));
            vst1q_f64(yp.add(i), y0);
            vst1q_f64(yp.add(i + 2), y1);
        }
        for i in chunks * 4..n {
            y[i] += alpha * x[i];
        }
    }
}

/// `x *= alpha`. One rounding per element — bitwise identical to scalar.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[target_feature(enable = "neon")]
unsafe fn scal_neon(alpha: f64, x: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = x.len();
        let va = vdupq_n_f64(alpha);
        let xp = x.as_mut_ptr();
        let chunks = n / 2;
        for ch in 0..chunks {
            let i = ch * 2;
            vst1q_f64(xp.add(i), vmulq_f64(va, vld1q_f64(xp.add(i))));
        }
        for i in chunks * 2..n {
            x[i] *= alpha;
        }
    }
}

/// Fused radix-4 butterfly — two cascaded add/sub levels per lane, bitwise
/// identical to two stage-per-pass butterflies on every backend.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[target_feature(enable = "neon")]
unsafe fn butterfly4_neon(r0: &mut [f64], r1: &mut [f64], r2: &mut [f64], r3: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = r0.len();
        let p0 = r0.as_mut_ptr();
        let p1 = r1.as_mut_ptr();
        let p2 = r2.as_mut_ptr();
        let p3 = r3.as_mut_ptr();
        let chunks = n / 2;
        for ch in 0..chunks {
            let i = ch * 2;
            let a = vld1q_f64(p0.add(i));
            let b = vld1q_f64(p1.add(i));
            let c = vld1q_f64(p2.add(i));
            let d = vld1q_f64(p3.add(i));
            let t0 = vaddq_f64(a, b);
            let t1 = vsubq_f64(a, b);
            let t2 = vaddq_f64(c, d);
            let t3 = vsubq_f64(c, d);
            vst1q_f64(p0.add(i), vaddq_f64(t0, t2));
            vst1q_f64(p1.add(i), vaddq_f64(t1, t3));
            vst1q_f64(p2.add(i), vsubq_f64(t0, t2));
            vst1q_f64(p3.add(i), vsubq_f64(t1, t3));
        }
        for i in chunks * 2..n {
            let (o0, o1, o2, o3) = super::butterfly4_lane(r0[i], r1[i], r2[i], r3[i]);
            r0[i] = o0;
            r1[i] = o1;
            r2[i] = o2;
            r3[i] = o3;
        }
    }
}

/// Fused radix-8 butterfly — three cascaded add/sub levels per lane,
/// bitwise identical to three stage-per-pass butterflies.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[target_feature(enable = "neon")]
unsafe fn butterfly8_neon(r: [&mut [f64]; 8]) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = r[0].len();
        let [r0, r1, r2, r3, r4, r5, r6, r7] = r;
        let p = [
            r0.as_mut_ptr(),
            r1.as_mut_ptr(),
            r2.as_mut_ptr(),
            r3.as_mut_ptr(),
            r4.as_mut_ptr(),
            r5.as_mut_ptr(),
            r6.as_mut_ptr(),
            r7.as_mut_ptr(),
        ];
        let chunks = n / 2;
        for ch in 0..chunks {
            let i = ch * 2;
            let zero: float64x2_t = vdupq_n_f64(0.0);
            let mut v = [zero; 8];
            for (vl, &pl) in v.iter_mut().zip(p.iter()) {
                *vl = vld1q_f64(pl.add(i));
            }
            let mut s = [zero; 8];
            for l in 0..4 {
                s[2 * l] = vaddq_f64(v[2 * l], v[2 * l + 1]);
                s[2 * l + 1] = vsubq_f64(v[2 * l], v[2 * l + 1]);
            }
            let mut t = [zero; 8];
            for half in 0..2 {
                let b = 4 * half;
                for l in 0..2 {
                    t[b + l] = vaddq_f64(s[b + l], s[b + l + 2]);
                    t[b + l + 2] = vsubq_f64(s[b + l], s[b + l + 2]);
                }
            }
            for l in 0..4 {
                vst1q_f64(p[l].add(i), vaddq_f64(t[l], t[l + 4]));
                vst1q_f64(p[l + 4].add(i), vsubq_f64(t[l], t[l + 4]));
            }
        }
        for i in chunks * 2..n {
            let mut v = [0.0f64; 8];
            for (vl, &pl) in v.iter_mut().zip(p.iter()) {
                *vl = *pl.add(i);
            }
            let o = super::butterfly8_lane(v);
            for (l, &pl) in p.iter().enumerate() {
                *pl.add(i) = o[l];
            }
        }
    }
}

/// Butterfly pass — adds/subs only, bitwise identical to scalar.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified NEON support on the
// running CPU before handing out this backend.
#[target_feature(enable = "neon")]
unsafe fn butterfly_neon(a: &mut [f64], b: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees NEON is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = a.len();
        let ap = a.as_mut_ptr();
        let bp = b.as_mut_ptr();
        let chunks = n / 2;
        for ch in 0..chunks {
            let i = ch * 2;
            let u = vld1q_f64(ap.add(i));
            let v = vld1q_f64(bp.add(i));
            vst1q_f64(ap.add(i), vaddq_f64(u, v));
            vst1q_f64(bp.add(i), vsubq_f64(u, v));
        }
        for i in chunks * 2..n {
            let u = a[i];
            let v = b[i];
            a[i] = u + v;
            b[i] = u - v;
        }
    }
}
