//! Runtime-dispatched SIMD microkernels for the dense hot paths.
//!
//! Murray et al. (2023) single out hardware-tuned kernel backends as the
//! gap between RandNLA theory and usable software; this module closes it
//! for the CPU layer. A small kernel trait ([`SimdKernels`]: fused GEMM
//! register tile, BLIS-style pack + packed-tile kernels, `dot`, `axpy`,
//! `scal`, FWHT butterfly pass) has four backends:
//!
//! * **scalar** — the portable unrolled reference (the seed kernels, kept
//!   bit-for-bit as the cross-check oracle);
//! * **avx2** — x86_64 AVX2+FMA via `std::arch`, 4x12 register tile;
//! * **avx512** — x86_64 AVX-512F via `std::arch`, 8x8 zmm register tile;
//! * **neon** — aarch64 NEON via `std::arch`, 4x8 register tile.
//!
//! Selection resolves per call through one atomic load, highest precedence
//! first: [`set_choice`] (wired from [`crate::config::SolveConfig`], the
//! `--simd` CLI/bench flags, and the `[parallel] simd` config key) →
//! `SNSOLVE_SIMD` env var (`auto|scalar|avx2|avx512|neon`) →
//! auto-detection (`is_x86_feature_detected!` at runtime on x86_64,
//! compile-time cfg on aarch64). A forced backend the host cannot run
//! resolves to scalar, so unsupported hosts never execute a SIMD
//! instruction.
//!
//! **Determinism contract.** For a fixed backend every kernel is a pure
//! per-element/per-tile function, so kernel results are bitwise identical
//! across thread counts (the GEMM row panels stay [`SimdKernels::mr`]-
//! aligned). The packed-tile kernel accumulates in the exact element order
//! of the direct tile kernel — packing relocates operands, it never
//! re-associates — so full tiles are bitwise identical between the packed
//! and unpacked GEMM paths too. Across backends agreement is ≤ 1e-12
//! relative: FMA contraction and wider accumulators re-round, but nothing
//! re-associates across the GEMM depth loop, and the FWHT butterfly
//! (adds/subs only) is bitwise identical on every backend. Asserted by
//! `tests/parallel_determinism.rs` and the `micro_linalg`/
//! `sketch_ablation` bench cross-checks.

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;
mod scalar;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// A resolved kernel backend (what actually executes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Avx512 => "avx512",
            Backend::Neon => "neon",
        }
    }

    /// The [`SimdChoice`] that forces this backend.
    pub fn as_choice(self) -> SimdChoice {
        match self {
            Backend::Scalar => SimdChoice::Scalar,
            Backend::Avx2 => SimdChoice::Avx2,
            Backend::Avx512 => SimdChoice::Avx512,
            Backend::Neon => SimdChoice::Neon,
        }
    }
}

/// A requested backend — the value `--simd`, `SNSOLVE_SIMD` and the
/// `[parallel] simd` config key accept.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdChoice {
    /// Best available: avx512 → avx2 → neon → scalar.
    #[default]
    Auto,
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl SimdChoice {
    /// Parse `auto|scalar|avx2|avx512|neon` (case-insensitive, trimmed).
    pub fn parse(s: &str) -> Option<SimdChoice> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(SimdChoice::Auto),
            "scalar" => Some(SimdChoice::Scalar),
            "avx2" => Some(SimdChoice::Avx2),
            "avx512" => Some(SimdChoice::Avx512),
            "neon" => Some(SimdChoice::Neon),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdChoice::Auto => "auto",
            SimdChoice::Scalar => "scalar",
            SimdChoice::Avx2 => "avx2",
            SimdChoice::Avx512 => "avx512",
            SimdChoice::Neon => "neon",
        }
    }
}

/// The kernel set every backend implements. All slice arguments follow the
/// seed scalar kernels' conventions (`gemm_tile` mirrors the old
/// `micro_4x8`); implementations must not skip zero operands — `0·NaN` and
/// `0·Inf` reach the output exactly as IEEE 754 prescribes, independent of
/// which tile an element lands in.
pub trait SimdKernels: Sync {
    fn backend(&self) -> Backend;

    /// GEMM register-tile rows. Row-panel boundaries must align to this so
    /// the tile layout (and hence every rounding) is identical at any
    /// thread count.
    fn mr(&self) -> usize;

    /// GEMM register-tile columns.
    fn nr(&self) -> usize;

    /// Fused register-tile multiply: `C[i0..i0+MR, j0..j0+NR] += A-panel ·
    /// B-panel` over `kc` depth steps, where `a` is an (m×k) row-major
    /// panel, `b` is k×n row-major, and `c` is m×n row-major. Accumulates
    /// in ascending `p` order per element (no cross-depth re-association),
    /// so backends differ from scalar only by FMA/vector-lane rounding.
    #[allow(clippy::too_many_arguments)]
    fn gemm_tile(
        &self,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        k: usize,
        n: usize,
        i0: usize,
        j0: usize,
        pc: usize,
        kc: usize,
    );

    /// Pack an `mc × kc` block of row-major `a` (row stride `lda`, origin
    /// `(i0, pc)`) into [`SimdKernels::mr`]-row strips for the packed GEMM
    /// path. Strip `si` occupies `buf[si·MR·kc .. (si+1)·MR·kc]` in
    /// depth-major order (`buf[si·MR·kc + p·MR + r]` = `A[i0+si·MR+r,
    /// pc+p]`), so the microkernel reads MR consecutive values per depth
    /// step. Rows past `mc` are **zero-filled** — the padded accumulator
    /// rows are computed but never written back, which is what removes the
    /// ragged edge kernel from the packed interior. `buf` must hold
    /// `mc.div_ceil(MR)·MR·kc` elements.
    #[allow(clippy::too_many_arguments)]
    fn pack_a(
        &self,
        a: &[f64],
        lda: usize,
        i0: usize,
        pc: usize,
        mc: usize,
        kc: usize,
        buf: &mut [f64],
    ) {
        let mr = self.mr();
        let strips = mc.div_ceil(mr);
        debug_assert!(buf.len() >= strips * mr * kc, "pack_a: buffer too small");
        for si in 0..strips {
            let base = si * mr * kc;
            for r in 0..mr {
                let row = si * mr + r;
                if row < mc {
                    let src = (i0 + row) * lda + pc;
                    for p in 0..kc {
                        buf[base + p * mr + r] = a[src + p];
                    }
                } else {
                    for p in 0..kc {
                        buf[base + p * mr + r] = 0.0;
                    }
                }
            }
        }
    }

    /// Pack a `kc × nc` block of row-major `b` (row stride `ldb`, origin
    /// `(pc, j0)`) into [`SimdKernels::nr`]-column panels. Panel `t`
    /// occupies `buf[t·NR·kc .. (t+1)·NR·kc]` in depth-major order
    /// (`buf[t·NR·kc + p·NR + s]` = `B[pc+p, j0+t·NR+s]`); columns past
    /// `nc` are **zero-filled** (same padded-edge contract as
    /// [`SimdKernels::pack_a`]). `buf` must hold `nc.div_ceil(NR)·NR·kc`
    /// elements.
    #[allow(clippy::too_many_arguments)]
    fn pack_b(
        &self,
        b: &[f64],
        ldb: usize,
        pc: usize,
        j0: usize,
        kc: usize,
        nc: usize,
        buf: &mut [f64],
    ) {
        let nr = self.nr();
        let panels = nc.div_ceil(nr);
        debug_assert!(buf.len() >= panels * nr * kc, "pack_b: buffer too small");
        for t in 0..panels {
            let base = t * nr * kc;
            let jt = t * nr;
            let w = nr.min(nc - jt);
            for p in 0..kc {
                let src = (pc + p) * ldb + j0 + jt;
                let dst = base + p * nr;
                buf[dst..dst + w].copy_from_slice(&b[src..src + w]);
                for v in buf[dst + w..dst + nr].iter_mut() {
                    *v = 0.0;
                }
            }
        }
    }

    /// Packed register-tile multiply: `C[i0..i0+mr, j0..j0+nr] +=
    /// strip · panel` over `kc` depth steps, where `ap` is one
    /// [`SimdKernels::pack_a`] strip (`kc·MR`), `bp` one
    /// [`SimdKernels::pack_b`] panel (`kc·NR`) and `c` is row-major with
    /// row stride `ldc`. `mr ≤ MR` / `nr ≤ NR` mask the write-back for
    /// tiles whose zero-padded rows/columns fall outside C; the interior
    /// accumulation is branch-free and **element-order identical** to
    /// [`SimdKernels::gemm_tile`], so full tiles are bitwise equal between
    /// the packed and unpacked paths on every backend.
    #[allow(clippy::too_many_arguments)]
    fn gemm_tile_packed(
        &self,
        ap: &[f64],
        bp: &[f64],
        c: &mut [f64],
        ldc: usize,
        i0: usize,
        j0: usize,
        kc: usize,
        mr: usize,
        nr: usize,
    );

    /// Unrolled dot product.
    fn dot(&self, a: &[f64], b: &[f64]) -> f64;

    /// `y += alpha · x`.
    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]);

    /// `x *= alpha`. Bitwise identical on every backend (one rounding per
    /// element).
    fn scal(&self, alpha: f64, x: &mut [f64]);

    /// FWHT butterfly pass: `(a[i], b[i]) ← (a[i]+b[i], a[i]−b[i])`.
    /// Bitwise identical on every backend (adds/subs only).
    fn butterfly(&self, a: &mut [f64], b: &mut [f64]);

    /// Fused radix-4 FWHT butterfly: two cascaded radix-2 levels on four
    /// equal-length row slices at stride h — level 1 pairs (r0,r1)/(r2,r3),
    /// level 2 pairs the level-1 outputs (r0,r2)/(r1,r3). Every element
    /// goes through exactly the adds/subs of two stage-per-pass
    /// [`SimdKernels::butterfly`] calls, in the same order, so the fused
    /// kernel is **bitwise identical** to the two-pass baseline on every
    /// backend.
    fn butterfly4(&self, r0: &mut [f64], r1: &mut [f64], r2: &mut [f64], r3: &mut [f64]);

    /// Fused radix-8 FWHT butterfly: three cascaded radix-2 levels on eight
    /// equal-length row slices at stride h — level 1 pairs (0,1)(2,3)(4,5)
    /// (6,7), level 2 pairs (0,2)(1,3)(4,6)(5,7), level 3 pairs (0,4)(1,5)
    /// (2,6)(3,7). Bitwise identical to three stage-per-pass butterflies
    /// (same adds/subs per element, same order) on every backend.
    fn butterfly8(&self, r: [&mut [f64]; 8]);
}

/// One radix-4 FWHT butterfly lane — THE two-level add/sub cascade. Every
/// implementation (the scalar kernel, the SIMD backends' tail loops, and
/// the FWHT engine's inline small-stride paths) routes through this one
/// function, so the cross-backend bitwise-identity contract cannot drift:
/// an operand-order change here changes every path together.
#[inline(always)]
pub(crate) fn butterfly4_lane(a: f64, b: f64, c: f64, d: f64) -> (f64, f64, f64, f64) {
    let t0 = a + b;
    let t1 = a - b;
    let t2 = c + d;
    let t3 = c - d;
    (t0 + t2, t1 + t3, t0 - t2, t1 - t3)
}

/// One radix-8 FWHT butterfly lane — THE three-level add/sub cascade (see
/// [`butterfly4_lane`] for why this is the single source of truth).
#[inline(always)]
pub(crate) fn butterfly8_lane(v: [f64; 8]) -> [f64; 8] {
    let mut s = [0.0f64; 8];
    for l in 0..4 {
        s[2 * l] = v[2 * l] + v[2 * l + 1];
        s[2 * l + 1] = v[2 * l] - v[2 * l + 1];
    }
    let mut t = [0.0f64; 8];
    for half in 0..2 {
        let b = 4 * half;
        for l in 0..2 {
            t[b + l] = s[b + l] + s[b + l + 2];
            t[b + l + 2] = s[b + l] - s[b + l + 2];
        }
    }
    let mut out = [0.0f64; 8];
    for l in 0..4 {
        out[l] = t[l] + t[l + 4];
        out[l + 4] = t[l] - t[l + 4];
    }
    out
}

/// Sentinel: no programmatic choice installed (fall through to the env).
const CHOICE_UNSET: u8 = u8::MAX;

/// Process-wide configured choice (see [`set_choice`]).
static CONFIGURED: AtomicU8 = AtomicU8::new(CHOICE_UNSET);

fn encode(c: SimdChoice) -> u8 {
    match c {
        SimdChoice::Auto => 0,
        SimdChoice::Scalar => 1,
        SimdChoice::Avx2 => 2,
        SimdChoice::Neon => 3,
        SimdChoice::Avx512 => 4,
    }
}

fn decode(v: u8) -> Option<SimdChoice> {
    match v {
        0 => Some(SimdChoice::Auto),
        1 => Some(SimdChoice::Scalar),
        2 => Some(SimdChoice::Avx2),
        3 => Some(SimdChoice::Neon),
        4 => Some(SimdChoice::Avx512),
        _ => None,
    }
}

/// Configure the backend for this process. Overrides `SNSOLVE_SIMD`.
pub fn set_choice(c: SimdChoice) {
    CONFIGURED.store(encode(c), Ordering::SeqCst);
}

/// Drop the programmatic choice — resolution falls back to the
/// `SNSOLVE_SIMD` env var (then auto-detection). Used by tests and bench
/// sweeps to restore the ambient configuration.
pub fn clear_choice() {
    CONFIGURED.store(CHOICE_UNSET, Ordering::SeqCst);
}

fn env_choice() -> SimdChoice {
    static ENV: OnceLock<SimdChoice> = OnceLock::new();
    *ENV.get_or_init(|| {
        // snsolve-lint: allow(env-reads-behind-config) — designated
        // knob-resolution site: OnceLock-cached SNSOLVE_SIMD fallback
        // behind set_choice() (CLI/config take precedence).
        std::env::var("SNSOLVE_SIMD")
            .ok()
            .and_then(|s| SimdChoice::parse(&s))
            .unwrap_or(SimdChoice::Auto)
    })
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    })
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn avx512_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| std::arch::is_x86_feature_detected!("avx512f"))
}

#[cfg(not(target_arch = "x86_64"))]
fn avx512_available() -> bool {
    false
}

/// NEON is architecturally mandatory on aarch64, so compile-time cfg is the
/// detection.
fn neon_available() -> bool {
    cfg!(target_arch = "aarch64")
}

/// Resolve a requested choice to a backend the host can actually run.
/// Unsupported forced backends degrade to scalar (never to a different
/// SIMD set), so `SNSOLVE_SIMD=avx512` on a non-AVX-512 host is safe.
pub fn resolve(choice: SimdChoice) -> Backend {
    match choice {
        SimdChoice::Auto => {
            if avx512_available() {
                Backend::Avx512
            } else if avx2_available() {
                Backend::Avx2
            } else if neon_available() {
                Backend::Neon
            } else {
                Backend::Scalar
            }
        }
        SimdChoice::Scalar => Backend::Scalar,
        SimdChoice::Avx2 => {
            if avx2_available() {
                Backend::Avx2
            } else {
                Backend::Scalar
            }
        }
        SimdChoice::Avx512 => {
            if avx512_available() {
                Backend::Avx512
            } else {
                Backend::Scalar
            }
        }
        SimdChoice::Neon => {
            if neon_available() {
                Backend::Neon
            } else {
                Backend::Scalar
            }
        }
    }
}

/// The backend the kernels will use right now: configured → env → auto.
pub fn active() -> Backend {
    let configured = decode(CONFIGURED.load(Ordering::SeqCst));
    resolve(configured.unwrap_or_else(env_choice))
}

/// Every backend this host can execute (scalar always; in backend-sweep
/// order for the tests and benches).
pub fn available() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    if avx2_available() {
        v.push(Backend::Avx2);
    }
    if avx512_available() {
        v.push(Backend::Avx512);
    }
    if neon_available() {
        v.push(Backend::Neon);
    }
    v
}

/// The kernels for the active backend (one atomic load — callers may hoist
/// this once per operation, but per-call dispatch is also fine).
pub fn kernels() -> &'static dyn SimdKernels {
    backend_kernels(active())
}

/// The kernels for a specific backend. Requests for a backend the host
/// cannot run return the scalar kernels — this is what makes handing out
/// `&Avx2Kernels` sound: it only ever escapes after feature detection.
pub fn backend_kernels(b: Backend) -> &'static dyn SimdKernels {
    match b {
        Backend::Scalar => &scalar::ScalarKernels,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if avx2_available() => &avx2::Avx2Kernels,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx512 if avx512_available() => &avx512::Avx512Kernels,
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => &neon::NeonKernels,
        _ => &scalar::ScalarKernels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    // NOTE: no test here calls `set_choice` — the configured choice is
    // process-global and unit tests run concurrently; flipping it mid-run
    // would race the bitwise-equality assertions elsewhere in the crate.
    // The global-dispatch path is exercised (single-threadedly) by
    // `tests/parallel_determinism.rs`.

    const ALL_CHOICES: [SimdChoice; 5] = [
        SimdChoice::Auto,
        SimdChoice::Scalar,
        SimdChoice::Avx2,
        SimdChoice::Avx512,
        SimdChoice::Neon,
    ];

    #[test]
    fn parse_choices() {
        assert_eq!(SimdChoice::parse("auto"), Some(SimdChoice::Auto));
        assert_eq!(SimdChoice::parse(" Scalar "), Some(SimdChoice::Scalar));
        assert_eq!(SimdChoice::parse("AVX2"), Some(SimdChoice::Avx2));
        assert_eq!(SimdChoice::parse("AVX512"), Some(SimdChoice::Avx512));
        assert_eq!(SimdChoice::parse("neon"), Some(SimdChoice::Neon));
        assert_eq!(SimdChoice::parse("sse9"), None);
        assert_eq!(SimdChoice::parse(""), None);
        for c in ALL_CHOICES {
            assert_eq!(SimdChoice::parse(c.name()), Some(c));
            assert_eq!(decode(encode(c)), Some(c));
        }
        assert_eq!(decode(CHOICE_UNSET), None);
    }

    #[test]
    fn scalar_always_available_and_resolution_is_safe() {
        let av = available();
        assert_eq!(av[0], Backend::Scalar);
        // resolve() never hands out a backend the host cannot run.
        for c in ALL_CHOICES {
            assert!(av.contains(&resolve(c)), "{:?}", c);
        }
        assert_eq!(resolve(SimdChoice::Scalar), Backend::Scalar);
        assert!(av.contains(&active()));
    }

    #[test]
    fn forced_unsupported_backend_falls_back_to_scalar() {
        #[cfg(not(target_arch = "x86_64"))]
        {
            assert_eq!(resolve(SimdChoice::Avx2), Backend::Scalar);
            assert_eq!(resolve(SimdChoice::Avx512), Backend::Scalar);
            assert_eq!(backend_kernels(Backend::Avx512).backend(), Backend::Scalar);
        }
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(resolve(SimdChoice::Neon), Backend::Scalar);
        // And backend_kernels never returns SIMD kernels for them either.
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(backend_kernels(Backend::Neon).backend(), Backend::Scalar);
    }

    #[test]
    fn tile_shapes_sane() {
        for b in available() {
            let k = backend_kernels(b);
            assert_eq!(k.backend(), b);
            // MR is 4 everywhere except the avx512 zmm tile (8); it must
            // stay a multiple of 4 so every backend's thread-panel
            // alignment also aligns the narrower tiles.
            assert!(k.mr() == 4 || k.mr() == 8, "{}", b.name());
            assert!(k.nr() >= 4, "{}", b.name());
        }
    }

    /// Every available backend agrees with scalar: dot/axpy within 1e-12,
    /// scal and butterfly bitwise.
    #[test]
    fn vector_kernels_agree_with_scalar() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(901));
        let scalar = backend_kernels(Backend::Scalar);
        for n in [0usize, 1, 3, 7, 16, 33, 100, 1003] {
            let a = g.gaussian_vec(n);
            let b = g.gaussian_vec(n);
            let d_ref = scalar.dot(&a, &b);
            let mut axpy_ref = b.clone();
            scalar.axpy(0.37, &a, &mut axpy_ref);
            let mut scal_ref = a.clone();
            scalar.scal(-1.25, &mut scal_ref);
            let (mut bf_a_ref, mut bf_b_ref) = (a.clone(), b.clone());
            scalar.butterfly(&mut bf_a_ref, &mut bf_b_ref);

            for bk in available() {
                let kern = backend_kernels(bk);
                let d = kern.dot(&a, &b);
                // Relative to Σ|aᵢbᵢ| — the scale rounding actually acts on.
                let scale: f64 = a.iter().zip(b.iter()).map(|(x, y)| (x * y).abs()).sum();
                let tol = 1e-12 * scale.max(1.0);
                assert!((d - d_ref).abs() <= tol, "{} dot n={n}: {d} vs {d_ref}", bk.name());
                let mut y = b.clone();
                kern.axpy(0.37, &a, &mut y);
                for (u, v) in y.iter().zip(axpy_ref.iter()) {
                    assert!((u - v).abs() <= 1e-12, "{} axpy n={n}", bk.name());
                }
                let mut x = a.clone();
                kern.scal(-1.25, &mut x);
                assert_eq!(x, scal_ref, "{} scal n={n}", bk.name());
                let (mut ba, mut bb) = (a.clone(), b.clone());
                kern.butterfly(&mut ba, &mut bb);
                assert_eq!(ba, bf_a_ref, "{} butterfly(+) n={n}", bk.name());
                assert_eq!(bb, bf_b_ref, "{} butterfly(-) n={n}", bk.name());
            }
        }
    }

    /// The fused radix-4/radix-8 butterflies are **bitwise identical** to
    /// the cascaded stage-per-pass radix-2 butterflies on every backend —
    /// the contract the blocked FWHT engine's equivalence rides on.
    #[test]
    fn fused_butterflies_match_cascaded_radix2_bitwise() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(905));
        let scalar = backend_kernels(Backend::Scalar);
        for n in [0usize, 1, 2, 3, 5, 8, 16, 33, 100] {
            let rows: Vec<Vec<f64>> = (0..8).map(|_| g.gaussian_vec(n)).collect();
            // Radix-2 cascade reference (scalar butterfly, stride order
            // h, 2h, 4h on the 8 logical rows).
            let mut rr: Vec<Vec<f64>> = rows.clone();
            for stride in [1usize, 2, 4] {
                for block in (0..8).step_by(2 * stride) {
                    for i in block..block + stride {
                        let (lo, hi) = rr.split_at_mut(i + stride);
                        scalar.butterfly(&mut lo[i], &mut hi[0]);
                    }
                }
            }
            for bk in available() {
                let kern = backend_kernels(bk);
                // butterfly4 on rows 0..4 == two radix-2 levels.
                let mut r4: Vec<Vec<f64>> = rows[..4].to_vec();
                {
                    let (a, rest) = r4.split_at_mut(1);
                    let (b, rest) = rest.split_at_mut(1);
                    let (c, d) = rest.split_at_mut(1);
                    kern.butterfly4(&mut a[0], &mut b[0], &mut c[0], &mut d[0]);
                }
                let mut ref4: Vec<Vec<f64>> = rows[..4].to_vec();
                for stride in [1usize, 2] {
                    for block in (0..4).step_by(2 * stride) {
                        for i in block..block + stride {
                            let (lo, hi) = ref4.split_at_mut(i + stride);
                            scalar.butterfly(&mut lo[i], &mut hi[0]);
                        }
                    }
                }
                assert_eq!(r4, ref4, "{} butterfly4 n={n}", bk.name());

                // butterfly8 == three radix-2 levels.
                let mut r8: Vec<Vec<f64>> = rows.clone();
                {
                    let mut it = r8.iter_mut();
                    let arr: [&mut [f64]; 8] = [
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                    ];
                    kern.butterfly8(arr);
                }
                assert_eq!(r8, rr, "{} butterfly8 n={n}", bk.name());
            }
        }
    }

    /// `gemm_tile` of every backend matches a naive per-element reference
    /// within 1e-12, including NaN/Inf propagation from zero operands.
    #[test]
    fn gemm_tile_matches_naive_reference() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(902));
        for bk in available() {
            let kern = backend_kernels(bk);
            let (mr, nr) = (kern.mr(), kern.nr());
            let k = 37usize;
            let a = g.gaussian_vec(mr * k);
            let b = g.gaussian_vec(k * nr);
            let mut c = vec![0.0; mr * nr];
            kern.gemm_tile(&a, &b, &mut c, k, nr, 0, 0, 0, k);
            for i in 0..mr {
                for j in 0..nr {
                    let mut s = 0.0;
                    for p in 0..k {
                        s += a[i * k + p] * b[p * nr + j];
                    }
                    let got = c[i * nr + j];
                    assert!((got - s).abs() <= 1e-12, "{} tile ({i},{j})", bk.name());
                }
            }
            // 0 · NaN / 0 · Inf must poison the tile output.
            let az = vec![0.0; mr * k];
            let mut bnf = vec![1.0; k * nr];
            bnf[0] = f64::NAN; // column 0
            bnf[nr + 1] = f64::INFINITY; // column 1
            let mut cz = vec![0.0; mr * nr];
            kern.gemm_tile(&az, &bnf, &mut cz, k, nr, 0, 0, 0, k);
            for i in 0..mr {
                assert!(cz[i * nr].is_nan(), "{} 0*NaN row {i}", bk.name());
                assert!(cz[i * nr + 1].is_nan(), "{} 0*Inf row {i}", bk.name());
                assert_eq!(cz[i * nr + 2], 0.0, "{} clean col row {i}", bk.name());
            }
        }
    }

    /// Pack layout invariants: strip/panel contents match the source block
    /// in the documented depth-major order, and rows/columns past the block
    /// edge are exactly zero.
    #[test]
    fn pack_layouts_and_zero_padding() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(903));
        for bk in available() {
            let kern = backend_kernels(bk);
            let (mr, nr) = (kern.mr(), kern.nr());
            let (m, k, n) = (3 * mr + 1, 23usize, 2 * nr + 3);
            let a = g.gaussian_vec(m * k);
            let b = g.gaussian_vec(k * n);
            let (i0, pc, mc, kc) = (mr, 3usize, m - mr, k - 5);
            let strips = mc.div_ceil(mr);
            let mut abuf = vec![f64::NAN; strips * mr * kc];
            kern.pack_a(&a, k, i0, pc, mc, kc, &mut abuf);
            for si in 0..strips {
                for p in 0..kc {
                    for r in 0..mr {
                        let got = abuf[si * mr * kc + p * mr + r];
                        let row = si * mr + r;
                        if row < mc {
                            assert_eq!(got, a[(i0 + row) * k + pc + p], "{} a", bk.name());
                        } else {
                            assert_eq!(got, 0.0, "{} a pad", bk.name());
                        }
                    }
                }
            }
            let (j0, nc) = (nr, n - nr);
            let panels = nc.div_ceil(nr);
            let mut bbuf = vec![f64::NAN; panels * nr * kc];
            kern.pack_b(&b, n, pc, j0, kc, nc, &mut bbuf);
            for t in 0..panels {
                for p in 0..kc {
                    for s in 0..nr {
                        let got = bbuf[t * nr * kc + p * nr + s];
                        let col = t * nr + s;
                        if col < nc {
                            assert_eq!(got, b[(pc + p) * n + j0 + col], "{} b", bk.name());
                        } else {
                            assert_eq!(got, 0.0, "{} b pad", bk.name());
                        }
                    }
                }
            }
        }
    }

    /// The packed tile is bitwise identical to the direct tile on full
    /// tiles (same element accumulation order), masks its write-back on
    /// ragged tiles, and matches the naive reference within 1e-12.
    #[test]
    fn gemm_tile_packed_matches_direct_and_masks_writeback() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(904));
        for bk in available() {
            let kern = backend_kernels(bk);
            let (mr, nr) = (kern.mr(), kern.nr());
            let k = 31usize;
            let a = g.gaussian_vec(mr * k);
            let b = g.gaussian_vec(k * nr);
            let mut ap = vec![0.0; mr * k];
            let mut bp = vec![0.0; nr * k];
            kern.pack_a(&a, k, 0, 0, mr, k, &mut ap);
            kern.pack_b(&b, nr, 0, 0, k, nr, &mut bp);
            let mut c_direct = vec![0.25; mr * nr];
            kern.gemm_tile(&a, &b, &mut c_direct, k, nr, 0, 0, 0, k);
            let mut c_packed = vec![0.25; mr * nr];
            kern.gemm_tile_packed(&ap, &bp, &mut c_packed, nr, 0, 0, k, mr, nr);
            assert_eq!(c_packed, c_direct, "{}: full packed tile not bitwise", bk.name());

            // Ragged tile: pack a (mr-1) x (nr-1) block with padding; the
            // masked write-back must leave the sentinel border untouched.
            let (mre, nre) = (mr - 1, nr - 1);
            let mut ape = vec![0.0; mr * k];
            let mut bpe = vec![0.0; nr * k];
            kern.pack_a(&a, k, 0, 0, mre, k, &mut ape);
            kern.pack_b(&b, nr, 0, 0, k, nre, &mut bpe);
            let sentinel = -7.5;
            let mut ce = vec![sentinel; mr * nr];
            kern.gemm_tile_packed(&ape, &bpe, &mut ce, nr, 0, 0, k, mre, nre);
            for i in 0..mr {
                for j in 0..nr {
                    let got = ce[i * nr + j];
                    if i < mre && j < nre {
                        let mut want = sentinel;
                        for p in 0..k {
                            want += a[i * k + p] * b[p * nr + j];
                        }
                        assert!(
                            (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                            "{} edge ({i},{j}): {got} vs {want}",
                            bk.name()
                        );
                    } else {
                        assert_eq!(got, sentinel, "{} write-back leak ({i},{j})", bk.name());
                    }
                }
            }
        }
    }
}
