//! x86_64 AVX2+FMA kernels.
//!
//! Register budget for the 4x12 GEMM tile: 12 ymm accumulators (4 rows ×
//! 3 vectors of 4 doubles) + 3 ymm for the B row + 1 broadcast of A =
//! exactly the 16 architectural ymm registers — the classic FMA-era DGEMM
//! microkernel shape.
//!
//! Every loop accumulates in the same element order as the scalar
//! reference (ascending depth, per-lane), so the only divergence from
//! scalar is FMA contraction / lane-partitioned partial sums — ≤ 1e-12
//! relative on the tested workloads.
//!
//! # Safety
//! All `#[target_feature]` functions here are only reachable through
//! [`super::backend_kernels`], which hands out [`Avx2Kernels`] strictly
//! after `is_x86_feature_detected!("avx2")`/`("fma")` both pass.

use core::arch::x86_64::{
    _mm256_add_pd, _mm256_castpd256_pd128, _mm256_extractf128_pd, _mm256_fmadd_pd,
    _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd,
    _mm256_sub_pd, _mm_add_pd, _mm_add_sd, _mm_cvtsd_f64, _mm_unpackhi_pd,
};

use super::{Backend, SimdKernels};

const MR: usize = 4;
const NR: usize = 12;

pub struct Avx2Kernels;

impl SimdKernels for Avx2Kernels {
    fn backend(&self) -> Backend {
        Backend::Avx2
    }

    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_tile(
        &self,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        k: usize,
        n: usize,
        i0: usize,
        j0: usize,
        pc: usize,
        kc: usize,
    ) {
        // SAFETY: AVX2+FMA verified at dispatch time (see module docs);
        // bounds are checked inside (safe panic, never OOB).
        unsafe { gemm_tile_avx2(a, b, c, k, n, i0, j0, pc, kc) }
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_tile_packed(
        &self,
        ap: &[f64],
        bp: &[f64],
        c: &mut [f64],
        ldc: usize,
        i0: usize,
        j0: usize,
        kc: usize,
        mr: usize,
        nr: usize,
    ) {
        // SAFETY: AVX2+FMA verified at dispatch time (see module docs);
        // bounds are checked inside (safe panic, never OOB).
        unsafe { gemm_tile_packed_avx2(ap, bp, c, ldc, i0, j0, kc, mr, nr) }
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        // SAFETY: AVX2+FMA verified at dispatch time.
        unsafe { dot_avx2(a, b) }
    }

    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), y.len());
        // SAFETY: AVX2+FMA verified at dispatch time.
        unsafe { axpy_avx2(alpha, x, y) }
    }

    fn scal(&self, alpha: f64, x: &mut [f64]) {
        // SAFETY: AVX2+FMA verified at dispatch time.
        unsafe { scal_avx2(alpha, x) }
    }

    fn butterfly(&self, a: &mut [f64], b: &mut [f64]) {
        assert_eq!(a.len(), b.len());
        // SAFETY: AVX2+FMA verified at dispatch time.
        unsafe { butterfly_avx2(a, b) }
    }

    fn butterfly4(&self, r0: &mut [f64], r1: &mut [f64], r2: &mut [f64], r3: &mut [f64]) {
        assert!(r0.len() == r1.len() && r1.len() == r2.len() && r2.len() == r3.len());
        // SAFETY: AVX2+FMA verified at dispatch time.
        unsafe { butterfly4_avx2(r0, r1, r2, r3) }
    }

    fn butterfly8(&self, r: [&mut [f64]; 8]) {
        let n = r[0].len();
        assert!(r.iter().all(|s| s.len() == n));
        // SAFETY: AVX2+FMA verified at dispatch time.
        unsafe { butterfly8_avx2(r) }
    }
}

/// 4x12 register-tile `C += A·B` over `kc` depth steps.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn gemm_tile_avx2(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    pc: usize,
    kc: usize,
) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        assert!(kc > 0 && (i0 + MR - 1) * k + pc + kc <= a.len());
        assert!((pc + kc - 1) * n + j0 + NR <= b.len());
        assert!((i0 + MR - 1) * n + j0 + NR <= c.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let zero = _mm256_setzero_pd();
        let mut acc = [[zero; 3]; MR];
        let a_off = [i0 * k + pc, (i0 + 1) * k + pc, (i0 + 2) * k + pc, (i0 + 3) * k + pc];
        for p in 0..kc {
            let brow = bp.add((pc + p) * n + j0);
            let b0 = _mm256_loadu_pd(brow);
            let b1 = _mm256_loadu_pd(brow.add(4));
            let b2 = _mm256_loadu_pd(brow.add(8));
            for r in 0..MR {
                let ar = _mm256_set1_pd(*ap.add(a_off[r] + p));
                acc[r][0] = _mm256_fmadd_pd(ar, b0, acc[r][0]);
                acc[r][1] = _mm256_fmadd_pd(ar, b1, acc[r][1]);
                acc[r][2] = _mm256_fmadd_pd(ar, b2, acc[r][2]);
            }
        }
        for (r, row) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i0 + r) * n + j0);
            for (s, &v) in row.iter().enumerate() {
                let cp = crow.add(4 * s);
                _mm256_storeu_pd(cp, _mm256_add_pd(_mm256_loadu_pd(cp), v));
            }
        }
    }
}

/// Packed 4x12 tile: identical FMA sequence to `gemm_tile_avx2` (ascending
/// depth, three ymm columns per row), reading the contiguous pack strip /
/// panel instead of strided rows — full tiles are bitwise identical to the
/// direct tile. Ragged tiles (`mr < 4` or `nr < 12`, zero-padded in the
/// pack) spill the accumulators and mask the write-back.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn gemm_tile_packed_avx2(
    ap: &[f64],
    bp: &[f64],
    c: &mut [f64],
    ldc: usize,
    i0: usize,
    j0: usize,
    kc: usize,
    mr: usize,
    nr: usize,
) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        assert!(kc > 0 && mr <= MR && nr <= NR);
        assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
        assert!((i0 + mr - 1) * ldc + j0 + nr <= c.len());
        let app = ap.as_ptr();
        let bpp = bp.as_ptr();
        let zero = _mm256_setzero_pd();
        let mut acc = [[zero; 3]; MR];
        for p in 0..kc {
            let brow = bpp.add(p * NR);
            let b0 = _mm256_loadu_pd(brow);
            let b1 = _mm256_loadu_pd(brow.add(4));
            let b2 = _mm256_loadu_pd(brow.add(8));
            let arow = app.add(p * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let ar = _mm256_set1_pd(*arow.add(r));
                accr[0] = _mm256_fmadd_pd(ar, b0, accr[0]);
                accr[1] = _mm256_fmadd_pd(ar, b1, accr[1]);
                accr[2] = _mm256_fmadd_pd(ar, b2, accr[2]);
            }
        }
        if mr == MR && nr == NR {
            for (r, row) in acc.iter().enumerate() {
                let crow = c.as_mut_ptr().add((i0 + r) * ldc + j0);
                for (s, &v) in row.iter().enumerate() {
                    let cp = crow.add(4 * s);
                    _mm256_storeu_pd(cp, _mm256_add_pd(_mm256_loadu_pd(cp), v));
                }
            }
        } else {
            // Spill and mask: the padded accumulator rows/columns never reach C.
            let mut spill = [0.0f64; MR * NR];
            for (r, row) in acc.iter().enumerate() {
                for (s, &v) in row.iter().enumerate() {
                    _mm256_storeu_pd(spill.as_mut_ptr().add(r * NR + 4 * s), v);
                }
            }
            for r in 0..mr {
                let crow = (i0 + r) * ldc + j0;
                for s in 0..nr {
                    c[crow + s] += spill[r * NR + s];
                }
            }
        }
    }
}

/// Dot product: 4 vector accumulators (stride 16), combined pairwise like
/// the scalar kernel's 4 partial sums, scalar tail.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut s0 = _mm256_setzero_pd();
        let mut s1 = _mm256_setzero_pd();
        let mut s2 = _mm256_setzero_pd();
        let mut s3 = _mm256_setzero_pd();
        let chunks = n / 16;
        for ch in 0..chunks {
            let i = ch * 16;
            s0 = _mm256_fmadd_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i)), s0);
            s1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(ap.add(i + 4)),
                _mm256_loadu_pd(bp.add(i + 4)),
                s1,
            );
            s2 = _mm256_fmadd_pd(
                _mm256_loadu_pd(ap.add(i + 8)),
                _mm256_loadu_pd(bp.add(i + 8)),
                s2,
            );
            s3 = _mm256_fmadd_pd(
                _mm256_loadu_pd(ap.add(i + 12)),
                _mm256_loadu_pd(bp.add(i + 12)),
                s3,
            );
        }
        let t = _mm256_add_pd(_mm256_add_pd(s0, s1), _mm256_add_pd(s2, s3));
        let pair = _mm_add_pd(_mm256_castpd256_pd128(t), _mm256_extractf128_pd::<1>(t));
        let mut s = _mm_cvtsd_f64(_mm_add_sd(pair, _mm_unpackhi_pd(pair, pair)));
        for i in chunks * 16..n {
            s += a[i] * b[i];
        }
        s
    }
}

/// `y += alpha · x`, two vectors per iteration, scalar tail.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = x.len();
        let va = _mm256_set1_pd(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let chunks = n / 8;
        for ch in 0..chunks {
            let i = ch * 8;
            let y0 = _mm256_fmadd_pd(va, _mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)));
            let y1 =
                _mm256_fmadd_pd(va, _mm256_loadu_pd(xp.add(i + 4)), _mm256_loadu_pd(yp.add(i + 4)));
            _mm256_storeu_pd(yp.add(i), y0);
            _mm256_storeu_pd(yp.add(i + 4), y1);
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i];
        }
    }
}

/// `x *= alpha`. One rounding per element — bitwise identical to scalar.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[target_feature(enable = "avx2,fma")]
unsafe fn scal_avx2(alpha: f64, x: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = x.len();
        let va = _mm256_set1_pd(alpha);
        let xp = x.as_mut_ptr();
        let chunks = n / 4;
        for ch in 0..chunks {
            let i = ch * 4;
            _mm256_storeu_pd(xp.add(i), _mm256_mul_pd(va, _mm256_loadu_pd(xp.add(i))));
        }
        for i in chunks * 4..n {
            x[i] *= alpha;
        }
    }
}

/// Fused radix-4 butterfly — two cascaded add/sub levels per lane, bitwise
/// identical to two stage-per-pass butterflies on every backend.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[target_feature(enable = "avx2,fma")]
unsafe fn butterfly4_avx2(r0: &mut [f64], r1: &mut [f64], r2: &mut [f64], r3: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = r0.len();
        let p0 = r0.as_mut_ptr();
        let p1 = r1.as_mut_ptr();
        let p2 = r2.as_mut_ptr();
        let p3 = r3.as_mut_ptr();
        let chunks = n / 4;
        for ch in 0..chunks {
            let i = ch * 4;
            let a = _mm256_loadu_pd(p0.add(i));
            let b = _mm256_loadu_pd(p1.add(i));
            let c = _mm256_loadu_pd(p2.add(i));
            let d = _mm256_loadu_pd(p3.add(i));
            let t0 = _mm256_add_pd(a, b);
            let t1 = _mm256_sub_pd(a, b);
            let t2 = _mm256_add_pd(c, d);
            let t3 = _mm256_sub_pd(c, d);
            _mm256_storeu_pd(p0.add(i), _mm256_add_pd(t0, t2));
            _mm256_storeu_pd(p1.add(i), _mm256_add_pd(t1, t3));
            _mm256_storeu_pd(p2.add(i), _mm256_sub_pd(t0, t2));
            _mm256_storeu_pd(p3.add(i), _mm256_sub_pd(t1, t3));
        }
        for i in chunks * 4..n {
            let (o0, o1, o2, o3) = super::butterfly4_lane(r0[i], r1[i], r2[i], r3[i]);
            r0[i] = o0;
            r1[i] = o1;
            r2[i] = o2;
            r3[i] = o3;
        }
    }
}

/// Fused radix-8 butterfly — three cascaded add/sub levels per lane,
/// bitwise identical to three stage-per-pass butterflies.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[target_feature(enable = "avx2,fma")]
unsafe fn butterfly8_avx2(r: [&mut [f64]; 8]) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = r[0].len();
        let [r0, r1, r2, r3, r4, r5, r6, r7] = r;
        let p = [
            r0.as_mut_ptr(),
            r1.as_mut_ptr(),
            r2.as_mut_ptr(),
            r3.as_mut_ptr(),
            r4.as_mut_ptr(),
            r5.as_mut_ptr(),
            r6.as_mut_ptr(),
            r7.as_mut_ptr(),
        ];
        let chunks = n / 4;
        for ch in 0..chunks {
            let i = ch * 4;
            let mut v = [_mm256_setzero_pd(); 8];
            for (vl, &pl) in v.iter_mut().zip(p.iter()) {
                *vl = _mm256_loadu_pd(pl.add(i));
            }
            let mut s = [_mm256_setzero_pd(); 8];
            for l in 0..4 {
                s[2 * l] = _mm256_add_pd(v[2 * l], v[2 * l + 1]);
                s[2 * l + 1] = _mm256_sub_pd(v[2 * l], v[2 * l + 1]);
            }
            let mut t = [_mm256_setzero_pd(); 8];
            for half in 0..2 {
                let b = 4 * half;
                for l in 0..2 {
                    t[b + l] = _mm256_add_pd(s[b + l], s[b + l + 2]);
                    t[b + l + 2] = _mm256_sub_pd(s[b + l], s[b + l + 2]);
                }
            }
            for l in 0..4 {
                _mm256_storeu_pd(p[l].add(i), _mm256_add_pd(t[l], t[l + 4]));
                _mm256_storeu_pd(p[l + 4].add(i), _mm256_sub_pd(t[l], t[l + 4]));
            }
        }
        for i in chunks * 4..n {
            let mut v = [0.0f64; 8];
            for (vl, &pl) in v.iter_mut().zip(p.iter()) {
                *vl = *pl.add(i);
            }
            let o = super::butterfly8_lane(v);
            for (l, &pl) in p.iter().enumerate() {
                *pl.add(i) = o[l];
            }
        }
    }
}

/// Butterfly pass — adds/subs only, bitwise identical to scalar.
// SAFETY: callers must only reach this through the dispatch layer
// (`backend_kernels()`), which verified AVX2+FMA support on the
// running CPU before handing out this backend.
#[target_feature(enable = "avx2,fma")]
unsafe fn butterfly_avx2(a: &mut [f64], b: &mut [f64]) {
    // SAFETY: the enclosing fn's contract guarantees AVX2+FMA is
    // available; every load/store/`add` offset below stays inside the
    // bounds of the argument slices (chunked main loops with scalar
    // tails, or tile offsets pinned by the asserts).
    unsafe {
        let n = a.len();
        let ap = a.as_mut_ptr();
        let bp = b.as_mut_ptr();
        let chunks = n / 4;
        for ch in 0..chunks {
            let i = ch * 4;
            let u = _mm256_loadu_pd(ap.add(i));
            let v = _mm256_loadu_pd(bp.add(i));
            _mm256_storeu_pd(ap.add(i), _mm256_add_pd(u, v));
            _mm256_storeu_pd(bp.add(i), _mm256_sub_pd(u, v));
        }
        for i in chunks * 4..n {
            let u = a[i];
            let v = b[i];
            a[i] = u + v;
            b[i] = u - v;
        }
    }
}
