//! The perturbation fallback substrate (Algorithm 1, lines 10–11):
//! `Ã = A + σ·G/√m`, `σ = 10·‖A‖₂·u` with `u` the unit roundoff.
//!
//! For dense A we materialize Ã (one pass, same footprint). For sparse A a
//! dense m×n Gaussian would dwarf the problem itself (m = 2²⁰, n = 1000 →
//! 8 GB), so [`StreamingGaussianOperator`] regenerates G block-by-block
//! from per-block RNG streams on every matvec — O(s·BLOCK) memory,
//! deterministic in the seed, exact same distribution. DESIGN.md §6
//! records this substitution.

use crate::linalg::{DenseMatrix, LinearOperator};
use crate::rng::{GaussianSource, Xoshiro256pp};

/// Unit roundoff for f64.
pub const UNIT_ROUNDOFF: f64 = f64::EPSILON / 2.0;

/// Algorithm 1 line 11: σ = 10‖A‖₂·u.
pub fn perturbation_sigma(a_norm2: f64) -> f64 {
    10.0 * a_norm2 * UNIT_ROUNDOFF
}

/// An m×n standard-Gaussian matrix that is never stored: entries are
/// regenerated from seeded row-block streams on each application.
pub struct StreamingGaussianOperator {
    m: usize,
    n: usize,
    seed: u64,
    scale: f64,
}

const BLOCK: usize = 512;

impl StreamingGaussianOperator {
    /// `scale` multiplies every entry (callers pass σ/√m).
    pub fn new(m: usize, n: usize, seed: u64, scale: f64) -> Self {
        Self { m, n, seed, scale }
    }

    fn block_rows(&self, block_idx: usize) -> DenseMatrix {
        let r0 = block_idx * BLOCK;
        let rows = BLOCK.min(self.m - r0);
        let mut g = GaussianSource::new(Xoshiro256pp::stream(self.seed, block_idx as u64));
        let mut blk = DenseMatrix::zeros(rows, self.n);
        g.fill_gaussian(blk.data_mut());
        blk
    }
}

impl LinearOperator for StreamingGaussianOperator {
    fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), self.m);
        let nblocks = self.m.div_ceil(BLOCK);
        for bi in 0..nblocks {
            let blk = self.block_rows(bi);
            let yb = blk.matvec(x);
            let r0 = bi * BLOCK;
            for (dst, &v) in y[r0..r0 + yb.len()].iter_mut().zip(yb.iter()) {
                *dst = self.scale * v;
            }
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.m);
        debug_assert_eq!(y.len(), self.n);
        y.fill(0.0);
        let nblocks = self.m.div_ceil(BLOCK);
        for bi in 0..nblocks {
            let blk = self.block_rows(bi);
            let r0 = bi * BLOCK;
            let yb = blk.matvec_t(&x[r0..r0 + blk.rows()]);
            for (dst, &v) in y.iter_mut().zip(yb.iter()) {
                *dst += self.scale * v;
            }
        }
    }
}

/// `Ã = A + G_stream` as an implicit operator (sparse fallback path).
pub struct StreamPerturbedOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    g: StreamingGaussianOperator,
}

impl<'a, Op: LinearOperator + ?Sized> StreamPerturbedOperator<'a, Op> {
    pub fn new(a: &'a Op, seed: u64, sigma: f64) -> Self {
        let (m, n) = a.shape();
        let g = StreamingGaussianOperator::new(m, n, seed, sigma / (m as f64).sqrt());
        Self { a, g }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for StreamPerturbedOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply(x, y);
        let mut gy = vec![0.0; y.len()];
        self.g.apply(x, &mut gy);
        for (yi, gi) in y.iter_mut().zip(gy.iter()) {
            *yi += gi;
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply_transpose(x, y);
        let mut gy = vec![0.0; y.len()];
        self.g.apply_transpose(x, &mut gy);
        for (yi, gi) in y.iter_mut().zip(gy.iter()) {
            *yi += gi;
        }
    }
}

/// Materialized dense perturbation `Ã = A + (σ/√m)·G` with the *same* G as
/// the streaming operator (shared seed): used on the dense path and by the
/// equivalence tests.
pub fn perturb_dense(a: &DenseMatrix, seed: u64, sigma: f64) -> DenseMatrix {
    let (m, n) = a.shape();
    let scale = sigma / (m as f64).sqrt();
    let mut out = a.clone();
    let nblocks = m.div_ceil(BLOCK);
    for bi in 0..nblocks {
        let r0 = bi * BLOCK;
        let rows = BLOCK.min(m - r0);
        let mut g = GaussianSource::new(Xoshiro256pp::stream(seed, bi as u64));
        for i in 0..rows {
            let row = out.row_mut(r0 + i);
            for v in row.iter_mut().take(n) {
                *v += scale * g.next_gaussian();
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;

    #[test]
    fn sigma_formula() {
        let s = perturbation_sigma(2.0);
        assert!((s - 20.0 * UNIT_ROUNDOFF).abs() < 1e-30);
    }

    #[test]
    fn streaming_matches_materialized() {
        let (m, n) = (BLOCK + 100, 17);
        let a = DenseMatrix::zeros(m, n);
        let sigma = 3.0;
        let tilde = perturb_dense(&a, 99, sigma);
        let op = StreamPerturbedOperator::new(&a, 99, sigma);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(1));
        let x = g.gaussian_vec(n);
        let u = g.gaussian_vec(m);
        let y1 = op.apply_vec(&x);
        let y2 = tilde.matvec(&x);
        for (p, q) in y1.iter().zip(y2.iter()) {
            assert!((p - q).abs() < 1e-10, "{p} vs {q}");
        }
        let z1 = op.apply_transpose_vec(&u);
        let z2 = tilde.matvec_t(&u);
        for (p, q) in z1.iter().zip(z2.iter()) {
            assert!((p - q).abs() < 1e-10);
        }
    }

    #[test]
    fn perturbation_is_small_relative_to_a() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(2));
        let a = DenseMatrix::gaussian(200, 10, &mut g);
        let norm_a = crate::linalg::norms::spectral_norm_est(&a, 40, 3);
        let sigma = perturbation_sigma(norm_a);
        let tilde = perturb_dense(&a, 4, sigma);
        let diff = tilde.fro_distance(&a);
        // ‖ΔA‖_F ≈ σ/√m · √(mn) = σ√n — tiny compared to ‖A‖.
        assert!(diff < 1e-10 * a.fro_norm(), "diff {diff}");
        assert!(diff > 0.0);
    }

    #[test]
    fn streaming_gaussian_entries_standard() {
        let op = StreamingGaussianOperator::new(2048, 4, 7, 1.0);
        // Apply to e_0: extracts column 0 of G.
        let mut e0 = vec![0.0; 4];
        e0[0] = 1.0;
        let col = op.apply_vec(&e0);
        let mean: f64 = col.iter().sum::<f64>() / col.len() as f64;
        let var: f64 = col.iter().map(|v| v * v).sum::<f64>() / col.len() as f64 - mean * mean;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.15, "var {var}");
    }
}
