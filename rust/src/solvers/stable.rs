//! Forward-stable solver: the escalation ladder behind a [`Solver`] face.
//!
//! `StableSolver` packages the [`super::ladder`] pipeline — sketch-and-solve
//! → preconditioned LSQR → iterative sketching with momentum → dense QR —
//! as a drop-in solver choice. It builds its own sketched factorization
//! (the serving tier instead reuses the worker's factor cache and calls
//! [`super::ladder::run_ladder`] directly), and is the reference
//! implementation for the `--solver stable` CLI path and the
//! accuracy-vs-κ(A) bench.
//!
//! ## Refinement-sweep knob
//!
//! The maximum number of stage-3 refinement sweeps resolves, highest
//! precedence first:
//!
//! 1. [`set_refine_iters`] — `--refine-iters` / `[solver] refine_iters`.
//! 2. `SNSOLVE_REFINE_ITERS` environment variable.
//! 3. The built-in default (30: at contraction ε = ½ per sweep that is
//!    enough to pull even an O(1) forward error to the rounding floor).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::linalg::triangular::right_solve_upper_multi;
use crate::linalg::{qr, DenseMatrix, Matrix};
use crate::sketch::{self, SketchKind};
use crate::testing::FaultPlan;

use super::ladder::{run_ladder, LadderConfig, LadderOutcome};
use super::lsqr::{LsqrConfig, SolveWorkspace};
use super::saa::sketch_rows;
use super::{check_dims, Result, Solution, Solver, SolverError};

/// Built-in default for the maximum refinement sweeps.
const DEFAULT_REFINE_ITERS: usize = 30;

/// Programmatic override (CLI flag / config file). 0 = unset.
static REFINE_CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide maximum refinement sweeps (0 restores the
/// ambient env/default resolution).
pub fn set_refine_iters(n: usize) {
    REFINE_CONFIGURED.store(n, Ordering::Relaxed);
}

fn env_refine_iters() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        // snsolve-lint: allow(env-reads-behind-config) — this *is* the
        // config layer for SNSOLVE_REFINE_ITERS; precedence over it is
        // enforced in set_refine_iters's callers (CLI flag, config file).
        std::env::var("SNSOLVE_REFINE_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0)
    })
}

/// Resolve the maximum refinement sweeps: configured → env → default.
pub fn refine_iters() -> usize {
    let configured = REFINE_CONFIGURED.load(Ordering::Relaxed);
    if configured != 0 {
        return configured;
    }
    let env = env_refine_iters();
    if env != 0 {
        return env;
    }
    DEFAULT_REFINE_ITERS
}

/// Tuning for [`StableSolver`].
#[derive(Debug, Clone)]
pub struct StableConfig {
    /// Sketch family for the preconditioner factorization.
    pub sketch: SketchKind,
    /// Sketch rows as a multiple of n.
    pub sketch_factor: f64,
    /// LSQR settings for the sketch-and-precondition stage.
    pub lsqr: LsqrConfig,
    /// Sketch seed.
    pub seed: u64,
    /// Evidence tolerance (relative forward-error proxy).
    pub tol: f64,
    /// Maximum refinement sweeps; 0 defers to [`refine_iters`].
    pub refine_iters: usize,
    /// Condition estimates beyond this skip straight to dense QR.
    pub cond_limit: f64,
    /// Acceptance safety multiplier on the attainable-accuracy floor.
    pub safety: f64,
}

impl Default for StableConfig {
    fn default() -> Self {
        Self {
            sketch: SketchKind::CountSketch,
            sketch_factor: 4.0,
            lsqr: LsqrConfig { atol: 1e-12, btol: 1e-12, conlim: 0.0, ..LsqrConfig::default() },
            seed: 0x57AB_1E00,
            tol: 1e-10,
            refine_iters: 0,
            cond_limit: 1e15,
            safety: 32.0,
        }
    }
}

/// The forward-stable solver choice (`--solver stable`).
#[derive(Debug, Clone, Default)]
pub struct StableSolver {
    pub config: StableConfig,
}

impl StableSolver {
    pub fn new(config: StableConfig) -> Self {
        Self { config }
    }

    /// Ladder configuration with the refine-sweep knob resolved.
    fn ladder_config(&self) -> LadderConfig {
        let sweeps = if self.config.refine_iters != 0 {
            self.config.refine_iters
        } else {
            refine_iters()
        };
        LadderConfig {
            tol: self.config.tol,
            lsqr: self.config.lsqr.clone(),
            refine_iters: sweeps,
            cond_limit: self.config.cond_limit,
            safety: self.config.safety,
        }
    }

    /// Block entry: solve the `k` right-hand sides in `rhs` (one per row),
    /// building the sketched factorization once, then running the
    /// escalation ladder. `faults` injects deterministic stage failures
    /// (tests / chaos drills); pass `None` in production.
    pub fn solve_block(
        &self,
        a: &Matrix,
        rhs: &DenseMatrix,
        ws: &mut SolveWorkspace,
        faults: Option<&FaultPlan>,
    ) -> Result<LadderOutcome> {
        let (m, n) = a.shape();
        if rhs.cols() != m {
            return Err(SolverError::Dimension(format!(
                "stable: rhs block has {} cols, A is {m}x{n}",
                rhs.cols()
            )));
        }
        if m <= n + 1 {
            return Err(SolverError::Dimension(format!(
                "stable solver needs a strictly tall matrix, got {m}x{n}"
            )));
        }
        let s_rows = sketch_rows(self.config.sketch_factor, m, n);
        let s_op = sketch::build(self.config.sketch, s_rows, m, self.config.seed);
        let b_sk = s_op.apply_matrix(a);
        let f = qr::qr_compact(&b_sk).map_err(SolverError::Linalg)?;
        let r = f.r();
        let c_block = s_op.apply_mat(rhs);
        let z0 = f.q_transpose_mat(&c_block);
        // Materialize Y = A·R⁻¹ on the dense path (the blocked LSQR then
        // runs on a plain GEMM operator); CSR applies R⁻¹ on the fly.
        let y = match a {
            Matrix::Dense(ad) => Some(right_solve_upper_multi(ad, &r)?),
            Matrix::Csr(_) => None,
        };
        run_ladder(a, rhs, &r, &z0, y.as_ref(), &self.ladder_config(), ws, faults)
    }
}

impl Solver for StableSolver {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        check_dims(a, b)?;
        let m = a.shape().0;
        let mut rhs = DenseMatrix::zeros(1, m);
        rhs.row_mut(0).copy_from_slice(b);
        let mut ws = SolveWorkspace::new();
        let out = self.solve_block(a, &rhs, &mut ws, None)?;
        Ok(Solution {
            x: out.x.row(0).to_vec(),
            iterations: out.iterations[0],
            resnorm: out.resnorm[0],
            arnorm: f64::NAN,
            converged: true,
            fallback_used: out.stage_of[0] == super::ladder::Stage::DenseQr,
            residual_history: Vec::new(),
        })
    }

    fn name(&self) -> &'static str {
        "stable"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{generate_dense, DenseProblemSpec};
    use crate::solvers::ladder::Stage;

    #[test]
    fn refine_iters_precedence() {
        // configured beats env/default; 0 restores ambient.
        set_refine_iters(7);
        assert_eq!(refine_iters(), 7);
        set_refine_iters(0);
        assert!(refine_iters() >= 1);
    }

    #[test]
    fn solves_well_conditioned_problem() {
        let p = generate_dense(&DenseProblemSpec {
            m: 300,
            n: 12,
            cond: 50.0,
            resid_norm: 1e-8,
            seed: 901,
        });
        let solver = StableSolver::default();
        let sol = solver.solve(&p.a, &p.b).unwrap();
        assert!(sol.converged);
        assert!(p.relative_error(&sol.x) < 1e-8, "err {:.3e}", p.relative_error(&sol.x));
        assert_eq!(solver.name(), "stable");
    }

    #[test]
    fn recovers_accuracy_on_ill_conditioned_problem() {
        let p = generate_dense(&DenseProblemSpec {
            m: 400,
            n: 16,
            cond: 1e10,
            resid_norm: 1e-10,
            seed: 902,
        });
        let solver = StableSolver::default();
        let sol = solver.solve(&p.a, &p.b).unwrap();
        let err = p.relative_error(&sol.x);
        assert!(err < 1e-4, "forward error {err:.3e} at κ=1e10");
    }

    #[test]
    fn short_fat_matrix_rejected() {
        let p = generate_dense(&DenseProblemSpec {
            m: 10,
            n: 9,
            cond: 2.0,
            resid_norm: 0.0,
            seed: 903,
        });
        let err = StableSolver::default().solve(&p.a, &p.b);
        assert!(matches!(err, Err(SolverError::Dimension(_))));
    }

    #[test]
    fn block_path_reports_stages_per_column() {
        let p = generate_dense(&DenseProblemSpec {
            m: 300,
            n: 10,
            cond: 10.0,
            resid_norm: 1e-8,
            seed: 904,
        });
        let m = p.a.shape().0;
        let mut rhs = DenseMatrix::zeros(2, m);
        rhs.row_mut(0).copy_from_slice(&p.b);
        rhs.row_mut(1).copy_from_slice(&p.b);
        let mut ws = SolveWorkspace::new();
        let out = StableSolver::default().solve_block(&p.a, &rhs, &mut ws, None).unwrap();
        assert_eq!(out.stage_of.len(), 2);
        assert!(out.stage_of.iter().all(|&s| s <= Stage::DenseQr));
        assert_eq!(out.x.rows(), 2);
    }
}
