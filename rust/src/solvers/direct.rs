//! Direct dense QR solve — the small-problem oracle the randomized solvers
//! are validated against (`x = R⁻¹Qᵀb` from the full, unsketched QR).

use crate::linalg::{qr, triangular, Matrix};

use super::{check_dims, Result, Solution, Solver};

/// Householder-QR direct least-squares solver. O(mn²) — use at test scale.
#[derive(Debug, Clone, Copy, Default)]
pub struct DirectQr;

impl Solver for DirectQr {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        check_dims(a, b)?;
        // Sparse inputs are densified: this is an oracle, not a fast path.
        let ad = a.to_dense();
        let f = qr::qr_compact(&ad)?;
        let z = f.q_transpose_vec(b);
        let x = triangular::solve_upper(&f.r(), &z)?;
        let ax = ad.matvec(&x);
        let resid: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
        let resnorm = crate::linalg::norms::nrm2(&resid);
        let arnorm = crate::linalg::norms::nrm2(&ad.matvec_t(&resid));
        Ok(Solution {
            x,
            iterations: 0,
            resnorm,
            arnorm,
            converged: true,
            fallback_used: false,
            residual_history: Vec::new(),
        })
    }

    fn name(&self) -> &'static str {
        "direct-qr"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{nrm2, nrm2_diff};
    use crate::linalg::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    #[test]
    fn matches_normal_equations_on_small_problem() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(401));
        let a = DenseMatrix::gaussian(50, 7, &mut g);
        let b = g.gaussian_vec(50);
        let sol = DirectQr.solve(&Matrix::Dense(a.clone()), &b).unwrap();
        // Normal equations via the same QR machinery on AᵀA is circular;
        // instead check the optimality condition directly.
        let ax = a.matvec(&sol.x);
        let r: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
        let grad = a.matvec_t(&r);
        assert!(nrm2(&grad) < 1e-10 * nrm2(&r), "grad {}", nrm2(&grad));
    }

    #[test]
    fn exact_on_consistent() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(402));
        let a = DenseMatrix::gaussian(40, 8, &mut g);
        let x_true = g.gaussian_vec(8);
        let b = a.matvec(&x_true);
        let sol = DirectQr.solve(&Matrix::Dense(a), &b).unwrap();
        assert!(nrm2_diff(&sol.x, &x_true) / nrm2(&x_true) < 1e-11);
    }
}
