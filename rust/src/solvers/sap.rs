//! **SAP-SAS** — Sketch-and-Precondition (§4, the paradigm the paper tried
//! and set aside).
//!
//! Identical sketch → QR machinery, but used only to *precondition*: LSQR
//! runs on `Y = A·R⁻¹` from a **zero** initial guess — no `z₀ = Qᵀc` warm
//! start, i.e. no dimension-reduced solve seeding the iteration. The paper's
//! observation ("the matrix A is just better conditioned, but the problem
//! size remains the same") is exactly what the T-sap ablation measures:
//! SAP needs the full LSQR convergence path where SAA starts ε-close.

use crate::linalg::operator::PreconditionedOperator;
use crate::linalg::{qr, triangular, Matrix};
use crate::sketch::{self, SketchKind, SketchOperator};

use super::lsqr::{lsqr, LsqrConfig};
use super::saa::sketch_rows;
use super::{check_dims, Result, Solution, Solver, SolverError};

/// SAP-SAS configuration (mirrors [`super::saa::SaaConfig`] minus fallback).
#[derive(Debug, Clone)]
pub struct SapConfig {
    pub sketch: SketchKind,
    pub sketch_factor: f64,
    pub lsqr: LsqrConfig,
    pub seed: u64,
}

impl Default for SapConfig {
    fn default() -> Self {
        Self {
            sketch: SketchKind::CountSketch,
            sketch_factor: 4.0,
            lsqr: LsqrConfig { atol: 1e-12, btol: 1e-12, conlim: 0.0, ..Default::default() },
            seed: 0x5A9_0BEEF,
        }
    }
}

/// The SAP-SAS solver.
#[derive(Debug, Clone, Default)]
pub struct SapSolver {
    pub config: SapConfig,
}

impl SapSolver {
    pub fn new(config: SapConfig) -> Self {
        Self { config }
    }
}

impl Solver for SapSolver {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        let (m, n) = check_dims(a, b)?;
        let cfg = &self.config;
        if m <= n + 1 {
            return Err(SolverError::Dimension(format!(
                "SAP-SAS needs m ≫ s > n; got m={m}, n={n}"
            )));
        }
        let s_rows = sketch_rows(cfg.sketch_factor, m, n);
        let s_op = sketch::build(cfg.sketch, s_rows, m, cfg.seed);
        let b_sk = s_op.apply_matrix(a);
        let f = qr::qr_compact(&b_sk)?;
        let r = f.r();

        // LSQR on the preconditioned operator, cold start.
        let res = match a {
            Matrix::Dense(ad) => {
                let y = triangular::right_solve_upper(ad, &r)?;
                lsqr(&y, b, None, &cfg.lsqr)
            }
            Matrix::Csr(ac) => {
                let op = PreconditionedOperator::new(ac, &r);
                lsqr(&op, b, None, &cfg.lsqr)
            }
        };
        let x = triangular::solve_upper(&r, &res.x)?;
        Ok(Solution {
            x,
            iterations: res.itn,
            resnorm: res.r1norm.abs(),
            arnorm: res.arnorm,
            converged: res.istop.converged(),
            fallback_used: false,
            residual_history: res.history,
        })
    }

    fn name(&self) -> &'static str {
        "sap-sas"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{nrm2, nrm2_diff};
    use crate::linalg::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};
    use crate::solvers::saa::SaaSolver;

    #[test]
    fn sap_solves_but_needs_more_iterations_than_saa() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(201));
        let a = DenseMatrix::gaussian(1500, 40, &mut g);
        let x_true = g.gaussian_vec(40);
        let mut b = a.matvec(&x_true);
        for v in b.iter_mut() {
            *v += 1e-6 * g.next_gaussian();
        }
        let am = Matrix::Dense(a);
        let sap = SapSolver::default().solve(&am, &b).unwrap();
        let saa = SaaSolver::default().solve(&am, &b).unwrap();
        assert!(sap.converged);
        assert!(saa.converged);
        let sap_err = nrm2_diff(&sap.x, &x_true) / nrm2(&x_true);
        assert!(sap_err < 1e-4, "sap err {sap_err}");
        // The paper's observation: warm-started SAA does no worse (usually
        // strictly better) in iteration count.
        assert!(
            saa.iterations <= sap.iterations,
            "saa {} vs sap {}",
            saa.iterations,
            sap.iterations
        );
    }

    #[test]
    fn sap_dimension_guards() {
        let s = SapSolver::default();
        let sq = Matrix::Dense(DenseMatrix::eye(4));
        assert!(s.solve(&sq, &[0.0; 4]).is_err());
    }
}
