//! LSQR (Paige & Saunders 1982) — the deterministic baseline (§3.1).
//!
//! A faithful port of the SciPy `lsqr` implementation: Golub–Kahan
//! bidiagonalization with QR via Givens rotations, optional Tikhonov
//! damping, warm start `x0`, and the standard three stopping tests
//! (`atol`/`btol` residual tests, `conlim` condition guard). The SAA-SAS
//! algorithm reuses this exact routine on the preconditioned operator, so
//! baseline and treatment share every line of iteration code — differences
//! in the figures are attributable to the sketching, not the solver.

use crate::linalg::norms::nrm2;
use crate::linalg::LinearOperator;
use crate::linalg::Matrix;

use super::{check_dims, Result, Solution, Solver};

/// Why LSQR stopped (SciPy `istop` codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// x = x0 is already the exact solution (b in range, zero residual).
    TrivialSolution = 0,
    /// Ax − b is small enough relative to atol/btol: consistent system
    /// solved.
    ResidualTol = 1,
    /// ‖Aᵀr‖ small: least-squares optimum reached to atol.
    LeastSquaresTol = 2,
    /// Condition estimate exceeded conlim.
    ConditionLimit = 3,
    /// Machine-precision version of `ResidualTol`.
    ResidualMachineEps = 4,
    /// Machine-precision version of `LeastSquaresTol`.
    LeastSquaresMachineEps = 5,
    /// Machine-precision version of `ConditionLimit`.
    ConditionMachineEps = 6,
    /// Iteration limit hit before convergence.
    IterLimit = 7,
}

impl StopReason {
    /// LSQR "converged" in Algorithm 1's sense (line 7): any stop that
    /// certifies the residual/optimality tolerance, at machine precision or
    /// requested precision.
    pub fn converged(self) -> bool {
        matches!(
            self,
            StopReason::TrivialSolution
                | StopReason::ResidualTol
                | StopReason::LeastSquaresTol
                | StopReason::ResidualMachineEps
                | StopReason::LeastSquaresMachineEps
        )
    }
}

/// LSQR tuning parameters (defaults mirror SciPy).
#[derive(Debug, Clone)]
pub struct LsqrConfig {
    /// Relative tolerance on ‖Aᵀr‖.
    pub atol: f64,
    /// Relative tolerance on ‖r‖.
    pub btol: f64,
    /// Condition-number limit (0 = unlimited).
    pub conlim: f64,
    /// Tikhonov damping λ (0 = plain least squares).
    pub damp: f64,
    /// Max iterations; `None` → 2n.
    pub iter_lim: Option<usize>,
    /// Record ‖r‖ per iteration (Figure 4 needs it).
    pub track_history: bool,
}

impl Default for LsqrConfig {
    fn default() -> Self {
        Self {
            atol: 1e-8,
            btol: 1e-8,
            conlim: 1e8,
            damp: 0.0,
            iter_lim: None,
            track_history: false,
        }
    }
}

/// Full LSQR diagnostics (superset of [`Solution`]).
#[derive(Debug, Clone)]
pub struct LsqrResult {
    pub x: Vec<f64>,
    pub istop: StopReason,
    pub itn: usize,
    /// ‖r‖ for the undamped problem.
    pub r1norm: f64,
    /// ‖[r; damp·x]‖ (= r1norm when damp = 0).
    pub r2norm: f64,
    /// Frobenius-ish estimate of ‖A‖.
    pub anorm: f64,
    /// Condition estimate of Ā.
    pub acond: f64,
    /// ‖Aᵀr‖.
    pub arnorm: f64,
    /// ‖x‖.
    pub xnorm: f64,
    /// ‖r‖ per iteration if tracked.
    pub history: Vec<f64>,
}

/// Solve `min ‖Ax − b‖² + damp²‖x‖²` by LSQR.
///
/// `x0` warm-starts the iteration (Algorithm 1 step 6 passes `z₀ = Qᵀc`).
pub fn lsqr<Op: LinearOperator + ?Sized>(
    a: &Op,
    b: &[f64],
    x0: Option<&[f64]>,
    cfg: &LsqrConfig,
) -> LsqrResult {
    let (m, n) = a.shape();
    assert_eq!(b.len(), m, "lsqr: b has {} entries, A is {m}x{n}", b.len());
    let iter_lim = cfg.iter_lim.unwrap_or(2 * n);
    let eps = f64::EPSILON;
    let ctol = if cfg.conlim > 0.0 { 1.0 / cfg.conlim } else { 0.0 };
    let dampsq = cfg.damp * cfg.damp;

    let mut history = Vec::new();

    // --- initialization ---------------------------------------------------
    let bnorm = nrm2(b);
    let mut x: Vec<f64>;
    let mut u = b.to_vec();
    let mut beta;
    match x0 {
        Some(x0v) => {
            assert_eq!(x0v.len(), n, "lsqr: x0 has {} entries, need {n}", x0v.len());
            x = x0v.to_vec();
            let mut ax = vec![0.0; m];
            a.apply(x0v, &mut ax);
            for (ui, &axi) in u.iter_mut().zip(ax.iter()) {
                *ui -= axi;
            }
            beta = nrm2(&u);
        }
        None => {
            x = vec![0.0; n];
            beta = bnorm;
        }
    }

    let mut v = vec![0.0; n];
    let mut alpha;
    if beta > 0.0 {
        let inv = 1.0 / beta;
        for ui in u.iter_mut() {
            *ui *= inv;
        }
        a.apply_transpose(&u, &mut v);
        alpha = nrm2(&v);
    } else {
        // u is zero: x0 (or 0) is already exact.
        v.copy_from_slice(&x);
        alpha = 0.0;
    }
    if alpha > 0.0 {
        let inv = 1.0 / alpha;
        for vi in v.iter_mut() {
            *vi *= inv;
        }
    }
    let mut w = v.clone();

    let mut rhobar = alpha;
    let mut phibar = beta;
    let mut rnorm = beta;
    let mut r1norm = rnorm;
    let mut r2norm = rnorm;
    let mut anorm = 0.0f64;
    let mut acond = 0.0f64;
    let mut ddnorm = 0.0f64;
    let mut res2 = 0.0f64;
    let mut xnorm = 0.0f64;
    let mut xxnorm = 0.0f64;
    let mut z = 0.0f64;
    let mut cs2 = -1.0f64;
    let mut sn2 = 0.0f64;
    let mut arnorm = alpha * beta;

    if arnorm == 0.0 {
        return LsqrResult {
            x,
            istop: StopReason::TrivialSolution,
            itn: 0,
            r1norm,
            r2norm,
            anorm,
            acond,
            arnorm,
            xnorm,
            history,
        };
    }

    let mut istop = StopReason::IterLimit;
    let mut itn = 0usize;
    let mut scratch_m = vec![0.0; m];
    let mut scratch_n = vec![0.0; n];

    // --- main loop ---------------------------------------------------------
    while itn < iter_lim {
        itn += 1;

        // Bidiagonalization: β u = A v − α u ; α v = Aᵀ u − β v.
        a.apply(&v, &mut scratch_m);
        for (ui, &avi) in u.iter_mut().zip(scratch_m.iter()) {
            *ui = avi - alpha * *ui;
        }
        beta = nrm2(&u);
        if beta > 0.0 {
            let inv = 1.0 / beta;
            for ui in u.iter_mut() {
                *ui *= inv;
            }
            anorm = (anorm * anorm + alpha * alpha + beta * beta + dampsq).sqrt();
            a.apply_transpose(&u, &mut scratch_n);
            for (vi, &atui) in v.iter_mut().zip(scratch_n.iter()) {
                *vi = atui - beta * *vi;
            }
            alpha = nrm2(&v);
            if alpha > 0.0 {
                let inv = 1.0 / alpha;
                for vi in v.iter_mut() {
                    *vi *= inv;
                }
            }
        }

        // Eliminate the damping parameter.
        let (rhobar1, psi) = if cfg.damp > 0.0 {
            let rhobar1 = (rhobar * rhobar + dampsq).sqrt();
            let cs1 = rhobar / rhobar1;
            let sn1 = cfg.damp / rhobar1;
            let psi = sn1 * phibar;
            phibar *= cs1;
            (rhobar1, psi)
        } else {
            (rhobar, 0.0)
        };

        // Givens rotation on the bidiagonal system.
        let rho = (rhobar1 * rhobar1 + beta * beta).sqrt();
        let cs = rhobar1 / rho;
        let sn = beta / rho;
        let theta = sn * alpha;
        rhobar = -cs * alpha;
        let phi = cs * phibar;
        phibar *= sn;
        let tau = sn * phi;

        // Update x and w.
        let t1 = phi / rho;
        let t2 = -theta / rho;
        let inv_rho = 1.0 / rho;
        let mut dknorm2 = 0.0;
        for i in 0..n {
            let wi = w[i];
            let dk = wi * inv_rho;
            dknorm2 += dk * dk;
            x[i] += t1 * wi;
            w[i] = v[i] + t2 * wi;
        }
        ddnorm += dknorm2;

        // Norm estimates.
        let delta = sn2 * rho;
        let gambar = -cs2 * rho;
        let rhs = phi - delta * z;
        let zbar = rhs / gambar;
        xnorm = (xxnorm + zbar * zbar).sqrt();
        let gamma = (gambar * gambar + theta * theta).sqrt();
        cs2 = gambar / gamma;
        sn2 = theta / gamma;
        z = rhs / gamma;
        xxnorm += z * z;

        acond = anorm * ddnorm.sqrt();
        let res1 = phibar * phibar;
        res2 += psi * psi;
        rnorm = (res1 + res2).sqrt();
        arnorm = alpha * tau.abs();

        // r1norm: residual of the undamped system.
        let r1sq = rnorm * rnorm - dampsq * xxnorm;
        r1norm = r1sq.abs().sqrt();
        if r1sq < 0.0 {
            r1norm = -r1norm;
        }
        r2norm = rnorm;

        if cfg.track_history {
            history.push(rnorm);
        }

        // Stopping tests.
        let test1 = rnorm / bnorm;
        let test2 = arnorm / (anorm * rnorm + eps);
        let test3 = 1.0 / (acond + eps);
        let t1s = test1 / (1.0 + anorm * xnorm / bnorm);
        let rtol = cfg.btol + cfg.atol * anorm * xnorm / bnorm;

        if itn >= iter_lim {
            istop = StopReason::IterLimit;
        }
        if 1.0 + test3 <= 1.0 {
            istop = StopReason::ConditionMachineEps;
        }
        if 1.0 + test2 <= 1.0 {
            istop = StopReason::LeastSquaresMachineEps;
        }
        if 1.0 + t1s <= 1.0 {
            istop = StopReason::ResidualMachineEps;
        }
        if test3 <= ctol {
            istop = StopReason::ConditionLimit;
        }
        if test2 <= cfg.atol {
            istop = StopReason::LeastSquaresTol;
        }
        if test1 <= rtol {
            istop = StopReason::ResidualTol;
        }
        if istop != StopReason::IterLimit || itn >= iter_lim {
            break;
        }
    }

    LsqrResult {
        x,
        istop,
        itn,
        r1norm,
        r2norm,
        anorm,
        acond,
        arnorm,
        xnorm,
        history,
    }
}

/// The deterministic baseline as a [`Solver`].
#[derive(Debug, Clone, Default)]
pub struct LsqrSolver {
    pub config: LsqrConfig,
}

impl LsqrSolver {
    pub fn new(config: LsqrConfig) -> Self {
        Self { config }
    }
}

impl Solver for LsqrSolver {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        check_dims(a, b)?;
        let r = lsqr(a.as_operator(), b, None, &self.config);
        Ok(Solution {
            x: r.x,
            iterations: r.itn,
            resnorm: r.r1norm.abs(),
            arnorm: r.arnorm,
            converged: r.istop.converged(),
            fallback_used: false,
            residual_history: r.history,
        })
    }

    fn name(&self) -> &'static str {
        "lsqr"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::nrm2_diff;
    use crate::linalg::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn well_conditioned(m: usize, n: usize, seed: u64) -> (DenseMatrix, Vec<f64>, Vec<f64>) {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let x_true = g.gaussian_vec(n);
        let b = a.matvec(&x_true);
        (a, x_true, b)
    }

    #[test]
    fn solves_consistent_system() {
        let (a, x_true, b) = well_conditioned(60, 12, 71);
        let r = lsqr(&a, &b, None, &LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() });
        assert!(r.istop.converged(), "istop {:?}", r.istop);
        let err = nrm2_diff(&r.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn solves_inconsistent_least_squares() {
        let (a, _xt, mut b) = well_conditioned(80, 10, 72);
        // Add a residual component.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(73));
        for bi in b.iter_mut() {
            *bi += 0.5 * g.next_gaussian();
        }
        let r = lsqr(&a, &b, None, &LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() });
        // Optimality: Aᵀ(Ax−b) ≈ 0.
        let ax = a.matvec(&r.x);
        let resid: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
        let grad = a.matvec_t(&resid);
        let rel = nrm2(&grad) / (nrm2(&resid) * r.anorm);
        assert!(rel < 1e-8, "optimality {rel}");
        assert!(matches!(r.istop, StopReason::LeastSquaresTol | StopReason::LeastSquaresMachineEps),
            "istop {:?}", r.istop);
    }

    #[test]
    fn warm_start_accelerates() {
        let (a, x_true, b) = well_conditioned(100, 20, 74);
        let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() };
        let cold = lsqr(&a, &b, None, &cfg);
        // Start very close to the solution.
        let mut x0 = x_true.clone();
        x0[0] += 1e-9;
        let warm = lsqr(&a, &b, Some(&x0), &cfg);
        assert!(warm.itn < cold.itn, "warm {} vs cold {}", warm.itn, cold.itn);
        let err = nrm2_diff(&warm.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8);
    }

    #[test]
    fn exact_warm_start_is_trivial() {
        let (a, x_true, b) = well_conditioned(40, 8, 75);
        let r = lsqr(&a, &b, Some(&x_true), &LsqrConfig::default());
        assert!(r.itn <= 1);
        assert!(r.istop.converged());
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let (a, _xt, _b) = well_conditioned(30, 6, 76);
        let b = vec![0.0; 30];
        let r = lsqr(&a, &b, None, &LsqrConfig::default());
        assert_eq!(r.istop, StopReason::TrivialSolution);
        assert!(r.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn iteration_limit_respected() {
        let (a, _xt, b) = well_conditioned(200, 50, 77);
        let cfg = LsqrConfig { iter_lim: Some(3), atol: 1e-16, btol: 1e-16, ..Default::default() };
        let r = lsqr(&a, &b, None, &cfg);
        assert_eq!(r.itn, 3);
        assert_eq!(r.istop, StopReason::IterLimit);
    }

    #[test]
    fn damping_shrinks_solution() {
        let (a, _xt, b) = well_conditioned(60, 10, 78);
        let plain = lsqr(&a, &b, None, &LsqrConfig::default());
        let damped = lsqr(&a, &b, None, &LsqrConfig { damp: 10.0, ..Default::default() });
        assert!(nrm2(&damped.x) < nrm2(&plain.x));
    }

    #[test]
    fn history_tracked() {
        let (a, _xt, b) = well_conditioned(50, 10, 79);
        let cfg = LsqrConfig { track_history: true, ..Default::default() };
        let r = lsqr(&a, &b, None, &cfg);
        assert_eq!(r.history.len(), r.itn);
        // residuals non-increasing (monotone for LSQR)
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-12));
        }
    }

    #[test]
    fn conlim_triggers_on_illconditioned() {
        // Build an ill-conditioned A via scaled columns.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(80));
        let mut a = DenseMatrix::gaussian(100, 10, &mut g);
        for j in 0..10 {
            let s = 10f64.powi(-(j as i32) * 2);
            for i in 0..100 {
                a[(i, j)] *= s;
            }
        }
        let b = g.gaussian_vec(100);
        let cfg = LsqrConfig { conlim: 1e6, atol: 1e-16, btol: 1e-16, ..Default::default() };
        let r = lsqr(&a, &b, None, &cfg);
        assert!(
            matches!(r.istop, StopReason::ConditionLimit | StopReason::ConditionMachineEps),
            "istop {:?} acond {:.3e}",
            r.istop,
            r.acond
        );
    }

    #[test]
    fn solver_trait_wrapper() {
        let (a, x_true, b) = well_conditioned(50, 8, 81);
        let s = LsqrSolver::new(LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() });
        let sol = s.solve(&Matrix::Dense(a), &b).unwrap();
        assert!(sol.converged);
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8);
        assert_eq!(s.name(), "lsqr");
    }

    #[test]
    fn dimension_errors() {
        let a = Matrix::Dense(DenseMatrix::zeros(5, 3));
        let s = LsqrSolver::default();
        assert!(s.solve(&a, &[0.0; 4]).is_err());
        let wide = Matrix::Dense(DenseMatrix::zeros(3, 5));
        assert!(s.solve(&wide, &[0.0; 3]).is_err());
    }
}
