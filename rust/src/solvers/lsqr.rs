//! LSQR (Paige & Saunders 1982) — the deterministic baseline (§3.1).
//!
//! A faithful port of the SciPy `lsqr` implementation: Golub–Kahan
//! bidiagonalization with QR via Givens rotations, optional Tikhonov
//! damping, warm start `x0`, and the standard three stopping tests
//! (`atol`/`btol` residual tests, `conlim` condition guard). The SAA-SAS
//! algorithm reuses this exact routine on the preconditioned operator, so
//! baseline and treatment share every line of iteration code — differences
//! in the figures are attributable to the sketching, not the solver.

use crate::linalg::norms::nrm2;
use crate::linalg::DenseMatrix;
use crate::linalg::LinearOperator;
use crate::linalg::Matrix;

use super::{check_dims, Result, Solution, Solver};

/// Why LSQR stopped (SciPy `istop` codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// x = x0 is already the exact solution (b in range, zero residual).
    TrivialSolution = 0,
    /// Ax − b is small enough relative to atol/btol: consistent system
    /// solved.
    ResidualTol = 1,
    /// ‖Aᵀr‖ small: least-squares optimum reached to atol.
    LeastSquaresTol = 2,
    /// Condition estimate exceeded conlim.
    ConditionLimit = 3,
    /// Machine-precision version of `ResidualTol`.
    ResidualMachineEps = 4,
    /// Machine-precision version of `LeastSquaresTol`.
    LeastSquaresMachineEps = 5,
    /// Machine-precision version of `ConditionLimit`.
    ConditionMachineEps = 6,
    /// Iteration limit hit before convergence.
    IterLimit = 7,
}

impl StopReason {
    /// LSQR "converged" in Algorithm 1's sense (line 7): any stop that
    /// certifies the residual/optimality tolerance, at machine precision or
    /// requested precision.
    pub fn converged(self) -> bool {
        matches!(
            self,
            StopReason::TrivialSolution
                | StopReason::ResidualTol
                | StopReason::LeastSquaresTol
                | StopReason::ResidualMachineEps
                | StopReason::LeastSquaresMachineEps
        )
    }
}

/// LSQR tuning parameters (defaults mirror SciPy).
#[derive(Debug, Clone)]
pub struct LsqrConfig {
    /// Relative tolerance on ‖Aᵀr‖.
    pub atol: f64,
    /// Relative tolerance on ‖r‖.
    pub btol: f64,
    /// Condition-number limit (0 = unlimited).
    pub conlim: f64,
    /// Tikhonov damping λ (0 = plain least squares).
    pub damp: f64,
    /// Max iterations; `None` → 2n.
    pub iter_lim: Option<usize>,
    /// Record ‖r‖ per iteration (Figure 4 needs it).
    pub track_history: bool,
}

impl Default for LsqrConfig {
    fn default() -> Self {
        Self {
            atol: 1e-8,
            btol: 1e-8,
            conlim: 1e8,
            damp: 0.0,
            iter_lim: None,
            track_history: false,
        }
    }
}

/// Full LSQR diagnostics (superset of [`Solution`]).
#[derive(Debug, Clone)]
pub struct LsqrResult {
    pub x: Vec<f64>,
    pub istop: StopReason,
    pub itn: usize,
    /// ‖r‖ for the undamped problem.
    pub r1norm: f64,
    /// ‖[r; damp·x]‖ (= r1norm when damp = 0).
    pub r2norm: f64,
    /// Frobenius-ish estimate of ‖A‖.
    pub anorm: f64,
    /// Condition estimate of Ā.
    pub acond: f64,
    /// ‖Aᵀr‖.
    pub arnorm: f64,
    /// ‖x‖.
    pub xnorm: f64,
    /// ‖r‖ per iteration if tracked.
    pub history: Vec<f64>,
}

/// Reusable scratch arena for [`lsqr_ws`]/[`lsqr_block_ws`]: the u/v/w
/// bidiagonalization vectors, the apply scratch, and the per-iteration
/// active-column blocks of the blocked solver all draw from (and return
/// to) one [`crate::workspace::BufferPool`], so a warm worker's repeated
/// solves perform no scratch allocations. Recycled buffers are re-zeroed
/// on `take`, making workspace reuse **bitwise identical** to fresh
/// allocation (pinned by `tests/workspace_reuse.rs`).
#[derive(Debug, Default)]
pub struct SolveWorkspace {
    pool: crate::workspace::BufferPool,
}

impl SolveWorkspace {
    pub fn new() -> Self {
        Self { pool: crate::workspace::BufferPool::new() }
    }

    pub(crate) fn take(&mut self, len: usize) -> Vec<f64> {
        self.pool.take(len)
    }

    /// Unspecified-contents take — only for buffers every element of which
    /// is plain-store overwritten before any read (see
    /// [`crate::workspace::BufferPool::take_overwrite`]); NOT for apply
    /// outputs, whose `beta·y + …` kernels read the buffer.
    pub(crate) fn take_overwrite(&mut self, len: usize) -> Vec<f64> {
        self.pool.take_overwrite(len)
    }

    pub(crate) fn take_mat(&mut self, rows: usize, cols: usize) -> DenseMatrix {
        self.pool.take_matrix(rows, cols)
    }

    /// See [`SolveWorkspace::take_overwrite`].
    pub(crate) fn take_mat_overwrite(&mut self, rows: usize, cols: usize) -> DenseMatrix {
        self.pool.take_matrix_overwrite(rows, cols)
    }

    pub(crate) fn recycle(&mut self, v: Vec<f64>) {
        self.pool.recycle(v);
    }

    pub(crate) fn recycle_mat(&mut self, m: DenseMatrix) {
        self.pool.recycle_matrix(m);
    }
}

/// Solve `min ‖Ax − b‖² + damp²‖x‖²` by LSQR.
///
/// `x0` warm-starts the iteration (Algorithm 1 step 6 passes `z₀ = Qᵀc`).
pub fn lsqr<Op: LinearOperator + ?Sized>(
    a: &Op,
    b: &[f64],
    x0: Option<&[f64]>,
    cfg: &LsqrConfig,
) -> LsqrResult {
    lsqr_ws(a, b, x0, cfg, &mut SolveWorkspace::new())
}

/// [`lsqr`] with a reusable [`SolveWorkspace`]: the u/v/w vectors and the
/// apply scratch come from the pool instead of fresh `vec![0.0; …]`
/// allocations, so warm-started re-solves (the worker's factor-cache path)
/// stop allocating. Bitwise identical to [`lsqr`].
pub fn lsqr_ws<Op: LinearOperator + ?Sized>(
    a: &Op,
    b: &[f64],
    x0: Option<&[f64]>,
    cfg: &LsqrConfig,
    ws: &mut SolveWorkspace,
) -> LsqrResult {
    let (m, n) = a.shape();
    assert_eq!(b.len(), m, "lsqr: b has {} entries, A is {m}x{n}", b.len());
    let iter_lim = cfg.iter_lim.unwrap_or(2 * n);
    let eps = f64::EPSILON;
    let ctol = if cfg.conlim > 0.0 { 1.0 / cfg.conlim } else { 0.0 };
    let dampsq = cfg.damp * cfg.damp;

    let mut history = Vec::new();

    // --- initialization ---------------------------------------------------
    let bnorm = nrm2(b);
    let mut x: Vec<f64>;
    // Fully copy-overwritten before any read → unspecified-contents take.
    let mut u = ws.take_overwrite(m);
    u.copy_from_slice(b);
    let mut beta;
    match x0 {
        Some(x0v) => {
            assert_eq!(x0v.len(), n, "lsqr: x0 has {} entries, need {n}", x0v.len());
            x = x0v.to_vec();
            let mut ax = ws.take(m);
            a.apply(x0v, &mut ax);
            for (ui, &axi) in u.iter_mut().zip(ax.iter()) {
                *ui -= axi;
            }
            ws.recycle(ax);
            beta = nrm2(&u);
        }
        None => {
            x = vec![0.0; n];
            beta = bnorm;
        }
    }

    let mut v = ws.take(n);
    let mut alpha;
    if beta > 0.0 {
        let inv = 1.0 / beta;
        for ui in u.iter_mut() {
            *ui *= inv;
        }
        a.apply_transpose(&u, &mut v);
        alpha = nrm2(&v);
    } else {
        // u is zero: x0 (or 0) is already exact.
        v.copy_from_slice(&x);
        alpha = 0.0;
    }
    if alpha > 0.0 {
        let inv = 1.0 / alpha;
        for vi in v.iter_mut() {
            *vi *= inv;
        }
    }
    let mut w = ws.take_overwrite(n);
    w.copy_from_slice(&v);

    let mut rhobar = alpha;
    let mut phibar = beta;
    let mut rnorm = beta;
    let mut r1norm = rnorm;
    let mut r2norm = rnorm;
    let mut anorm = 0.0f64;
    let mut acond = 0.0f64;
    let mut ddnorm = 0.0f64;
    let mut res2 = 0.0f64;
    let mut xnorm = 0.0f64;
    let mut xxnorm = 0.0f64;
    let mut z = 0.0f64;
    let mut cs2 = -1.0f64;
    let mut sn2 = 0.0f64;
    let mut arnorm = alpha * beta;

    if arnorm == 0.0 {
        ws.recycle(u);
        ws.recycle(v);
        ws.recycle(w);
        return LsqrResult {
            x,
            istop: StopReason::TrivialSolution,
            itn: 0,
            r1norm,
            r2norm,
            anorm,
            acond,
            arnorm,
            xnorm,
            history,
        };
    }

    let mut istop = StopReason::IterLimit;
    let mut itn = 0usize;
    let mut scratch_m = ws.take(m);
    let mut scratch_n = ws.take(n);

    // --- main loop ---------------------------------------------------------
    while itn < iter_lim {
        itn += 1;

        // Bidiagonalization: β u = A v − α u ; α v = Aᵀ u − β v.
        a.apply(&v, &mut scratch_m);
        for (ui, &avi) in u.iter_mut().zip(scratch_m.iter()) {
            *ui = avi - alpha * *ui;
        }
        beta = nrm2(&u);
        if beta > 0.0 {
            let inv = 1.0 / beta;
            for ui in u.iter_mut() {
                *ui *= inv;
            }
            anorm = (anorm * anorm + alpha * alpha + beta * beta + dampsq).sqrt();
            a.apply_transpose(&u, &mut scratch_n);
            for (vi, &atui) in v.iter_mut().zip(scratch_n.iter()) {
                *vi = atui - beta * *vi;
            }
            alpha = nrm2(&v);
            if alpha > 0.0 {
                let inv = 1.0 / alpha;
                for vi in v.iter_mut() {
                    *vi *= inv;
                }
            }
        }

        // Eliminate the damping parameter.
        let (rhobar1, psi) = if cfg.damp > 0.0 {
            let rhobar1 = (rhobar * rhobar + dampsq).sqrt();
            let cs1 = rhobar / rhobar1;
            let sn1 = cfg.damp / rhobar1;
            let psi = sn1 * phibar;
            phibar *= cs1;
            (rhobar1, psi)
        } else {
            (rhobar, 0.0)
        };

        // Givens rotation on the bidiagonal system.
        let rho = (rhobar1 * rhobar1 + beta * beta).sqrt();
        let cs = rhobar1 / rho;
        let sn = beta / rho;
        let theta = sn * alpha;
        rhobar = -cs * alpha;
        let phi = cs * phibar;
        phibar *= sn;
        let tau = sn * phi;

        // Update x and w.
        let t1 = phi / rho;
        let t2 = -theta / rho;
        let inv_rho = 1.0 / rho;
        let mut dknorm2 = 0.0;
        for i in 0..n {
            let wi = w[i];
            let dk = wi * inv_rho;
            dknorm2 += dk * dk;
            x[i] += t1 * wi;
            w[i] = v[i] + t2 * wi;
        }
        ddnorm += dknorm2;

        // Norm estimates.
        let delta = sn2 * rho;
        let gambar = -cs2 * rho;
        let rhs = phi - delta * z;
        let zbar = rhs / gambar;
        xnorm = (xxnorm + zbar * zbar).sqrt();
        let gamma = (gambar * gambar + theta * theta).sqrt();
        cs2 = gambar / gamma;
        sn2 = theta / gamma;
        z = rhs / gamma;
        xxnorm += z * z;

        acond = anorm * ddnorm.sqrt();
        let res1 = phibar * phibar;
        res2 += psi * psi;
        rnorm = (res1 + res2).sqrt();
        arnorm = alpha * tau.abs();

        // r1norm: residual of the undamped system.
        let r1sq = rnorm * rnorm - dampsq * xxnorm;
        r1norm = r1sq.abs().sqrt();
        if r1sq < 0.0 {
            r1norm = -r1norm;
        }
        r2norm = rnorm;

        if cfg.track_history {
            history.push(rnorm);
        }

        // Stopping tests.
        let test1 = rnorm / bnorm;
        let test2 = arnorm / (anorm * rnorm + eps);
        let test3 = 1.0 / (acond + eps);
        let t1s = test1 / (1.0 + anorm * xnorm / bnorm);
        let rtol = cfg.btol + cfg.atol * anorm * xnorm / bnorm;

        if itn >= iter_lim {
            istop = StopReason::IterLimit;
        }
        if 1.0 + test3 <= 1.0 {
            istop = StopReason::ConditionMachineEps;
        }
        if 1.0 + test2 <= 1.0 {
            istop = StopReason::LeastSquaresMachineEps;
        }
        if 1.0 + t1s <= 1.0 {
            istop = StopReason::ResidualMachineEps;
        }
        if test3 <= ctol {
            istop = StopReason::ConditionLimit;
        }
        if test2 <= cfg.atol {
            istop = StopReason::LeastSquaresTol;
        }
        if test1 <= rtol {
            istop = StopReason::ResidualTol;
        }
        if istop != StopReason::IterLimit || itn >= iter_lim {
            break;
        }
    }

    let result = LsqrResult {
        x,
        istop,
        itn,
        r1norm,
        r2norm,
        anorm,
        acond,
        arnorm,
        xnorm,
        history,
    };
    ws.recycle(u);
    ws.recycle(v);
    ws.recycle(w);
    ws.recycle(scratch_m);
    ws.recycle(scratch_n);
    result
}

/// Per-column scalar state of the blocked iteration — exactly the locals of
/// [`lsqr`], one copy per right-hand side.
struct BlockCol {
    alpha: f64,
    beta: f64,
    rhobar: f64,
    phibar: f64,
    bnorm: f64,
    rnorm: f64,
    r1norm: f64,
    r2norm: f64,
    anorm: f64,
    acond: f64,
    ddnorm: f64,
    res2: f64,
    xnorm: f64,
    xxnorm: f64,
    z: f64,
    cs2: f64,
    sn2: f64,
    arnorm: f64,
    istop: StopReason,
    itn: usize,
    done: bool,
    history: Vec<f64>,
}

/// One column's Givens rotation, x/w update, norm estimates and stopping
/// tests — the exact scalar recurrences of `lsqr`, operating on column
/// `j`'s state (`c`) and its rows of x/w/v. Free of any cross-column
/// reads or writes, which is what lets [`lsqr_block_ws`] shard the active
/// set across the worker pool bitwise-identically to the serial loop.
#[allow(clippy::too_many_arguments)]
fn update_column(
    c: &mut BlockCol,
    xrow: &mut [f64],
    wrow: &mut [f64],
    vrow: &[f64],
    cfg: &LsqrConfig,
    dampsq: f64,
    eps: f64,
    ctol: f64,
    itn: usize,
    iter_lim: usize,
) {
    let (rhobar1, psi) = if cfg.damp > 0.0 {
        let rhobar1 = (c.rhobar * c.rhobar + dampsq).sqrt();
        let cs1 = c.rhobar / rhobar1;
        let sn1 = cfg.damp / rhobar1;
        let psi = sn1 * c.phibar;
        c.phibar *= cs1;
        (rhobar1, psi)
    } else {
        (c.rhobar, 0.0)
    };

    let rho = (rhobar1 * rhobar1 + c.beta * c.beta).sqrt();
    let cs = rhobar1 / rho;
    let sn = c.beta / rho;
    let theta = sn * c.alpha;
    c.rhobar = -cs * c.alpha;
    let phi = cs * c.phibar;
    c.phibar *= sn;
    let tau = sn * phi;

    let t1 = phi / rho;
    let t2 = -theta / rho;
    let inv_rho = 1.0 / rho;
    let mut dknorm2 = 0.0;
    for ((xi, wslot), &vi) in xrow.iter_mut().zip(wrow.iter_mut()).zip(vrow.iter()) {
        let wi = *wslot;
        let dk = wi * inv_rho;
        dknorm2 += dk * dk;
        *xi += t1 * wi;
        *wslot = vi + t2 * wi;
    }
    c.ddnorm += dknorm2;

    let delta = c.sn2 * rho;
    let gambar = -c.cs2 * rho;
    let rhs = phi - delta * c.z;
    let zbar = rhs / gambar;
    c.xnorm = (c.xxnorm + zbar * zbar).sqrt();
    let gamma = (gambar * gambar + theta * theta).sqrt();
    c.cs2 = gambar / gamma;
    c.sn2 = theta / gamma;
    c.z = rhs / gamma;
    c.xxnorm += c.z * c.z;

    c.acond = c.anorm * c.ddnorm.sqrt();
    let res1 = c.phibar * c.phibar;
    c.res2 += psi * psi;
    c.rnorm = (res1 + c.res2).sqrt();
    c.arnorm = c.alpha * tau.abs();

    let r1sq = c.rnorm * c.rnorm - dampsq * c.xxnorm;
    c.r1norm = r1sq.abs().sqrt();
    if r1sq < 0.0 {
        c.r1norm = -c.r1norm;
    }
    c.r2norm = c.rnorm;

    if cfg.track_history {
        c.history.push(c.rnorm);
    }

    let test1 = c.rnorm / c.bnorm;
    let test2 = c.arnorm / (c.anorm * c.rnorm + eps);
    let test3 = 1.0 / (c.acond + eps);
    let t1s = test1 / (1.0 + c.anorm * c.xnorm / c.bnorm);
    let rtol = cfg.btol + cfg.atol * c.anorm * c.xnorm / c.bnorm;

    let mut istop = StopReason::IterLimit;
    if 1.0 + test3 <= 1.0 {
        istop = StopReason::ConditionMachineEps;
    }
    if 1.0 + test2 <= 1.0 {
        istop = StopReason::LeastSquaresMachineEps;
    }
    if 1.0 + t1s <= 1.0 {
        istop = StopReason::ResidualMachineEps;
    }
    if test3 <= ctol {
        istop = StopReason::ConditionLimit;
    }
    if test2 <= cfg.atol {
        istop = StopReason::LeastSquaresTol;
    }
    if test1 <= rtol {
        istop = StopReason::ResidualTol;
    }
    if istop != StopReason::IterLimit || itn >= iter_lim {
        c.istop = istop;
        c.itn = itn;
        c.done = true;
    }
}

/// Blocked multi-RHS LSQR: solve `min ‖A xᵣ − bᵣ‖² + damp²‖xᵣ‖²` for the k
/// right-hand sides stored as the rows of `b` (k×m; row r = RHS r), with
/// optional per-RHS warm starts `x0` (k×n).
///
/// Each iteration performs **one** shared [`LinearOperator::apply_mat`] /
/// [`LinearOperator::apply_transpose_mat`] over the still-active columns
/// (GEMM-shaped: the operator streams through memory once for the whole
/// block) while every column keeps its own α/β/ρ̄/φ̄ scalar recurrence and
/// its own stopping tests. Columns that converge are masked out of
/// subsequent applies and stop iterating — exactly as if they had been
/// solved alone.
///
/// **Per-RHS equivalence contract** (pinned by
/// `tests/block_solve_properties.rs`): because the block applies are
/// bitwise identical per row to the single-vector applies, column r of the
/// result — `x`, `istop`, *and* the iteration count — matches an
/// independent `lsqr(a, b.row(r), x0.row(r), cfg)` call.
pub fn lsqr_block<Op: LinearOperator + ?Sized>(
    a: &Op,
    b: &DenseMatrix,
    x0: Option<&DenseMatrix>,
    cfg: &LsqrConfig,
) -> Vec<LsqrResult> {
    lsqr_block_ws(a, b, x0, cfg, &mut SolveWorkspace::new())
}

/// [`lsqr_block`] with a reusable [`SolveWorkspace`]: the u/v/w/x blocks
/// and the per-iteration active-column staging matrices (va/av/ub/atu —
/// previously fresh `DenseMatrix::zeros` clones every iteration) come from
/// the pool, so the worker's steady-state batched serving loop performs no
/// scratch allocations. Bitwise identical to [`lsqr_block`].
pub fn lsqr_block_ws<Op: LinearOperator + ?Sized>(
    a: &Op,
    b: &DenseMatrix,
    x0: Option<&DenseMatrix>,
    cfg: &LsqrConfig,
    ws: &mut SolveWorkspace,
) -> Vec<LsqrResult> {
    let (m, n) = a.shape();
    let k = b.rows();
    assert_eq!(b.cols(), m, "lsqr_block: RHS block has {} cols, A is {m}x{n}", b.cols());
    if k == 0 {
        return Vec::new();
    }
    let iter_lim = cfg.iter_lim.unwrap_or(2 * n);
    let eps = f64::EPSILON;
    let ctol = if cfg.conlim > 0.0 { 1.0 / cfg.conlim } else { 0.0 };
    let dampsq = cfg.damp * cfg.damp;

    // --- initialization (identical to lsqr, vectorized over columns) -----
    // Blocks that are fully copy-overwritten before any read use the
    // unspecified-contents takes; apply outputs (ax/av/atu) and the
    // zero-started x keep the zeroed takes (their kernels read the buffer).
    let mut x: DenseMatrix;
    let mut u = ws.take_mat_overwrite(k, m);
    u.data_mut().copy_from_slice(b.data());
    let mut betas = vec![0.0f64; k];
    match x0 {
        Some(x0m) => {
            assert_eq!(
                x0m.shape(),
                (k, n),
                "lsqr_block: x0 block is {:?}, need ({k}, {n})",
                x0m.shape()
            );
            x = ws.take_mat_overwrite(k, n);
            x.data_mut().copy_from_slice(x0m.data());
            let mut ax = ws.take_mat(k, m);
            a.apply_mat(x0m, &mut ax);
            for j in 0..k {
                let urow = u.row_mut(j);
                for (ui, &axi) in urow.iter_mut().zip(ax.row(j).iter()) {
                    *ui -= axi;
                }
                betas[j] = nrm2(u.row(j));
            }
            ws.recycle_mat(ax);
        }
        None => {
            x = ws.take_mat(k, n);
            for j in 0..k {
                betas[j] = nrm2(b.row(j));
            }
        }
    }

    // Every row of v is copy-overwritten below (β > 0 rows from atu, the
    // rest from x) → unspecified-contents take.
    let mut v = ws.take_mat_overwrite(k, n);
    let mut alphas = vec![0.0f64; k];
    {
        // One shared transpose apply for every column with β > 0; columns
        // with β = 0 copy x (their u is zero — x0 already exact).
        let pos: Vec<usize> = (0..k).filter(|&j| betas[j] > 0.0).collect();
        for &j in &pos {
            let inv = 1.0 / betas[j];
            for ui in u.row_mut(j).iter_mut() {
                *ui *= inv;
            }
        }
        if !pos.is_empty() {
            let mut ub = ws.take_mat_overwrite(pos.len(), m);
            for (bi, &j) in pos.iter().enumerate() {
                ub.row_mut(bi).copy_from_slice(u.row(j));
            }
            let mut atu = ws.take_mat(pos.len(), n);
            a.apply_transpose_mat(&ub, &mut atu);
            for (bi, &j) in pos.iter().enumerate() {
                v.row_mut(j).copy_from_slice(atu.row(bi));
                alphas[j] = nrm2(v.row(j));
            }
            ws.recycle_mat(ub);
            ws.recycle_mat(atu);
        }
        for j in 0..k {
            if betas[j] > 0.0 {
                continue;
            }
            v.row_mut(j).copy_from_slice(x.row(j));
            alphas[j] = 0.0;
        }
        for j in 0..k {
            if alphas[j] > 0.0 {
                let inv = 1.0 / alphas[j];
                for vi in v.row_mut(j).iter_mut() {
                    *vi *= inv;
                }
            }
        }
    }
    let mut w = ws.take_mat_overwrite(k, n);
    w.data_mut().copy_from_slice(v.data());

    let mut cols: Vec<BlockCol> = (0..k)
        .map(|j| {
            let bnorm = nrm2(b.row(j));
            let (alpha, beta) = (alphas[j], betas[j]);
            let arnorm = alpha * beta;
            // arnorm == 0 is lsqr's early TrivialSolution return: b is in
            // range of the warm start (or zero) — the column never iterates.
            let (istop, done) = if arnorm == 0.0 {
                (StopReason::TrivialSolution, true)
            } else {
                (StopReason::IterLimit, false)
            };
            BlockCol {
                alpha,
                beta,
                rhobar: alpha,
                phibar: beta,
                bnorm,
                rnorm: beta,
                r1norm: beta,
                r2norm: beta,
                anorm: 0.0,
                acond: 0.0,
                ddnorm: 0.0,
                res2: 0.0,
                xnorm: 0.0,
                xxnorm: 0.0,
                z: 0.0,
                cs2: -1.0,
                sn2: 0.0,
                arnorm,
                istop,
                itn: 0,
                done,
                history: Vec::new(),
            }
        })
        .collect();

    // --- main loop (shared applies, per-column scalars and masking) ------
    let mut itn = 0usize;
    while itn < iter_lim {
        let active: Vec<usize> = (0..k).filter(|&j| !cols[j].done).collect();
        if active.is_empty() {
            break;
        }
        itn += 1;

        // Bidiagonalization, blocked: β u = A v − α u ; α v = Aᵀ u − β v.
        // The active-column staging blocks come from the workspace pool —
        // after the first iteration these are pure reuses (the active set
        // only shrinks), so the loop allocates nothing.
        let ka = active.len();
        let mut va = ws.take_mat_overwrite(ka, n);
        for (ai, &j) in active.iter().enumerate() {
            va.row_mut(ai).copy_from_slice(v.row(j));
        }
        let mut av = ws.take_mat(ka, m);
        a.apply_mat(&va, &mut av);
        for (ai, &j) in active.iter().enumerate() {
            let alpha = cols[j].alpha;
            let urow = u.row_mut(j);
            for (ui, &avi) in urow.iter_mut().zip(av.row(ai).iter()) {
                *ui = avi - alpha * *ui;
            }
            cols[j].beta = nrm2(u.row(j));
        }
        ws.recycle_mat(va);
        ws.recycle_mat(av);

        let tcols: Vec<usize> = active.iter().copied().filter(|&j| cols[j].beta > 0.0).collect();
        if !tcols.is_empty() {
            for &j in &tcols {
                let c = &mut cols[j];
                let inv = 1.0 / c.beta;
                for ui in u.row_mut(j).iter_mut() {
                    *ui *= inv;
                }
                c.anorm =
                    (c.anorm * c.anorm + c.alpha * c.alpha + c.beta * c.beta + dampsq).sqrt();
            }
            let kb = tcols.len();
            let mut ub = ws.take_mat_overwrite(kb, m);
            for (bi, &j) in tcols.iter().enumerate() {
                ub.row_mut(bi).copy_from_slice(u.row(j));
            }
            let mut atu = ws.take_mat(kb, n);
            a.apply_transpose_mat(&ub, &mut atu);
            for (bi, &j) in tcols.iter().enumerate() {
                let beta = cols[j].beta;
                let vrow = v.row_mut(j);
                for (vi, &atui) in vrow.iter_mut().zip(atu.row(bi).iter()) {
                    *vi = atui - beta * *vi;
                }
                let alpha = nrm2(v.row(j));
                cols[j].alpha = alpha;
                if alpha > 0.0 {
                    let inv = 1.0 / alpha;
                    for vi in v.row_mut(j).iter_mut() {
                        *vi *= inv;
                    }
                }
            }
            ws.recycle_mat(ub);
            ws.recycle_mat(atu);
        }

        // Per-column Givens rotation, x/w update, norm estimates and
        // stopping tests — the exact scalar recurrences of lsqr. Columns
        // are independent (disjoint cols[j] state, disjoint rows of
        // x/w/v), so the active set shards across the worker pool behind
        // the usual work gate; every column runs the identical scalar
        // recurrence whatever the schedule, so the result is bitwise
        // identical to the serial loop at any thread count.
        let threads = if active.len().saturating_mul(n) < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(active.len(), 1)
        };
        if threads <= 1 {
            for &j in &active {
                update_column(
                    &mut cols[j],
                    x.row_mut(j),
                    w.row_mut(j),
                    v.row(j),
                    cfg,
                    dampsq,
                    eps,
                    ctol,
                    itn,
                    iter_lim,
                );
            }
        } else {
            let cols_ptr = crate::parallel::SendPtr(cols.as_mut_ptr());
            let x_ptr = crate::parallel::SendMutPtr(x.data_mut().as_mut_ptr());
            let w_ptr = crate::parallel::SendMutPtr(w.data_mut().as_mut_ptr());
            let (vdata, active_ref) = (v.data(), &active);
            crate::parallel::run_partitioned(active.len(), threads, |_, range| {
                for ai in range {
                    let j = active_ref[ai];
                    // SAFETY: `active` holds distinct column indices and
                    // each index lands in exactly one unit, so column j's
                    // state and row j of x/w have a unique accessor; all
                    // buffers outlive the pool region.
                    unsafe {
                        let c = &mut *cols_ptr.0.add(j);
                        let xrow = std::slice::from_raw_parts_mut(x_ptr.0.add(j * n), n);
                        let wrow = std::slice::from_raw_parts_mut(w_ptr.0.add(j * n), n);
                        let vrow = &vdata[j * n..(j + 1) * n];
                        update_column(c, xrow, wrow, vrow, cfg, dampsq, eps, ctol, itn, iter_lim);
                    }
                }
            });
        }
    }

    let results: Vec<LsqrResult> = cols
        .into_iter()
        .enumerate()
        .map(|(j, c)| LsqrResult {
            x: x.row(j).to_vec(),
            istop: c.istop,
            itn: c.itn,
            r1norm: c.r1norm,
            r2norm: c.r2norm,
            anorm: c.anorm,
            acond: c.acond,
            arnorm: c.arnorm,
            xnorm: c.xnorm,
            history: c.history,
        })
        .collect();
    ws.recycle_mat(x);
    ws.recycle_mat(u);
    ws.recycle_mat(v);
    ws.recycle_mat(w);
    results
}

/// The deterministic baseline as a [`Solver`].
#[derive(Debug, Clone, Default)]
pub struct LsqrSolver {
    pub config: LsqrConfig,
}

impl LsqrSolver {
    pub fn new(config: LsqrConfig) -> Self {
        Self { config }
    }
}

impl Solver for LsqrSolver {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        check_dims(a, b)?;
        let r = lsqr(a.as_operator(), b, None, &self.config);
        Ok(Solution {
            x: r.x,
            iterations: r.itn,
            resnorm: r.r1norm.abs(),
            arnorm: r.arnorm,
            converged: r.istop.converged(),
            fallback_used: false,
            residual_history: r.history,
        })
    }

    fn name(&self) -> &'static str {
        "lsqr"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::nrm2_diff;
    use crate::linalg::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn well_conditioned(m: usize, n: usize, seed: u64) -> (DenseMatrix, Vec<f64>, Vec<f64>) {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let x_true = g.gaussian_vec(n);
        let b = a.matvec(&x_true);
        (a, x_true, b)
    }

    #[test]
    fn solves_consistent_system() {
        let (a, x_true, b) = well_conditioned(60, 12, 71);
        let r = lsqr(&a, &b, None, &LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() });
        assert!(r.istop.converged(), "istop {:?}", r.istop);
        let err = nrm2_diff(&r.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn solves_inconsistent_least_squares() {
        let (a, _xt, mut b) = well_conditioned(80, 10, 72);
        // Add a residual component.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(73));
        for bi in b.iter_mut() {
            *bi += 0.5 * g.next_gaussian();
        }
        let r = lsqr(&a, &b, None, &LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() });
        // Optimality: Aᵀ(Ax−b) ≈ 0.
        let ax = a.matvec(&r.x);
        let resid: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
        let grad = a.matvec_t(&resid);
        let rel = nrm2(&grad) / (nrm2(&resid) * r.anorm);
        assert!(rel < 1e-8, "optimality {rel}");
        assert!(matches!(r.istop, StopReason::LeastSquaresTol | StopReason::LeastSquaresMachineEps),
            "istop {:?}", r.istop);
    }

    #[test]
    fn warm_start_accelerates() {
        let (a, x_true, b) = well_conditioned(100, 20, 74);
        let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() };
        let cold = lsqr(&a, &b, None, &cfg);
        // Start very close to the solution.
        let mut x0 = x_true.clone();
        x0[0] += 1e-9;
        let warm = lsqr(&a, &b, Some(&x0), &cfg);
        assert!(warm.itn < cold.itn, "warm {} vs cold {}", warm.itn, cold.itn);
        let err = nrm2_diff(&warm.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8);
    }

    #[test]
    fn exact_warm_start_is_trivial() {
        let (a, x_true, b) = well_conditioned(40, 8, 75);
        let r = lsqr(&a, &b, Some(&x_true), &LsqrConfig::default());
        assert!(r.itn <= 1);
        assert!(r.istop.converged());
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let (a, _xt, _b) = well_conditioned(30, 6, 76);
        let b = vec![0.0; 30];
        let r = lsqr(&a, &b, None, &LsqrConfig::default());
        assert_eq!(r.istop, StopReason::TrivialSolution);
        assert!(r.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn iteration_limit_respected() {
        let (a, _xt, b) = well_conditioned(200, 50, 77);
        let cfg = LsqrConfig { iter_lim: Some(3), atol: 1e-16, btol: 1e-16, ..Default::default() };
        let r = lsqr(&a, &b, None, &cfg);
        assert_eq!(r.itn, 3);
        assert_eq!(r.istop, StopReason::IterLimit);
    }

    #[test]
    fn damping_shrinks_solution() {
        let (a, _xt, b) = well_conditioned(60, 10, 78);
        let plain = lsqr(&a, &b, None, &LsqrConfig::default());
        let damped = lsqr(&a, &b, None, &LsqrConfig { damp: 10.0, ..Default::default() });
        assert!(nrm2(&damped.x) < nrm2(&plain.x));
    }

    #[test]
    fn history_tracked() {
        let (a, _xt, b) = well_conditioned(50, 10, 79);
        let cfg = LsqrConfig { track_history: true, ..Default::default() };
        let r = lsqr(&a, &b, None, &cfg);
        assert_eq!(r.history.len(), r.itn);
        // residuals non-increasing (monotone for LSQR)
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-12));
        }
    }

    #[test]
    fn conlim_triggers_on_illconditioned() {
        // Build an ill-conditioned A via scaled columns.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(80));
        let mut a = DenseMatrix::gaussian(100, 10, &mut g);
        for j in 0..10 {
            let s = 10f64.powi(-(j as i32) * 2);
            for i in 0..100 {
                a[(i, j)] *= s;
            }
        }
        let b = g.gaussian_vec(100);
        let cfg = LsqrConfig { conlim: 1e6, atol: 1e-16, btol: 1e-16, ..Default::default() };
        let r = lsqr(&a, &b, None, &cfg);
        assert!(
            matches!(r.istop, StopReason::ConditionLimit | StopReason::ConditionMachineEps),
            "istop {:?} acond {:.3e}",
            r.istop,
            r.acond
        );
    }

    /// Stack k RHS vectors as the rows of a block.
    fn rhs_block(rows: &[Vec<f64>]) -> DenseMatrix {
        let m = rows[0].len();
        let mut b = DenseMatrix::zeros(rows.len(), m);
        for (j, r) in rows.iter().enumerate() {
            b.row_mut(j).copy_from_slice(r);
        }
        b
    }

    #[test]
    fn block_matches_independent_solves_exactly() {
        let (a, x_true, b0) = well_conditioned(90, 14, 82);
        // Mixed batch: consistent, noisy, scaled, and all-zero columns.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(83));
        let mut b1 = b0.clone();
        for bi in b1.iter_mut() {
            *bi += 0.3 * g.next_gaussian();
        }
        let b2: Vec<f64> = b0.iter().map(|v| 1e-3 * v).collect();
        let b3 = vec![0.0; 90];
        let rhs = [b0.clone(), b1, b2, b3];
        let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() };
        let block = lsqr_block(&a, &rhs_block(&rhs), None, &cfg);
        assert_eq!(block.len(), 4);
        for (j, bj) in rhs.iter().enumerate() {
            let solo = lsqr(&a, bj, None, &cfg);
            assert_eq!(block[j].istop, solo.istop, "col {j}");
            assert_eq!(block[j].itn, solo.itn, "col {j}");
            assert_eq!(block[j].x, solo.x, "col {j}");
        }
        // The zero column is trivial; the consistent one recovers x_true.
        assert_eq!(block[3].istop, StopReason::TrivialSolution);
        let err = nrm2_diff(&block[0].x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn block_mixed_convergence_masks_columns() {
        // Columns of very different difficulty converge at different
        // iterations; each must still match its solo run.
        let (a, x_true, b) = well_conditioned(120, 16, 84);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(85));
        let mut noisy = b.clone();
        for bi in noisy.iter_mut() {
            *bi += 2.0 * g.next_gaussian();
        }
        let cfg = LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() };
        // Warm-start the first column at the exact solution: converges at
        // iteration 0/1 while the others keep iterating.
        let mut x0 = DenseMatrix::zeros(3, 16);
        x0.row_mut(0).copy_from_slice(&x_true);
        let rhs = rhs_block(&[b.clone(), b.clone(), noisy.clone()]);
        let block = lsqr_block(&a, &rhs, Some(&x0), &cfg);
        assert!(block[0].itn <= 1, "warm col itn {}", block[0].itn);
        assert!(block[2].itn > block[0].itn, "mixed convergence expected");
        let zeros = vec![0.0; 16];
        let solo0 = lsqr(&a, &b, Some(&x_true), &cfg);
        let solo2 = lsqr(&a, &noisy, Some(&zeros), &cfg);
        assert_eq!(block[0].itn, solo0.itn);
        assert_eq!(block[0].x, solo0.x);
        assert_eq!(block[2].itn, solo2.itn);
        assert_eq!(block[2].x, solo2.x);
    }

    #[test]
    fn block_k1_equals_single() {
        let (a, _xt, b) = well_conditioned(70, 10, 86);
        let cfg = LsqrConfig { atol: 1e-10, btol: 1e-10, track_history: true, ..Default::default() };
        let block = lsqr_block(&a, &rhs_block(&[b.clone()]), None, &cfg);
        let solo = lsqr(&a, &b, None, &cfg);
        assert_eq!(block[0].x, solo.x);
        assert_eq!(block[0].itn, solo.itn);
        assert_eq!(block[0].history, solo.history);
        assert_eq!(block[0].r1norm.to_bits(), solo.r1norm.to_bits());
    }

    #[test]
    fn block_damping_matches_solo() {
        let (a, _xt, b) = well_conditioned(60, 8, 87);
        let cfg = LsqrConfig { damp: 2.5, ..Default::default() };
        let block = lsqr_block(&a, &rhs_block(&[b.clone(), b.clone()]), None, &cfg);
        let solo = lsqr(&a, &b, None, &cfg);
        for r in &block {
            assert_eq!(r.x, solo.x);
            assert_eq!(r.itn, solo.itn);
        }
    }

    #[test]
    fn block_iteration_limit_and_empty() {
        let (a, _xt, b) = well_conditioned(150, 40, 88);
        let cfg = LsqrConfig { iter_lim: Some(3), atol: 1e-16, btol: 1e-16, ..Default::default() };
        let block = lsqr_block(&a, &rhs_block(&[b.clone()]), None, &cfg);
        assert_eq!(block[0].itn, 3);
        assert_eq!(block[0].istop, StopReason::IterLimit);
        let empty = lsqr_block(&a, &DenseMatrix::zeros(0, 150), None, &cfg);
        assert!(empty.is_empty());
    }

    #[test]
    fn workspace_reuse_is_bitwise_identical() {
        // Repeated solves through ONE SolveWorkspace (recycled, re-zeroed
        // buffers) must match the fresh-allocation path bitwise — the
        // guarantee the worker's steady-state serving loop relies on.
        let (a, _xt, b) = well_conditioned(80, 12, 89);
        let cfg =
            LsqrConfig { atol: 1e-12, btol: 1e-12, track_history: true, ..Default::default() };
        let fresh = lsqr(&a, &b, None, &cfg);
        let mut ws = SolveWorkspace::new();
        for trial in 0..3 {
            let r = lsqr_ws(&a, &b, None, &cfg, &mut ws);
            assert_eq!(r.x, fresh.x, "trial {trial}");
            assert_eq!(r.itn, fresh.itn, "trial {trial}");
            assert_eq!(r.istop, fresh.istop, "trial {trial}");
            assert_eq!(r.r1norm.to_bits(), fresh.r1norm.to_bits(), "trial {trial}");
            assert_eq!(r.history, fresh.history, "trial {trial}");
        }
        // Blocked path (with warm starts) through the same workspace.
        let x0 = rhs_block(&[vec![0.1; 12], vec![0.0; 12]]);
        let rhs = rhs_block(&[b.clone(), b.clone()]);
        let fresh_blk = lsqr_block(&a, &rhs, Some(&x0), &cfg);
        for trial in 0..3 {
            let blk = lsqr_block_ws(&a, &rhs, Some(&x0), &cfg, &mut ws);
            for (col, (rb, rf)) in blk.iter().zip(fresh_blk.iter()).enumerate() {
                assert_eq!(rb.x, rf.x, "trial {trial} col {col}");
                assert_eq!(rb.itn, rf.itn, "trial {trial} col {col}");
                assert_eq!(rb.istop, rf.istop, "trial {trial} col {col}");
            }
        }
    }

    #[test]
    fn solver_trait_wrapper() {
        let (a, x_true, b) = well_conditioned(50, 8, 81);
        let s = LsqrSolver::new(LsqrConfig { atol: 1e-12, btol: 1e-12, ..Default::default() });
        let sol = s.solve(&Matrix::Dense(a), &b).unwrap();
        assert!(sol.converged);
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8);
        assert_eq!(s.name(), "lsqr");
    }

    #[test]
    fn dimension_errors() {
        let a = Matrix::Dense(DenseMatrix::zeros(5, 3));
        let s = LsqrSolver::default();
        assert!(s.solve(&a, &[0.0; 4]).is_err());
        let wide = Matrix::Dense(DenseMatrix::zeros(3, 5));
        assert!(s.solve(&wide, &[0.0; 3]).is_err());
    }
}
