//! Classical one-shot **sketch-and-solve**: solve the sketched problem
//! `min ‖SA·x − Sb‖` exactly and return its minimizer `x̂ = R⁻¹Qᵀ(Sb)`.
//!
//! This is SAA-SAS *without* the LSQR refinement — the estimate Algorithm 1
//! uses as its warm start (`x̂ = R⁻¹z₀`). Error is O(ε‖r‖) rather than
//! machine precision; it anchors the accuracy end of the ablation table.

use crate::linalg::{qr, triangular, Matrix};
use crate::sketch::{self, SketchKind, SketchOperator};

use super::saa::sketch_rows;
use super::{check_dims, Result, Solution, Solver, SolverError};

/// One-shot sketch-and-solve configuration.
#[derive(Debug, Clone)]
pub struct SasConfig {
    pub sketch: SketchKind,
    pub sketch_factor: f64,
    pub seed: u64,
}

impl Default for SasConfig {
    fn default() -> Self {
        Self { sketch: SketchKind::CountSketch, sketch_factor: 4.0, seed: 0xD00D_CAFE }
    }
}

/// The classical sketch-and-solve estimator.
#[derive(Debug, Clone, Default)]
pub struct SketchAndSolve {
    pub config: SasConfig,
}

impl SketchAndSolve {
    pub fn new(config: SasConfig) -> Self {
        Self { config }
    }
}

impl Solver for SketchAndSolve {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        let (m, n) = check_dims(a, b)?;
        let cfg = &self.config;
        if m <= n + 1 {
            return Err(SolverError::Dimension(format!(
                "sketch-and-solve needs m ≫ s > n; got m={m}, n={n}"
            )));
        }
        let s_rows = sketch_rows(cfg.sketch_factor, m, n);
        let s_op = sketch::build(cfg.sketch, s_rows, m, cfg.seed);
        let b_sk = s_op.apply_matrix(a);
        let c = s_op.apply_vec(b);
        let f = qr::qr_compact(&b_sk)?;
        let z0 = f.q_transpose_vec(&c);
        let x = triangular::solve_upper(&f.r(), &z0)?;

        // Diagnostics: true residual of the returned estimate.
        let ax = a.as_operator().apply_vec(&x);
        let resnorm = crate::linalg::norms::nrm2(
            &ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect::<Vec<_>>(),
        );
        Ok(Solution {
            x,
            iterations: 0,
            resnorm,
            arnorm: f64::NAN,
            converged: true,
            fallback_used: false,
            residual_history: Vec::new(),
        })
    }

    fn name(&self) -> &'static str {
        "sketch-and-solve"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{nrm2, nrm2_diff};
    use crate::linalg::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    #[test]
    fn one_shot_estimate_is_close_but_not_exact() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(301));
        let a = DenseMatrix::gaussian(3000, 30, &mut g);
        let x_true = g.gaussian_vec(30);
        let mut b = a.matvec(&x_true);
        for v in b.iter_mut() {
            *v += 0.01 * g.next_gaussian();
        }
        let am = Matrix::Dense(a);
        let sol = SketchAndSolve::default().solve(&am, &b).unwrap();
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        // Close (sketch preserves the solution) but far from machine eps.
        assert!(err < 0.05, "err {err}");
        // The refined SAA solution must beat one-shot on the same problem.
        let saa = crate::solvers::saa::SaaSolver::default().solve(&am, &b).unwrap();
        let err_saa = nrm2_diff(&saa.x, &x_true) / nrm2(&x_true);
        assert!(err_saa <= err, "saa {err_saa} vs sas {err}");
    }

    #[test]
    fn exact_on_consistent_systems() {
        // b in range(A): sketched solve recovers x exactly (S preserves
        // the row space of [A b]).
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(302));
        let a = DenseMatrix::gaussian(500, 10, &mut g);
        let x_true = g.gaussian_vec(10);
        let b = a.matvec(&x_true);
        let sol = SketchAndSolve::default().solve(&Matrix::Dense(a), &b).unwrap();
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-10, "err {err}");
    }
}
