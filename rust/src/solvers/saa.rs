//! **SAA-SAS** — Sketch-and-Apply (the paper's Algorithm 1).
//!
//! ```text
//! 1  Draw a random sketching matrix S ∈ R^{s×m},  m ≫ s > n
//! 2  B = S·A,  c = S·b
//! 3  [Q, R] = HHQR(B)
//! 4  Y = A·R⁻¹                    (forward substitution)
//! 5  z₀ = Qᵀ·c
//! 6  solve Y·z = b by LSQR, no preconditioner, initial guess z₀
//! 7  if converged:  x = R⁻¹·z     (back substitution)
//! 8  else: Ã = A + σG/√m, σ = 10‖A‖₂u, redo 2–6 on Ã, x = R⁻¹z
//! ```
//!
//! Why it is fast: R from the sketched QR is a near-perfect right
//! preconditioner (κ(AR⁻¹) = O(1) when S is a subspace embedding), so LSQR
//! converges in a handful of iterations; and z₀ = Qᵀc is the classical
//! sketch-and-solve estimate, which already has O(ε) error — LSQR only
//! polishes it.
//!
//! Representation choices:
//! * dense A → Y is materialized once (step 4) so LSQR iterates on plain
//!   GEMV — the fastest inner loop;
//! * sparse A → Y would be dense m×n; we iterate on the *implicit*
//!   `PreconditionedOperator` (A·R⁻¹ as two cheap ops) instead.

use crate::linalg::operator::PreconditionedOperator;
use crate::linalg::{norms, qr, triangular, DenseMatrix, LinearOperator, Matrix};
use crate::sketch::{self, SketchKind, SketchOperator};

use super::lsqr::{lsqr, LsqrConfig, LsqrResult};
use super::perturb::{perturb_dense, perturbation_sigma, StreamPerturbedOperator};
use super::{check_dims, Result, Solution, Solver, SolverError};

/// SAA-SAS configuration.
#[derive(Debug, Clone)]
pub struct SaaConfig {
    /// Sketch family (paper's final choice: Clarkson–Woodruff).
    pub sketch: SketchKind,
    /// Sketch rows as a multiple of n: `s = ceil(sketch_factor · n)`,
    /// clamped to (n, m]. Paper requires m ≫ s > n; 2–4 is standard.
    pub sketch_factor: f64,
    /// LSQR tolerances for the inner solve.
    pub lsqr: LsqrConfig,
    /// RNG seed for S (and G on the fallback path).
    pub seed: u64,
    /// Allow the Algorithm-1 perturbation fallback (lines 10–17).
    pub enable_fallback: bool,
    /// Power-iteration steps for the ‖A‖₂ estimate used by σ.
    pub norm_est_iters: usize,
}

impl Default for SaaConfig {
    fn default() -> Self {
        Self {
            sketch: SketchKind::CountSketch,
            sketch_factor: 4.0,
            lsqr: LsqrConfig {
                atol: 1e-12,
                btol: 1e-12,
                conlim: 0.0,
                ..Default::default()
            },
            seed: 0x5A5A_1234,
            enable_fallback: true,
            norm_est_iters: 30,
        }
    }
}

/// The SAA-SAS solver (Algorithm 1).
#[derive(Debug, Clone, Default)]
pub struct SaaSolver {
    pub config: SaaConfig,
}

impl SaaSolver {
    pub fn new(config: SaaConfig) -> Self {
        Self { config }
    }

    pub fn with_sketch(kind: SketchKind) -> Self {
        Self { config: SaaConfig { sketch: kind, ..Default::default() } }
    }

    /// Sketch rows for an m×n input.
    pub fn sketch_rows(&self, m: usize, n: usize) -> usize {
        sketch_rows(self.config.sketch_factor, m, n)
    }
}

pub(crate) fn sketch_rows(factor: f64, m: usize, n: usize) -> usize {
    let s = (factor * n as f64).ceil() as usize;
    s.max(n + 1).min(m)
}

/// One sketch→QR→warm-LSQR pass (Algorithm 1 lines 2–6) over operator
/// `op` (= A or Ã). Returns (z-result, R, z₀).
fn saa_pass(
    a_sketchable: &Matrix,
    b: &[f64],
    s_op: &dyn SketchOperator,
    cfg: &SaaConfig,
) -> Result<(LsqrResult, DenseMatrix, Vec<f64>)> {
    // Step 2: B = S·A, c = S·b.
    let b_sk = s_op.apply_matrix(a_sketchable);
    let c = s_op.apply_vec(b);

    // Step 3: HHQR of the sketched matrix.
    let f = qr::qr_compact(&b_sk)?;
    let r = f.r();

    // Step 5: z₀ = Qᵀc (economy part).
    let z0 = f.q_transpose_vec(&c);

    // Steps 4+6: LSQR on Y z = b with Y = A R⁻¹.
    let res = match a_sketchable {
        Matrix::Dense(ad) => {
            // Materialize Y once; LSQR then runs on contiguous GEMV.
            let y = triangular::right_solve_upper(ad, &r)?;
            lsqr(&y, b, Some(&z0), &cfg.lsqr)
        }
        Matrix::Csr(ac) => {
            let op = PreconditionedOperator::new(ac, &r);
            lsqr(&op, b, Some(&z0), &cfg.lsqr)
        }
    };
    Ok((res, r, z0))
}

/// The fallback pass (Algorithm 1 lines 10–17) on `Ã = A + σG/√m`.
fn saa_pass_perturbed(
    a: &Matrix,
    b: &[f64],
    s_op: &dyn SketchOperator,
    sigma: f64,
    cfg: &SaaConfig,
) -> Result<(LsqrResult, DenseMatrix)> {
    let g_seed = cfg.seed ^ 0xFA11_BACC;
    match a {
        Matrix::Dense(ad) => {
            // Dense: materialize Ã once, then identical to the main pass.
            let tilde = perturb_dense(ad, g_seed, sigma);
            let b_sk = s_op.apply_dense(&tilde);
            let c = s_op.apply_vec(b);
            let f = qr::qr_compact(&b_sk)?;
            let r = f.r();
            let z0 = f.q_transpose_vec(&c);
            let y = triangular::right_solve_upper(&tilde, &r)?;
            Ok((lsqr(&y, b, Some(&z0), &cfg.lsqr), r))
        }
        Matrix::Csr(ac) => {
            // Sparse: keep Ã implicit. B̃ = S·A + S·(σ/√m)G; the second term
            // is computed by sketching the streaming G column-block-wise
            // (S applied to a dense matrix of G's rows — still O(s·n·m/BLOCK)
            // work but no m×n allocation).
            let tilde = StreamPerturbedOperator::new(ac, g_seed, sigma);
            // Sketch Ã column by column through the operator: S(Ã e_j).
            // n is ≤ ~1000; each column costs one matvec + one vec-sketch.
            let (m, n) = ac.shape();
            let mut b_sk = DenseMatrix::zeros(s_op.sketch_dim(), n);
            let mut ej = vec![0.0; n];
            let mut col = vec![0.0; m];
            for j in 0..n {
                ej[j] = 1.0;
                tilde.apply(&ej, &mut col);
                let sc = s_op.apply_vec(&col);
                for (i, &v) in sc.iter().enumerate() {
                    b_sk[(i, j)] = v;
                }
                ej[j] = 0.0;
            }
            let c = s_op.apply_vec(b);
            let f = qr::qr_compact(&b_sk)?;
            let r = f.r();
            let z0 = f.q_transpose_vec(&c);
            let op = PreconditionedOperator::new(&tilde, &r);
            Ok((lsqr(&op, b, Some(&z0), &cfg.lsqr), r))
        }
    }
}

impl Solver for SaaSolver {
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution> {
        let (m, n) = check_dims(a, b)?;
        let cfg = &self.config;
        if m <= n + 1 {
            return Err(SolverError::Dimension(format!(
                "SAA-SAS needs m ≫ s > n; got m={m}, n={n}"
            )));
        }
        // Step 1: draw S.
        let s_rows = self.sketch_rows(m, n);
        let s_op = sketch::build(cfg.sketch, s_rows, m, cfg.seed);

        // Steps 2–6.
        let (res, r, _z0) = saa_pass(a, b, s_op.as_ref(), cfg)?;

        if res.istop.converged() || !cfg.enable_fallback {
            // Step 8: x = R⁻¹ z.
            let x = triangular::solve_upper(&r, &res.x)?;
            return Ok(Solution {
                x,
                iterations: res.itn,
                resnorm: res.r1norm.abs(),
                arnorm: res.arnorm,
                converged: res.istop.converged(),
                fallback_used: false,
                residual_history: res.history,
            });
        }

        // Lines 10–17: perturb and retry.
        let norm_a = norms::spectral_norm_est(a.as_operator(), cfg.norm_est_iters, cfg.seed ^ 0xE5);
        let sigma = perturbation_sigma(norm_a);
        let (res2, r2) = saa_pass_perturbed(a, b, s_op.as_ref(), sigma, cfg)?;
        let x = triangular::solve_upper(&r2, &res2.x)?;
        let total_itn = res.itn + res2.itn;
        Ok(Solution {
            x,
            iterations: total_itn,
            resnorm: res2.r1norm.abs(),
            arnorm: res2.arnorm,
            converged: res2.istop.converged(),
            fallback_used: true,
            residual_history: res2.history,
        })
    }

    fn name(&self) -> &'static str {
        "saa-sas"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{nrm2, nrm2_diff};
    use crate::linalg::sparse::CooBuilder;
    use crate::rng::{GaussianSource, RngCore, Xoshiro256pp};

    fn planted_dense(m: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>, Vec<f64>) {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let x = g.gaussian_vec(n);
        let b = a.matvec(&x);
        (Matrix::Dense(a), x, b)
    }

    #[test]
    fn solves_consistent_dense() {
        let (a, x_true, b) = planted_dense(600, 30, 101);
        let sol = SaaSolver::default().solve(&a, &b).unwrap();
        assert!(sol.converged, "not converged: {sol:?}");
        assert!(!sol.fallback_used);
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn solves_inconsistent_dense() {
        let (a, _xt, mut b) = planted_dense(500, 20, 102);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(103));
        for v in b.iter_mut() {
            *v += 0.1 * g.next_gaussian();
        }
        let sol = SaaSolver::default().solve(&a, &b).unwrap();
        // optimality check
        let ad = a.to_dense();
        let ax = ad.matvec(&sol.x);
        let r: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
        let grad = ad.matvec_t(&r);
        assert!(nrm2(&grad) / nrm2(&r) < 1e-6, "gradient {}", nrm2(&grad));
    }

    #[test]
    fn solves_sparse_via_implicit_preconditioner() {
        let (m, n) = (2000, 40);
        let mut rng = Xoshiro256pp::seed_from_u64(104);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(105));
        let mut bld = CooBuilder::new(m, n);
        // ~15 nnz per row, always j=i%n to guarantee full column rank.
        for i in 0..m {
            bld.push(i, i % n, 1.0 + g.next_gaussian().abs());
            for _ in 0..14 {
                bld.push(i, rng.next_bounded(n as u64) as usize, g.next_gaussian());
            }
        }
        let a = Matrix::Csr(bld.build());
        let x_true = g.gaussian_vec(n);
        let b = a.as_operator().apply_vec(&x_true);
        let sol = SaaSolver::default().solve(&a, &b).unwrap();
        assert!(sol.converged);
        let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
        assert!(err < 1e-7, "err {err}");
    }

    #[test]
    fn few_iterations_thanks_to_preconditioning() {
        // Ill-conditioned dense problem: LSQR alone stalls; SAA converges in
        // a handful of iterations.
        let (m, n) = (2000, 50);
        let p = crate::problems::generate_dense(&crate::problems::DenseProblemSpec {
            m,
            n,
            cond: 1e8,
            resid_norm: 1e-8,
            seed: 7,
        });
        let sol = SaaSolver::default().solve(&p.a, &p.b).unwrap();
        assert!(sol.converged);
        assert!(
            sol.iterations <= 30,
            "expected rapid convergence, got {} iterations",
            sol.iterations
        );
        let err = p.relative_error(&sol.x);
        assert!(err < 1e-4, "relative error {err}");
    }

    #[test]
    fn all_sketch_kinds_work() {
        let (a, x_true, b) = planted_dense(800, 25, 106);
        for kind in SketchKind::ALL {
            let sol = SaaSolver::with_sketch(kind).solve(&a, &b).unwrap();
            assert!(sol.converged, "{}", kind.name());
            let err = nrm2_diff(&sol.x, &x_true) / nrm2(&x_true);
            assert!(err < 1e-6, "{}: err {err}", kind.name());
        }
    }

    #[test]
    fn rejects_underdetermined_and_tiny() {
        let s = SaaSolver::default();
        let a = Matrix::Dense(DenseMatrix::zeros(5, 10));
        assert!(s.solve(&a, &[0.0; 5]).is_err());
        let sq = Matrix::Dense(DenseMatrix::eye(4));
        assert!(s.solve(&sq, &[0.0; 4]).is_err());
    }

    #[test]
    fn sketch_rows_bounds() {
        let s = SaaSolver::default();
        // factor 4, n=100 → 400
        assert_eq!(s.sketch_rows(100_000, 100), 400);
        // clamped to m
        assert_eq!(s.sketch_rows(300, 100), 300);
        // at least n+1
        let s2 = SaaSolver::new(SaaConfig { sketch_factor: 0.5, ..Default::default() });
        assert_eq!(s2.sketch_rows(10_000, 100), 101);
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _xt, b) = planted_dense(400, 15, 107);
        let s = SaaSolver::default();
        let s1 = s.solve(&a, &b).unwrap();
        let s2 = s.solve(&a, &b).unwrap();
        assert_eq!(s1.x, s2.x);
    }
}
