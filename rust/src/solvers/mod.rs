//! Least-squares solvers (§3–§4 of the paper).
//!
//! * [`lsqr`] — the deterministic baseline: Paige–Saunders LSQR with
//!   SciPy-compatible stopping rules.
//! * [`saa`] — **SAA-SAS**, the paper's Algorithm 1 (sketch-and-apply):
//!   sketch → HHQR → implicit right-preconditioning → warm-started LSQR →
//!   back substitution, with the σ-perturbation fallback.
//! * [`sap`] — SAP-SAS (sketch-and-precondition), the ablation the paper
//!   found no faster than the baseline.
//! * [`sas`] — the classical one-shot sketch-and-solve estimate
//!   `x̂ = R⁻¹Qᵀ(Sb)` (cheapest, lowest accuracy).
//! * [`direct`] — dense Householder-QR direct solve (small-problem oracle).
//! * [`perturb`] — the implicit `A + σG/√m` operator for the fallback path.
//! * [`stable`] — the forward-stable tier: iterative sketching with
//!   momentum + refinement sweeps behind the [`ladder`] escalation ladder
//!   (sketch-and-solve → preconditioned LSQR → refinement → dense QR),
//!   escalating on an R-preconditioned forward-error proxy instead of
//!   trusting any single stage.

pub mod direct;
pub mod ladder;
pub mod lsqr;
pub mod perturb;
pub mod saa;
pub mod sap;
pub mod sas;
pub mod stable;

use crate::linalg::Matrix;

pub use ladder::{LadderConfig, LadderOutcome, Stage};
pub use lsqr::{lsqr, LsqrConfig, LsqrResult, StopReason};
pub use saa::SaaSolver;
pub use sap::SapSolver;
pub use sas::SketchAndSolve;
pub use stable::{StableConfig, StableSolver};

/// Errors from the solver layer.
#[derive(Debug)]
pub enum SolverError {
    Dimension(String),
    Linalg(crate::linalg::LinalgError),
    NoConvergence(String),
}

impl std::fmt::Display for SolverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverError::Dimension(m) => write!(f, "dimension mismatch: {m}"),
            SolverError::Linalg(e) => write!(f, "{e}"),
            SolverError::NoConvergence(m) => write!(f, "solver failed to converge: {m}"),
        }
    }
}

impl std::error::Error for SolverError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SolverError::Linalg(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::linalg::LinalgError> for SolverError {
    fn from(e: crate::linalg::LinalgError) -> Self {
        SolverError::Linalg(e)
    }
}

pub type Result<T> = std::result::Result<T, SolverError>;

/// A solve outcome with enough diagnostics to drive the figures.
#[derive(Debug, Clone)]
pub struct Solution {
    /// The approximate solution x̂ (length n).
    pub x: Vec<f64>,
    /// Total LSQR (or equivalent) iterations performed.
    pub iterations: usize,
    /// Final residual norm ‖Ax̂ − b‖ as tracked by the solver.
    pub resnorm: f64,
    /// Final ‖Aᵀr‖ (least-squares optimality measure).
    pub arnorm: f64,
    /// Whether the solver's own convergence test passed.
    pub converged: bool,
    /// Whether Algorithm 1's perturbation fallback path ran (SAA only).
    pub fallback_used: bool,
    /// Per-iteration residual norms, when tracked (drives Figure 4).
    pub residual_history: Vec<f64>,
}

impl Solution {
    pub fn n(&self) -> usize {
        self.x.len()
    }
}

/// A named least-squares solver over dense-or-sparse inputs — the interface
/// the coordinator workers and bench harness drive.
pub trait Solver: Send + Sync {
    /// Solve `min ‖Ax − b‖₂`.
    fn solve(&self, a: &Matrix, b: &[f64]) -> Result<Solution>;

    /// Solver name for reports ("lsqr", "saa-sas", ...).
    fn name(&self) -> &'static str;
}

pub(crate) fn check_dims(a: &Matrix, b: &[f64]) -> Result<(usize, usize)> {
    let (m, n) = a.shape();
    if b.len() != m {
        return Err(SolverError::Dimension(format!(
            "A is {m}x{n} but b has length {}",
            b.len()
        )));
    }
    if m < n {
        return Err(SolverError::Dimension(format!(
            "problem must be overdetermined (m >= n), got {m}x{n}"
        )));
    }
    Ok((m, n))
}
