//! Condition-driven escalation ladder for the forward-stable solver tier.
//!
//! Plain sketch-and-precondition is fast but not backward stable
//! (Meier–Nakatsukasa–Townsend–Webb, arXiv:2302.07202): on ill-conditioned
//! nearly-consistent problems its *forward* error can be O(1) while every
//! cheap residual-based check still passes. The ladder therefore never
//! trusts a single stage. Each candidate iterate is judged by a
//! preconditioned forward-error proxy (see [`assess`]) and escalated while
//! the evidence says the answer is worse than what a stable solver could
//! deliver:
//!
//! 1. **sas** — sketch-and-solve: `x = R⁻¹z₀`. One triangular solve; wins
//!    on well-conditioned or low-accuracy requests.
//! 2. **lsqr** — sketch-and-precondition: LSQR on `A R⁻¹`, warm-started
//!    from `z₀`, then `x = R⁻¹z`.
//! 3. **refine** — iterative sketching with momentum (Epperly,
//!    arXiv:2311.04362): heavy-ball refinement sweeps recomputing the true
//!    residual each sweep, `x⁺ = x + α·R⁻¹R⁻ᵀAᵀ(b−Ax) + β(x − x⁻)`. The
//!    step/momentum pair is tuned from a cheap power-iteration estimate of
//!    the preconditioned spectrum (`α = 4/(√L+√μ)²`,
//!    `β = ((√L−√μ)/(√L+√μ))²`), so the contraction rate depends only on
//!    the embedding distortion — not on κ(A) — restoring direct-solver
//!    forward accuracy at randomized speed.
//! 4. **dense** — terminal dense Householder QR. Always answers (or
//!    errors), never silently returns a rejected iterate.
//!
//! Escalation is per right-hand side: a block request only pays for the
//! stages its hard columns need; accepted columns are frozen.
//!
//! The [`FaultPlan`] hook can force any stage to fail, panic, or emit a
//! deterministically poisoned iterate, so tests exercise the escalation
//! path itself — not just matrices that happen to be nasty.

use crate::linalg::operator::PreconditionedOperator;
use crate::linalg::qr;
use crate::linalg::{norms, triangular, DenseMatrix, LinearOperator, Matrix};
use crate::testing::{FaultAction, FaultPlan};

use super::lsqr::{lsqr_block_ws, LsqrConfig, SolveWorkspace};
use super::{Result, SolverError};

/// The ladder's stages, cheapest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Sketch-and-solve: one triangular solve from the sketched factor.
    SketchSolve,
    /// Sketch-and-precondition: LSQR on `A R⁻¹` warm-started from `z₀`.
    PrecondLsqr,
    /// Iterative sketching with momentum: true-residual refinement sweeps.
    Refine,
    /// Terminal dense Householder QR.
    DenseQr,
}

impl Stage {
    /// Stage name as used by [`FaultPlan`] and the metrics report.
    pub fn name(self) -> &'static str {
        match self {
            Stage::SketchSolve => "sas",
            Stage::PrecondLsqr => "lsqr",
            Stage::Refine => "refine",
            Stage::DenseQr => "dense",
        }
    }
}

/// Tuning for one ladder run.
#[derive(Debug, Clone)]
pub struct LadderConfig {
    /// Requested relative forward-error tolerance (evidence scale).
    pub tol: f64,
    /// LSQR settings for the sketch-and-precondition stage.
    pub lsqr: LsqrConfig,
    /// Maximum refinement sweeps (stage 3). 0 skips the stage.
    pub refine_iters: usize,
    /// R-diagonal condition estimates beyond this jump straight to the
    /// dense terminal stage (the sketched factor is numerically rank
    /// deficient; iterating on it is wasted work).
    pub cond_limit: f64,
    /// Multiplier on the attainable-accuracy floor when deciding
    /// acceptance: candidates are accepted when their evidence is below
    /// `max(tol, safety · achievable)`.
    pub safety: f64,
}

impl Default for LadderConfig {
    fn default() -> Self {
        Self {
            tol: 1e-10,
            lsqr: LsqrConfig::default(),
            refine_iters: 0, // 0 ⇒ resolve via solvers::stable::refine_iters()
            cond_limit: 1e15,
            safety: 32.0,
        }
    }
}

/// Result of a ladder run over a `k`-row RHS block.
#[derive(Debug, Clone)]
pub struct LadderOutcome {
    /// Accepted solutions, one row per RHS.
    pub x: DenseMatrix,
    /// The stage whose iterate was finally accepted, per RHS.
    pub stage_of: Vec<Stage>,
    /// Total stages entered beyond the first, summed over RHS columns.
    pub escalations: u64,
    /// Iteration count (LSQR iterations + refinement sweeps), per RHS.
    pub iterations: Vec<usize>,
    /// ‖b − Ax‖ of the accepted iterate, per RHS.
    pub resnorm: Vec<f64>,
    /// Final forward-error evidence `‖R⁻ᵀAᵀr‖ / ‖Rx‖`, per RHS.
    pub rel: Vec<f64>,
    /// `max|rᵢᵢ|/min|rᵢᵢ|` condition estimate from the sketched factor.
    pub cond_est: f64,
}

/// Forward-error evidence for one candidate column.
#[derive(Debug, Clone, Copy)]
struct Evidence {
    /// `‖w‖/‖Rx‖` with `w = R⁻ᵀAᵀ(b−Ax)`. Since `AᵀA·e = Aᵀr` and
    /// `AR⁻¹` is a near-isometry, `‖w‖ ≈ ‖A·e‖` — a *forward*-error
    /// proxy in the A-metric, which plain residual checks are blind to.
    rel: f64,
    /// ‖b − Ax‖.
    resnorm: f64,
    /// Attainable-accuracy floor for this column (rounding in the
    /// residual recomputation plus the κ-amplified residual term).
    achievable: f64,
}

impl Evidence {
    fn accept(&self, tol: f64, safety: f64) -> bool {
        self.rel.is_finite() && self.rel <= f64::max(tol, safety * self.achievable)
    }
}

/// ‖R·x‖ by upper-triangular matvec (R is small: n×n).
fn r_scaled_norm(r: &DenseMatrix, x: &[f64]) -> f64 {
    let n = r.cols();
    let mut acc = 0.0f64;
    for p in 0..n {
        let row = r.row(p);
        let mut s = 0.0f64;
        for q in p..n {
            s += row[q] * x[q];
        }
        acc += s * s;
    }
    acc.sqrt()
}

/// Judge a candidate block: residual, preconditioned gradient, and the
/// per-column forward-error proxy. `rhs` and `x` are `ka×m` / `ka×n`
/// row-blocks over the still-active columns.
#[allow(clippy::too_many_arguments)]
fn assess(
    op: &dyn LinearOperator,
    r: &DenseMatrix,
    rhs: &DenseMatrix,
    x: &DenseMatrix,
    cond_est: f64,
    a_fro: f64,
    ws: &mut SolveWorkspace,
) -> Result<Vec<Evidence>> {
    let (m, n) = op.shape();
    let ka = x.rows();
    let eps = f64::EPSILON;
    let mut ax = ws.take_mat(ka, m);
    op.apply_mat(x, &mut ax);
    // residual in place: ax ← b − Ax
    for (av, bv) in ax.data_mut().iter_mut().zip(rhs.data().iter()) {
        *av = *bv - *av;
    }
    let mut g = ws.take_mat(ka, n);
    op.apply_transpose_mat(&ax, &mut g);
    let w = triangular::solve_upper_transpose_block(r, &g)?;
    let mut out = Vec::with_capacity(ka);
    for i in 0..ka {
        let resnorm = norms::nrm2(ax.row(i));
        let wnorm = norms::nrm2(w.row(i));
        let xnorm = norms::nrm2(x.row(i));
        let scale = r_scaled_norm(r, x.row(i)).max(f64::MIN_POSITIVE);
        let rel = wnorm / scale;
        let achievable = eps * (a_fro * xnorm + cond_est * resnorm) / scale;
        out.push(Evidence { rel, resnorm, achievable });
    }
    ws.recycle_mat(ax);
    ws.recycle_mat(g);
    Ok(out)
}

/// Deterministic large-but-finite corruption of a candidate block,
/// derived from the fault plan's seed (splitmix-style hash per element).
fn poison_block(x: &mut DenseMatrix, seed: u64) {
    let magnitude = 1e8 * (1.0 + x.max_abs());
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        let mut h = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        let unit = ((h >> 11) as f64) / ((1u64 << 53) as f64); // [0, 1)
        *v += magnitude * (0.5 + unit);
    }
}

/// Estimate the extreme eigenvalues `(L, μ)` of `H = R⁻ᵀAᵀAR⁻¹` by
/// deterministic power iteration (plain for `L`, shifted by `L` for `μ`).
/// `H`'s spectrum depends only on the sketch's embedding distortion, not
/// on κ(A), so a dozen iterations pin it well enough to set the
/// heavy-ball parameters; the 1.05×/0.95× widening absorbs the power
/// method's one-sided bias.
fn estimate_spectrum(op: &dyn LinearOperator, r: &DenseMatrix) -> Option<(f64, f64)> {
    let n = r.cols();
    let iters = 12usize;
    let apply_h = |v: &[f64]| -> Option<Vec<f64>> {
        let xr = triangular::solve_upper(r, v).ok()?;
        let av = op.apply_vec(&xr);
        let g = op.apply_transpose_vec(&av);
        triangular::solve_upper_transpose(r, &g).ok()
    };
    // Deterministic ±1 start vectors (index-hash sign patterns).
    let start = |mult: u64| -> Vec<f64> {
        let nrm = (n as f64).sqrt();
        (0..n)
            .map(|i| {
                let h = (i as u64).wrapping_mul(mult);
                if h & 0x10000 != 0 { 1.0 / nrm } else { -1.0 / nrm }
            })
            .collect()
    };
    let mut v = start(0x9E37_79B9);
    let mut top = 0.0f64;
    for _ in 0..iters {
        let w = apply_h(&v)?;
        top = v.iter().zip(w.iter()).map(|(a, b)| a * b).sum();
        let nw = norms::nrm2(&w);
        if !nw.is_finite() || nw == 0.0 {
            return None;
        }
        v = w.iter().map(|x| x / nw).collect();
    }
    if !top.is_finite() || top <= 0.0 {
        return None;
    }
    let l = top * 1.05;
    let mut v = start(0x85EB_CA6B);
    let mut shifted = 0.0f64;
    for _ in 0..iters {
        let hv = apply_h(&v)?;
        let w: Vec<f64> = v.iter().zip(hv.iter()).map(|(a, b)| l * a - b).collect();
        shifted = v.iter().zip(w.iter()).map(|(a, b)| a * b).sum();
        let nw = norms::nrm2(&w);
        if !nw.is_finite() || nw == 0.0 {
            return None;
        }
        v = w.iter().map(|x| x / nw).collect();
    }
    let mu = ((l - shifted) * 0.95).max(1e-6 * l);
    Some((l, mu))
}

fn fault_action(faults: Option<&FaultPlan>, stage: Stage) -> Option<FaultAction> {
    let action = faults.and_then(|f| f.action(stage.name()));
    if action == Some(FaultAction::Panic) {
        panic!("fault-injected panic in ladder stage '{}'", stage.name());
    }
    action
}

struct State {
    x: DenseMatrix,
    best: DenseMatrix,
    accepted: Vec<bool>,
    stage_of: Vec<Stage>,
    entered: Vec<usize>,
    iterations: Vec<usize>,
    resnorm: Vec<f64>,
    rel: Vec<f64>,
}

impl State {
    fn active(&self) -> Vec<usize> {
        (0..self.accepted.len()).filter(|&i| !self.accepted[i]).collect()
    }

    fn gather_rows(src: &DenseMatrix, idxs: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(idxs.len(), src.cols());
        for (r, &i) in idxs.iter().enumerate() {
            out.row_mut(r).copy_from_slice(src.row(i));
        }
        out
    }

    /// Record a stage's candidate for the given active columns, accepting
    /// those whose evidence clears the bar (`extra_ok` gates per-candidate
    /// stage-specific acceptance, e.g. LSQR convergence flags).
    fn judge(
        &mut self,
        stage: Stage,
        idxs: &[usize],
        cand: &DenseMatrix,
        ev: &[Evidence],
        extra_ok: &[bool],
        tol: f64,
        safety: f64,
    ) {
        for (r, &i) in idxs.iter().enumerate() {
            self.best.row_mut(i).copy_from_slice(cand.row(r));
            self.rel[i] = ev[r].rel;
            self.resnorm[i] = ev[r].resnorm;
            if extra_ok[r] && ev[r].accept(tol, safety) {
                self.x.row_mut(i).copy_from_slice(cand.row(r));
                self.accepted[i] = true;
                self.stage_of[i] = stage;
            }
        }
    }
}

/// Run the escalation ladder for a `k`-row RHS block against a cached
/// sketched factorization.
///
/// * `rhs` — `k×m` right-hand sides (one per row).
/// * `r` — `n×n` upper-triangular factor of the sketched matrix `SA`.
/// * `z0` — `k×n` sketch-and-solve coordinates `QᵀS b` (one per row).
/// * `y` — materialized preconditioned operator `A R⁻¹`, if available
///   (dense path); otherwise the ladder applies `R⁻¹` on the fly.
#[allow(clippy::too_many_arguments)]
pub fn run_ladder(
    a: &Matrix,
    rhs: &DenseMatrix,
    r: &DenseMatrix,
    z0: &DenseMatrix,
    y: Option<&DenseMatrix>,
    cfg: &LadderConfig,
    ws: &mut SolveWorkspace,
    faults: Option<&FaultPlan>,
) -> Result<LadderOutcome> {
    let (m, n) = a.shape();
    let k = rhs.rows();
    if rhs.cols() != m || z0.shape() != (k, n) || r.shape() != (n, n) {
        return Err(SolverError::Dimension(format!(
            "ladder: A is {m}x{n}, rhs {}x{}, z0 {}x{}, R {}x{}",
            rhs.rows(),
            rhs.cols(),
            z0.rows(),
            z0.cols(),
            r.rows(),
            r.cols()
        )));
    }
    let op = a.as_operator();
    let tol = cfg.tol;
    let safety = cfg.safety;

    // Cheap condition evidence from the sketched factor's diagonal: the
    // sketch preserves singular values to the embedding distortion, so
    // max|rᵢᵢ|/min|rᵢᵢ| is an order-of-magnitude read on κ(A).
    let mut dmax = 0.0f64;
    let mut dmin = f64::INFINITY;
    for i in 0..n {
        let d = r[(i, i)].abs();
        dmax = dmax.max(d);
        dmin = dmin.min(d);
    }
    let cond_est = if dmin > 0.0 { dmax / dmin } else { f64::INFINITY };
    let a_fro = r.fro_norm();
    // Rank-deficient-in-double factor: R⁻¹ applications are numerically
    // meaningless, so skip every iterative stage.
    let skip_iterative = !cond_est.is_finite() || cond_est > cfg.cond_limit;

    let mut st = State {
        x: DenseMatrix::zeros(k, n),
        best: DenseMatrix::zeros(k, n),
        accepted: vec![false; k],
        stage_of: vec![Stage::DenseQr; k],
        entered: vec![0; k],
        iterations: vec![0; k],
        resnorm: vec![f64::NAN; k],
        rel: vec![f64::NAN; k],
    };

    // ---- stage 1: sketch-and-solve --------------------------------------
    if !skip_iterative && fault_action(faults, Stage::SketchSolve) != Some(FaultAction::Fail) {
        let idxs = st.active();
        for &i in &idxs {
            st.entered[i] += 1;
        }
        if let Ok(mut cand) = triangular::solve_upper_block(r, z0) {
            if fault_action(faults, Stage::SketchSolve) == Some(FaultAction::Poison) {
                poison_block(&mut cand, faults.map(|f| f.seed).unwrap_or(0));
            }
            let ev = assess(op, r, rhs, &cand, cond_est, a_fro, ws)?;
            let ok = vec![true; idxs.len()];
            st.judge(Stage::SketchSolve, &idxs, &cand, &ev, &ok, tol, safety);
        }
    }

    // ---- stage 2: sketch-and-precondition (LSQR) ------------------------
    let idxs = st.active();
    if !idxs.is_empty()
        && !skip_iterative
        && fault_action(faults, Stage::PrecondLsqr) != Some(FaultAction::Fail)
    {
        for &i in &idxs {
            st.entered[i] += 1;
        }
        let rhs_sub = State::gather_rows(rhs, &idxs);
        let z0_sub = State::gather_rows(z0, &idxs);
        let results = match (y, a) {
            (Some(ym), _) => lsqr_block_ws(ym, &rhs_sub, Some(&z0_sub), &cfg.lsqr, ws),
            (None, Matrix::Csr(ac)) => {
                let pop = PreconditionedOperator::new(ac, r);
                lsqr_block_ws(&pop, &rhs_sub, Some(&z0_sub), &cfg.lsqr, ws)
            }
            (None, Matrix::Dense(ad)) => {
                let pop = PreconditionedOperator::new(ad, r);
                lsqr_block_ws(&pop, &rhs_sub, Some(&z0_sub), &cfg.lsqr, ws)
            }
        };
        let mut z = DenseMatrix::zeros(idxs.len(), n);
        let mut ok = Vec::with_capacity(idxs.len());
        for (row, res) in results.iter().enumerate() {
            z.row_mut(row).copy_from_slice(&res.x);
            st.iterations[idxs[row]] += res.itn;
            ok.push(res.istop.converged());
        }
        if let Ok(mut cand) = triangular::solve_upper_block(r, &z) {
            if fault_action(faults, Stage::PrecondLsqr) == Some(FaultAction::Poison) {
                poison_block(&mut cand, faults.map(|f| f.seed).unwrap_or(0));
            }
            let ev = assess(op, r, &rhs_sub, &cand, cond_est, a_fro, ws)?;
            st.judge(Stage::PrecondLsqr, &idxs, &cand, &ev, &ok, tol, safety);
        }
    }

    // ---- stage 3: iterative sketching with momentum ---------------------
    let idxs = st.active();
    let sweeps = cfg.refine_iters;
    if !idxs.is_empty()
        && !skip_iterative
        && sweeps > 0
        && fault_action(faults, Stage::Refine) != Some(FaultAction::Fail)
    {
        for &i in &idxs {
            st.entered[i] += 1;
        }
        let rhs_sub = State::gather_rows(rhs, &idxs);
        let z0_sub = State::gather_rows(z0, &idxs);
        let mut cur = State::gather_rows(&st.best, &idxs);
        // Warm-start policy: sweep from the better-evidenced of the
        // inherited iterate and a fresh sketch-and-solve iterate, per
        // column. A poisoned/diverged inherited iterate contracts too
        // slowly to be worth sweeping from, and a *zero* restart is
        // forward-unstable at large κ (the MNTW zero-initializer
        // instability) — the sketch-and-solve iterate is the cheap
        // forward-decent start.
        if let Ok(xs) = triangular::solve_upper_block(r, &z0_sub) {
            let ev_s = assess(op, r, &rhs_sub, &xs, cond_est, a_fro, ws)?;
            for (row, &i) in idxs.iter().enumerate() {
                if !st.rel[i].is_finite() || ev_s[row].rel < st.rel[i] {
                    cur.row_mut(row).copy_from_slice(xs.row(row));
                }
            }
        }
        // Heavy-ball parameters from the estimated spectrum of
        // H = R⁻ᵀAᵀAR⁻¹: α = 4/(√L+√μ)², β = ((√L−√μ)/(√L+√μ))²
        // (asymptotic contraction √β per sweep, independent of κ(A)).
        let spectrum = estimate_spectrum(op, r);
        if let Some((big_l, mu)) = spectrum {
            let (sl, sm) = (big_l.sqrt(), mu.sqrt());
            let alpha = 4.0 / ((sl + sm) * (sl + sm));
            let beta = ((sl - sm) / (sl + sm)).powi(2);
            let ka = idxs.len();
            let mut prev = cur.clone();
            let mut wnorm_prev = vec![f64::INFINITY; ka];
            let mut stagnant = 0usize;
            let mut used = 0usize;
            for sweep in 0..sweeps {
                used += 1;
                let mut ax = ws.take_mat(ka, m);
                op.apply_mat(&cur, &mut ax);
                for (av, bv) in ax.data_mut().iter_mut().zip(rhs_sub.data().iter()) {
                    *av = *bv - *av;
                }
                let mut g = ws.take_mat(ka, n);
                op.apply_transpose_mat(&ax, &mut g);
                ws.recycle_mat(ax);
                let wt = triangular::solve_upper_transpose_block(r, &g)?;
                ws.recycle_mat(g);
                let d = triangular::solve_upper_block(r, &wt)?;
                // x⁺ = x + α·d + β(x − x⁻), rowwise
                let mut worse = true;
                for row in 0..ka {
                    let wn = norms::nrm2(wt.row(row));
                    if wn < 0.9 * wnorm_prev[row] {
                        worse = false;
                    }
                    wnorm_prev[row] = wn;
                }
                for ((xv, dv), pv) in
                    cur.data_mut().iter_mut().zip(d.data().iter()).zip(prev.data_mut().iter_mut())
                {
                    let old = *xv;
                    *xv = old + alpha * *dv + beta * (old - *pv);
                    *pv = old;
                }
                // Stagnation exit: heavy ball is non-monotone early, so
                // only count once the transient is over.
                if worse && sweep >= 3 {
                    stagnant += 1;
                    if stagnant >= 2 {
                        break; // rounding floor: stop burning sweeps
                    }
                } else if !worse {
                    stagnant = 0;
                }
            }
            for &i in &idxs {
                st.iterations[i] += used;
            }
            let mut cand = cur;
            if fault_action(faults, Stage::Refine) == Some(FaultAction::Poison) {
                poison_block(&mut cand, faults.map(|f| f.seed).unwrap_or(0));
            }
            let ev = assess(op, r, &rhs_sub, &cand, cond_est, a_fro, ws)?;
            let ok = vec![true; idxs.len()];
            st.judge(Stage::Refine, &idxs, &cand, &ev, &ok, tol, safety);
        }
    }

    // ---- stage 4: dense QR (terminal) -----------------------------------
    let idxs = st.active();
    if !idxs.is_empty() {
        if fault_action(faults, Stage::DenseQr) == Some(FaultAction::Fail) {
            return Err(SolverError::NoConvergence(
                "ladder: dense terminal stage fault-injected to fail".to_string(),
            ));
        }
        for &i in &idxs {
            st.entered[i] += 1;
        }
        let ad = a.to_dense();
        let f = qr::qr_compact(&ad).map_err(SolverError::Linalg)?;
        let rhs_sub = State::gather_rows(rhs, &idxs);
        let zd = f.q_transpose_mat(&rhs_sub);
        let rd = f.r();
        let mut cand = triangular::solve_upper_block(&rd, &zd)?;
        if fault_action(faults, Stage::DenseQr) == Some(FaultAction::Poison) {
            poison_block(&mut cand, faults.map(|f| f.seed).unwrap_or(0));
        }
        let ev = assess(op, r, &rhs_sub, &cand, cond_est, a_fro, ws)?;
        // Terminal stage: accept unconditionally short of gross
        // corruption — there is no stage 5, and at extreme κ even dense
        // QR legitimately sits above the requested tolerance.
        for (row, &i) in idxs.iter().enumerate() {
            let finite = cand.row(row).iter().all(|v| v.is_finite());
            if !finite || ev[row].rel > 0.1 {
                return Err(SolverError::NoConvergence(format!(
                    "ladder: dense terminal iterate failed verification \
                     (rel evidence {:.3e})",
                    ev[row].rel
                )));
            }
            st.x.row_mut(i).copy_from_slice(cand.row(row));
            st.accepted[i] = true;
            st.stage_of[i] = Stage::DenseQr;
            st.rel[i] = ev[row].rel;
            st.resnorm[i] = ev[row].resnorm;
        }
    }

    let escalations = st.entered.iter().map(|&e| (e.max(1) - 1) as u64).sum();
    Ok(LadderOutcome {
        x: st.x,
        stage_of: st.stage_of,
        escalations,
        iterations: st.iterations,
        resnorm: st.resnorm,
        rel: st.rel,
        cond_est,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::triangular::right_solve_upper_multi;
    use crate::problems::{generate_dense, DenseProblemSpec};
    use crate::sketch::{self, SketchKind, SketchOperator};

    fn setup(
        m: usize,
        n: usize,
        cond: f64,
        seed: u64,
    ) -> (Matrix, DenseMatrix, DenseMatrix, DenseMatrix, DenseMatrix, Vec<f64>) {
        let p = generate_dense(&DenseProblemSpec {
            m,
            n,
            cond,
            resid_norm: 1e-10,
            seed,
        });
        let ad = p.a.to_dense();
        let s_rows = (4 * n).min(m);
        let s_op = sketch::build(SketchKind::Gaussian, s_rows, m, 0xABCD_0001);
        let b_sk = s_op.apply_matrix(&p.a);
        let f = qr::qr_compact(&b_sk).unwrap();
        let r = f.r();
        let c = s_op.apply_vec(&p.b);
        let z0v = f.q_transpose_vec(&c);
        let mut z0 = DenseMatrix::zeros(1, n);
        z0.row_mut(0).copy_from_slice(&z0v);
        let mut rhs = DenseMatrix::zeros(1, m);
        rhs.row_mut(0).copy_from_slice(&p.b);
        let y = right_solve_upper_multi(&ad, &r).unwrap();
        (p.a, rhs, r, z0, y, p.x_true)
    }

    fn forward_err(x: &[f64], x_true: &[f64]) -> f64 {
        norms::nrm2_diff(x, x_true) / norms::nrm2(x_true).max(1e-300)
    }

    #[test]
    fn well_conditioned_accepts_at_first_stage() {
        let (a, rhs, r, z0, y, x_true) = setup(400, 20, 10.0, 42);
        let cfg = LadderConfig { tol: 1e-8, refine_iters: 30, ..Default::default() };
        let mut ws = SolveWorkspace::new();
        let out = run_ladder(&a, &rhs, &r, &z0, Some(&y), &cfg, &mut ws, None).unwrap();
        assert!(out.stage_of[0] <= Stage::PrecondLsqr, "stage {:?}", out.stage_of[0]);
        assert!(forward_err(out.x.row(0), &x_true) < 1e-6);
    }

    #[test]
    fn ill_conditioned_escalates_past_sketch_and_solve() {
        let (a, rhs, r, z0, y, x_true) = setup(400, 20, 1e10, 43);
        let cfg = LadderConfig { tol: 1e-10, refine_iters: 40, ..Default::default() };
        let mut ws = SolveWorkspace::new();
        let out = run_ladder(&a, &rhs, &r, &z0, Some(&y), &cfg, &mut ws, None).unwrap();
        assert!(out.stage_of[0] > Stage::SketchSolve, "sketch-and-solve must not pass at κ=1e10");
        assert!(out.escalations >= 1);
        let err = forward_err(out.x.row(0), &x_true);
        assert!(err < 1e-4, "forward error {err:.3e}");
    }

    #[test]
    fn dimension_mismatch_is_typed() {
        let (a, rhs, r, z0, _y, _) = setup(100, 8, 10.0, 44);
        let bad = DenseMatrix::zeros(1, 3);
        let mut ws = SolveWorkspace::new();
        let err = run_ladder(&a, &rhs, &r, &bad, None, &LadderConfig::default(), &mut ws, None);
        assert!(matches!(err, Err(SolverError::Dimension(_))));
        let err2 = run_ladder(&a, &bad, &r, &z0, None, &LadderConfig::default(), &mut ws, None);
        assert!(matches!(err2, Err(SolverError::Dimension(_))));
    }

    #[test]
    fn poison_pattern_is_deterministic() {
        let mut a = DenseMatrix::zeros(2, 3);
        let mut b = DenseMatrix::zeros(2, 3);
        poison_block(&mut a, 7);
        poison_block(&mut b, 7);
        assert_eq!(a.data(), b.data());
        assert!(a.data().iter().all(|v| v.is_finite() && v.abs() > 1e7));
        let mut c = DenseMatrix::zeros(2, 3);
        poison_block(&mut c, 8);
        assert_ne!(a.data(), c.data());
    }
}
