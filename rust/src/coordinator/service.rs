//! The solve service: queue → dispatcher/batcher → worker pool → responses.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batch, BatchKey, Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{BoundedQueue, PopError, PushError};
use crate::coordinator::registry::{MatrixId, MatrixRegistry};
use crate::coordinator::router::{Route, Router, RouterConfig};
use crate::coordinator::worker::{BatchItem, WorkerConfig, WorkerContext};
use crate::coordinator::{
    ExecutedOn, RequestId, ServiceError, SolveRequest, SolveResponse,
};
use crate::linalg::Matrix;
use crate::runtime::Manifest;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    pub worker: WorkerConfig,
    /// How long submit() waits for queue space before Overloaded.
    pub submit_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            worker: WorkerConfig::default(),
            submit_timeout: Duration::from_millis(50),
        }
    }
}

/// Where a finished solve goes: a blocking waiter's channel, or a
/// completion callback (the oneshot-per-request shape the pipelined TCP
/// front-end uses to route responses back to the owning connection's
/// writer). Exactly one response is delivered either way; a callback that
/// already fired swallows later sends.
pub(crate) enum Responder {
    Channel(mpsc::Sender<SolveResponse>),
    Callback(Option<Box<dyn FnOnce(SolveResponse) + Send>>),
}

impl Responder {
    fn send(&mut self, resp: SolveResponse) {
        match self {
            Responder::Channel(tx) => {
                let _ = tx.send(resp);
            }
            Responder::Callback(cb) => {
                if let Some(f) = cb.take() {
                    f(resp);
                }
            }
        }
    }
}

/// Internal queued item.
struct Pending {
    id: RequestId,
    req: SolveRequest,
    submitted: Instant,
    responder: Responder,
}

/// Handle to await one response.
pub struct ResponseHandle {
    pub id: RequestId,
    rx: mpsc::Receiver<SolveResponse>,
}

impl ResponseHandle {
    /// Block until the response arrives (or the service dies).
    pub fn wait(self) -> Result<SolveResponse, ServiceError> {
        self.rx.recv().map_err(|_| ServiceError::ShuttingDown)
    }

    pub fn wait_timeout(&self, d: Duration) -> Result<SolveResponse, ServiceError> {
        self.rx.recv_timeout(d).map_err(|_| ServiceError::ShuttingDown)
    }
}

/// The running service.
pub struct Service {
    registry: Arc<MatrixRegistry>,
    metrics: Arc<Metrics>,
    queue: Arc<BoundedQueue<Pending>>,
    batch_queue: Arc<BoundedQueue<Batch<Pending>>>,
    next_id: AtomicU64,
    submit_timeout: Duration,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Start the service: spawns the dispatcher and `workers` worker
    /// threads (each builds its own PJRT engine if configured).
    pub fn start(config: ServiceConfig) -> Arc<Service> {
        let registry = Arc::new(MatrixRegistry::new());
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(BoundedQueue::<Pending>::new(config.queue_capacity));
        let batch_queue =
            Arc::new(BoundedQueue::<Batch<Pending>>::new(config.queue_capacity));

        // Router needs the manifest (for buckets) but not the engine.
        let manifest = config
            .worker
            .artifact_dir
            .as_ref()
            .and_then(|d| Manifest::load(d).ok());
        let router = Arc::new(Router::new(manifest.as_ref(), config.router.clone()));

        // Dispatcher: drain queue → batcher → batch_queue.
        let dispatcher = {
            let queue = queue.clone();
            let batch_queue = batch_queue.clone();
            let metrics = metrics.clone();
            let bcfg = config.batcher.clone();
            std::thread::Builder::new()
                .name("sns-dispatch".into())
                .spawn(move || dispatcher_loop(queue, batch_queue, bcfg, metrics))
                .expect("spawn dispatcher")
        };

        // Workers.
        let mut workers = Vec::with_capacity(config.workers);
        for w in 0..config.workers.max(1) {
            let batch_queue = batch_queue.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            let router = router.clone();
            let mut wcfg = config.worker.clone();
            // De-correlate worker RNG streams (sketch seeds stay shared so
            // the factor cache is consistent across workers).
            wcfg.seed = config.worker.seed;
            let _ = w;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sns-worker-{w}"))
                    .spawn(move || worker_loop(batch_queue, registry, metrics, router, wcfg))
                    .expect("spawn worker"),
            );
        }

        Arc::new(Service {
            registry,
            metrics,
            queue,
            batch_queue,
            next_id: AtomicU64::new(1),
            submit_timeout: config.submit_timeout,
            dispatcher: Some(dispatcher),
            workers,
        })
    }

    pub fn registry(&self) -> &MatrixRegistry {
        &self.registry
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Register a design matrix for subsequent solves.
    pub fn register_matrix(&self, m: Matrix) -> MatrixId {
        self.registry.register(m)
    }

    /// Submit a solve request; returns a handle to await the response.
    pub fn submit(&self, req: SolveRequest) -> Result<ResponseHandle, ServiceError> {
        let (tx, rx) = mpsc::channel();
        let id = self.submit_inner(req, Responder::Channel(tx))?;
        Ok(ResponseHandle { id, rx })
    }

    /// Submit a solve request with a completion callback instead of a
    /// channel. The callback fires exactly once, on whatever thread
    /// finishes the request (a worker, or the dispatcher on shutdown) —
    /// this is the oneshot shape the pipelined TCP front-end uses to route
    /// out-of-order completions back to each connection's writer.
    pub fn submit_with<F>(
        &self,
        req: SolveRequest,
        complete: F,
    ) -> Result<RequestId, ServiceError>
    where
        F: FnOnce(SolveResponse) + Send + 'static,
    {
        self.submit_inner(req, Responder::Callback(Some(Box::new(complete))))
    }

    fn submit_inner(
        &self,
        req: SolveRequest,
        responder: Responder,
    ) -> Result<RequestId, ServiceError> {
        Metrics::inc(&self.metrics.submitted);
        if self.registry.get(req.matrix).is_none() {
            Metrics::inc(&self.metrics.failed);
            return Err(ServiceError::UnknownMatrix(req.matrix.0));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let pending = Pending { id, req, submitted: Instant::now(), responder };
        match self.queue.push_timeout(pending, self.submit_timeout) {
            Ok(()) => Ok(id),
            Err(PushError::Full(_)) => {
                Metrics::inc(&self.metrics.rejected_overload);
                Err(ServiceError::Overloaded)
            }
            Err(PushError::Closed(_)) => Err(ServiceError::ShuttingDown),
        }
    }

    /// Convenience: submit and wait.
    pub fn solve_blocking(&self, req: SolveRequest) -> Result<SolveResponse, ServiceError> {
        self.submit(req)?.wait()
    }

    /// Graceful shutdown: drain, then join all threads.
    pub fn shutdown(mut self: Arc<Service>) {
        self.queue.close();
        // Wait for the dispatcher + workers to drain; Arc juggling because
        // JoinHandles need ownership.
        let this = Arc::get_mut(&mut self);
        if let Some(svc) = this {
            if let Some(d) = svc.dispatcher.take() {
                let _ = d.join();
            }
            svc.batch_queue.close();
            for w in svc.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

fn dispatcher_loop(
    queue: Arc<BoundedQueue<Pending>>,
    batch_queue: Arc<BoundedQueue<Batch<Pending>>>,
    bcfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    let mut batcher: Batcher<Pending> = Batcher::new(bcfg);
    loop {
        let wait = batcher
            .next_due_in(Instant::now())
            .unwrap_or(Duration::from_millis(20))
            .min(Duration::from_millis(20));
        match queue.pop_timeout(wait.max(Duration::from_micros(100))) {
            Ok(p) => {
                let key = BatchKey { matrix: p.req.matrix, solver: p.req.solver };
                let now = Instant::now();
                if let Some(full) = batcher.offer(key, p, now) {
                    emit(&batch_queue, &metrics, full);
                }
                // Opportunistically drain whatever else is queued.
                for p in queue.drain_up_to(64) {
                    let key = BatchKey { matrix: p.req.matrix, solver: p.req.solver };
                    if let Some(full) = batcher.offer(key, p, now) {
                        emit(&batch_queue, &metrics, full);
                    }
                }
            }
            Err(PopError::TimedOut) => {}
            Err(PopError::Closed) => {
                for b in batcher.flush_all() {
                    emit(&batch_queue, &metrics, b);
                }
                batch_queue.close();
                return;
            }
        }
        for b in batcher.flush_due(Instant::now()) {
            emit(&batch_queue, &metrics, b);
        }
    }
}

fn emit(
    batch_queue: &BoundedQueue<Batch<Pending>>,
    metrics: &Metrics,
    batch: Batch<Pending>,
) {
    Metrics::inc(&metrics.batches);
    Metrics::add(&metrics.batched_requests, batch.items.len() as u64);
    // Blocking push: batches must not be dropped; queue bounds still apply
    // end-to-end because the ingress queue is bounded.
    let mut item = batch;
    loop {
        match batch_queue.push_timeout(item, Duration::from_secs(1)) {
            Ok(()) => return,
            Err(PushError::Full(b)) => item = b,
            Err(PushError::Closed(b)) => {
                // Shutting down: fail the batch.
                for mut p in b.items {
                    let id = p.id;
                    p.responder.send(SolveResponse {
                        id,
                        result: Err(ServiceError::ShuttingDown),
                        executed_on: ExecutedOn::Native,
                        queue_us: 0,
                        solve_us: 0,
                    });
                }
                return;
            }
        }
    }
}

fn worker_loop(
    batch_queue: Arc<BoundedQueue<Batch<Pending>>>,
    registry: Arc<MatrixRegistry>,
    metrics: Arc<Metrics>,
    router: Arc<Router>,
    wcfg: WorkerConfig,
) {
    // The PJRT engine must be constructed on this thread (!Send types).
    let mut ctx = WorkerContext::new(wcfg, registry.clone(), metrics.clone());
    loop {
        let batch = match batch_queue.pop_timeout(Duration::from_millis(100)) {
            Ok(b) => b,
            Err(PopError::TimedOut) => continue,
            Err(PopError::Closed) => return,
        };
        let key = batch.key;

        // Deadline checks up front; survivors drain into blocked solves.
        let mut live: Vec<(Pending, u64)> = Vec::new();
        for mut p in batch.items {
            let queue_us = p.submitted.elapsed().as_micros() as u64;
            metrics.queue_latency.record(queue_us);
            if p.req.deadline_us > 0 && queue_us > p.req.deadline_us {
                Metrics::inc(&metrics.deadline_missed);
                Metrics::inc(&metrics.failed);
                let id = p.id;
                p.responder.send(SolveResponse {
                    id,
                    result: Err(ServiceError::DeadlineExceeded),
                    executed_on: ExecutedOn::Native,
                    queue_us,
                    solve_us: 0,
                });
                continue;
            }
            live.push((p, queue_us));
        }
        if live.is_empty() {
            continue;
        }

        // A batch shares matrix + solver, but routes can differ per item
        // (tolerance-dependent PJRT eligibility): group by route and hand
        // each group to the worker as one blocked multi-RHS solve.
        let matrix = registry.get(key.matrix);
        let mut route_groups: Vec<(Route, Vec<usize>)> = Vec::new();
        for (i, (p, _)) in live.iter().enumerate() {
            let route = match &matrix {
                Some(a) => router.route(a, p.req.solver, p.req.tol),
                None => Route::Native,
            };
            match route_groups.iter_mut().find(|(r, _)| *r == route) {
                Some((_, idxs)) => idxs.push(i),
                None => route_groups.push((route, vec![i])),
            }
        }

        for (route, idxs) in route_groups {
            let bitems: Vec<BatchItem> = idxs
                .iter()
                .map(|&i| BatchItem {
                    rhs: std::mem::take(&mut live[i].0.req.rhs),
                    tol: live[i].0.req.tol,
                    refine_iters: live[i].0.req.refine_iters,
                })
                .collect();
            let t0 = Instant::now();
            // Panic containment: a solver bug (or an injected "worker"
            // fault) must cost its own batch an error response, not the
            // worker thread — a dead worker thread silently shrinks the
            // pool until the service stops answering.
            let results = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || ctx.execute_batch(&route, key.matrix, key.solver, &bitems),
            )) {
                Ok(results) => results,
                Err(_) => {
                    Metrics::inc(&metrics.worker_panics);
                    // The unwound solve may have left a cache entry or
                    // scratch arena half-built; drop them all.
                    ctx.clear_factor_cache();
                    idxs.iter()
                        .map(|_| {
                            (
                                Err(ServiceError::Solver(
                                    "worker panicked during solve".to_string(),
                                )),
                                ExecutedOn::Native,
                            )
                        })
                        .collect()
                }
            };
            // The group solves as one blocked operation; its wall time is
            // every member's solve latency.
            let solve_us = t0.elapsed().as_micros() as u64;
            for (&i, (result, executed_on)) in idxs.iter().zip(results) {
                let (p, queue_us) = &mut live[i];
                let queue_us = *queue_us;
                metrics.solve_latency.record(solve_us);
                metrics.e2e_latency.record(queue_us + solve_us);
                // Deadline enforcement at completion time: a solve that ran
                // past its deadline must not report success, even though the
                // work was already done (the client has long stopped caring).
                let result = if result.is_ok()
                    && deadline_blown(p.req.deadline_us, queue_us, solve_us)
                {
                    Metrics::inc(&metrics.deadline_missed);
                    Err(ServiceError::DeadlineExceeded)
                } else {
                    result
                };
                match &result {
                    Ok(_) => Metrics::inc(&metrics.completed),
                    Err(_) => Metrics::inc(&metrics.failed),
                }
                let id = p.id;
                p.responder.send(SolveResponse {
                    id,
                    result,
                    executed_on,
                    queue_us,
                    solve_us,
                });
            }
        }
    }
}

/// True when a request with a deadline finished after it: total observed
/// latency (queue wait + solve wall time) exceeds `deadline_us`. A zero
/// deadline means "no deadline".
pub(crate) fn deadline_blown(deadline_us: u64, queue_us: u64, solve_us: u64) -> bool {
    deadline_us > 0 && queue_us.saturating_add(solve_us) > deadline_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SolverChoice;
    use crate::linalg::norms;
    use crate::linalg::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn test_service(workers: usize) -> (Arc<Service>, MatrixId, Vec<f64>, Vec<f64>) {
        let svc = Service::start(ServiceConfig {
            workers,
            ..Default::default()
        });
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(11));
        let a = DenseMatrix::gaussian(400, 16, &mut g);
        let x_true = g.gaussian_vec(16);
        let b = a.matvec(&x_true);
        let id = svc.register_matrix(Matrix::Dense(a));
        (svc, id, x_true, b)
    }

    fn req(id: MatrixId, b: &[f64]) -> SolveRequest {
        SolveRequest {
            matrix: id,
            rhs: b.to_vec(),
            solver: SolverChoice::Saa,
            tol: 1e-10,
            deadline_us: 0,
            refine_iters: 0,
        }
    }

    #[test]
    fn end_to_end_single_solve() {
        let (svc, id, x_true, b) = test_service(1);
        let resp = svc.solve_blocking(req(id, &b)).unwrap();
        let sol = resp.result.unwrap();
        let err = norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
        assert_eq!(resp.executed_on, ExecutedOn::Native);
        assert_eq!(Metrics::get(&svc.metrics().completed), 1);
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (svc, id, x_true, b) = test_service(2);
        let handles: Vec<_> = (0..32).map(|_| svc.submit(req(id, &b)).unwrap()).collect();
        for h in handles {
            let resp = h.wait().unwrap();
            let sol = resp.result.unwrap();
            let err = norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true);
            assert!(err < 1e-8);
        }
        assert_eq!(Metrics::get(&svc.metrics().completed), 32);
        // batching happened: fewer batches than requests (same matrix key).
        assert!(Metrics::get(&svc.metrics().batches) <= 32);
        // factor computed at most once per worker.
        assert!(Metrics::get(&svc.metrics().factor_cache_misses) <= 2);
    }

    #[test]
    fn unknown_matrix_rejected_at_submit() {
        let (svc, _id, _xt, b) = test_service(1);
        let r = svc.submit(req(MatrixId(12345), &b));
        assert!(matches!(r, Err(ServiceError::UnknownMatrix(12345))));
    }

    #[test]
    fn deadline_exceeded_reported() {
        let (svc, id, _xt, b) = test_service(1);
        let mut r = req(id, &b);
        r.deadline_us = 1; // already expired by the time a worker sees it
        let resp = svc.solve_blocking(r).unwrap();
        assert!(matches!(resp.result, Err(ServiceError::DeadlineExceeded)));
    }

    #[test]
    fn deadline_blown_helper() {
        assert!(!deadline_blown(0, 1_000_000, 1_000_000)); // 0 = no deadline
        assert!(!deadline_blown(100, 40, 60)); // exactly on time
        assert!(deadline_blown(100, 40, 61));
        assert!(deadline_blown(100, 101, 0)); // queue alone blows it
        assert!(deadline_blown(100, 0, 101)); // solve alone blows it
        assert!(deadline_blown(1, u64::MAX, u64::MAX)); // saturating add
    }

    #[test]
    fn completion_time_deadline_enforced() {
        // An ill-conditioned inconsistent system: LSQR with tol 0 cannot
        // satisfy any residual test and runs to its iteration limit
        // (2n = 400), so the solve takes far longer than the 2 ms deadline
        // while the request spends almost no time queued (max_batch 1
        // flushes immediately). Code that only checks the deadline at
        // worker pickup returns Ok here — the completion-time check is
        // what fails it.
        let svc = Service::start(ServiceConfig {
            workers: 1,
            batcher: BatcherConfig { max_batch: 1, ..Default::default() },
            ..Default::default()
        });
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(21));
        let mut a = DenseMatrix::gaussian(800, 200, &mut g);
        for i in 0..800 {
            for (j, v) in a.row_mut(i).iter_mut().enumerate() {
                *v *= 10f64.powf(-8.0 * j as f64 / 199.0);
            }
        }
        let b = g.gaussian_vec(800); // inconsistent: rnorm plateaus
        let id = svc.register_matrix(Matrix::Dense(a));
        let resp = svc
            .solve_blocking(SolveRequest {
                matrix: id,
                rhs: b,
                solver: SolverChoice::Lsqr,
                tol: 0.0,
                deadline_us: 2_000,
                refine_iters: 0,
            })
            .unwrap();
        assert!(
            matches!(resp.result, Err(ServiceError::DeadlineExceeded)),
            "expected DeadlineExceeded, got ok={} (queue={}us solve={}us)",
            resp.result.is_ok(),
            resp.queue_us,
            resp.solve_us,
        );
        assert!(Metrics::get(&svc.metrics().deadline_missed) >= 1);
    }

    #[test]
    fn mixed_solvers_work() {
        let (svc, id, x_true, b) = test_service(2);
        for solver in [
            SolverChoice::Saa,
            SolverChoice::Lsqr,
            SolverChoice::SketchOnly,
            SolverChoice::Stable,
        ] {
            let mut r = req(id, &b);
            r.solver = solver;
            r.tol = 1e-10;
            let resp = svc.solve_blocking(r).unwrap();
            let sol = resp.result.unwrap();
            let err = norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true);
            assert!(err < 1e-6, "{}: err {err}", solver.name());
        }
    }
}
