//! Consistent-hash shard map for the multi-node coordinator tier.
//!
//! Matrices are sharded across coordinator processes by hashing
//! [`MatrixId`] onto a ring of virtual nodes (64 per shard, so keyspace
//! ownership stays balanced even with 2–3 shards). Each id is owned by
//! the first `R` **distinct, alive** shards clockwise from its hash
//! point — `R` is the replication factor, so a single shard loss never
//! loses a registered matrix.
//!
//! Two properties the router tier leans on:
//!
//! * **Stability** — shard identity is the index into the address list
//!   and dead shards stay on the ring (they are skipped, not removed),
//!   so ownership of unaffected ids never moves when membership flaps.
//!   A dead shard's ids fail over to the *next* ring successor — exactly
//!   the replica that already holds them when `R ≥ 2`.
//! * **Determinism** — the ring is a pure function of `(shard count,
//!   vnodes, splitmix64)`: no `RandomState`, no iteration-order hazards,
//!   same ownership in every process that shares the member list.
//!
//! Membership is **epoch-versioned**: every aliveness transition bumps a
//! monotone epoch. The router stamps heartbeats with its epoch and serves
//! requests caught mid-rebalance with a typed retryable error, so clients
//! can distinguish "resend after backoff" from real failures.

use super::registry::MatrixId;

/// Virtual nodes per shard. 64 keeps max/min keyspace share within ~2x
/// for small clusters while the ring stays tiny (192 entries at 3 shards).
pub const VNODES_PER_SHARD: usize = 64;

/// splitmix64 finalizer: a full-avalanche 64-bit mixer (public domain
/// constants from Vigna's splitmix64). Used for both vnode placement and
/// key hashing so the ring is reproducible across processes.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Epoch-versioned consistent-hash ring over a fixed shard list.
#[derive(Debug, Clone)]
pub struct ShardMap {
    addrs: Vec<String>,
    alive: Vec<bool>,
    replication: usize,
    epoch: u64,
    /// `(hash, shard index)` sorted by hash — the ring.
    ring: Vec<(u64, usize)>,
}

impl ShardMap {
    /// Build the ring. `replication` is clamped to `[1, addrs.len()]`.
    pub fn new(addrs: Vec<String>, replication: usize) -> Self {
        assert!(!addrs.is_empty(), "shard map needs at least one shard");
        let replication = replication.clamp(1, addrs.len());
        let mut ring = Vec::with_capacity(addrs.len() * VNODES_PER_SHARD);
        for shard in 0..addrs.len() {
            for vnode in 0..VNODES_PER_SHARD {
                let h = mix64(((shard as u64) << 32) ^ vnode as u64);
                ring.push((h, shard));
            }
        }
        // Sort by hash; break (astronomically unlikely) hash ties by shard
        // index so the ring order is total and deterministic.
        ring.sort_unstable();
        let alive = vec![true; addrs.len()];
        Self { addrs, alive, replication, epoch: 0, ring }
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn addr(&self, shard: usize) -> &str {
        &self.addrs[shard]
    }

    pub fn is_alive(&self, shard: usize) -> bool {
        self.alive[shard]
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Record a liveness transition. Returns `true` (and bumps the epoch)
    /// only when the state actually changed — heartbeat confirmations of
    /// the status quo must not churn the epoch.
    pub fn set_alive(&mut self, shard: usize, alive: bool) -> bool {
        if self.alive[shard] == alive {
            return false;
        }
        self.alive[shard] = alive;
        self.epoch += 1;
        true
    }

    /// The first `R` distinct alive shards clockwise from the id's hash
    /// point. Fewer than `R` entries are returned only when fewer than `R`
    /// shards are alive; empty means a total outage.
    pub fn owners(&self, id: MatrixId) -> Vec<usize> {
        self.owners_where(id, |s| self.alive[s])
    }

    /// Ownership ignoring liveness — what the placement *will be* once
    /// every shard is back. Used to diff rebalance targets.
    pub fn owners_any(&self, id: MatrixId) -> Vec<usize> {
        self.owners_where(id, |_| true)
    }

    fn owners_where(&self, id: MatrixId, keep: impl Fn(usize) -> bool) -> Vec<usize> {
        let h = mix64(id.0);
        let start = self.ring.partition_point(|&(rh, _)| rh < h);
        let mut out = Vec::with_capacity(self.replication);
        for i in 0..self.ring.len() {
            let (_, shard) = self.ring[(start + i) % self.ring.len()];
            if keep(shard) && !out.contains(&shard) {
                out.push(shard);
                if out.len() == self.replication {
                    break;
                }
            }
        }
        out
    }

    /// Primary owner (first alive successor), if any shard is alive.
    pub fn primary(&self, id: MatrixId) -> Option<usize> {
        self.owners(id).first().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(n: usize, r: usize) -> ShardMap {
        ShardMap::new((0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect(), r)
    }

    #[test]
    fn owners_deterministic_and_distinct() {
        let a = map(3, 2);
        let b = map(3, 2);
        for k in 0..500u64 {
            let o = a.owners(MatrixId(k));
            assert_eq!(o, b.owners(MatrixId(k)), "ring must be reproducible");
            assert_eq!(o.len(), 2);
            assert_ne!(o[0], o[1], "replicas must land on distinct shards");
        }
    }

    #[test]
    fn replication_clamped_to_cluster_size() {
        let m = map(2, 5);
        assert_eq!(m.replication(), 2);
        assert_eq!(map(3, 0).replication(), 1);
    }

    #[test]
    fn keyspace_is_spread() {
        let m = map(3, 1);
        let mut counts = [0usize; 3];
        for k in 0..3000u64 {
            counts[m.primary(MatrixId(k)).unwrap()] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!(c > 300, "shard {s} owns only {c}/3000 keys — ring is skewed");
        }
    }

    #[test]
    fn dead_shard_fails_over_to_existing_replica() {
        let mut m = map(3, 2);
        for k in 0..300u64 {
            let before = m.owners(MatrixId(k));
            let dead = before[0];
            assert!(m.set_alive(dead, false));
            let after = m.owners(MatrixId(k));
            // The surviving replica is promoted to primary: every key the
            // dead shard fronted is still served by a shard that already
            // holds it.
            assert_eq!(after[0], before[1]);
            assert!(!after.contains(&dead));
            assert!(m.set_alive(dead, true));
        }
    }

    #[test]
    fn unaffected_keys_do_not_move() {
        let mut m = map(3, 1);
        let before: Vec<_> = (0..1000u64).map(|k| m.primary(MatrixId(k)).unwrap()).collect();
        m.set_alive(2, false);
        for (k, &b) in before.iter().enumerate() {
            if b != 2 {
                assert_eq!(m.primary(MatrixId(k as u64)).unwrap(), b, "stable keys must not move");
            } else {
                assert_ne!(m.primary(MatrixId(k as u64)).unwrap(), 2);
            }
        }
    }

    #[test]
    fn epoch_bumps_only_on_transitions() {
        let mut m = map(3, 2);
        assert_eq!(m.epoch(), 0);
        assert!(m.set_alive(1, false));
        assert_eq!(m.epoch(), 1);
        assert!(!m.set_alive(1, false), "no-op transition must not bump");
        assert_eq!(m.epoch(), 1);
        assert!(m.set_alive(1, true));
        assert_eq!(m.epoch(), 2);
    }

    #[test]
    fn owners_any_ignores_liveness() {
        let mut m = map(3, 2);
        let id = MatrixId(42);
        let placed = m.owners_any(id);
        m.set_alive(placed[0], false);
        assert_eq!(m.owners_any(id), placed, "planned placement ignores liveness");
        assert_ne!(m.owners(id), placed);
    }

    #[test]
    fn total_outage_yields_no_owners() {
        let mut m = map(2, 2);
        m.set_alive(0, false);
        m.set_alive(1, false);
        assert!(m.owners(MatrixId(5)).is_empty());
        assert!(m.primary(MatrixId(5)).is_none());
    }
}
