//! Dynamic batcher: coalesce requests by (matrix, route-class) under a
//! max-batch / max-wait policy — the dispatch-cost and factor-reuse lever.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::registry::MatrixId;
use super::SolverChoice;

/// Batching key: requests in one batch share the design matrix and solver
/// class, so workers can reuse the sketch→QR factorization across them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub matrix: MatrixId,
    pub solver: SolverChoice,
}

/// Batcher policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time the *oldest* member of a group may wait before flush.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A flushed batch.
#[derive(Debug)]
pub struct Batch<T> {
    pub key: BatchKey,
    pub items: Vec<T>,
}

struct Group<T> {
    items: Vec<T>,
    oldest: Instant,
}

/// Accumulates pending items into key groups; flushes on size or age.
pub struct Batcher<T> {
    config: BatcherConfig,
    groups: HashMap<BatchKey, Group<T>>,
}

impl<T> Batcher<T> {
    pub fn new(config: BatcherConfig) -> Self {
        Self { config, groups: HashMap::new() }
    }

    /// Number of buffered (not yet flushed) items.
    pub fn pending(&self) -> usize {
        self.groups.values().map(|g| g.items.len()).sum()
    }

    /// Add an item; returns a full batch if the group hit `max_batch`.
    pub fn offer(&mut self, key: BatchKey, item: T, now: Instant) -> Option<Batch<T>> {
        let group = self
            .groups
            .entry(key)
            .or_insert_with(|| Group { items: Vec::new(), oldest: now });
        group.items.push(item);
        if group.items.len() >= self.config.max_batch {
            let g = self.groups.remove(&key).unwrap();
            return Some(Batch { key, items: g.items });
        }
        None
    }

    /// Flush all groups whose oldest member has waited ≥ max_wait.
    pub fn flush_due(&mut self, now: Instant) -> Vec<Batch<T>> {
        let due: Vec<BatchKey> = self
            .groups
            .iter()
            .filter(|(_, g)| now.duration_since(g.oldest) >= self.config.max_wait)
            .map(|(k, _)| *k)
            .collect();
        due.into_iter()
            .map(|k| {
                let g = self.groups.remove(&k).unwrap();
                Batch { key: k, items: g.items }
            })
            .collect()
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch<T>> {
        self.groups
            .drain()
            .map(|(k, g)| Batch { key: k, items: g.items })
            .collect()
    }

    /// Time until the next group becomes due (for the dispatcher's sleep).
    pub fn next_due_in(&self, now: Instant) -> Option<Duration> {
        self.groups
            .values()
            .map(|g| {
                let age = now.duration_since(g.oldest);
                self.config.max_wait.saturating_sub(age)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(id: u64) -> BatchKey {
        BatchKey { matrix: MatrixId(id), solver: SolverChoice::Saa }
    }

    #[test]
    fn size_triggered_flush() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, ..Default::default() });
        let t = Instant::now();
        assert!(b.offer(key(1), "a", t).is_none());
        assert!(b.offer(key(1), "b", t).is_none());
        let batch = b.offer(key(1), "c", t).expect("full batch");
        assert_eq!(batch.items, vec!["a", "b", "c"]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn groups_are_keyed() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        let t = Instant::now();
        assert!(b.offer(key(1), 1, t).is_none());
        assert!(b.offer(key(2), 2, t).is_none());
        assert_eq!(b.pending(), 2);
        // Different solver = different key even with same matrix.
        let k_lsqr = BatchKey { matrix: MatrixId(1), solver: SolverChoice::Lsqr };
        assert!(b.offer(k_lsqr, 3, t).is_none());
        assert_eq!(b.pending(), 3);
        let full = b.offer(key(1), 4, t).unwrap();
        assert_eq!(full.items, vec![1, 4]);
    }

    #[test]
    fn age_triggered_flush() {
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let mut b = Batcher::new(cfg);
        let t0 = Instant::now();
        b.offer(key(1), "x", t0);
        assert!(b.flush_due(t0).is_empty());
        let later = t0 + Duration::from_millis(6);
        let due = b.flush_due(later);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].items, vec!["x"]);
    }

    #[test]
    fn next_due_in_reports_min() {
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(10) };
        let mut b = Batcher::new(cfg);
        let t0 = Instant::now();
        assert!(b.next_due_in(t0).is_none());
        b.offer(key(1), 1, t0);
        let d = b.next_due_in(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn flush_all_drains() {
        let mut b = Batcher::new(BatcherConfig::default());
        let t = Instant::now();
        b.offer(key(1), 1, t);
        b.offer(key(2), 2, t);
        let all = b.flush_all();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flush_is_fifo_within_key() {
        // The blocked multi-RHS path pairs results back to requests by
        // position, so submission order must survive every flush path.
        let cfg = BatcherConfig { max_batch: 5, max_wait: Duration::from_millis(1) };
        let mut b = Batcher::new(cfg);
        let t = Instant::now();
        for item in ["a", "b", "c"] {
            assert!(b.offer(key(1), item, t).is_none());
        }
        let due = b.flush_due(t + Duration::from_millis(2));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].items, vec!["a", "b", "c"]);

        // Size-triggered flush preserves order too.
        let full = ["d", "e", "f", "g", "h"]
            .iter()
            .find_map(|&item| b.offer(key(1), item, t))
            .expect("fifth offer fills the batch");
        assert_eq!(full.items, vec!["d", "e", "f", "g", "h"]);
    }

    #[test]
    fn single_item_age_flush_is_a_batch() {
        // A lone request that ages out still flushes as a (k=1) batch — it
        // routes through Worker::execute_batch like any other flush.
        let cfg = BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(3) };
        let mut b = Batcher::new(cfg);
        let t0 = Instant::now();
        b.offer(key(9), "solo", t0);
        let due = b.flush_due(t0 + Duration::from_millis(4));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].key, key(9));
        assert_eq!(due[0].items, vec!["solo"]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn interleaved_keys_never_cross_contaminate() {
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(10) };
        let mut b = Batcher::new(cfg);
        let t = Instant::now();
        // Interleave three keys; key 1 fills first.
        assert!(b.offer(key(1), (1, 'a'), t).is_none());
        assert!(b.offer(key(2), (2, 'a'), t).is_none());
        assert!(b.offer(key(3), (3, 'a'), t).is_none());
        assert!(b.offer(key(1), (1, 'b'), t).is_none());
        assert!(b.offer(key(2), (2, 'b'), t).is_none());
        let full = b.offer(key(1), (1, 'c'), t).expect("key 1 full");
        assert_eq!(full.key, key(1));
        assert_eq!(full.items, vec![(1, 'a'), (1, 'b'), (1, 'c')]);
        // The other groups are intact, in order, under their own keys.
        let rest = b.flush_due(t + Duration::from_millis(20));
        assert_eq!(rest.len(), 2);
        for batch in rest {
            let expect_id = batch.key.matrix.0 as i32;
            let expect: Vec<(i32, char)> = vec![(expect_id, 'a'), (expect_id, 'b')];
            assert_eq!(batch.items, expect, "key {expect_id}");
        }
        assert_eq!(b.pending(), 0);
    }
}
