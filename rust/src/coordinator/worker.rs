//! Worker: owns a PJRT engine (optional) and a per-matrix factor cache;
//! executes batches.
//!
//! The factor cache is the serving win the batcher sets up: all requests in
//! a batch share the design matrix, so the sketch → QR factorization (the
//! expensive, b-independent 60–90% of SAA-SAS) is computed once and reused —
//! the direct analogue of prefix/KV-cache reuse in LLM serving.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::linalg::operator::PreconditionedOperator;
use crate::linalg::qr::{qr_compact, QrCompact};
use crate::linalg::{norms, triangular, DenseMatrix, LinearOperator, Matrix};
use crate::runtime::{Engine, Tensor};
use crate::sketch::{CountSketch, SketchOperator, SketchWorkspace};
use crate::solvers::ladder::{run_ladder, LadderConfig, Stage};
use crate::solvers::lsqr::{lsqr_block_ws, LsqrConfig, SolveWorkspace};
use crate::solvers::saa::SaaSolver;
use crate::solvers::{Solution, Solver};
use crate::testing::FaultAction;

use super::metrics::Metrics;
use super::registry::{MatrixId, MatrixRegistry};
use super::router::Route;
use super::{ExecutedOn, ServiceError, SolverChoice};

/// Cached, b-independent SAA factorization of one registered matrix.
struct FactorEntry {
    sketch: CountSketch,
    qr: QrCompact,
    r: DenseMatrix,
    /// Materialized Y = A·R⁻¹ for dense A (fast LSQR GEMV); None for CSR.
    y: Option<DenseMatrix>,
    /// f32 copy for the PJRT path (built on first PJRT dispatch).
    f32_data: Option<Arc<Vec<f32>>>,
}

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifact_dir: Option<PathBuf>,
    pub sketch_factor: f64,
    pub seed: u64,
    pub lsqr: LsqrConfig,
    /// Max matrices whose factorization is kept (FIFO eviction).
    pub factor_cache_cap: usize,
    /// Kernel worker-pool size for the parallel GEMM/FWHT/sketch hot paths
    /// (0 = auto / inherit the process-wide setting). Sized from the same
    /// `[parallel]` config section as [`crate::config::SolveConfig`].
    ///
    /// Note: the pool setting is process-wide, so with `workers > 1`
    /// service workers solving concurrently the box can run up to
    /// `workers × threads` compute threads. Deployments with several
    /// workers should set `threads ≈ cores / workers` (per-worker pools
    /// are a ROADMAP item).
    pub threads: usize,
    /// Solve a flushed same-matrix batch as one blocked multi-RHS LSQR
    /// ([`crate::solvers::lsqr::lsqr_block`]) instead of a per-item loop.
    /// Per-RHS results are identical either way (the blocked kernels are
    /// bitwise-per-column equivalents); `false` restores the per-item loop
    /// — kept as the baseline for `coordinator_throughput --block-rhs`.
    pub block_rhs: bool,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            artifact_dir: None,
            sketch_factor: 4.0,
            seed: 0xC0FF_EE00,
            lsqr: LsqrConfig { atol: 1e-12, btol: 1e-12, conlim: 0.0, ..Default::default() },
            factor_cache_cap: 4,
            threads: 0,
            block_rhs: true,
        }
    }
}

/// One request's payload inside a flushed batch handed to
/// [`WorkerContext::execute_batch`] (the batch shares matrix and solver;
/// tolerance stays per-request). With the pipelined TCP front-end, items
/// batched together may come from different connections — results are
/// routed back per-request through each item's completion responder, so
/// nothing here may assume a single downstream consumer.
#[derive(Debug)]
pub struct BatchItem {
    pub rhs: Vec<f64>,
    pub tol: f64,
    /// Per-request refinement-sweep cap for the stable ladder (0 = defer
    /// to the server-side `--refine-iters` knob). Negotiated over the wire
    /// as the optional trailing `OP_SOLVE` field.
    pub refine_iters: usize,
}

/// A worker execution context. `!Send` by design (owns the PJRT engine);
/// construct inside the worker thread.
pub struct WorkerContext {
    config: WorkerConfig,
    engine: Option<Engine>,
    registry: Arc<MatrixRegistry>,
    metrics: Arc<Metrics>,
    cache: HashMap<MatrixId, FactorEntry>,
    cache_order: Vec<MatrixId>,
    /// Reusable sketch scratch (SRHT pads, blocked-RHS rows): the
    /// steady-state serving loop re-zeroes and reuses these instead of
    /// allocating per request. Reuse is bitwise identical to fresh buffers.
    sketch_ws: SketchWorkspace,
    /// Reusable LSQR scratch (u/v/w, apply scratch, per-iteration
    /// active-column blocks).
    solve_ws: SolveWorkspace,
}

impl WorkerContext {
    /// Build the context (loads the PJRT engine if an artifact dir is set
    /// and loadable; PJRT load failures degrade to native-only).
    pub fn new(
        config: WorkerConfig,
        registry: Arc<MatrixRegistry>,
        metrics: Arc<Metrics>,
    ) -> Self {
        if config.threads != 0 {
            // Explicit pool size: install process-wide so the parallel
            // kernels this worker drives see it (0 keeps the ambient
            // setting — env var or auto-detect).
            crate::parallel::set_threads(config.threads);
        }
        let engine = config.artifact_dir.as_ref().and_then(|d| match Engine::load(d) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("worker: PJRT engine unavailable ({err}); native-only");
                None
            }
        });
        Self {
            config,
            engine,
            registry,
            metrics,
            cache: HashMap::new(),
            cache_order: Vec::new(),
            sketch_ws: SketchWorkspace::new(),
            solve_ws: SolveWorkspace::new(),
        }
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    /// Solve one request that was routed `route`. Returns the solution and
    /// where it actually executed (PJRT failures fall back to native).
    pub fn execute(
        &mut self,
        route: &Route,
        matrix_id: MatrixId,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
    ) -> (Result<Solution, ServiceError>, ExecutedOn) {
        self.execute_one(route, matrix_id, rhs, solver, tol, 0)
    }

    /// [`WorkerContext::execute`] with an explicit per-request refinement
    /// cap (0 defers to the server-side knob).
    fn execute_one(
        &mut self,
        route: &Route,
        matrix_id: MatrixId,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        refine_iters: usize,
    ) -> (Result<Solution, ServiceError>, ExecutedOn) {
        let a = match self.registry.get(matrix_id) {
            Some(a) => a,
            None => {
                return (Err(ServiceError::UnknownMatrix(matrix_id.0)), ExecutedOn::Native)
            }
        };
        if rhs.len() != a.rows() {
            return (
                Err(ServiceError::BadRequest(format!(
                    "rhs has {} entries, matrix has {} rows",
                    rhs.len(),
                    a.rows()
                ))),
                ExecutedOn::Native,
            );
        }
        if !rhs.iter().all(|v| v.is_finite()) {
            return (
                Err(ServiceError::BadRequest(
                    "rhs contains non-finite (NaN/Inf) values".to_string(),
                )),
                ExecutedOn::Native,
            );
        }
        match route {
            Route::Artifact(name) if self.engine.is_some() => {
                match self.execute_pjrt(name, matrix_id, &a, rhs, tol) {
                    Ok(sol) => {
                        Metrics::inc(&self.metrics.pjrt_dispatches);
                        (Ok(sol), ExecutedOn::Pjrt(name.clone()))
                    }
                    Err(e) => {
                        eprintln!("worker: pjrt path failed ({e}); falling back to native");
                        let out =
                            self.execute_native(matrix_id, &a, rhs, solver, tol, refine_iters);
                        Metrics::inc(&self.metrics.native_dispatches);
                        (out, ExecutedOn::Native)
                    }
                }
            }
            _ => {
                let out = self.execute_native(matrix_id, &a, rhs, solver, tol, refine_iters);
                Metrics::inc(&self.metrics.native_dispatches);
                (out, ExecutedOn::Native)
            }
        }
    }

    /// Execute a flushed same-key batch, returning one result per item in
    /// submission order.
    ///
    /// The native route drains the whole batch into **one blocked
    /// multi-RHS solve** against the cached factorization ([`lsqr_block`]):
    /// the RHS block is sketched in a single parallel pass, `Qᵀ` and the
    /// triangular back-substitution are applied block-wise, and the LSQR
    /// iterations share every operator apply across the batch. Per-item
    /// results are identical to the per-item loop (the blocked kernels are
    /// bitwise-per-column), so batching is invisible to clients.
    ///
    /// Shape validation is hoisted here per item: a malformed right-hand
    /// side fails with its own `BadRequest` instead of poisoning the rest
    /// of the batch. Items may carry different tolerances; the batch is
    /// sub-grouped by tolerance (FIFO order preserved within each group).
    ///
    /// PJRT-routed batches (single-RHS executables) and configurations with
    /// `block_rhs = false` fall back to the per-item loop.
    pub fn execute_batch(
        &mut self,
        route: &Route,
        matrix_id: MatrixId,
        solver: SolverChoice,
        items: &[BatchItem],
    ) -> Vec<(Result<Solution, ServiceError>, ExecutedOn)> {
        // Deterministic chaos hook: an installed "worker" panic plan blows
        // up here, exercising the service loop's `catch_unwind` containment
        // exactly where a latent solver bug would.
        if let Some(plan) = crate::testing::active_faults() {
            if plan.action("worker") == Some(FaultAction::Panic) {
                panic!("injected fault: worker panic in execute_batch");
            }
        }
        let use_block = self.config.block_rhs
            && !(matches!(route, Route::Artifact(_)) && self.engine.is_some());
        if !use_block {
            return items
                .iter()
                .map(|it| {
                    self.execute_one(route, matrix_id, &it.rhs, solver, it.tol, it.refine_iters)
                })
                .collect();
        }
        let a = match self.registry.get(matrix_id) {
            Some(a) => a,
            None => {
                return items
                    .iter()
                    .map(|_| (Err(ServiceError::UnknownMatrix(matrix_id.0)), ExecutedOn::Native))
                    .collect()
            }
        };
        let m = a.rows();
        let mut out: Vec<Option<(Result<Solution, ServiceError>, ExecutedOn)>> = items
            .iter()
            .map(|it| {
                if it.rhs.len() != m {
                    Some((
                        Err(ServiceError::BadRequest(format!(
                            "rhs has {} entries, matrix has {m} rows",
                            it.rhs.len()
                        ))),
                        ExecutedOn::Native,
                    ))
                } else if !it.rhs.iter().all(|v| v.is_finite()) {
                    Some((
                        Err(ServiceError::BadRequest(
                            "rhs contains non-finite (NaN/Inf) values".to_string(),
                        )),
                        ExecutedOn::Native,
                    ))
                } else {
                    None
                }
            })
            .collect();
        // Sub-group the valid items by (tolerance bits, refinement cap),
        // FIFO within a group — items that negotiated a different
        // per-request refine cap must not share a ladder run.
        let mut groups: Vec<((u64, usize), Vec<usize>)> = Vec::new();
        for (i, slot) in out.iter().enumerate() {
            if slot.is_some() {
                continue;
            }
            let key = (items[i].tol.to_bits(), items[i].refine_iters);
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((key, vec![i])),
            }
        }
        for ((bits, refine_iters), idxs) in groups {
            let tol = f64::from_bits(bits);
            let solved =
                self.solve_block_native(matrix_id, &a, items, &idxs, solver, tol, refine_iters);
            Metrics::add(&self.metrics.native_dispatches, idxs.len() as u64);
            Metrics::inc(&self.metrics.blocked_batches);
            Metrics::add(&self.metrics.blocked_rhs, idxs.len() as u64);
            for (&i, res) in idxs.iter().zip(solved) {
                out[i] = Some((res, ExecutedOn::Native));
            }
        }
        out.into_iter().map(|o| o.expect("every batch item resolved")).collect()
    }

    // ---------------- native path with factor reuse ----------------------

    /// Drop every cached factorization and replace the scratch arenas.
    /// Called by the service loop after a contained solve panic: the
    /// unwound solve may have left a cache entry or workspace half-built.
    pub(crate) fn clear_factor_cache(&mut self) {
        self.cache.clear();
        self.cache_order.clear();
        self.sketch_ws = SketchWorkspace::new();
        self.solve_ws = SolveWorkspace::new();
    }

    fn factor_for(&mut self, id: MatrixId, a: &Matrix) -> Result<(), ServiceError> {
        if self.cache.contains_key(&id) {
            Metrics::inc(&self.metrics.factor_cache_hits);
            return Ok(());
        }
        Metrics::inc(&self.metrics.factor_cache_misses);
        let (m, n) = a.shape();
        let s_rows = ((self.config.sketch_factor * n as f64).ceil() as usize)
            .max(n + 1)
            .min(m);
        let sketch = CountSketch::new(s_rows, m, self.config.seed);
        let b_sk = sketch.apply_matrix_ws(a, &mut self.sketch_ws);
        let qr = qr_compact(&b_sk).map_err(|e| ServiceError::Solver(e.to_string()))?;
        let r = qr.r();
        let y = match a {
            // Row-parallel right-solve (bitwise identical to the serial
            // path, so cached factors agree across pool sizes).
            Matrix::Dense(ad) => Some(
                triangular::right_solve_upper_multi(ad, &r)
                    .map_err(|e| ServiceError::Solver(e.to_string()))?,
            ),
            Matrix::Csr(_) => None,
        };
        self.cache.insert(id, FactorEntry { sketch, qr, r, y, f32_data: None });
        self.cache_order.push(id);
        if self.cache_order.len() > self.config.factor_cache_cap {
            let evict = self.cache_order.remove(0);
            self.cache.remove(&evict);
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_native(
        &mut self,
        id: MatrixId,
        a: &Matrix,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        refine_iters: usize,
    ) -> Result<Solution, ServiceError> {
        // A single request is the k = 1 column of the blocked path — the
        // blocked kernels are bitwise-per-column equivalents of the vector
        // kernels (pinned by tests/block_solve_properties.rs), so there is
        // exactly one native solve implementation to keep correct.
        let items = [BatchItem { rhs: rhs.to_vec(), tol, refine_iters }];
        self.solve_block_native(id, a, &items, &[0], solver, tol, refine_iters)
            .pop()
            .expect("one item in, one result out")
    }

    /// Blocked native solve of one tolerance group (`idxs` into `items`).
    /// This is **the** native solve implementation: single requests run it
    /// with k = 1 (via [`WorkerContext::execute_native`]), so the per-RHS
    /// equivalence of batched and solo solves is structural, not maintained
    /// by hand.
    #[allow(clippy::too_many_arguments)]
    fn solve_block_native(
        &mut self,
        id: MatrixId,
        a: &Matrix,
        items: &[BatchItem],
        idxs: &[usize],
        solver: SolverChoice,
        tol: f64,
        refine_iters: usize,
    ) -> Vec<Result<Solution, ServiceError>> {
        let k = idxs.len();
        let (m, n) = a.shape();
        let mut rhs_block = DenseMatrix::zeros(k, m);
        for (r, &i) in idxs.iter().enumerate() {
            rhs_block.row_mut(r).copy_from_slice(&items[i].rhs);
        }
        match solver {
            SolverChoice::Lsqr => {
                let cfg = LsqrConfig { atol: tol, btol: tol, ..self.config.lsqr.clone() };
                lsqr_block_ws(a.as_operator(), &rhs_block, None, &cfg, &mut self.solve_ws)
                    .into_iter()
                    .map(|res| {
                        Ok(Solution {
                            x: res.x,
                            iterations: res.itn,
                            resnorm: res.r1norm.abs(),
                            arnorm: res.arnorm,
                            converged: res.istop.converged(),
                            fallback_used: false,
                            residual_history: res.history,
                        })
                    })
                    .collect()
            }
            SolverChoice::Stable => {
                if let Err(e) = self.factor_for(id, a) {
                    return (0..k).map(|_| Err(e.clone())).collect();
                }
                let faults = crate::testing::active_faults();
                let entry = self.cache.get(&id).expect("just inserted");
                let c_block = entry.sketch.apply_mat_ws(&rhs_block, &mut self.sketch_ws);
                let z0_block = entry.qr.q_transpose_mat(&c_block);
                let cfg = LadderConfig {
                    tol,
                    lsqr: LsqrConfig { atol: tol, btol: tol, ..self.config.lsqr.clone() },
                    // Per-request negotiated cap wins; 0 defers to the
                    // server-side knob.
                    refine_iters: if refine_iters != 0 {
                        refine_iters
                    } else {
                        crate::solvers::stable::refine_iters()
                    },
                    ..Default::default()
                };
                let out = run_ladder(
                    a,
                    &rhs_block,
                    &entry.r,
                    &z0_block,
                    entry.y.as_ref(),
                    &cfg,
                    &mut self.solve_ws,
                    faults.as_ref(),
                );
                match out {
                    Ok(out) => {
                        for &st in &out.stage_of {
                            Metrics::inc(match st {
                                Stage::SketchSolve => &self.metrics.ladder_sas,
                                Stage::PrecondLsqr => &self.metrics.ladder_lsqr,
                                Stage::Refine => &self.metrics.ladder_refine,
                                Stage::DenseQr => &self.metrics.ladder_dense,
                            });
                        }
                        Metrics::add(&self.metrics.ladder_escalations, out.escalations);
                        (0..k)
                            .map(|r| {
                                Ok(Solution {
                                    x: out.x.row(r).to_vec(),
                                    iterations: out.iterations[r],
                                    resnorm: out.resnorm[r],
                                    arnorm: f64::NAN,
                                    converged: true,
                                    fallback_used: out.stage_of[r] == Stage::DenseQr,
                                    residual_history: Vec::new(),
                                })
                            })
                            .collect()
                    }
                    Err(e) => {
                        let err = ServiceError::Solver(e.to_string());
                        (0..k).map(|_| Err(err.clone())).collect()
                    }
                }
            }
            SolverChoice::Saa | SolverChoice::SketchOnly => {
                if let Err(e) = self.factor_for(id, a) {
                    return (0..k).map(|_| Err(e.clone())).collect();
                }
                let entry = self.cache.get(&id).expect("just inserted");
                // b-dependent part only, blocked: C = S·B, Z₀ = Qᵀ·C —
                // one parallel pass each for the whole batch, through the
                // worker's reusable sketch workspace.
                let c_block = entry.sketch.apply_mat_ws(&rhs_block, &mut self.sketch_ws);
                let z0_block = entry.qr.q_transpose_mat(&c_block);
                if solver == SolverChoice::SketchOnly {
                    let x_block = match triangular::solve_upper_block(&entry.r, &z0_block) {
                        Ok(x) => x,
                        Err(e) => {
                            let err = ServiceError::Solver(e.to_string());
                            return (0..k).map(|_| Err(err.clone())).collect();
                        }
                    };
                    let mut ax = DenseMatrix::zeros(k, m);
                    a.as_operator().apply_mat(&x_block, &mut ax);
                    let mut out = Vec::with_capacity(k);
                    for r in 0..k {
                        let diff: Vec<f64> = ax
                            .row(r)
                            .iter()
                            .zip(rhs_block.row(r).iter())
                            .map(|(p, q)| p - q)
                            .collect();
                        out.push(Ok(Solution {
                            x: x_block.row(r).to_vec(),
                            iterations: 0,
                            resnorm: norms::nrm2(&diff),
                            arnorm: f64::NAN,
                            converged: true,
                            fallback_used: false,
                            residual_history: Vec::new(),
                        }));
                    }
                    return out;
                }
                let cfg = LsqrConfig { atol: tol, btol: tol, ..self.config.lsqr.clone() };
                let results = match (&entry.y, a) {
                    (Some(y), _) => {
                        lsqr_block_ws(y, &rhs_block, Some(&z0_block), &cfg, &mut self.solve_ws)
                    }
                    (None, Matrix::Csr(ac)) => {
                        let op = PreconditionedOperator::new(ac, &entry.r);
                        lsqr_block_ws(&op, &rhs_block, Some(&z0_block), &cfg, &mut self.solve_ws)
                    }
                    (None, Matrix::Dense(ad)) => {
                        let op = PreconditionedOperator::new(ad, &entry.r);
                        lsqr_block_ws(&op, &rhs_block, Some(&z0_block), &cfg, &mut self.solve_ws)
                    }
                };
                // One blocked back-substitution for every column; columns
                // whose LSQR did not converge take the solo fallback below.
                let mut zx = DenseMatrix::zeros(k, n);
                for (r, res) in results.iter().enumerate() {
                    zx.row_mut(r).copy_from_slice(&res.x);
                }
                let x_block = match triangular::solve_upper_block(&entry.r, &zx) {
                    Ok(x) => x,
                    Err(e) => {
                        let err = ServiceError::Solver(e.to_string());
                        return (0..k).map(|_| Err(err.clone())).collect();
                    }
                };
                let mut out = Vec::with_capacity(k);
                for (r, res) in results.into_iter().enumerate() {
                    if !res.istop.converged() {
                        // Algorithm 1 fallback: rare; identical to the
                        // single-vector path's uncached SAA solve.
                        let saa = SaaSolver::new(crate::solvers::saa::SaaConfig {
                            lsqr: cfg.clone(),
                            seed: self.config.seed,
                            sketch_factor: self.config.sketch_factor,
                            ..Default::default()
                        });
                        out.push(
                            saa.solve(a, &items[idxs[r]].rhs)
                                .map_err(|e| ServiceError::Solver(e.to_string())),
                        );
                        continue;
                    }
                    out.push(Ok(Solution {
                        x: x_block.row(r).to_vec(),
                        iterations: res.itn,
                        resnorm: res.r1norm.abs(),
                        arnorm: res.arnorm,
                        converged: true,
                        fallback_used: false,
                        residual_history: res.history,
                    }));
                }
                out
            }
        }
    }

    // ---------------- PJRT path ------------------------------------------

    fn f32_matrix(&mut self, id: MatrixId, a: &Matrix) -> Result<Arc<Vec<f32>>, ServiceError> {
        self.factor_for(id, a)?;
        let entry = self.cache.get_mut(&id).expect("factored");
        if entry.f32_data.is_none() {
            let dense = match a {
                Matrix::Dense(d) => d.clone(),
                Matrix::Csr(c) => c.to_dense(),
            };
            entry.f32_data =
                Some(Arc::new(dense.data().iter().map(|&v| v as f32).collect()));
        }
        Ok(entry.f32_data.clone().unwrap())
    }

    fn execute_pjrt(
        &mut self,
        artifact: &str,
        id: MatrixId,
        a: &Matrix,
        rhs: &[f64],
        tol: f64,
    ) -> Result<Solution, ServiceError> {
        let spec = {
            let engine = self.engine.as_ref().expect("caller checked");
            engine
                .manifest()
                .find(artifact)
                .ok_or_else(|| ServiceError::Solver(format!("no artifact {artifact}")))?
                .clone()
        };
        let (m, n, s) = (spec.m, spec.n, spec.s);
        let a32 = self.f32_matrix(id, a)?;
        let b32: Vec<f32> = rhs.iter().map(|&v| v as f32).collect();

        let mut inputs: Vec<Tensor> = Vec::with_capacity(4);
        inputs.push(Tensor::F32 { data: a32.as_ref().clone(), shape: vec![m, n] });
        match spec.entry.as_str() {
            "lsqr_baseline" => {
                inputs.push(Tensor::f32(b32, vec![m]));
            }
            _ => {
                // CountSketch hash arrays shared with the native cache so
                // both paths use the *same* S (cross-checkable).
                let entry = self.cache.get(&id).expect("factored");
                let (buckets, signs) = entry.sketch.hash_arrays();
                if entry.sketch.sketch_dim() != s {
                    return Err(ServiceError::Solver(format!(
                        "sketch dim mismatch: cache {} vs artifact {s}",
                        entry.sketch.sketch_dim()
                    )));
                }
                inputs.push(Tensor::f32(b32, vec![m]));
                inputs.push(Tensor::i32(
                    buckets.iter().map(|&v| v as i32).collect(),
                    vec![m],
                ));
                inputs.push(Tensor::f32(
                    signs.iter().map(|&v| v as f32).collect(),
                    vec![m],
                ));
            }
        }
        let engine = self.engine.as_ref().expect("caller checked");
        let out = engine
            .execute(artifact, &inputs)
            .map_err(|e| ServiceError::Solver(e.to_string()))?;
        let x = out[0].to_f64();
        let (resnorm, history, iterations) = if out.len() > 1 {
            let h = out[1].to_f64();
            let last = h.last().copied().unwrap_or(f64::NAN);
            let iters = h.len();
            (last, h, iters)
        } else {
            (f64::NAN, Vec::new(), 0)
        };
        let bnorm = norms::nrm2(rhs).max(1e-300);
        let converged = if resnorm.is_nan() { true } else { resnorm / bnorm <= tol.max(1e-5) };
        Ok(Solution {
            x,
            iterations,
            resnorm,
            arnorm: f64::NAN,
            converged,
            fallback_used: false,
            residual_history: history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn setup(
        cap: usize,
    ) -> (WorkerContext, Arc<MatrixRegistry>, Arc<Metrics>, MatrixId, Vec<f64>, Vec<f64>) {
        let registry = Arc::new(MatrixRegistry::new());
        let metrics = Arc::new(Metrics::new());
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(77));
        let a = DenseMatrix::gaussian(300, 12, &mut g);
        let x_true = g.gaussian_vec(12);
        let b = a.matvec(&x_true);
        let id = registry.register(Matrix::Dense(a));
        let ctx = WorkerContext::new(
            WorkerConfig { factor_cache_cap: cap, ..Default::default() },
            registry.clone(),
            metrics.clone(),
        );
        (ctx, registry, metrics, id, x_true, b)
    }

    #[test]
    fn native_saa_solves_and_caches() {
        let (mut ctx, _reg, metrics, id, x_true, b) = setup(4);
        let (r1, on1) =
            ctx.execute(&Route::Native, id, &b, SolverChoice::Saa, 1e-10);
        assert_eq!(on1, ExecutedOn::Native);
        let s1 = r1.unwrap();
        let err = norms::nrm2_diff(&s1.x, &x_true) / norms::nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
        assert_eq!(Metrics::get(&metrics.factor_cache_misses), 1);
        // Second request: cache hit, same answer.
        let (r2, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::Saa, 1e-10);
        assert_eq!(Metrics::get(&metrics.factor_cache_hits), 1);
        assert_eq!(r2.unwrap().x, s1.x);
    }

    #[test]
    fn lsqr_and_sketch_only_choices() {
        let (mut ctx, _reg, _m, id, x_true, b) = setup(4);
        let (r, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::Lsqr, 1e-12);
        let sol = r.unwrap();
        assert!(sol.converged);
        assert!(norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true) < 1e-7);
        let (r2, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::SketchOnly, 1e-2);
        let sol2 = r2.unwrap();
        // consistent system: sketch-only is exact too
        assert!(norms::nrm2_diff(&sol2.x, &x_true) / norms::nrm2(&x_true) < 1e-8);
        assert_eq!(sol2.iterations, 0);
    }

    #[test]
    fn stable_choice_runs_ladder_and_counts_stages() {
        let (mut ctx, _reg, metrics, id, x_true, b) = setup(4);
        let (r, on) = ctx.execute(&Route::Native, id, &b, SolverChoice::Stable, 1e-10);
        assert_eq!(on, ExecutedOn::Native);
        let sol = r.unwrap();
        let err = norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
        // Exactly one RHS landed somewhere on the ladder.
        let answered = Metrics::get(&metrics.ladder_sas)
            + Metrics::get(&metrics.ladder_lsqr)
            + Metrics::get(&metrics.ladder_refine)
            + Metrics::get(&metrics.ladder_dense);
        assert_eq!(answered, 1);
    }

    #[test]
    fn non_finite_rhs_rejected() {
        let (mut ctx, _reg, _m, id, _xt, b) = setup(4);
        let mut nan_rhs = b.clone();
        nan_rhs[3] = f64::NAN;
        let (r, _) = ctx.execute(&Route::Native, id, &nan_rhs, SolverChoice::Saa, 1e-8);
        assert!(matches!(r, Err(ServiceError::BadRequest(ref m)) if m.contains("non-finite")));
        // Blocked path: the bad item fails alone, its batch-mate solves.
        let mut inf_rhs = b.clone();
        inf_rhs[0] = f64::INFINITY;
        let items = vec![
            BatchItem { rhs: b.clone(), tol: 1e-10, refine_iters: 0 },
            BatchItem { rhs: inf_rhs, tol: 1e-10, refine_iters: 0 },
        ];
        let out = ctx.execute_batch(&Route::Native, id, SolverChoice::Saa, &items);
        assert!(out[0].0.is_ok());
        assert!(matches!(out[1].0, Err(ServiceError::BadRequest(_))));
    }

    #[test]
    fn unknown_matrix_and_bad_rhs() {
        let (mut ctx, _reg, _m, id, _xt, _b) = setup(4);
        let (r, _) = ctx.execute(&Route::Native, MatrixId(999), &[1.0], SolverChoice::Saa, 1e-6);
        assert!(matches!(r, Err(ServiceError::UnknownMatrix(999))));
        let (r2, _) = ctx.execute(&Route::Native, id, &[1.0, 2.0], SolverChoice::Saa, 1e-6);
        assert!(matches!(r2, Err(ServiceError::BadRequest(_))));
    }

    #[test]
    fn execute_batch_matches_per_item_results() {
        let (mut ctx, _reg, metrics, id, x_true, b) = setup(4);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(90));
        let mut noisy = b.clone();
        for bi in noisy.iter_mut() {
            *bi += 0.1 * g.next_gaussian();
        }
        let items = vec![
            BatchItem { rhs: b.clone(), tol: 1e-10, refine_iters: 0 },
            BatchItem { rhs: noisy.clone(), tol: 1e-10, refine_iters: 0 },
            BatchItem { rhs: b.clone(), tol: 1e-8, refine_iters: 0 }, // second tol group
        ];
        let out = ctx.execute_batch(&Route::Native, id, SolverChoice::Saa, &items);
        assert_eq!(out.len(), 3);
        assert!(Metrics::get(&metrics.blocked_rhs) >= 3);
        // A separate context (same seed => same sketch) solving one-by-one
        // must produce the same answers.
        let (mut solo_ctx, _r2, _m2, _id2, _xt2, _b2) = setup(4);
        for (it, (res, on)) in items.iter().zip(&out) {
            assert_eq!(*on, ExecutedOn::Native);
            let x = res.as_ref().unwrap().x.clone();
            let (solo, _) = solo_ctx.execute(&Route::Native, id, &it.rhs, SolverChoice::Saa, it.tol);
            assert_eq!(x, solo.unwrap().x);
        }
        let err = norms::nrm2_diff(&out[0].0.as_ref().unwrap().x, &x_true) / norms::nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn malformed_item_fails_alone_in_batch() {
        // Hoisted shape validation: a bad RHS inside a batch must return a
        // per-item BadRequest without poisoning its batch-mates.
        let (mut ctx, _reg, _m, id, x_true, b) = setup(4);
        let items = vec![
            BatchItem { rhs: b.clone(), tol: 1e-10, refine_iters: 0 },
            BatchItem { rhs: vec![1.0, 2.0], tol: 1e-10, refine_iters: 0 }, // wrong length
            BatchItem { rhs: b.clone(), tol: 1e-10, refine_iters: 0 },
        ];
        let out = ctx.execute_batch(&Route::Native, id, SolverChoice::Saa, &items);
        assert!(matches!(out[1].0, Err(ServiceError::BadRequest(_))));
        for j in [0usize, 2] {
            let sol = out[j].0.as_ref().unwrap();
            let err = norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true);
            assert!(err < 1e-8, "item {j} err {err}");
        }
    }

    #[test]
    fn execute_batch_per_item_loop_when_disabled() {
        let registry = Arc::new(MatrixRegistry::new());
        let metrics = Arc::new(Metrics::new());
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(91));
        let a = DenseMatrix::gaussian(120, 8, &mut g);
        let x_true = g.gaussian_vec(8);
        let b = a.matvec(&x_true);
        let id = registry.register(Matrix::Dense(a));
        let mut ctx = WorkerContext::new(
            WorkerConfig { block_rhs: false, ..Default::default() },
            registry,
            metrics.clone(),
        );
        let items = vec![
            BatchItem { rhs: b.clone(), tol: 1e-10, refine_iters: 0 },
            BatchItem { rhs: b, tol: 1e-10, refine_iters: 0 },
        ];
        let out = ctx.execute_batch(&Route::Native, id, SolverChoice::Saa, &items);
        assert_eq!(Metrics::get(&metrics.blocked_rhs), 0);
        for (res, _) in &out {
            let sol = res.as_ref().unwrap();
            let err = norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true);
            assert!(err < 1e-8);
        }
    }

    #[test]
    fn execute_batch_unknown_matrix_errors_every_item() {
        let (mut ctx, _reg, _m, _id, _xt, b) = setup(4);
        let items = vec![BatchItem { rhs: b.clone(), tol: 1e-8, refine_iters: 0 }];
        let out = ctx.execute_batch(&Route::Native, MatrixId(4242), SolverChoice::Saa, &items);
        assert!(matches!(out[0].0, Err(ServiceError::UnknownMatrix(4242))));
    }

    #[test]
    fn cache_eviction_fifo() {
        let (mut ctx, reg, metrics, _id, _xt, _b) = setup(2);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(88));
        let ids: Vec<MatrixId> = (0..3)
            .map(|_| reg.register(Matrix::Dense(DenseMatrix::gaussian(100, 6, &mut g))))
            .collect();
        let b = g.gaussian_vec(100);
        for &id in &ids {
            let (r, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::Saa, 1e-8);
            r.unwrap();
        }
        assert_eq!(Metrics::get(&metrics.factor_cache_misses), 3);
        // First registered matrix was evicted (cap 2): re-solving misses.
        let (r, _) = ctx.execute(&Route::Native, ids[0], &b, SolverChoice::Saa, 1e-8);
        r.unwrap();
        assert_eq!(Metrics::get(&metrics.factor_cache_misses), 4);
    }
}
