//! Worker: owns a PJRT engine (optional) and a per-matrix factor cache;
//! executes batches.
//!
//! The factor cache is the serving win the batcher sets up: all requests in
//! a batch share the design matrix, so the sketch → QR factorization (the
//! expensive, b-independent 60–90% of SAA-SAS) is computed once and reused —
//! the direct analogue of prefix/KV-cache reuse in LLM serving.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::linalg::operator::PreconditionedOperator;
use crate::linalg::qr::{qr_compact, QrCompact};
use crate::linalg::{norms, triangular, DenseMatrix, LinearOperator, Matrix};
use crate::runtime::{Engine, Tensor};
use crate::sketch::{CountSketch, SketchOperator};
use crate::solvers::lsqr::{lsqr, LsqrConfig};
use crate::solvers::saa::SaaSolver;
use crate::solvers::{Solution, Solver};

use super::metrics::Metrics;
use super::registry::{MatrixId, MatrixRegistry};
use super::router::Route;
use super::{ExecutedOn, ServiceError, SolverChoice};

/// Cached, b-independent SAA factorization of one registered matrix.
struct FactorEntry {
    sketch: CountSketch,
    qr: QrCompact,
    r: DenseMatrix,
    /// Materialized Y = A·R⁻¹ for dense A (fast LSQR GEMV); None for CSR.
    y: Option<DenseMatrix>,
    /// f32 copy for the PJRT path (built on first PJRT dispatch).
    f32_data: Option<Arc<Vec<f32>>>,
}

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifact_dir: Option<PathBuf>,
    pub sketch_factor: f64,
    pub seed: u64,
    pub lsqr: LsqrConfig,
    /// Max matrices whose factorization is kept (FIFO eviction).
    pub factor_cache_cap: usize,
    /// Kernel worker-pool size for the parallel GEMM/FWHT/sketch hot paths
    /// (0 = auto / inherit the process-wide setting). Sized from the same
    /// `[parallel]` config section as [`crate::config::SolveConfig`].
    ///
    /// Note: the pool setting is process-wide, so with `workers > 1`
    /// service workers solving concurrently the box can run up to
    /// `workers × threads` compute threads. Deployments with several
    /// workers should set `threads ≈ cores / workers` (per-worker pools
    /// are a ROADMAP item).
    pub threads: usize,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            artifact_dir: None,
            sketch_factor: 4.0,
            seed: 0xC0FF_EE00,
            lsqr: LsqrConfig { atol: 1e-12, btol: 1e-12, conlim: 0.0, ..Default::default() },
            factor_cache_cap: 4,
            threads: 0,
        }
    }
}

/// A worker execution context. `!Send` by design (owns the PJRT engine);
/// construct inside the worker thread.
pub struct WorkerContext {
    config: WorkerConfig,
    engine: Option<Engine>,
    registry: Arc<MatrixRegistry>,
    metrics: Arc<Metrics>,
    cache: HashMap<MatrixId, FactorEntry>,
    cache_order: Vec<MatrixId>,
}

impl WorkerContext {
    /// Build the context (loads the PJRT engine if an artifact dir is set
    /// and loadable; PJRT load failures degrade to native-only).
    pub fn new(
        config: WorkerConfig,
        registry: Arc<MatrixRegistry>,
        metrics: Arc<Metrics>,
    ) -> Self {
        if config.threads != 0 {
            // Explicit pool size: install process-wide so the parallel
            // kernels this worker drives see it (0 keeps the ambient
            // setting — env var or auto-detect).
            crate::parallel::set_threads(config.threads);
        }
        let engine = config.artifact_dir.as_ref().and_then(|d| match Engine::load(d) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("worker: PJRT engine unavailable ({err}); native-only");
                None
            }
        });
        Self { config, engine, registry, metrics, cache: HashMap::new(), cache_order: Vec::new() }
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    /// Solve one request that was routed `route`. Returns the solution and
    /// where it actually executed (PJRT failures fall back to native).
    pub fn execute(
        &mut self,
        route: &Route,
        matrix_id: MatrixId,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
    ) -> (Result<Solution, ServiceError>, ExecutedOn) {
        let a = match self.registry.get(matrix_id) {
            Some(a) => a,
            None => {
                return (Err(ServiceError::UnknownMatrix(matrix_id.0)), ExecutedOn::Native)
            }
        };
        if rhs.len() != a.rows() {
            return (
                Err(ServiceError::BadRequest(format!(
                    "rhs has {} entries, matrix has {} rows",
                    rhs.len(),
                    a.rows()
                ))),
                ExecutedOn::Native,
            );
        }
        match route {
            Route::Artifact(name) if self.engine.is_some() => {
                match self.execute_pjrt(name, matrix_id, &a, rhs, tol) {
                    Ok(sol) => {
                        Metrics::inc(&self.metrics.pjrt_dispatches);
                        (Ok(sol), ExecutedOn::Pjrt(name.clone()))
                    }
                    Err(e) => {
                        eprintln!("worker: pjrt path failed ({e}); falling back to native");
                        let out = self.execute_native(matrix_id, &a, rhs, solver, tol);
                        Metrics::inc(&self.metrics.native_dispatches);
                        (out, ExecutedOn::Native)
                    }
                }
            }
            _ => {
                let out = self.execute_native(matrix_id, &a, rhs, solver, tol);
                Metrics::inc(&self.metrics.native_dispatches);
                (out, ExecutedOn::Native)
            }
        }
    }

    // ---------------- native path with factor reuse ----------------------

    fn factor_for(&mut self, id: MatrixId, a: &Matrix) -> Result<(), ServiceError> {
        if self.cache.contains_key(&id) {
            Metrics::inc(&self.metrics.factor_cache_hits);
            return Ok(());
        }
        Metrics::inc(&self.metrics.factor_cache_misses);
        let (m, n) = a.shape();
        let s_rows = ((self.config.sketch_factor * n as f64).ceil() as usize)
            .max(n + 1)
            .min(m);
        let sketch = CountSketch::new(s_rows, m, self.config.seed);
        let b_sk = sketch.apply_matrix(a);
        let qr = qr_compact(&b_sk).map_err(|e| ServiceError::Solver(e.to_string()))?;
        let r = qr.r();
        let y = match a {
            Matrix::Dense(ad) => Some(
                triangular::right_solve_upper(ad, &r)
                    .map_err(|e| ServiceError::Solver(e.to_string()))?,
            ),
            Matrix::Csr(_) => None,
        };
        self.cache.insert(id, FactorEntry { sketch, qr, r, y, f32_data: None });
        self.cache_order.push(id);
        if self.cache_order.len() > self.config.factor_cache_cap {
            let evict = self.cache_order.remove(0);
            self.cache.remove(&evict);
        }
        Ok(())
    }

    fn execute_native(
        &mut self,
        id: MatrixId,
        a: &Matrix,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
    ) -> Result<Solution, ServiceError> {
        match solver {
            SolverChoice::Lsqr => {
                let cfg = LsqrConfig { atol: tol, btol: tol, ..self.config.lsqr.clone() };
                let res = lsqr(a.as_operator(), rhs, None, &cfg);
                Ok(Solution {
                    x: res.x,
                    iterations: res.itn,
                    resnorm: res.r1norm.abs(),
                    arnorm: res.arnorm,
                    converged: res.istop.converged(),
                    fallback_used: false,
                    residual_history: res.history,
                })
            }
            SolverChoice::Saa | SolverChoice::SketchOnly => {
                self.factor_for(id, a)?;
                let entry = self.cache.get(&id).expect("just inserted");
                // b-dependent part only: c = S·b, z0 = Qᵀc.
                let c = entry.sketch.apply_vec(rhs);
                let z0 = entry.qr.q_transpose_vec(&c);
                if solver == SolverChoice::SketchOnly {
                    let x = triangular::solve_upper(&entry.r, &z0)
                        .map_err(|e| ServiceError::Solver(e.to_string()))?;
                    let ax = a.as_operator().apply_vec(&x);
                    let rn = norms::nrm2(
                        &ax.iter().zip(rhs.iter()).map(|(p, q)| p - q).collect::<Vec<_>>(),
                    );
                    return Ok(Solution {
                        x,
                        iterations: 0,
                        resnorm: rn,
                        arnorm: f64::NAN,
                        converged: true,
                        fallback_used: false,
                        residual_history: Vec::new(),
                    });
                }
                let cfg = LsqrConfig { atol: tol, btol: tol, ..self.config.lsqr.clone() };
                let res = match (&entry.y, a) {
                    (Some(y), _) => lsqr(y, rhs, Some(&z0), &cfg),
                    (None, Matrix::Csr(ac)) => {
                        let op = PreconditionedOperator::new(ac, &entry.r);
                        lsqr(&op, rhs, Some(&z0), &cfg)
                    }
                    (None, Matrix::Dense(ad)) => {
                        let op = PreconditionedOperator::new(ad, &entry.r);
                        lsqr(&op, rhs, Some(&z0), &cfg)
                    }
                };
                if !res.istop.converged() {
                    // Algorithm 1 fallback: rare; run the full (uncached)
                    // SAA solver which owns the perturbation logic.
                    let saa = SaaSolver::new(crate::solvers::saa::SaaConfig {
                        lsqr: cfg,
                        seed: self.config.seed,
                        sketch_factor: self.config.sketch_factor,
                        ..Default::default()
                    });
                    return saa
                        .solve(a, rhs)
                        .map_err(|e| ServiceError::Solver(e.to_string()));
                }
                let x = triangular::solve_upper(&entry.r, &res.x)
                    .map_err(|e| ServiceError::Solver(e.to_string()))?;
                Ok(Solution {
                    x,
                    iterations: res.itn,
                    resnorm: res.r1norm.abs(),
                    arnorm: res.arnorm,
                    converged: true,
                    fallback_used: false,
                    residual_history: res.history,
                })
            }
        }
    }

    // ---------------- PJRT path ------------------------------------------

    fn f32_matrix(&mut self, id: MatrixId, a: &Matrix) -> Result<Arc<Vec<f32>>, ServiceError> {
        self.factor_for(id, a)?;
        let entry = self.cache.get_mut(&id).expect("factored");
        if entry.f32_data.is_none() {
            let dense = match a {
                Matrix::Dense(d) => d.clone(),
                Matrix::Csr(c) => c.to_dense(),
            };
            entry.f32_data =
                Some(Arc::new(dense.data().iter().map(|&v| v as f32).collect()));
        }
        Ok(entry.f32_data.clone().unwrap())
    }

    fn execute_pjrt(
        &mut self,
        artifact: &str,
        id: MatrixId,
        a: &Matrix,
        rhs: &[f64],
        tol: f64,
    ) -> Result<Solution, ServiceError> {
        let spec = {
            let engine = self.engine.as_ref().expect("caller checked");
            engine
                .manifest()
                .find(artifact)
                .ok_or_else(|| ServiceError::Solver(format!("no artifact {artifact}")))?
                .clone()
        };
        let (m, n, s) = (spec.m, spec.n, spec.s);
        let a32 = self.f32_matrix(id, a)?;
        let b32: Vec<f32> = rhs.iter().map(|&v| v as f32).collect();

        let mut inputs: Vec<Tensor> = Vec::with_capacity(4);
        inputs.push(Tensor::F32 { data: a32.as_ref().clone(), shape: vec![m, n] });
        match spec.entry.as_str() {
            "lsqr_baseline" => {
                inputs.push(Tensor::f32(b32, vec![m]));
            }
            _ => {
                // CountSketch hash arrays shared with the native cache so
                // both paths use the *same* S (cross-checkable).
                let entry = self.cache.get(&id).expect("factored");
                let (buckets, signs) = entry.sketch.hash_arrays();
                if entry.sketch.sketch_dim() != s {
                    return Err(ServiceError::Solver(format!(
                        "sketch dim mismatch: cache {} vs artifact {s}",
                        entry.sketch.sketch_dim()
                    )));
                }
                inputs.push(Tensor::f32(b32, vec![m]));
                inputs.push(Tensor::i32(
                    buckets.iter().map(|&v| v as i32).collect(),
                    vec![m],
                ));
                inputs.push(Tensor::f32(
                    signs.iter().map(|&v| v as f32).collect(),
                    vec![m],
                ));
            }
        }
        let engine = self.engine.as_ref().expect("caller checked");
        let out = engine
            .execute(artifact, &inputs)
            .map_err(|e| ServiceError::Solver(e.to_string()))?;
        let x = out[0].to_f64();
        let (resnorm, history, iterations) = if out.len() > 1 {
            let h = out[1].to_f64();
            let last = h.last().copied().unwrap_or(f64::NAN);
            let iters = h.len();
            (last, h, iters)
        } else {
            (f64::NAN, Vec::new(), 0)
        };
        let bnorm = norms::nrm2(rhs).max(1e-300);
        let converged = if resnorm.is_nan() { true } else { resnorm / bnorm <= tol.max(1e-5) };
        Ok(Solution {
            x,
            iterations,
            resnorm,
            arnorm: f64::NAN,
            converged,
            fallback_used: false,
            residual_history: history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn setup(
        cap: usize,
    ) -> (WorkerContext, Arc<MatrixRegistry>, Arc<Metrics>, MatrixId, Vec<f64>, Vec<f64>) {
        let registry = Arc::new(MatrixRegistry::new());
        let metrics = Arc::new(Metrics::new());
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(77));
        let a = DenseMatrix::gaussian(300, 12, &mut g);
        let x_true = g.gaussian_vec(12);
        let b = a.matvec(&x_true);
        let id = registry.register(Matrix::Dense(a));
        let ctx = WorkerContext::new(
            WorkerConfig { factor_cache_cap: cap, ..Default::default() },
            registry.clone(),
            metrics.clone(),
        );
        (ctx, registry, metrics, id, x_true, b)
    }

    #[test]
    fn native_saa_solves_and_caches() {
        let (mut ctx, _reg, metrics, id, x_true, b) = setup(4);
        let (r1, on1) =
            ctx.execute(&Route::Native, id, &b, SolverChoice::Saa, 1e-10);
        assert_eq!(on1, ExecutedOn::Native);
        let s1 = r1.unwrap();
        let err = norms::nrm2_diff(&s1.x, &x_true) / norms::nrm2(&x_true);
        assert!(err < 1e-8, "err {err}");
        assert_eq!(Metrics::get(&metrics.factor_cache_misses), 1);
        // Second request: cache hit, same answer.
        let (r2, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::Saa, 1e-10);
        assert_eq!(Metrics::get(&metrics.factor_cache_hits), 1);
        assert_eq!(r2.unwrap().x, s1.x);
    }

    #[test]
    fn lsqr_and_sketch_only_choices() {
        let (mut ctx, _reg, _m, id, x_true, b) = setup(4);
        let (r, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::Lsqr, 1e-12);
        let sol = r.unwrap();
        assert!(sol.converged);
        assert!(norms::nrm2_diff(&sol.x, &x_true) / norms::nrm2(&x_true) < 1e-7);
        let (r2, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::SketchOnly, 1e-2);
        let sol2 = r2.unwrap();
        // consistent system: sketch-only is exact too
        assert!(norms::nrm2_diff(&sol2.x, &x_true) / norms::nrm2(&x_true) < 1e-8);
        assert_eq!(sol2.iterations, 0);
    }

    #[test]
    fn unknown_matrix_and_bad_rhs() {
        let (mut ctx, _reg, _m, id, _xt, _b) = setup(4);
        let (r, _) = ctx.execute(&Route::Native, MatrixId(999), &[1.0], SolverChoice::Saa, 1e-6);
        assert!(matches!(r, Err(ServiceError::UnknownMatrix(999))));
        let (r2, _) = ctx.execute(&Route::Native, id, &[1.0, 2.0], SolverChoice::Saa, 1e-6);
        assert!(matches!(r2, Err(ServiceError::BadRequest(_))));
    }

    #[test]
    fn cache_eviction_fifo() {
        let (mut ctx, reg, metrics, _id, _xt, _b) = setup(2);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(88));
        let ids: Vec<MatrixId> = (0..3)
            .map(|_| reg.register(Matrix::Dense(DenseMatrix::gaussian(100, 6, &mut g))))
            .collect();
        let b = g.gaussian_vec(100);
        for &id in &ids {
            let (r, _) = ctx.execute(&Route::Native, id, &b, SolverChoice::Saa, 1e-8);
            r.unwrap();
        }
        assert_eq!(Metrics::get(&metrics.factor_cache_misses), 3);
        // First registered matrix was evicted (cap 2): re-solving misses.
        let (r, _) = ctx.execute(&Route::Native, ids[0], &b, SolverChoice::Saa, 1e-8);
        r.unwrap();
        assert_eq!(Metrics::get(&metrics.factor_cache_misses), 4);
    }
}
