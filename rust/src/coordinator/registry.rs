//! Matrix registry: clients register a design matrix once, then stream
//! right-hand sides against it. Shared, read-mostly state (RwLock).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::linalg::Matrix;

/// Opaque handle to a registered matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MatrixId(pub u64);

/// Thread-safe matrix store.
#[derive(Default)]
pub struct MatrixRegistry {
    next: AtomicU64,
    map: RwLock<HashMap<MatrixId, Arc<Matrix>>>,
}

impl MatrixRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a matrix; returns its handle.
    pub fn register(&self, m: Matrix) -> MatrixId {
        let id = MatrixId(self.next.fetch_add(1, Ordering::Relaxed));
        self.map.write().unwrap().insert(id, Arc::new(m));
        id
    }

    /// Insert a matrix at a caller-chosen id (router replication/handoff:
    /// the router allocates ids so replicas agree on them). Overwrites any
    /// existing entry — re-registration during rebalance is idempotent —
    /// and bumps the allocator past `id` so locally-registered matrices
    /// never collide with router-assigned ones.
    pub fn register_at(&self, id: MatrixId, m: Matrix) {
        self.next.fetch_max(id.0.saturating_add(1), Ordering::Relaxed);
        self.map.write().unwrap().insert(id, Arc::new(m));
    }

    pub fn get(&self, id: MatrixId) -> Option<Arc<Matrix>> {
        self.map.read().unwrap().get(&id).cloned()
    }

    /// Remove a matrix (outstanding Arc references stay valid).
    pub fn evict(&self, id: MatrixId) -> bool {
        self.map.write().unwrap().remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes held (dense: 8·m·n; sparse: 8·nnz + indices).
    pub fn resident_bytes(&self) -> usize {
        let g = self.map.read().unwrap();
        g.values()
            .map(|m| match m.as_ref() {
                Matrix::Dense(d) => d.rows() * d.cols() * 8,
                Matrix::Csr(c) => c.nnz() * 12 + (c.rows() + 1) * 8,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    #[test]
    fn register_get_evict() {
        let r = MatrixRegistry::new();
        assert!(r.is_empty());
        let id = r.register(Matrix::Dense(DenseMatrix::eye(3)));
        let id2 = r.register(Matrix::Dense(DenseMatrix::zeros(2, 2)));
        assert_ne!(id, id2);
        assert_eq!(r.len(), 2);
        assert_eq!(r.get(id).unwrap().shape(), (3, 3));
        assert!(r.evict(id));
        assert!(!r.evict(id));
        assert!(r.get(id).is_none());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn register_at_is_idempotent_and_bumps_allocator() {
        let r = MatrixRegistry::new();
        r.register_at(MatrixId(7), Matrix::Dense(DenseMatrix::eye(3)));
        // Overwrite is allowed (rebalance re-registration).
        r.register_at(MatrixId(7), Matrix::Dense(DenseMatrix::zeros(2, 2)));
        assert_eq!(r.get(MatrixId(7)).unwrap().shape(), (2, 2));
        assert_eq!(r.len(), 1);
        // Local allocation must skip past the pinned id.
        let id = r.register(Matrix::Dense(DenseMatrix::eye(2)));
        assert!(id.0 > 7, "allocator must jump past pinned ids, got {}", id.0);
    }

    #[test]
    fn arc_survives_eviction() {
        let r = MatrixRegistry::new();
        let id = r.register(Matrix::Dense(DenseMatrix::eye(4)));
        let held = r.get(id).unwrap();
        r.evict(id);
        assert_eq!(held.shape(), (4, 4));
    }

    #[test]
    fn resident_bytes_tracks() {
        let r = MatrixRegistry::new();
        assert_eq!(r.resident_bytes(), 0);
        r.register(Matrix::Dense(DenseMatrix::zeros(10, 10)));
        assert_eq!(r.resident_bytes(), 800);
    }

    #[test]
    fn concurrent_register() {
        let r = std::sync::Arc::new(MatrixRegistry::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    (0..50)
                        .map(|_| r.register(Matrix::Dense(DenseMatrix::eye(2))))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let mut all: Vec<MatrixId> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 400, "ids must be unique");
        assert_eq!(r.len(), 400);
    }
}
