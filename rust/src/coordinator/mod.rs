//! The solve-service coordinator — Layer 3's system contribution.
//!
//! A production least-squares service shaped like a vLLM-style router:
//!
//! ```text
//!  clients ──submit──▶ BoundedQueue (backpressure)
//!                         │
//!                   DynamicBatcher  (coalesce by matrix/bucket,
//!                         │          max_batch / max_wait)
//!                   ┌─────┴──────┐
//!                Worker 0 …  Worker K-1     (each owns a PJRT Engine +
//!                   │                        a per-matrix factor cache)
//!                   └──▶ Response channels, Metrics
//! ```
//!
//! * **Router** — maps problem shapes to execution routes: an exact-match
//!   AOT artifact bucket (PJRT executable) or the native f64 solvers.
//! * **Dynamic batcher** — requests against the *same registered matrix*
//!   share the sketch→QR factorization (the SAA analogue of prefix-cache
//!   reuse); unrelated requests are grouped to bound dispatch overhead.
//! * **Matrix registry** — clients register a design matrix once, then
//!   stream right-hand sides against it.
//! * **Backpressure** — the bounded queue rejects (or blocks) when workers
//!   fall behind; deadline-expired requests are failed, not solved.
//! * **Metrics** — counters and log-bucketed latency histograms.
//!
//! Python never appears anywhere on this path: workers execute AOT HLO via
//! PJRT or the native Rust solvers.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod router;
pub mod service;
pub mod shard;
pub mod tcp;
pub mod worker;

pub use registry::{MatrixId, MatrixRegistry};
pub use router::{Route, Router, ShardRouter, ShardRouterConfig};
pub use service::{Service, ServiceConfig};
pub use shard::ShardMap;

use crate::solvers::Solution;

/// Request identifier (unique per service instance).
pub type RequestId = u64;

/// How a request asks to be solved.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SolverChoice {
    /// SAA-SAS (the paper's algorithm) — default.
    Saa,
    /// Deterministic LSQR baseline.
    Lsqr,
    /// One-shot sketch-and-solve (cheap, coarse).
    SketchOnly,
    /// Forward-stable escalation ladder (sketch-and-solve → preconditioned
    /// LSQR → refinement sweeps → dense QR) — see [`crate::solvers::ladder`].
    Stable,
}

impl SolverChoice {
    pub fn name(self) -> &'static str {
        match self {
            SolverChoice::Saa => "saa",
            SolverChoice::Lsqr => "lsqr",
            SolverChoice::SketchOnly => "sketch-only",
            SolverChoice::Stable => "stable",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "saa" | "saa-sas" => Some(SolverChoice::Saa),
            "lsqr" => Some(SolverChoice::Lsqr),
            "sketch-only" | "sas" => Some(SolverChoice::SketchOnly),
            "stable" => Some(SolverChoice::Stable),
            _ => None,
        }
    }
}

/// Default solver when the caller leaves the choice blank (the `solve`
/// CLI and demo paths). `0xFF` = unset.
static SOLVER_CONFIGURED: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0xFF);

/// Set the process-wide default solver (`None` restores the ambient
/// env/default resolution). Highest-precedence layer of the
/// `--solver` / `SNSOLVE_SOLVER` / `[solver] solver` knob.
pub fn set_default_solver(choice: Option<SolverChoice>) {
    let code = match choice {
        Some(c) => protocol::solver_to_u8(c),
        None => 0xFF,
    };
    SOLVER_CONFIGURED.store(code, std::sync::atomic::Ordering::Relaxed);
}

fn env_default_solver() -> Option<SolverChoice> {
    static ENV: std::sync::OnceLock<Option<SolverChoice>> = std::sync::OnceLock::new();
    *ENV.get_or_init(|| {
        // snsolve-lint: allow(env-reads-behind-config) — this *is* the
        // config layer for SNSOLVE_SOLVER; precedence over it is enforced
        // in set_default_solver's callers (CLI flag, config file).
        std::env::var("SNSOLVE_SOLVER").ok().as_deref().and_then(SolverChoice::parse)
    })
}

/// Resolve the default solver: configured → env → SAA.
pub fn default_solver() -> SolverChoice {
    let code = SOLVER_CONFIGURED.load(std::sync::atomic::Ordering::Relaxed);
    if let Ok(c) = protocol::solver_from_u8(code) {
        return c;
    }
    env_default_solver().unwrap_or(SolverChoice::Saa)
}

/// A solve request: a registered matrix + a right-hand side.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub matrix: MatrixId,
    pub rhs: Vec<f64>,
    pub solver: SolverChoice,
    /// Relative tolerance the caller wants certified.
    pub tol: f64,
    /// Wall-clock deadline from submit, microseconds (0 = none).
    pub deadline_us: u64,
    /// Per-request refinement-sweep cap for the stable ladder
    /// (0 = defer to the server-side `--refine-iters` knob).
    pub refine_iters: usize,
}

/// Execution route actually taken (reported for observability).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecutedOn {
    /// PJRT artifact by name.
    Pjrt(String),
    /// Native Rust solver path.
    Native,
}

/// A solve response.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    pub id: RequestId,
    pub result: Result<Solution, ServiceError>,
    pub executed_on: ExecutedOn,
    /// Queue wait + solve time, microseconds.
    pub queue_us: u64,
    pub solve_us: u64,
}

/// Service-level failures.
#[derive(Debug, Clone)]
pub enum ServiceError {
    Overloaded,
    DeadlineExceeded,
    UnknownMatrix(u64),
    Solver(String),
    ShuttingDown,
    BadRequest(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Overloaded => write!(f, "queue full: the service is overloaded"),
            ServiceError::DeadlineExceeded => {
                write!(f, "deadline exceeded before completion")
            }
            ServiceError::UnknownMatrix(id) => write!(f, "unknown matrix id {id}"),
            ServiceError::Solver(m) => write!(f, "solver error: {m}"),
            ServiceError::ShuttingDown => write!(f, "service is shutting down"),
            ServiceError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}

impl std::error::Error for ServiceError {}
