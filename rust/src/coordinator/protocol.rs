//! Wire protocol for the TCP front-end: length-prefixed binary frames.
//!
//! Two protocol versions share one framing layer. Every connection starts
//! in **v1 (legacy)**: one logical request/response pair at a time, with
//! responses written in request order. A client that sends `HELLO` with
//! `version = 2` upgrades the connection to **v2 (pipelined)**: every
//! request frame carries a client-chosen `u64 request_id`, many requests
//! may be in flight on one socket, and responses complete **out of
//! order**, each tagged with the id of the request it answers. The `HELLO`
//! exchange itself is a v1 frame pair; the first v2-format frame is the
//! one after `OK_HELLO`.
//!
//! ```text
//! frame      := u32le payload_len, payload           (len ∈ [1, MAX_FRAME])
//!
//! v1 payload := u8 opcode, body
//! v2 payload := u8 opcode, u64le request_id, body    (requests AND responses)
//!
//! request opcodes (body grammar identical in v1 and v2):
//!   1 REGISTER_DENSE  := u32 m, u32 n, f64le[m*n] row-major
//!   2 SOLVE           := u64 matrix_id, u8 solver, f64 tol, u64 deadline_us,
//!                        u32 m, f64le[m] rhs [, u32 refine_iters]
//!                        (solver: 0 saa, 1 lsqr, 2 sketch-only, 3 stable;
//!                         the trailing refine_iters field is optional — absent
//!                         or 0 defers to the server-side knob)
//!   3 METRICS         := (empty)
//!   4 EVICT           := u64 matrix_id
//!   5 HELLO           := u8 version            (v1-format; version 2 = pipelined)
//!   6 REGISTER_AT     := u64 matrix_id, u32 m, u32 n, f64le[m*n] row-major
//!                        (router→shard replication: insert at a caller-chosen
//!                         id; idempotent — re-registering an id overwrites)
//!   7 FETCH_MATRIX    := u64 matrix_id        (router→shard handoff read-back)
//!   8 PING            := u64 epoch            (router heartbeat; epoch echoed)
//! response opcodes:
//!   128 OK_REGISTER   := u64 matrix_id
//!   129 OK_SOLVE      := u32 n, f64le[n] x, u32 iterations, f64 resnorm,
//!                        u8 converged, u64 queue_us, u64 solve_us
//!   130 OK_METRICS    := utf8 text
//!   131 OK_EVICT      := u8 existed
//!   132 OK_HELLO      := u8 version            (v1-format, even when upgrading)
//!   133 OK_MATRIX     := u32 m, u32 n, f64le[m*n] row-major
//!   134 OK_PING       := u64 epoch
//!   254 ERR_RETRYABLE := utf8 message          (transient: resend the same
//!                        request after a backoff — shard mid-rebalance, stale
//!                        epoch, or all replicas briefly unreachable)
//!   255 ERROR         := utf8 message          (permanent for this request)
//! ```
//!
//! v2 error scoping: a malformed frame whose opcode + request id still
//! decode fails **only that request** (an `ERROR` tagged with its id); a
//! frame too short to carry an id is answered with `ERROR` id 0; only a
//! broken framing layer (bad length prefix) tears down the connection,
//! because byte-stream resynchronization is impossible.
//!
//! Request ids are chosen by the client (uniqueness per connection is the
//! client's job — the reference client uses a counter starting at 1) and
//! echoed verbatim; the server never interprets them beyond routing.

use super::SolverChoice;

pub const OP_REGISTER_DENSE: u8 = 1;
pub const OP_SOLVE: u8 = 2;
pub const OP_METRICS: u8 = 3;
pub const OP_EVICT: u8 = 4;
pub const OP_HELLO: u8 = 5;
pub const OP_REGISTER_AT: u8 = 6;
pub const OP_FETCH_MATRIX: u8 = 7;
pub const OP_PING: u8 = 8;
pub const OP_OK_REGISTER: u8 = 128;
pub const OP_OK_SOLVE: u8 = 129;
pub const OP_OK_METRICS: u8 = 130;
pub const OP_OK_EVICT: u8 = 131;
pub const OP_OK_HELLO: u8 = 132;
pub const OP_OK_MATRIX: u8 = 133;
pub const OP_OK_PING: u8 = 134;
pub const OP_ERR_RETRYABLE: u8 = 254;
pub const OP_ERROR: u8 = 255;

/// The pipelined protocol version negotiated by `HELLO`.
pub const PROTO_V2: u8 = 2;

/// Max accepted frame: 1 GiB (a 8192×16384 f64 matrix).
pub const MAX_FRAME: usize = 1 << 30;

/// Incremental little-endian reader.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

#[derive(Debug)]
pub struct DecodeError(pub String);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "protocol decode error: {}", self.0)
    }
}

impl std::error::Error for DecodeError {}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.pos + n > self.buf.len() {
            return Err(DecodeError(format!(
                "truncated frame: need {n} bytes at {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64_vec(&mut self, count: usize) -> Result<Vec<f64>, DecodeError> {
        let bytes = self.take(count * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn rest_utf8(&mut self) -> Result<String, DecodeError> {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        String::from_utf8(s.to_vec()).map_err(|e| DecodeError(e.to_string()))
    }

    pub fn finished(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Frame writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new(opcode: u8) -> Self {
        let mut w = Writer { buf: Vec::with_capacity(64) };
        w.buf.push(opcode);
        w
    }

    pub fn u8(mut self, v: u8) -> Self {
        self.buf.push(v);
        self
    }

    pub fn u32(mut self, v: u32) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(mut self, v: u64) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(mut self, v: f64) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64_slice(mut self, vs: &[f64]) -> Self {
        self.buf.reserve(vs.len() * 8);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self
    }

    pub fn utf8(mut self, s: &str) -> Self {
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    /// Final frame bytes: u32 length prefix + opcode + payload.
    pub fn frame(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.buf.len() + 4);
        out.extend_from_slice(&(self.buf.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.buf);
        out
    }
}

/// Solver byte encoding.
pub fn solver_to_u8(s: SolverChoice) -> u8 {
    match s {
        SolverChoice::Saa => 0,
        SolverChoice::Lsqr => 1,
        SolverChoice::SketchOnly => 2,
        SolverChoice::Stable => 3,
    }
}

pub fn solver_from_u8(v: u8) -> Result<SolverChoice, DecodeError> {
    match v {
        0 => Ok(SolverChoice::Saa),
        1 => Ok(SolverChoice::Lsqr),
        2 => Ok(SolverChoice::SketchOnly),
        3 => Ok(SolverChoice::Stable),
        _ => Err(DecodeError(format!("unknown solver byte {v}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let frame = Writer::new(OP_SOLVE)
            .u64(7)
            .u8(solver_to_u8(SolverChoice::Lsqr))
            .f64(1e-8)
            .u64(0)
            .u32(3)
            .f64_slice(&[1.0, -2.0, 3.5])
            .frame();
        // strip prefix
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        let mut r = Reader::new(&frame[4..]);
        assert_eq!(r.u8().unwrap(), OP_SOLVE);
        assert_eq!(r.u64().unwrap(), 7);
        assert_eq!(solver_from_u8(r.u8().unwrap()).unwrap(), SolverChoice::Lsqr);
        assert_eq!(r.f64().unwrap(), 1e-8);
        assert_eq!(r.u64().unwrap(), 0);
        let m = r.u32().unwrap() as usize;
        assert_eq!(r.f64_vec(m).unwrap(), vec![1.0, -2.0, 3.5]);
        assert!(r.finished());
    }

    #[test]
    fn truncation_detected() {
        let frame = Writer::new(OP_SOLVE).u32(5).frame();
        let mut r = Reader::new(&frame[4..]);
        r.u8().unwrap();
        assert!(r.u64().is_err());
    }

    #[test]
    fn utf8_rest() {
        let frame = Writer::new(OP_ERROR).utf8("boom").frame();
        let mut r = Reader::new(&frame[4..]);
        assert_eq!(r.u8().unwrap(), OP_ERROR);
        assert_eq!(r.rest_utf8().unwrap(), "boom");
    }

    #[test]
    fn solver_codes_roundtrip() {
        for s in [
            SolverChoice::Saa,
            SolverChoice::Lsqr,
            SolverChoice::SketchOnly,
            SolverChoice::Stable,
        ] {
            assert_eq!(solver_from_u8(solver_to_u8(s)).unwrap(), s);
        }
        assert!(solver_from_u8(9).is_err());
    }
}
