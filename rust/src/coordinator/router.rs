//! Shape-bucket router: decides, per request, whether to dispatch to an
//! AOT PJRT artifact (exact shape match, dense matrix, SAA/LSQR entries)
//! or to the native f64 solver path (everything else).

use crate::linalg::Matrix;
use crate::runtime::Manifest;

use super::SolverChoice;

/// An execution route.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// Execute the named PJRT artifact.
    Artifact(String),
    /// Run the native Rust solver.
    Native,
}

/// Routing policy.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Disable the PJRT path entirely (native-only deployments).
    pub enable_pjrt: bool,
    /// Problems above this f32 condition-risk bound are routed native even
    /// when a bucket matches (the artifact path is f32; κ·ε₃₂ accuracy).
    pub max_pjrt_tol: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        // f32 path certifies ~1e-3 comfortably for the bucketed shapes.
        Self { enable_pjrt: true, max_pjrt_tol: 1e-3 }
    }
}

/// The router: manifest buckets + policy.
pub struct Router {
    buckets: Vec<(usize, usize)>,
    config: RouterConfig,
}

impl Router {
    pub fn new(manifest: Option<&Manifest>, config: RouterConfig) -> Self {
        let buckets = manifest.map(|m| m.buckets()).unwrap_or_default();
        Self { buckets, config }
    }

    /// Route a request for matrix `a` solved with `solver` to tolerance
    /// `tol`.
    pub fn route(&self, a: &Matrix, solver: SolverChoice, tol: f64) -> Route {
        if !self.config.enable_pjrt || self.buckets.is_empty() {
            return Route::Native;
        }
        // Sparse matrices and tight tolerances go native (f64, O(nnz)).
        if a.is_sparse() || tol < self.config.max_pjrt_tol {
            return Route::Native;
        }
        let (m, n) = a.shape();
        if !self.buckets.contains(&(m, n)) {
            return Route::Native;
        }
        let entry = match solver {
            SolverChoice::Saa => "saa_solve",
            SolverChoice::Lsqr => "lsqr_baseline",
            SolverChoice::SketchOnly => "sketch_and_solve_only",
        };
        Route::Artifact(format!("{entry}_{m}x{n}"))
    }

    /// The shape buckets this router can dispatch to PJRT.
    pub fn buckets(&self) -> &[(usize, usize)] {
        &self.buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::CooBuilder;
    use crate::linalg::DenseMatrix;
    use std::path::Path;

    fn manifest() -> Manifest {
        let json = r#"{"version":1,"artifacts":[
          {"name":"saa_solve_64x8","entry":"saa_solve","file":"f","m":64,"n":8,
           "s":32,"iters":8,"inputs":[],"outputs":[]},
          {"name":"lsqr_baseline_64x8","entry":"lsqr_baseline","file":"f","m":64,"n":8,
           "s":32,"iters":16,"inputs":[],"outputs":[]}
        ]}"#;
        Manifest::parse(Path::new("."), json).unwrap()
    }

    #[test]
    fn exact_bucket_routes_to_artifact() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig::default());
        let a = Matrix::Dense(DenseMatrix::zeros(64, 8));
        assert_eq!(
            r.route(&a, SolverChoice::Saa, 1e-2),
            Route::Artifact("saa_solve_64x8".into())
        );
        assert_eq!(
            r.route(&a, SolverChoice::Lsqr, 1e-2),
            Route::Artifact("lsqr_baseline_64x8".into())
        );
    }

    #[test]
    fn mismatched_shape_goes_native() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig::default());
        let a = Matrix::Dense(DenseMatrix::zeros(65, 8));
        assert_eq!(r.route(&a, SolverChoice::Saa, 1e-2), Route::Native);
    }

    #[test]
    fn sparse_and_tight_tolerance_go_native() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig::default());
        let mut b = CooBuilder::new(64, 8);
        b.push(0, 0, 1.0);
        let sp = Matrix::Csr(b.build());
        assert_eq!(r.route(&sp, SolverChoice::Saa, 1e-2), Route::Native);
        let a = Matrix::Dense(DenseMatrix::zeros(64, 8));
        assert_eq!(r.route(&a, SolverChoice::Saa, 1e-10), Route::Native);
    }

    #[test]
    fn pjrt_disabled_goes_native() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig { enable_pjrt: false, ..Default::default() });
        let a = Matrix::Dense(DenseMatrix::zeros(64, 8));
        assert_eq!(r.route(&a, SolverChoice::Saa, 1e-2), Route::Native);
        let r2 = Router::new(None, RouterConfig::default());
        assert_eq!(r2.route(&a, SolverChoice::Saa, 1e-2), Route::Native);
    }
}
