//! Routing, two tiers.
//!
//! **Shape-bucket router** ([`Router`]): decides, per request, whether to
//! dispatch to an AOT PJRT artifact (exact shape match, dense matrix,
//! SAA/LSQR entries) or to the native f64 solver path (everything else).
//!
//! **Shard router** ([`ShardRouter`]): a multi-node front-end tier.
//! Clients speak the ordinary v1/v2 wire protocol to the router, which
//! owns a consistent-hash [`ShardMap`] over a fixed list of coordinator
//! processes and forwards each request to the shards that own its matrix:
//!
//! * `OP_REGISTER_DENSE` allocates a cluster-wide id and replicates the
//!   matrix to all `R` owners (`OP_REGISTER_AT`, so every replica agrees
//!   on the id).
//! * `OP_SOLVE` forwards to the primary owner with exponential backoff
//!   and a deadline-aware per-attempt timeout; transient failures retry
//!   the same shard, a dead or stale shard fails over to the next
//!   replica, and an exhausted candidate list answers with the typed
//!   `OP_ERR_RETRYABLE` frame — an accepted request id is **never**
//!   silently dropped.
//! * `OP_METRICS` aggregates every alive shard's report
//!   ([`aggregate_reports`]) and appends the router's own counter line.
//!
//! A heartbeat thread pings each shard every `heartbeat_ms`; aliveness
//! transitions bump the map epoch. A shard coming back (typically a
//! restarted, empty process) triggers a **rebalance**: the router streams
//! each affected matrix from a surviving replica (`OP_FETCH_MATRIX`) and
//! re-registers it on the shards the map wants it on.
//!
//! Outbound shard links are [`PipelinedClient`]s labeled with the shard
//! address, so a seeded [`crate::testing::FaultPlan`] network fault plan
//! (drop / delay / sever per opcode and frame window) applies to the
//! router's wire path deterministically in tests.

use std::collections::{BTreeMap, HashMap};
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::linalg::{DenseMatrix, Matrix};
use crate::runtime::Manifest;

use super::metrics::{aggregate_reports, Metrics};
use super::protocol::*;
use super::registry::MatrixId;
use super::shard::ShardMap;
use super::tcp::{
    accept_retry_backoff, decode_register, decode_solve, error_frame, read_frame, retag_v2,
    retryable_frame, write_frame, ClientError, PipelinedClient, WireSolution,
};
use super::{SolveRequest, SolverChoice};

// ----------------------------------------------------------------------
// Shape-bucket router (single-process dispatch)
// ----------------------------------------------------------------------

/// An execution route.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// Execute the named PJRT artifact.
    Artifact(String),
    /// Run the native Rust solver.
    Native,
}

/// Routing policy.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Disable the PJRT path entirely (native-only deployments).
    pub enable_pjrt: bool,
    /// Problems above this f32 condition-risk bound are routed native even
    /// when a bucket matches (the artifact path is f32; κ·ε₃₂ accuracy).
    pub max_pjrt_tol: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        // f32 path certifies ~1e-3 comfortably for the bucketed shapes.
        Self { enable_pjrt: true, max_pjrt_tol: 1e-3 }
    }
}

/// The router: manifest buckets + policy.
pub struct Router {
    buckets: Vec<(usize, usize)>,
    config: RouterConfig,
}

impl Router {
    pub fn new(manifest: Option<&Manifest>, config: RouterConfig) -> Self {
        let buckets = manifest.map(|m| m.buckets()).unwrap_or_default();
        Self { buckets, config }
    }

    /// Route a request for matrix `a` solved with `solver` to tolerance
    /// `tol`.
    pub fn route(&self, a: &Matrix, solver: SolverChoice, tol: f64) -> Route {
        if !self.config.enable_pjrt || self.buckets.is_empty() {
            return Route::Native;
        }
        // Sparse matrices and tight tolerances go native (f64, O(nnz)).
        if a.is_sparse() || tol < self.config.max_pjrt_tol {
            return Route::Native;
        }
        let (m, n) = a.shape();
        if !self.buckets.contains(&(m, n)) {
            return Route::Native;
        }
        let entry = match solver {
            SolverChoice::Saa => "saa_solve",
            SolverChoice::Lsqr => "lsqr_baseline",
            SolverChoice::SketchOnly => "sketch_and_solve_only",
            // The condition-driven fallback ladder is native-only: its
            // escalation evidence needs the f64 path.
            SolverChoice::Stable => return Route::Native,
        };
        Route::Artifact(format!("{entry}_{m}x{n}"))
    }

    /// The shape buckets this router can dispatch to PJRT.
    pub fn buckets(&self) -> &[(usize, usize)] {
        &self.buckets
    }
}

// ----------------------------------------------------------------------
// Shard router: retry/backoff policy (pure, unit-tested)
// ----------------------------------------------------------------------

/// Same-shard retries per request before giving up on that shard and
/// failing over to the next replica.
pub const MAX_ATTEMPTS_PER_SHARD: u32 = 3;

/// Socket error kinds worth retrying **on the same shard**: transient
/// mid-connection failures where the process is probably still there.
/// `ConnectionRefused` is deliberately absent — nothing is listening, so
/// the right move is failover, not hammering a dead address.
pub fn retryable_io(kind: io::ErrorKind) -> bool {
    use io::ErrorKind::*;
    matches!(
        kind,
        ConnectionReset
            | ConnectionAborted
            | BrokenPipe
            | TimedOut
            | UnexpectedEof
            | Interrupted
            | NotConnected
            | WouldBlock
    )
}

/// What the forwarding loop should do with a failed attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Disposition {
    /// Transient: resend to the same shard after a backoff.
    RetrySameShard,
    /// This shard can't serve the request (down, or doesn't hold the
    /// matrix yet): move to the next replica.
    Failover,
    /// A real server-side failure — surface it to the client unchanged;
    /// retrying elsewhere would just repeat it.
    Fatal,
}

/// Classify one failed forwarding attempt.
pub fn classify(e: &ClientError) -> Disposition {
    match e {
        ClientError::Retryable(_) => Disposition::RetrySameShard,
        ClientError::Io(e) if retryable_io(e.kind()) => Disposition::RetrySameShard,
        ClientError::Io(_) => Disposition::Failover,
        // A replica that predates the handoff doesn't know the matrix yet.
        ClientError::Server(m) if m.contains("unknown matrix") => Disposition::Failover,
        ClientError::Server(_) | ClientError::Decode(_) | ClientError::UnexpectedOpcode(_) => {
            Disposition::Fatal
        }
    }
}

/// Exponential backoff before same-shard retry number `retry` (0-based):
/// `base · 2^retry`, saturating, capped. Pure and deterministic — the
/// actual sleep additionally clamps to the remaining deadline budget.
pub fn backoff_ms(base_ms: u64, retry: u32, cap_ms: u64) -> u64 {
    base_ms.saturating_mul(1u64 << retry.min(16)).min(cap_ms)
}

/// How long one attempt may wait for its shard response: the per-attempt
/// timeout, clamped to the remaining deadline budget so retries can never
/// overrun the request's end-to-end budget.
pub fn attempt_wait(remaining: Duration, attempt_timeout_ms: u64) -> Duration {
    remaining.min(Duration::from_millis(attempt_timeout_ms))
}

// ----------------------------------------------------------------------
// Shard router: configuration and state
// ----------------------------------------------------------------------

/// Shard-router tier configuration.
#[derive(Debug, Clone)]
pub struct ShardRouterConfig {
    /// Shard addresses (`host:port` of `snsolve serve` processes). Shard
    /// identity is the index into this list.
    pub shards: Vec<String>,
    /// Replication factor `R`: every registered matrix lives on the first
    /// `R` distinct alive shards clockwise on the ring (clamped to the
    /// cluster size).
    pub replication: usize,
    /// Heartbeat period (and per-ping timeout floor), milliseconds.
    pub heartbeat_ms: u64,
    /// Base of the exponential same-shard retry backoff, milliseconds.
    pub retry_base_ms: u64,
    /// Backoff cap, milliseconds.
    pub retry_cap_ms: u64,
    /// Per-attempt shard response timeout, milliseconds (clamped to the
    /// remaining deadline budget).
    pub attempt_timeout_ms: u64,
    /// Router-side end-to-end budget for solves that arrive without a
    /// deadline (`deadline_us == 0`), microseconds.
    pub default_deadline_us: u64,
}

impl ShardRouterConfig {
    pub fn new(shards: Vec<String>, replication: usize) -> Self {
        Self {
            shards,
            replication,
            heartbeat_ms: 200,
            retry_base_ms: 10,
            retry_cap_ms: 250,
            attempt_timeout_ms: 500,
            default_deadline_us: 2_000_000,
        }
    }
}

struct CatalogEntry {
    /// Shards confirmed to hold this matrix (registration acks plus
    /// rebalance repairs, minus death-time prunes).
    holders: Vec<usize>,
}

struct Inner {
    cfg: ShardRouterConfig,
    map: Mutex<ShardMap>,
    /// One lazily-connected pipelined link per shard. Lock order: never
    /// hold `map`/`catalog` while taking a conn lock.
    conns: Vec<Mutex<Option<PipelinedClient>>>,
    /// Cluster-wide matrix catalog (ids the router allocated).
    catalog: Mutex<BTreeMap<u64, CatalogEntry>>,
    next_id: AtomicU64,
    metrics: Metrics,
    stop: AtomicBool,
}

/// A running shard-router front-end.
pub struct ShardRouter {
    addr: SocketAddr,
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
    heartbeat: Option<JoinHandle<()>>,
    client_conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ShardRouter {
    /// Bind the router front-end on `addr` (port 0 for ephemeral) and
    /// start its accept and heartbeat threads. Shard links are dialed
    /// lazily — shards may come up after the router.
    pub fn serve(addr: impl ToSocketAddrs, cfg: ShardRouterConfig) -> io::Result<ShardRouter> {
        if cfg.shards.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "shard router needs at least one shard address",
            ));
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let map = ShardMap::new(cfg.shards.clone(), cfg.replication);
        let n = cfg.shards.len();
        let inner = Arc::new(Inner {
            map: Mutex::new(map),
            conns: (0..n).map(|_| Mutex::new(None)).collect(),
            catalog: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            metrics: Metrics::new(),
            stop: AtomicBool::new(false),
            cfg,
        });
        let client_conns: Arc<Mutex<HashMap<u64, TcpStream>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let inner2 = inner.clone();
        let cc = client_conns.clone();
        let ct = conn_threads.clone();
        let accept = std::thread::Builder::new()
            .name("sns-router-accept".into())
            .spawn(move || accept_loop(&listener, &inner2, &cc, &ct))?;

        let inner2 = inner.clone();
        let heartbeat = std::thread::Builder::new()
            .name("sns-router-heartbeat".into())
            .spawn(move || heartbeat_loop(&inner2))?;

        Ok(ShardRouter {
            addr: local,
            inner,
            accept: Some(accept),
            heartbeat: Some(heartbeat),
            client_conns,
            conn_threads,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, sever every client connection, and join all router
    /// threads (shard links drop with the router, joining their readers).
    pub fn stop(mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        for (_, s) in self.client_conns.lock().unwrap().drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        if let Some(t) = self.heartbeat.take() {
            let _ = t.join();
        }
        for h in self.conn_threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        for c in self.inner.conns.iter() {
            c.lock().unwrap().take();
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        // A router dropped without stop() still winds its threads down:
        // they all watch this flag with bounded waits.
        self.inner.stop.store(true, Ordering::Relaxed);
    }
}

// ----------------------------------------------------------------------
// Front-end: accept + per-connection loops
// ----------------------------------------------------------------------

fn accept_loop(
    listener: &TcpListener,
    inner: &Arc<Inner>,
    client_conns: &Arc<Mutex<HashMap<u64, TcpStream>>>,
    conn_threads: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let mut next: u64 = 1;
    while !inner.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let id = next;
                next += 1;
                if let Ok(clone) = stream.try_clone() {
                    client_conns.lock().unwrap().insert(id, clone);
                }
                let inner2 = inner.clone();
                let cc = client_conns.clone();
                let spawned = std::thread::Builder::new()
                    .name("sns-router-conn".into())
                    .spawn(move || conn_loop(&inner2, stream, id, &cc));
                match spawned {
                    Ok(h) => conn_threads.lock().unwrap().push(h),
                    Err(e) => {
                        eprintln!("router: connection thread spawn failed: {e}");
                        client_conns.lock().unwrap().remove(&id);
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => match accept_retry_backoff(&e) {
                Some(backoff) => std::thread::sleep(backoff),
                None => {
                    eprintln!("router: fatal accept error: {e}");
                    break;
                }
            },
        }
    }
}

/// One client connection: v1 requests are served synchronously (the legacy
/// in-order contract for free); after a HELLO upgrade, solves run on their
/// own forwarding threads and complete out of order, serialized onto the
/// socket through a shared write lock.
fn conn_loop(
    inner: &Arc<Inner>,
    stream: TcpStream,
    conn_id: u64,
    client_conns: &Arc<Mutex<HashMap<u64, TcpStream>>>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_nonblocking(false);
    let mut rstream = stream;
    if let Ok(w) = rstream.try_clone() {
        let wstream = Arc::new(Mutex::new(w));
        let mut proto = 1u8;
        let mut solvers: Vec<JoinHandle<()>> = Vec::new();
        while let Ok(Some(payload)) = read_frame(&mut rstream) {
            let ok = if proto == PROTO_V2 {
                handle_conn_v2(inner, &payload, &wstream, &mut solvers)
            } else {
                handle_conn_v1(inner, &payload, &wstream, &mut proto)
            };
            if !ok {
                break;
            }
        }
        for h in solvers {
            let _ = h.join();
        }
    }
    client_conns.lock().unwrap().remove(&conn_id);
}

/// Serve one v1 frame synchronously. Returns false when the connection is
/// done (write failure).
fn handle_conn_v1(
    inner: &Arc<Inner>,
    payload: &[u8],
    wstream: &Arc<Mutex<TcpStream>>,
    proto: &mut u8,
) -> bool {
    let mut r = Reader::new(payload);
    let resp = match r.u8() {
        Ok(OP_HELLO) => match r.u8() {
            Ok(v) if v >= PROTO_V2 => {
                *proto = PROTO_V2;
                Writer::new(OP_OK_HELLO).u8(PROTO_V2).frame()
            }
            Ok(_) => Writer::new(OP_OK_HELLO).u8(1).frame(),
            Err(e) => error_frame(&e.to_string()),
        },
        Ok(OP_SOLVE) => match decode_solve(&mut r) {
            Ok(req) => forward_solve(inner, &req),
            Err(e) => error_frame(&e.to_string()),
        },
        Ok(op) => router_inline(inner, op, &mut r),
        Err(e) => error_frame(&e.to_string()),
    };
    write_frame(&mut wstream.lock().unwrap(), &resp).is_ok()
}

/// Serve one v2 frame. Solves are spawned; everything else answers inline.
/// Returns false when the connection is done (write failure).
fn handle_conn_v2(
    inner: &Arc<Inner>,
    payload: &[u8],
    wstream: &Arc<Mutex<TcpStream>>,
    solvers: &mut Vec<JoinHandle<()>>,
) -> bool {
    let mut r = Reader::new(payload);
    let Ok(op) = r.u8() else {
        return true; // unreachable: frames have at least one byte
    };
    let id = match r.u64() {
        Ok(id) => id,
        Err(e) => {
            // Too short to carry a request id: ERROR tagged with id 0.
            let f = retag_v2(error_frame(&e.to_string()), 0);
            return write_frame(&mut wstream.lock().unwrap(), &f).is_ok();
        }
    };
    if op == OP_SOLVE {
        match decode_solve(&mut r) {
            Ok(req) => {
                let inner2 = inner.clone();
                let ws = wstream.clone();
                let spawned = std::thread::Builder::new()
                    .name("sns-router-solve".into())
                    .spawn(move || {
                        let resp = forward_solve(&inner2, &req);
                        let _ = write_frame(&mut ws.lock().unwrap(), &retag_v2(resp, id));
                    });
                match spawned {
                    Ok(h) => {
                        solvers.push(h);
                        return true;
                    }
                    Err(e) => {
                        let f = retag_v2(error_frame(&format!("router spawn failed: {e}")), id);
                        return write_frame(&mut wstream.lock().unwrap(), &f).is_ok();
                    }
                }
            }
            Err(e) => {
                let f = retag_v2(error_frame(&e.to_string()), id);
                return write_frame(&mut wstream.lock().unwrap(), &f).is_ok();
            }
        }
    }
    let resp = if op == OP_HELLO {
        Writer::new(OP_OK_HELLO).u8(PROTO_V2).frame()
    } else {
        router_inline(inner, op, &mut r)
    };
    write_frame(&mut wstream.lock().unwrap(), &retag_v2(resp, id)).is_ok()
}

/// Non-solve requests answered on the connection thread. Returns a v1
/// response frame; v2 connections retag it with the request id.
fn router_inline(inner: &Inner, op: u8, r: &mut Reader) -> Vec<u8> {
    match op {
        OP_REGISTER_DENSE => match decode_register(r) {
            Ok(Matrix::Dense(d)) => register_cluster(inner, &d),
            Ok(Matrix::Csr(_)) => error_frame("router registration supports dense matrices only"),
            Err(e) => error_frame(&e.to_string()),
        },
        OP_METRICS => cluster_metrics(inner),
        OP_EVICT => match r.u64() {
            Ok(id) => evict_cluster(inner, id),
            Err(e) => error_frame(&e.to_string()),
        },
        OP_PING => match r.u64() {
            Ok(epoch) => Writer::new(OP_OK_PING).u64(epoch).frame(),
            Err(e) => error_frame(&e.to_string()),
        },
        other => error_frame(&format!("unknown opcode {other} at router")),
    }
}

// ----------------------------------------------------------------------
// Shard links
// ----------------------------------------------------------------------

/// Run `f` against the shard's pipelined link, dialing it first if needed.
/// An `Io` failure poisons the link (the next call redials); the fault
/// target label makes seeded network faults address this shard by name.
fn with_conn<T>(
    inner: &Inner,
    shard: usize,
    f: impl FnOnce(&mut PipelinedClient) -> Result<T, ClientError>,
) -> Result<T, ClientError> {
    let addr = { inner.map.lock().unwrap().addr(shard).to_string() };
    let mut guard = inner.conns[shard].lock().unwrap();
    if guard.is_none() {
        let mut c = PipelinedClient::connect(addr.as_str())?;
        c.set_fault_target(addr.as_str());
        *guard = Some(c);
    }
    let out = f(guard.as_mut().expect("connected above"));
    if matches!(out, Err(ClientError::Io(_))) {
        *guard = None;
    }
    out
}

// ----------------------------------------------------------------------
// Forwarding
// ----------------------------------------------------------------------

fn ok_solve_frame(s: &WireSolution) -> Vec<u8> {
    Writer::new(OP_OK_SOLVE)
        .u32(s.x.len() as u32)
        .f64_slice(&s.x)
        .u32(s.iterations as u32)
        .f64(s.resnorm)
        .u8(s.converged as u8)
        .u64(s.queue_us)
        .u64(s.solve_us)
        .frame()
}

/// Forward one solve to the cluster. Candidate shards are the map's
/// current owners plus any alive catalog holders (covers requests racing
/// a membership change). The loop retries transient failures on the same
/// shard with exponential backoff, fails over on dead/stale shards, and
/// every wait is clamped to the request's deadline budget. Exhausting the
/// budget or the candidates yields the typed retryable frame — never a
/// silent drop.
fn forward_solve(inner: &Inner, req: &SolveRequest) -> Vec<u8> {
    let budget =
        if req.deadline_us > 0 { req.deadline_us } else { inner.cfg.default_deadline_us };
    let deadline = Instant::now() + Duration::from_micros(budget);
    let mut candidates = { inner.map.lock().unwrap().owners(req.matrix) };
    let holders: Vec<usize> = {
        inner
            .catalog
            .lock()
            .unwrap()
            .get(&req.matrix.0)
            .map(|e| e.holders.clone())
            .unwrap_or_default()
    };
    {
        let map = inner.map.lock().unwrap();
        for s in holders {
            if map.is_alive(s) && !candidates.contains(&s) {
                candidates.push(s);
            }
        }
    }
    if candidates.is_empty() {
        return retryable_frame("no alive shard owns this matrix; resend after backoff");
    }
    let mut fatal: Option<String> = None;
    'candidates: for (ci, &shard) in candidates.iter().enumerate() {
        if ci > 0 {
            Metrics::inc(&inner.metrics.router_failovers);
        }
        let mut retry: u32 = 0;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return retryable_frame(
                    "deadline budget exhausted while retrying; resend after backoff",
                );
            }
            if retry > 0 {
                Metrics::inc(&inner.metrics.router_retries);
            }
            let submitted = with_conn(inner, shard, |c| {
                c.submit_solve_opts(
                    req.matrix.0,
                    &req.rhs,
                    req.solver,
                    req.tol,
                    req.deadline_us,
                    req.refine_iters,
                )
            });
            let failure: Option<ClientError> = match submitted {
                Ok(mut ticket) => {
                    // Wait outside the conn lock so other requests keep
                    // pipelining onto this shard.
                    match ticket.wait_timeout(attempt_wait(
                        remaining,
                        inner.cfg.attempt_timeout_ms,
                    )) {
                        Some(Ok(sol)) => return ok_solve_frame(&sol),
                        Some(Err(e)) => {
                            if matches!(e, ClientError::Io(_)) {
                                inner.conns[shard].lock().unwrap().take();
                            }
                            Some(e)
                        }
                        // Attempt timed out (response may be dropped by a
                        // fault plan, or the shard is wedged): resend.
                        None => None,
                    }
                }
                Err(e) => Some(e),
            };
            let disp = match &failure {
                None => Disposition::RetrySameShard,
                Some(e) => classify(e),
            };
            match disp {
                Disposition::RetrySameShard => {
                    retry += 1;
                    if retry >= MAX_ATTEMPTS_PER_SHARD {
                        continue 'candidates;
                    }
                    let base = inner.cfg.retry_base_ms;
                    let ms = backoff_ms(base, retry - 1, inner.cfg.retry_cap_ms);
                    let rem = deadline.saturating_duration_since(Instant::now());
                    std::thread::sleep(Duration::from_millis(ms).min(rem));
                }
                Disposition::Failover => continue 'candidates,
                Disposition::Fatal => {
                    fatal = failure.map(|e| e.to_string());
                    break 'candidates;
                }
            }
        }
    }
    match fatal {
        Some(m) => error_frame(&m),
        None => retryable_frame(
            "every replica unavailable (membership change in progress); resend after backoff",
        ),
    }
}

// ----------------------------------------------------------------------
// Cluster operations (register / metrics / evict)
// ----------------------------------------------------------------------

/// Allocate a cluster-wide id and replicate the matrix to all `R` owners.
/// One confirmed replica is enough to answer OK — the rebalance path heals
/// under-replication as soon as the missing owners are reachable again.
fn register_cluster(inner: &Inner, a: &DenseMatrix) -> Vec<u8> {
    let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
    let owners = { inner.map.lock().unwrap().owners(MatrixId(id)) };
    if owners.is_empty() {
        return retryable_frame("no shard alive to accept registration; resend after backoff");
    }
    let (m, n) = (a.rows() as u32, a.cols() as u32);
    let mut holders = Vec::with_capacity(owners.len());
    for &shard in &owners {
        if with_conn(inner, shard, |c| c.register_at(id, m, n, a.data())).is_ok() {
            holders.push(shard);
        }
    }
    if holders.is_empty() {
        return retryable_frame("registration failed on every owner; resend after backoff");
    }
    inner.catalog.lock().unwrap().insert(id, CatalogEntry { holders });
    Writer::new(OP_OK_REGISTER).u64(id).frame()
}

/// Aggregate every alive shard's metrics report and append the router's
/// own counter line (`retries`/`failovers`/`rebalance_matrices` plus the
/// membership epoch), so one `OP_METRICS` shows the whole cluster.
fn cluster_metrics(inner: &Inner) -> Vec<u8> {
    let (total, alive_shards, epoch) = {
        let m = inner.map.lock().unwrap();
        let alive: Vec<usize> = (0..m.len()).filter(|&s| m.is_alive(s)).collect();
        (m.len(), alive, m.epoch())
    };
    let mut reports = Vec::new();
    for &shard in &alive_shards {
        if let Ok(rep) = with_conn(inner, shard, |c| c.metrics()) {
            reports.push(rep);
        }
    }
    let mut body = aggregate_reports(&reports);
    let line = format!(
        "router: shards={total} alive={} epoch={epoch} retries={} failovers={} \
         rebalance_matrices={}",
        alive_shards.len(),
        Metrics::get(&inner.metrics.router_retries),
        Metrics::get(&inner.metrics.router_failovers),
        Metrics::get(&inner.metrics.router_rebalanced),
    );
    if !body.is_empty() {
        body.push('\n');
    }
    body.push_str(&line);
    Writer::new(OP_OK_METRICS).utf8(&body).frame()
}

/// Evict from every holder (or every shard when the id is unknown to the
/// catalog — it may have been registered directly against a shard).
fn evict_cluster(inner: &Inner, id: u64) -> Vec<u8> {
    let holders = inner
        .catalog
        .lock()
        .unwrap()
        .remove(&id)
        .map(|e| e.holders)
        .unwrap_or_else(|| (0..inner.conns.len()).collect());
    let mut existed = false;
    for shard in holders {
        if let Ok(b) = with_conn(inner, shard, |c| c.evict(id)) {
            existed |= b;
        }
    }
    Writer::new(OP_OK_EVICT).u8(existed as u8).frame()
}

// ----------------------------------------------------------------------
// Heartbeat + rebalance
// ----------------------------------------------------------------------

fn heartbeat_loop(inner: &Arc<Inner>) {
    while !inner.stop.load(Ordering::Relaxed) {
        for shard in 0..inner.conns.len() {
            if inner.stop.load(Ordering::Relaxed) {
                return;
            }
            let epoch = { inner.map.lock().unwrap().epoch() };
            let timeout = Duration::from_millis(inner.cfg.heartbeat_ms.max(50));
            let up = with_conn(inner, shard, |c| c.ping_timeout(epoch, timeout)).is_ok();
            let transition = { inner.map.lock().unwrap().set_alive(shard, up) };
            if !transition {
                continue;
            }
            if up {
                // A shard coming back is typically a restarted process
                // with an empty registry: re-seed it from the survivors.
                rebalance(inner);
            } else {
                // Poison the link and forget the dead shard's holdings;
                // the map already routes its keys to the live replicas.
                inner.conns[shard].lock().unwrap().take();
                let mut cat = inner.catalog.lock().unwrap();
                for e in cat.values_mut() {
                    e.holders.retain(|&s| s != shard);
                }
            }
        }
        let mut waited = 0u64;
        while waited < inner.cfg.heartbeat_ms && !inner.stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(20));
            waited += 20;
        }
    }
}

/// Repair placement after a membership change: for every cataloged matrix,
/// stream it from a surviving holder onto each alive shard the map wants
/// it on that doesn't hold it yet.
fn rebalance(inner: &Inner) {
    let ids: Vec<u64> = { inner.catalog.lock().unwrap().keys().copied().collect() };
    for id in ids {
        let desired = { inner.map.lock().unwrap().owners(MatrixId(id)) };
        let holders: Vec<usize> = {
            match inner.catalog.lock().unwrap().get(&id) {
                Some(e) => e.holders.clone(),
                None => continue, // evicted meanwhile
            }
        };
        for &target in desired.iter().filter(|t| !holders.contains(t)) {
            let mut fetched = None;
            for &h in &holders {
                if let Ok(t) = with_conn(inner, h, |c| c.fetch_matrix(id)) {
                    fetched = Some(t);
                    break;
                }
            }
            let Some((m, n, data)) = fetched else {
                continue; // no reachable holder; retry on the next transition
            };
            if with_conn(inner, target, |c| c.register_at(id, m, n, &data)).is_ok() {
                if let Some(e) = inner.catalog.lock().unwrap().get_mut(&id) {
                    if !e.holders.contains(&target) {
                        e.holders.push(target);
                    }
                }
                Metrics::inc(&inner.metrics.router_rebalanced);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::CooBuilder;
    use std::path::Path;

    fn manifest() -> Manifest {
        let json = r#"{"version":1,"artifacts":[
          {"name":"saa_solve_64x8","entry":"saa_solve","file":"f","m":64,"n":8,
           "s":32,"iters":8,"inputs":[],"outputs":[]},
          {"name":"lsqr_baseline_64x8","entry":"lsqr_baseline","file":"f","m":64,"n":8,
           "s":32,"iters":16,"inputs":[],"outputs":[]}
        ]}"#;
        Manifest::parse(Path::new("."), json).unwrap()
    }

    #[test]
    fn exact_bucket_routes_to_artifact() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig::default());
        let a = Matrix::Dense(DenseMatrix::zeros(64, 8));
        assert_eq!(
            r.route(&a, SolverChoice::Saa, 1e-2),
            Route::Artifact("saa_solve_64x8".into())
        );
        assert_eq!(
            r.route(&a, SolverChoice::Lsqr, 1e-2),
            Route::Artifact("lsqr_baseline_64x8".into())
        );
        // The stable ladder needs the native f64 path even on a bucket hit.
        assert_eq!(r.route(&a, SolverChoice::Stable, 1e-2), Route::Native);
    }

    #[test]
    fn mismatched_shape_goes_native() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig::default());
        let a = Matrix::Dense(DenseMatrix::zeros(65, 8));
        assert_eq!(r.route(&a, SolverChoice::Saa, 1e-2), Route::Native);
    }

    #[test]
    fn sparse_and_tight_tolerance_go_native() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig::default());
        let mut b = CooBuilder::new(64, 8);
        b.push(0, 0, 1.0);
        let sp = Matrix::Csr(b.build());
        assert_eq!(r.route(&sp, SolverChoice::Saa, 1e-2), Route::Native);
        let a = Matrix::Dense(DenseMatrix::zeros(64, 8));
        assert_eq!(r.route(&a, SolverChoice::Saa, 1e-10), Route::Native);
    }

    #[test]
    fn pjrt_disabled_goes_native() {
        let m = manifest();
        let r = Router::new(Some(&m), RouterConfig { enable_pjrt: false, ..Default::default() });
        let a = Matrix::Dense(DenseMatrix::zeros(64, 8));
        assert_eq!(r.route(&a, SolverChoice::Saa, 1e-2), Route::Native);
        let r2 = Router::new(None, RouterConfig::default());
        assert_eq!(r2.route(&a, SolverChoice::Saa, 1e-2), Route::Native);
    }

    #[test]
    fn retry_classification_table() {
        use io::ErrorKind::*;
        // Transient mid-connection failures: resend to the same shard.
        for k in [
            ConnectionReset,
            ConnectionAborted,
            BrokenPipe,
            TimedOut,
            UnexpectedEof,
            Interrupted,
            NotConnected,
            WouldBlock,
        ] {
            assert!(retryable_io(k), "{k:?} must be same-shard retryable");
            assert_eq!(
                classify(&ClientError::Io(io::Error::new(k, "x"))),
                Disposition::RetrySameShard
            );
        }
        // Nothing listening: fail over instead of hammering a dead address.
        assert!(!retryable_io(ConnectionRefused));
        assert_eq!(
            classify(&ClientError::Io(io::Error::new(ConnectionRefused, "x"))),
            Disposition::Failover
        );
        // Typed retryable from a shard caught mid-rebalance.
        assert_eq!(
            classify(&ClientError::Retryable("rebalancing".into())),
            Disposition::RetrySameShard
        );
        // A replica that predates the handoff doesn't know the matrix yet.
        assert_eq!(
            classify(&ClientError::Server("unknown matrix id 7".into())),
            Disposition::Failover
        );
        // Real server-side failures surface to the client unchanged.
        assert_eq!(classify(&ClientError::Server("solver blew up".into())), Disposition::Fatal);
        assert_eq!(classify(&ClientError::UnexpectedOpcode(9)), Disposition::Fatal);
    }

    #[test]
    fn backoff_schedule_deterministic_and_capped() {
        let s: Vec<u64> = (0..8).map(|a| backoff_ms(10, a, 250)).collect();
        assert_eq!(s, vec![10, 20, 40, 80, 160, 250, 250, 250]);
        // Determinism: same inputs, same schedule.
        assert_eq!(s, (0..8).map(|a| backoff_ms(10, a, 250)).collect::<Vec<_>>());
        // Huge retry counts neither overflow nor exceed the cap.
        assert_eq!(backoff_ms(10, 63, 250), 250);
        assert_eq!(backoff_ms(u64::MAX, 3, 250), 250);
        assert_eq!(backoff_ms(0, 5, 250), 0);
    }

    #[test]
    fn retry_budget_never_exceeded() {
        // The forward path's arithmetic: attempt waits and backoff sleeps
        // are always clamped to the remaining budget, so their total can
        // never exceed it no matter how many retries run.
        let budget = Duration::from_millis(100);
        let mut spent = Duration::ZERO;
        let mut retry = 0u32;
        loop {
            let remaining = budget.saturating_sub(spent);
            if remaining.is_zero() {
                break;
            }
            let wait = attempt_wait(remaining, 40);
            assert!(wait <= remaining, "attempt wait exceeds remaining budget");
            spent += wait;
            let sleep = Duration::from_millis(backoff_ms(10, retry, 250))
                .min(budget.saturating_sub(spent));
            spent += sleep;
            retry += 1;
            assert!(spent <= budget, "retry {retry} overran the budget: {spent:?}");
        }
        assert!(retry >= 2, "schedule should have allowed multiple attempts");
    }

    #[test]
    fn router_serve_rejects_empty_shard_list() {
        let cfg = ShardRouterConfig::new(vec![], 2);
        let err = ShardRouter::serve("127.0.0.1:0", cfg).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }
}
