//! TCP front-end: a poll-driven reader pool speaking the length-prefixed
//! binary protocol (v1 legacy in-order, v2 pipelined out-of-order — see
//! [`crate::coordinator::protocol`]), plus blocking and pipelined clients
//! for tests, examples and the CLI.
//!
//! Server shape: one accept thread classifies `accept()` errors (transient
//! kinds retry with backoff instead of killing the loop) and hands accepted
//! sockets round-robin to a small pool of reader threads. Readers poll
//! their connections, decode frames, and submit solves through
//! [`Service::submit_with`] with a per-request completion handle; finished
//! solves are routed — in any order — to the owning connection's writer
//! thread, which interleaves responses as they complete. Legacy (v1)
//! connections get a per-connection sequence number and a reorder buffer so
//! their responses still come back in request order.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::*;
use crate::coordinator::registry::MatrixId;
use crate::coordinator::service::Service;
use crate::coordinator::{ServiceError, SolveRequest, SolveResponse, SolverChoice};
use crate::linalg::{DenseMatrix, Matrix};

// ----------------------------------------------------------------------
// poll(2) via FFI — no libc crate in a zero-dependency build.
// ----------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_short, c_ulong};

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }

    pub fn pollin(fd: c_int) -> PollFd {
        PollFd { fd, events: POLLIN, revents: 0 }
    }

    pub fn pollout(fd: c_int) -> PollFd {
        PollFd { fd, events: POLLOUT, revents: 0 }
    }

    /// Wait up to `timeout_ms` for events on `fds`; returns the number of
    /// descriptors with events (0 on timeout, negative on error).
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> i32 {
        if fds.is_empty() {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms.max(1) as u64));
            return 0;
        }
        // SAFETY: `fds` is a live, exclusively borrowed `#[repr(C)]`
        // PollFd slice, so the pointer/length pair describes exactly
        // `nfds` writable pollfd records for the duration of the call.
        unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    /// Fallback for non-Linux unix: pretend every descriptor is ready after
    /// a short sleep — the nonblocking reads/writes then report WouldBlock
    /// themselves, so correctness is kept at the cost of some polling.
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;

    pub fn pollin(fd: i32) -> PollFd {
        PollFd { fd, events: POLLIN, revents: 0 }
    }

    pub fn pollout(fd: i32) -> PollFd {
        PollFd { fd, events: POLLOUT, revents: 0 }
    }

    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> i32 {
        std::thread::sleep(std::time::Duration::from_millis((timeout_ms.max(1) as u64).min(10)));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        fds.len() as i32
    }
}

// ----------------------------------------------------------------------
// Framing helpers (shared by server and clients)
// ----------------------------------------------------------------------

/// Read one frame (payload including opcode) from a blocking stream.
pub(crate) fn read_frame(stream: &mut TcpStream) -> io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok(Some(payload))
}

pub(crate) fn write_frame(stream: &mut TcpStream, frame: &[u8]) -> io::Result<()> {
    stream.write_all(frame)?;
    stream.flush()
}

pub(crate) fn error_frame(msg: &str) -> Vec<u8> {
    Writer::new(OP_ERROR).utf8(msg).frame()
}

/// Typed *retryable* error frame: the client should resend the same
/// request after a backoff (shard mid-rebalance, replicas briefly down).
pub(crate) fn retryable_frame(msg: &str) -> Vec<u8> {
    Writer::new(OP_ERR_RETRYABLE).utf8(msg).frame()
}

/// Rewrite a v1 response frame (`len, opcode, body`) into its v2 form
/// (`len, opcode, request_id, body`) so every v1 encoder is reused verbatim
/// on pipelined connections.
pub(crate) fn retag_v2(frame: Vec<u8>, id: u64) -> Vec<u8> {
    debug_assert!(frame.len() >= 5);
    let mut out = Vec::with_capacity(frame.len() + 8);
    out.extend_from_slice(&((frame.len() - 4 + 8) as u32).to_le_bytes());
    out.push(frame[4]);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&frame[5..]);
    out
}

pub(crate) fn encode_solve_response(resp: &SolveResponse) -> Vec<u8> {
    match &resp.result {
        Ok(sol) => Writer::new(OP_OK_SOLVE)
            .u32(sol.x.len() as u32)
            .f64_slice(&sol.x)
            .u32(sol.iterations as u32)
            .f64(sol.resnorm)
            .u8(sol.converged as u8)
            .u64(resp.queue_us)
            .u64(resp.solve_us)
            .frame(),
        Err(e) => error_frame(&e.to_string()),
    }
}

// ----------------------------------------------------------------------
// Accept-error classification
// ----------------------------------------------------------------------

/// Classify an `accept()` error: `Some(backoff)` for transient kinds the
/// accept loop should retry after sleeping (a client resetting mid-accept,
/// a signal, fd/buffer exhaustion), `None` for fatal errors that mean the
/// listener itself is broken.
pub fn accept_retry_backoff(e: &io::Error) -> Option<Duration> {
    use io::ErrorKind::*;
    match e.kind() {
        // The peer gave up between SYN and accept(), or a signal landed:
        // nothing is wrong with the listener.
        ConnectionAborted | ConnectionReset | Interrupted => Some(Duration::from_millis(1)),
        _ => match e.raw_os_error() {
            // EMFILE(24)/ENFILE(23)/ENOBUFS(105)/ENOMEM(12): resource
            // exhaustion — back off longer so existing connections can
            // retire and free descriptors.
            Some(24) | Some(23) | Some(105) | Some(12) => Some(Duration::from_millis(20)),
            _ => None,
        },
    }
}

// ----------------------------------------------------------------------
// Per-connection outbox + writer
// ----------------------------------------------------------------------

/// Frames queued for one connection's writer thread. v2 completions land
/// directly in `ready` (any order); v1 completions carry a per-connection
/// sequence number and sit in `reorder` until every earlier response has
/// been queued, preserving the legacy in-order contract.
struct Outbox {
    state: Mutex<OutboxState>,
    cond: Condvar,
}

struct OutboxState {
    ready: VecDeque<Vec<u8>>,
    reorder: HashMap<u64, Vec<u8>>,
    next_seq: u64,
    closed: bool,
}

impl Outbox {
    fn new() -> Self {
        Self {
            state: Mutex::new(OutboxState {
                ready: VecDeque::new(),
                reorder: HashMap::new(),
                next_seq: 0,
                closed: false,
            }),
            cond: Condvar::new(),
        }
    }

    /// Queue a frame for immediate (out-of-order) write.
    fn push_ready(&self, frame: Vec<u8>) {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return;
        }
        s.ready.push_back(frame);
        drop(s);
        self.cond.notify_one();
    }

    /// Queue the response to legacy request number `seq`; releases to
    /// `ready` only once all earlier sequence numbers have been queued.
    fn push_seq(&self, seq: u64, frame: Vec<u8>) {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return;
        }
        s.reorder.insert(seq, frame);
        let mut released = false;
        while let Some(f) = s.reorder.remove(&s.next_seq) {
            s.ready.push_back(f);
            s.next_seq += 1;
            released = true;
        }
        drop(s);
        if released {
            self.cond.notify_one();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Next frame to write; drains `ready` even after close, then reports
    /// `None` once closed-and-empty.
    fn pop(&self) -> Option<Vec<u8>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(f) = s.ready.pop_front() {
                return Some(f);
            }
            if s.closed {
                return None;
            }
            s = self.cond.wait(s).unwrap();
        }
    }
}

/// Write all of `buf` to a nonblocking stream, polling for writability on
/// WouldBlock. (std's `write_all` is wrong here: it loses progress when a
/// partial write is followed by WouldBlock.)
fn write_all_nb(stream: &mut TcpStream, buf: &[u8]) -> io::Result<()> {
    let mut off = 0;
    while off < buf.len() {
        match stream.write(&buf[off..]) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "write returned 0")),
            Ok(k) => off += k,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                let mut fds = [sys::pollout(stream.as_raw_fd())];
                let _ = sys::poll_fds(&mut fds, 100);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn writer_loop(mut stream: TcpStream, outbox: Arc<Outbox>) {
    while let Some(frame) = outbox.pop() {
        if write_all_nb(&mut stream, &frame).is_err() {
            // Make sure the reader notices the dead connection too.
            let _ = stream.shutdown(Shutdown::Both);
            break;
        }
    }
}

// ----------------------------------------------------------------------
// Server
// ----------------------------------------------------------------------

/// State a connection shares with the server handle, so `stop()` can
/// unblock it: a stream clone to `shutdown()` and the outbox to close.
struct ConnShared {
    stream: TcpStream,
    outbox: Arc<Outbox>,
}

/// A connection as owned by its reader thread.
struct Conn {
    id: u64,
    stream: TcpStream,
    shared: Arc<ConnShared>,
    writer: Option<JoinHandle<()>>,
    /// Received-but-unparsed bytes.
    rbuf: Vec<u8>,
    /// Protocol version (1 until a HELLO upgrade).
    proto: u8,
    /// Next legacy sequence number to assign (v1 response ordering).
    next_seq: u64,
    dead: bool,
}

type ConnTable = Arc<Mutex<HashMap<u64, Arc<ConnShared>>>>;

/// Front-end tuning knobs.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Reader threads multiplexing all connections (`SNSOLVE_READERS` env
    /// override; CLI `--readers`).
    pub readers: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        // snsolve-lint: allow(env-reads-behind-config) — designated
        // knob-resolution site: SNSOLVE_READERS default for
        // FrontendConfig (--readers / [service] readers take precedence).
        let readers = std::env::var("SNSOLVE_READERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&r| r > 0)
            .unwrap_or(2);
        Self { readers }
    }
}

/// A running TCP server.
pub struct TcpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    readers: Vec<JoinHandle<()>>,
    conns: ConnTable,
    injected_accept_errors: Arc<Mutex<VecDeque<io::Error>>>,
}

impl TcpServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port) with the
    /// default front-end configuration.
    pub fn serve(service: Arc<Service>, addr: impl ToSocketAddrs) -> io::Result<TcpServer> {
        Self::serve_with(service, addr, FrontendConfig::default())
    }

    /// Bind and serve with an explicit [`FrontendConfig`].
    pub fn serve_with(
        service: Arc<Service>,
        addr: impl ToSocketAddrs,
        cfg: FrontendConfig,
    ) -> io::Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: ConnTable = Arc::new(Mutex::new(HashMap::new()));
        let injected: Arc<Mutex<VecDeque<io::Error>>> = Arc::new(Mutex::new(VecDeque::new()));

        let n_readers = cfg.readers.max(1);
        let mut reader_txs = Vec::with_capacity(n_readers);
        let mut readers = Vec::with_capacity(n_readers);
        for i in 0..n_readers {
            let (tx, rx) = mpsc::channel::<Conn>();
            reader_txs.push(tx);
            let stop2 = stop.clone();
            let table = conns.clone();
            let svc = service.clone();
            readers.push(
                std::thread::Builder::new()
                    .name(format!("sns-tcp-reader-{i}"))
                    .spawn(move || reader_loop(rx, stop2, table, svc))?,
            );
        }

        let stop2 = stop.clone();
        let table = conns.clone();
        let inj = injected.clone();
        let accept_thread = std::thread::Builder::new()
            .name("sns-tcp-accept".into())
            .spawn(move || accept_loop(listener, service, stop2, table, inj, reader_txs))?;

        Ok(TcpServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            readers,
            conns,
            injected_accept_errors: injected,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Test hook: the accept loop consumes this error as if `accept()` had
    /// returned it (per-server, so parallel tests can't cross-contaminate).
    pub fn inject_accept_error(&self, e: io::Error) {
        self.injected_accept_errors.lock().unwrap().push_back(e);
    }

    /// Stop accepting and tear down every live connection: sockets are
    /// `shutdown(Both)` so reader/writer threads blocked on them wake up,
    /// outboxes are closed, and all server threads are joined.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        {
            let table = self.conns.lock().unwrap();
            for shared in table.values() {
                let _ = shared.stream.shutdown(Shutdown::Both);
                shared.outbox.close();
            }
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        // A server dropped without stop() still winds its threads down:
        // they all watch this flag with bounded poll timeouts.
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn accept_loop(
    listener: TcpListener,
    service: Arc<Service>,
    stop: Arc<AtomicBool>,
    table: ConnTable,
    injected: Arc<Mutex<VecDeque<io::Error>>>,
    reader_txs: Vec<mpsc::Sender<Conn>>,
) {
    let mut next_id: u64 = 1;
    let mut rr: usize = 0;
    while !stop.load(Ordering::Relaxed) {
        let injected_err = injected.lock().unwrap().pop_front();
        let result = match injected_err {
            Some(e) => Err(e),
            None => listener.accept().map(|(s, _peer)| s),
        };
        match result {
            Ok(stream) => {
                let id = next_id;
                next_id += 1;
                let r = register_conn(stream, id, &service, &table, &reader_txs, &mut rr);
                if let Err(e) = r {
                    eprintln!("tcp: connection setup failed: {e}");
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                let mut fds = [sys::pollin(listener.as_raw_fd())];
                let _ = sys::poll_fds(&mut fds, 50);
            }
            Err(e) => {
                Metrics::inc(&service.metrics().accept_errors);
                match accept_retry_backoff(&e) {
                    Some(backoff) => std::thread::sleep(backoff),
                    None => {
                        eprintln!("tcp: fatal accept error: {e}");
                        break;
                    }
                }
            }
        }
    }
}

fn register_conn(
    stream: TcpStream,
    id: u64,
    service: &Arc<Service>,
    table: &ConnTable,
    reader_txs: &[mpsc::Sender<Conn>],
    rr: &mut usize,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    // Nonblocking applies to the shared file *description* — the writer's
    // clone inherits it, which is why writes go through write_all_nb.
    stream.set_nonblocking(true)?;
    let wstream = stream.try_clone()?;
    let sstream = stream.try_clone()?;
    let outbox = Arc::new(Outbox::new());
    let wb = outbox.clone();
    let writer = std::thread::Builder::new()
        .name("sns-tcp-writer".into())
        .spawn(move || writer_loop(wstream, wb))?;
    let shared = Arc::new(ConnShared { stream: sstream, outbox });
    table.lock().unwrap().insert(id, shared.clone());
    let conn = Conn {
        id,
        stream,
        shared,
        writer: Some(writer),
        rbuf: Vec::new(),
        proto: 1,
        next_seq: 0,
        dead: false,
    };
    Metrics::inc(&service.metrics().conns_opened);
    let k = *rr % reader_txs.len();
    *rr += 1;
    if let Err(mpsc::SendError(c)) = reader_txs[k].send(conn) {
        // Reader already gone (server stopping): retire immediately.
        retire(c, table, service.metrics());
    }
    Ok(())
}

/// Tear one connection down: drop it from the table, unblock and join its
/// writer, and count it closed.
fn retire(mut c: Conn, table: &ConnTable, metrics: &Metrics) {
    table.lock().unwrap().remove(&c.id);
    let _ = c.stream.shutdown(Shutdown::Both);
    c.shared.outbox.close();
    if let Some(w) = c.writer.take() {
        let _ = w.join();
    }
    Metrics::inc(&metrics.conns_closed);
}

fn reader_loop(
    rx: mpsc::Receiver<Conn>,
    stop: Arc<AtomicBool>,
    table: ConnTable,
    service: Arc<Service>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        while let Ok(c) = rx.try_recv() {
            conns.push(c);
        }
        if stop.load(Ordering::Relaxed) {
            break;
        }
        if conns.is_empty() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(c) => conns.push(c),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Accept loop died; nothing to read until stop().
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
            continue;
        }
        let mut fds: Vec<sys::PollFd> =
            conns.iter().map(|c| sys::pollin(c.stream.as_raw_fd())).collect();
        if sys::poll_fds(&mut fds, 10) <= 0 {
            continue;
        }
        for (i, f) in fds.iter().enumerate() {
            // Any event (readable, hangup, error) means "try to read".
            if f.revents != 0 && !drain_conn(&mut conns[i], &service) {
                conns[i].dead = true;
            }
        }
        let mut i = 0;
        while i < conns.len() {
            if conns[i].dead {
                let c = conns.swap_remove(i);
                retire(c, &table, service.metrics());
            } else {
                i += 1;
            }
        }
    }
    for c in conns.drain(..) {
        retire(c, &table, service.metrics());
    }
    while let Ok(c) = rx.try_recv() {
        retire(c, &table, service.metrics());
    }
}

/// Read everything currently available on the socket and process complete
/// frames. Returns false when the connection is finished (EOF, error, or a
/// broken framing layer).
fn drain_conn(c: &mut Conn, service: &Arc<Service>) -> bool {
    let mut tmp = [0u8; 16 * 1024];
    loop {
        match c.stream.read(&mut tmp) {
            Ok(0) => return false,
            Ok(k) => {
                c.rbuf.extend_from_slice(&tmp[..k]);
                if !parse_frames(c, service) {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// Process every complete frame in `rbuf`. Returns false only for a broken
/// framing layer (bad length prefix) — the one error byte-stream protocols
/// cannot recover from.
fn parse_frames(c: &mut Conn, service: &Arc<Service>) -> bool {
    loop {
        if c.rbuf.len() < 4 {
            return true;
        }
        let len = u32::from_le_bytes(c.rbuf[..4].try_into().unwrap()) as usize;
        if len == 0 || len > MAX_FRAME {
            return false;
        }
        if c.rbuf.len() < 4 + len {
            return true;
        }
        let payload: Vec<u8> = c.rbuf[4..4 + len].to_vec();
        c.rbuf.drain(..4 + len);
        if c.proto == PROTO_V2 {
            handle_v2(c, &payload, service);
        } else {
            handle_v1(c, &payload, service);
        }
    }
}

/// Where a finished solve's response goes.
#[derive(Clone)]
enum Completion {
    Legacy { outbox: Arc<Outbox>, seq: u64 },
    V2 { outbox: Arc<Outbox>, id: u64 },
}

impl Completion {
    fn deliver(&self, frame_v1: Vec<u8>) {
        match self {
            Completion::Legacy { outbox, seq } => outbox.push_seq(*seq, frame_v1),
            Completion::V2 { outbox, id } => outbox.push_ready(retag_v2(frame_v1, *id)),
        }
    }
}

fn submit_solve(service: &Arc<Service>, req: SolveRequest, done: Completion) {
    let m = service.metrics();
    Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
    let svc = service.clone();
    let done2 = done.clone();
    let res = service.submit_with(req, move |resp| {
        Metrics::dec(&svc.metrics().frontend_inflight);
        done2.deliver(encode_solve_response(&resp));
    });
    if let Err(e) = res {
        // Rejected at submit (overload, unknown matrix, shutdown): the
        // callback was never installed, so answer here.
        Metrics::dec(&m.frontend_inflight);
        done.deliver(error_frame(&e.to_string()));
    }
}

fn handle_v1(c: &mut Conn, payload: &[u8], service: &Arc<Service>) {
    // Every legacy request gets the next sequence number — including
    // inline ops — so responses interleave back in exact request order.
    let seq = c.next_seq;
    c.next_seq += 1;
    let mut r = Reader::new(payload);
    let op = match r.u8() {
        Ok(op) => op,
        Err(e) => {
            c.shared.outbox.push_seq(seq, error_frame(&e.to_string()));
            return;
        }
    };
    match op {
        OP_HELLO => {
            let resp = match r.u8() {
                Ok(v) if v >= PROTO_V2 => {
                    c.proto = PROTO_V2;
                    Writer::new(OP_OK_HELLO).u8(PROTO_V2).frame()
                }
                Ok(_) => Writer::new(OP_OK_HELLO).u8(1).frame(),
                Err(e) => error_frame(&e.to_string()),
            };
            c.shared.outbox.push_seq(seq, resp);
        }
        OP_SOLVE => match decode_solve(&mut r) {
            Ok(req) => submit_solve(
                service,
                req,
                Completion::Legacy { outbox: c.shared.outbox.clone(), seq },
            ),
            Err(e) => c.shared.outbox.push_seq(seq, error_frame(&e.to_string())),
        },
        other => {
            let resp = handle_inline(other, &mut r, service);
            c.shared.outbox.push_seq(seq, resp);
        }
    }
}

fn handle_v2(c: &mut Conn, payload: &[u8], service: &Arc<Service>) {
    let mut r = Reader::new(payload);
    let op = match r.u8() {
        Ok(op) => op,
        Err(_) => return, // unreachable: frames have at least one byte
    };
    let id = match r.u64() {
        Ok(id) => id,
        Err(e) => {
            // Too short to carry a request id: ERROR tagged with id 0.
            c.shared.outbox.push_ready(retag_v2(error_frame(&e.to_string()), 0));
            return;
        }
    };
    match op {
        OP_SOLVE => match decode_solve(&mut r) {
            Ok(req) => submit_solve(
                service,
                req,
                Completion::V2 { outbox: c.shared.outbox.clone(), id },
            ),
            // Malformed solve with a decodable id: fail only this request.
            Err(e) => c.shared.outbox.push_ready(retag_v2(error_frame(&e.to_string()), id)),
        },
        other => {
            let resp = handle_inline(other, &mut r, service);
            c.shared.outbox.push_ready(retag_v2(resp, id));
        }
    }
}

/// Requests answered directly on the reader thread (no worker round-trip).
/// Returns a v1 response frame; v2 connections retag it with the id.
fn handle_inline(op: u8, r: &mut Reader, service: &Arc<Service>) -> Vec<u8> {
    match op {
        OP_REGISTER_DENSE => match decode_register(r) {
            Ok(matrix) => {
                let id = service.register_matrix(matrix);
                Writer::new(OP_OK_REGISTER).u64(id.0).frame()
            }
            Err(e) => error_frame(&e.to_string()),
        },
        OP_METRICS => Writer::new(OP_OK_METRICS).utf8(&service.metrics().report()).frame(),
        OP_EVICT => match r.u64() {
            Ok(id) => {
                let existed = service.registry().evict(MatrixId(id));
                Writer::new(OP_OK_EVICT).u8(existed as u8).frame()
            }
            Err(e) => error_frame(&e.to_string()),
        },
        // Router→shard replication/handoff: insert at a caller-chosen id.
        OP_REGISTER_AT => {
            let parsed = r.u64().and_then(|id| decode_register(r).map(|matrix| (id, matrix)));
            match parsed {
                Ok((id, matrix)) => {
                    service.registry().register_at(MatrixId(id), matrix);
                    Writer::new(OP_OK_REGISTER).u64(id).frame()
                }
                Err(e) => error_frame(&e.to_string()),
            }
        }
        // Router handoff read-back: stream a registered matrix out so a
        // surviving replica can seed a new owner.
        OP_FETCH_MATRIX => match r.u64() {
            Ok(id) => match service.registry().get(MatrixId(id)) {
                Some(m) => match m.as_ref() {
                    Matrix::Dense(d) => Writer::new(OP_OK_MATRIX)
                        .u32(d.rows() as u32)
                        .u32(d.cols() as u32)
                        .f64_slice(d.data())
                        .frame(),
                    Matrix::Csr(_) => {
                        error_frame("fetch of sparse matrices is not supported")
                    }
                },
                None => error_frame(&format!("unknown matrix id {id}")),
            },
            Err(e) => error_frame(&e.to_string()),
        },
        // Router heartbeat: echo the epoch so the router can detect a
        // process that restarted (and therefore lost its registry).
        OP_PING => match r.u64() {
            Ok(epoch) => Writer::new(OP_OK_PING).u64(epoch).frame(),
            Err(e) => error_frame(&e.to_string()),
        },
        other => error_frame(&format!("unknown opcode {other}")),
    }
}

pub(crate) fn decode_register(r: &mut Reader) -> Result<Matrix, DecodeError> {
    let m = r.u32()? as usize;
    let n = r.u32()? as usize;
    if m == 0 || n == 0 || m.checked_mul(n).is_none() {
        return Err(DecodeError(format!("bad dims {m}x{n}")));
    }
    let data = r.f64_vec(m * n)?;
    // Reject poisoned registrations at the boundary: one NaN in A would
    // silently corrupt the cached factorization every later solve reuses.
    if !data.iter().all(|v| v.is_finite()) {
        return Err(DecodeError(
            "matrix data contains non-finite (NaN/Inf) values".to_string(),
        ));
    }
    let dm = DenseMatrix::from_vec(m, n, data).map_err(|e| DecodeError(e.to_string()))?;
    Ok(Matrix::Dense(dm))
}

pub(crate) fn decode_solve(r: &mut Reader) -> Result<SolveRequest, DecodeError> {
    let matrix = MatrixId(r.u64()?);
    let solver = solver_from_u8(r.u8()?)?;
    let tol = r.f64()?;
    if !tol.is_finite() || tol < 0.0 {
        return Err(DecodeError(format!("bad tolerance {tol}")));
    }
    let deadline_us = r.u64()?;
    let m = r.u32()? as usize;
    let rhs = r.f64_vec(m)?;
    if !rhs.iter().all(|v| v.is_finite()) {
        return Err(DecodeError(
            "rhs contains non-finite (NaN/Inf) values".to_string(),
        ));
    }
    // Optional trailing field (backward compatible both directions): a
    // per-request refinement-sweep cap for the stable ladder. Absent or 0
    // defers to the server-side `--refine-iters` knob.
    let refine_iters = if r.finished() { 0 } else { r.u32()? as usize };
    Ok(SolveRequest { matrix, rhs, solver, tol, deadline_us, refine_iters })
}

// ----------------------------------------------------------------------
// Blocking client (protocol v1)
// ----------------------------------------------------------------------

/// Blocking one-request-at-a-time client for the TCP front-end.
pub struct Client {
    stream: TcpStream,
}

#[derive(Debug)]
pub enum ClientError {
    Io(io::Error),
    Decode(DecodeError),
    Server(String),
    /// Typed retryable failure (`OP_ERR_RETRYABLE`): the request hit a
    /// transient cluster condition (shard mid-rebalance, replicas briefly
    /// unreachable) — resend the same request after a backoff.
    Retryable(String),
    UnexpectedOpcode(u8),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Decode(e) => write!(f, "decode: {e}"),
            ClientError::Server(m) => write!(f, "server error: {m}"),
            ClientError::Retryable(m) => write!(f, "retryable: {m}"),
            ClientError::UnexpectedOpcode(op) => write!(f, "unexpected opcode {op}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Decode(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<DecodeError> for ClientError {
    fn from(e: DecodeError) -> Self {
        ClientError::Decode(e)
    }
}

/// A solve result over the wire.
#[derive(Debug, Clone)]
pub struct WireSolution {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub resnorm: f64,
    pub converged: bool,
    pub queue_us: u64,
    pub solve_us: u64,
}

fn decode_wire_solution(body: &[u8]) -> Result<WireSolution, ClientError> {
    let mut r = Reader::new(body);
    let n = r.u32()? as usize;
    let x = r.f64_vec(n)?;
    let iterations = r.u32()? as usize;
    let resnorm = r.f64()?;
    let converged = r.u8()? != 0;
    let queue_us = r.u64()?;
    let solve_us = r.u64()?;
    Ok(WireSolution { x, iterations, resnorm, converged, queue_us, solve_us })
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    fn call(&mut self, frame: Vec<u8>) -> Result<Vec<u8>, ClientError> {
        write_frame(&mut self.stream, &frame)?;
        match read_frame(&mut self.stream)? {
            Some(p) => Ok(p),
            None => Err(ClientError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed",
            ))),
        }
    }

    fn expect(&mut self, frame: Vec<u8>, opcode: u8) -> Result<Vec<u8>, ClientError> {
        let p = self.call(frame)?;
        let mut r = Reader::new(&p);
        let op = r.u8()?;
        if op == OP_ERROR {
            return Err(ClientError::Server(r.rest_utf8()?));
        }
        if op == OP_ERR_RETRYABLE {
            return Err(ClientError::Retryable(r.rest_utf8()?));
        }
        if op != opcode {
            return Err(ClientError::UnexpectedOpcode(op));
        }
        Ok(p[1..].to_vec())
    }

    /// Register a dense matrix; returns the server-side id.
    pub fn register_dense(&mut self, a: &DenseMatrix) -> Result<u64, ClientError> {
        let frame = Writer::new(OP_REGISTER_DENSE)
            .u32(a.rows() as u32)
            .u32(a.cols() as u32)
            .f64_slice(a.data())
            .frame();
        let body = self.expect(frame, OP_OK_REGISTER)?;
        Ok(Reader::new(&body).u64()?)
    }

    /// Solve against a registered matrix (no deadline).
    pub fn solve(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
    ) -> Result<WireSolution, ClientError> {
        self.solve_with_deadline(matrix_id, rhs, solver, tol, 0)
    }

    /// Solve with an end-to-end deadline in microseconds (0 = none): the
    /// server fails the request with `deadline exceeded` if queue wait plus
    /// solve time overruns it.
    pub fn solve_with_deadline(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        deadline_us: u64,
    ) -> Result<WireSolution, ClientError> {
        self.solve_with_opts(matrix_id, rhs, solver, tol, deadline_us, 0)
    }

    /// Solve with every per-request knob: deadline plus a refinement-sweep
    /// cap for the stable ladder (0 = the server-side `--refine-iters`
    /// default). The cap rides as the optional trailing `SOLVE` field, so
    /// old servers that don't know it reject nothing — they never see it
    /// when it is 0 and newer servers ignore 0.
    pub fn solve_with_opts(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        deadline_us: u64,
        refine_iters: usize,
    ) -> Result<WireSolution, ClientError> {
        let mut w = Writer::new(OP_SOLVE)
            .u64(matrix_id)
            .u8(solver_to_u8(solver))
            .f64(tol)
            .u64(deadline_us)
            .u32(rhs.len() as u32)
            .f64_slice(rhs);
        if refine_iters > 0 {
            w = w.u32(refine_iters as u32);
        }
        let body = self.expect(w.frame(), OP_OK_SOLVE)?;
        decode_wire_solution(&body)
    }

    /// Fetch the metrics report.
    pub fn metrics(&mut self) -> Result<String, ClientError> {
        let body = self.expect(Writer::new(OP_METRICS).frame(), OP_OK_METRICS)?;
        Ok(Reader::new(&body).rest_utf8()?)
    }

    /// Evict a matrix; true if it existed.
    pub fn evict(&mut self, matrix_id: u64) -> Result<bool, ClientError> {
        let body = self.expect(Writer::new(OP_EVICT).u64(matrix_id).frame(), OP_OK_EVICT)?;
        Ok(Reader::new(&body).u8()? != 0)
    }
}

impl From<ServiceError> for ClientError {
    fn from(e: ServiceError) -> Self {
        ClientError::Server(e.to_string())
    }
}

// ----------------------------------------------------------------------
// Pipelined client (protocol v2)
// ----------------------------------------------------------------------

/// A response delivered to a ticket: raw payload plus the instant the
/// client's reader thread pulled it off the socket, so latency measurement
/// is independent of when the caller gets around to waiting.
struct PipelinedReply {
    payload: Vec<u8>,
    received: Instant,
}

type PendingMap = Arc<Mutex<HashMap<u64, mpsc::Sender<PipelinedReply>>>>;

fn conn_closed() -> ClientError {
    ClientError::Io(io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed"))
}

/// Handle to one in-flight pipelined solve.
pub struct SolveTicket {
    pub id: u64,
    rx: mpsc::Receiver<PipelinedReply>,
}

impl SolveTicket {
    fn decode(rep: PipelinedReply) -> Result<WireSolution, ClientError> {
        let mut r = Reader::new(&rep.payload);
        let op = r.u8()?;
        let _id = r.u64()?;
        if op == OP_ERROR {
            return Err(ClientError::Server(r.rest_utf8()?));
        }
        if op == OP_ERR_RETRYABLE {
            return Err(ClientError::Retryable(r.rest_utf8()?));
        }
        if op != OP_OK_SOLVE {
            return Err(ClientError::UnexpectedOpcode(op));
        }
        decode_wire_solution(&rep.payload[9..])
    }

    /// Block until this request completes.
    pub fn wait(self) -> Result<WireSolution, ClientError> {
        let rep = self.rx.recv().map_err(|_| conn_closed())?;
        Self::decode(rep)
    }

    /// Like [`SolveTicket::wait`], also returning the instant the response
    /// arrived at the client (recorded by the reader thread at delivery).
    pub fn wait_timed(self) -> Result<(WireSolution, Instant), ClientError> {
        let rep = self.rx.recv().map_err(|_| conn_closed())?;
        let t = rep.received;
        Self::decode(rep).map(|s| (s, t))
    }

    /// Non-blocking check: `None` while the request is still in flight.
    pub fn try_take(&mut self) -> Option<Result<WireSolution, ClientError>> {
        match self.rx.try_recv() {
            Ok(rep) => Some(Self::decode(rep)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(conn_closed())),
        }
    }

    /// Wait up to `d`; `None` on timeout (the request stays in flight).
    pub fn wait_timeout(&mut self, d: Duration) -> Option<Result<WireSolution, ClientError>> {
        match self.rx.recv_timeout(d) {
            Ok(rep) => Some(Self::decode(rep)),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => Some(Err(conn_closed())),
        }
    }
}

/// Pipelined (protocol v2) client: many solves in flight on one socket,
/// completing out of order. A background reader thread demultiplexes
/// responses to their tickets by request id.
pub struct PipelinedClient {
    stream: TcpStream,
    next_id: u64,
    pending: PendingMap,
    reader: Option<JoinHandle<()>>,
    /// Fault-injection label (the shard router sets this to the peer
    /// address): when set, every outbound frame consults the installed
    /// [`crate::testing::FaultPlan`]'s network entries. `None` (the
    /// default) skips the lookup entirely.
    fault_target: Option<String>,
    /// Outbound frame index since connect (HELLO excluded) — the pure
    /// matching coordinate for seeded network faults.
    frames_sent: u64,
}

impl PipelinedClient {
    /// Connect and upgrade the connection to protocol v2 via `HELLO`.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<PipelinedClient, ClientError> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        write_frame(&mut stream, &Writer::new(OP_HELLO).u8(PROTO_V2).frame())?;
        let p = read_frame(&mut stream)?.ok_or_else(conn_closed)?;
        let mut r = Reader::new(&p);
        match r.u8()? {
            OP_OK_HELLO => {
                if r.u8()? != PROTO_V2 {
                    return Err(ClientError::Server("server declined protocol v2".into()));
                }
            }
            OP_ERROR => return Err(ClientError::Server(r.rest_utf8()?)),
            op => return Err(ClientError::UnexpectedOpcode(op)),
        }
        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        let mut rstream = stream.try_clone().map_err(ClientError::Io)?;
        let pending2 = pending.clone();
        let reader = std::thread::Builder::new()
            .name("sns-pipe-reader".into())
            .spawn(move || loop {
                match read_frame(&mut rstream) {
                    Ok(Some(p)) => {
                        if p.len() < 9 {
                            continue; // response too short to route; drop
                        }
                        let id = u64::from_le_bytes(p[1..9].try_into().unwrap());
                        let tx = pending2.lock().unwrap().remove(&id);
                        if let Some(tx) = tx {
                            let _ = tx
                                .send(PipelinedReply { payload: p, received: Instant::now() });
                        }
                    }
                    Ok(None) | Err(_) => {
                        // Dropping the senders fails every outstanding wait.
                        pending2.lock().unwrap().clear();
                        return;
                    }
                }
            })
            .map_err(ClientError::Io)?;
        Ok(PipelinedClient {
            stream,
            next_id: 1,
            pending,
            reader: Some(reader),
            fault_target: None,
            frames_sent: 0,
        })
    }

    /// Label this connection for seeded network fault injection (used by
    /// the shard router, which labels each shard link with its address).
    pub fn set_fault_target(&mut self, target: impl Into<String>) {
        self.fault_target = Some(target.into());
    }

    fn submit(
        &mut self,
        build: impl FnOnce(u64) -> Vec<u8>,
    ) -> Result<(u64, mpsc::Receiver<PipelinedReply>), ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(id, tx);
        let frame = build(id);
        if let Some(action) = self.net_fault_for(&frame) {
            match action {
                crate::testing::NetFaultAction::Drop => {
                    // Never written: the caller's deadline-aware wait times
                    // out and the retry path runs. The pending entry stays
                    // until connection teardown — ids are never reused, so
                    // it can only leak, not misroute.
                    return Ok((id, rx));
                }
                crate::testing::NetFaultAction::DelayMs(ms) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                crate::testing::NetFaultAction::Sever => {
                    let _ = self.stream.shutdown(Shutdown::Both);
                }
            }
        }
        if let Err(e) = write_frame(&mut self.stream, &frame) {
            self.pending.lock().unwrap().remove(&id);
            return Err(e.into());
        }
        Ok((id, rx))
    }

    /// Consult the installed fault plan for this outbound frame. Bumps the
    /// frame index whenever a target label is set, so the index is a stable
    /// coordinate whether or not a plan is currently installed.
    fn net_fault_for(&mut self, frame: &[u8]) -> Option<crate::testing::NetFaultAction> {
        let target = self.fault_target.as_deref()?;
        let idx = self.frames_sent;
        self.frames_sent += 1;
        let plan = crate::testing::active_faults()?;
        if !plan.has_net_faults() {
            return None;
        }
        // frame = u32 len, u8 opcode, ...
        plan.net_action(target, frame[4], idx)
    }

    fn call(
        &mut self,
        build: impl FnOnce(u64) -> Vec<u8>,
        expect_op: u8,
    ) -> Result<Vec<u8>, ClientError> {
        let (_id, rx) = self.submit(build)?;
        let rep = rx.recv().map_err(|_| conn_closed())?;
        let mut r = Reader::new(&rep.payload);
        let op = r.u8()?;
        let _ = r.u64()?;
        if op == OP_ERROR {
            return Err(ClientError::Server(r.rest_utf8()?));
        }
        if op == OP_ERR_RETRYABLE {
            return Err(ClientError::Retryable(r.rest_utf8()?));
        }
        if op != expect_op {
            return Err(ClientError::UnexpectedOpcode(op));
        }
        Ok(rep.payload[9..].to_vec())
    }

    /// Fire a solve without waiting; the returned ticket resolves whenever
    /// the server finishes it, independent of other in-flight requests.
    pub fn submit_solve(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        deadline_us: u64,
    ) -> Result<SolveTicket, ClientError> {
        self.submit_solve_opts(matrix_id, rhs, solver, tol, deadline_us, 0)
    }

    /// [`PipelinedClient::submit_solve`] with the optional per-request
    /// refinement-sweep cap (0 = server-side default, field omitted).
    pub fn submit_solve_opts(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        deadline_us: u64,
        refine_iters: usize,
    ) -> Result<SolveTicket, ClientError> {
        let (id, rx) = self.submit(|id| {
            let mut w = Writer::new(OP_SOLVE)
                .u64(id)
                .u64(matrix_id)
                .u8(solver_to_u8(solver))
                .f64(tol)
                .u64(deadline_us)
                .u32(rhs.len() as u32)
                .f64_slice(rhs);
            if refine_iters > 0 {
                w = w.u32(refine_iters as u32);
            }
            w.frame()
        })?;
        Ok(SolveTicket { id, rx })
    }

    /// Blocking solve (submit + wait), for drop-in parity with [`Client`].
    pub fn solve(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
    ) -> Result<WireSolution, ClientError> {
        self.submit_solve(matrix_id, rhs, solver, tol, 0)?.wait()
    }

    /// Blocking solve with a deadline (see [`Client::solve_with_deadline`]).
    pub fn solve_with_deadline(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
        deadline_us: u64,
    ) -> Result<WireSolution, ClientError> {
        self.submit_solve(matrix_id, rhs, solver, tol, deadline_us)?.wait()
    }

    /// Register a dense matrix; returns the server-side id.
    pub fn register_dense(&mut self, a: &DenseMatrix) -> Result<u64, ClientError> {
        let body = self.call(
            |id| {
                Writer::new(OP_REGISTER_DENSE)
                    .u64(id)
                    .u32(a.rows() as u32)
                    .u32(a.cols() as u32)
                    .f64_slice(a.data())
                    .frame()
            },
            OP_OK_REGISTER,
        )?;
        Ok(Reader::new(&body).u64()?)
    }

    /// Fetch the metrics report.
    pub fn metrics(&mut self) -> Result<String, ClientError> {
        let body = self.call(|id| Writer::new(OP_METRICS).u64(id).frame(), OP_OK_METRICS)?;
        Ok(Reader::new(&body).rest_utf8()?)
    }

    /// Evict a matrix; true if it existed.
    pub fn evict(&mut self, matrix_id: u64) -> Result<bool, ClientError> {
        let body = self.call(
            |id| Writer::new(OP_EVICT).u64(id).u64(matrix_id).frame(),
            OP_OK_EVICT,
        )?;
        Ok(Reader::new(&body).u8()? != 0)
    }

    /// Register a dense matrix at a caller-chosen id (router replication:
    /// the router allocates ids so all replicas agree on them).
    pub fn register_at(
        &mut self,
        matrix_id: u64,
        m: u32,
        n: u32,
        data: &[f64],
    ) -> Result<(), ClientError> {
        self.call(
            |id| {
                Writer::new(OP_REGISTER_AT)
                    .u64(id)
                    .u64(matrix_id)
                    .u32(m)
                    .u32(n)
                    .f64_slice(data)
                    .frame()
            },
            OP_OK_REGISTER,
        )?;
        Ok(())
    }

    /// Fetch a registered dense matrix back (router handoff: a surviving
    /// replica streams the data toward a new owner).
    pub fn fetch_matrix(&mut self, matrix_id: u64) -> Result<(u32, u32, Vec<f64>), ClientError> {
        let body = self.call(
            |id| Writer::new(OP_FETCH_MATRIX).u64(id).u64(matrix_id).frame(),
            OP_OK_MATRIX,
        )?;
        let mut r = Reader::new(&body);
        let m = r.u32()?;
        let n = r.u32()?;
        let data = r.f64_vec((m as usize) * (n as usize))?;
        Ok((m, n, data))
    }

    /// Heartbeat: send the router's epoch, get it echoed back. An answered
    /// ping means the shard process is alive and draining its reader pool.
    pub fn ping(&mut self, epoch: u64) -> Result<u64, ClientError> {
        let body =
            self.call(|id| Writer::new(OP_PING).u64(id).u64(epoch).frame(), OP_OK_PING)?;
        Ok(Reader::new(&body).u64()?)
    }

    /// [`PipelinedClient::ping`] with a bounded wait, so a hung (not just
    /// dead) shard cannot stall the router's heartbeat loop.
    pub fn ping_timeout(&mut self, epoch: u64, d: Duration) -> Result<u64, ClientError> {
        let (_id, rx) = self.submit(|id| Writer::new(OP_PING).u64(id).u64(epoch).frame())?;
        let rep = rx.recv_timeout(d).map_err(|_| {
            ClientError::Io(io::Error::new(io::ErrorKind::TimedOut, "ping timed out"))
        })?;
        let mut r = Reader::new(&rep.payload);
        let op = r.u8()?;
        let _ = r.u64()?;
        if op != OP_OK_PING {
            return Err(ClientError::UnexpectedOpcode(op));
        }
        Ok(r.u64()?)
    }
}

impl Drop for PipelinedClient {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_backoff_classification() {
        use io::ErrorKind;
        // Transient kinds: retried with backoff.
        let transient = [
            ErrorKind::ConnectionAborted,
            ErrorKind::ConnectionReset,
            ErrorKind::Interrupted,
        ];
        for kind in transient {
            assert!(accept_retry_backoff(&io::Error::new(kind, "x")).is_some(), "{kind:?}");
        }
        // Resource exhaustion (EMFILE/ENFILE/ENOBUFS/ENOMEM): longer backoff.
        for code in [24, 23, 105, 12] {
            let e = io::Error::from_raw_os_error(code);
            assert!(accept_retry_backoff(&e).is_some(), "os error {code}");
        }
        // Fatal: accept loop must break.
        assert!(accept_retry_backoff(&io::Error::new(ErrorKind::InvalidInput, "x")).is_none());
        assert!(accept_retry_backoff(&io::Error::from_raw_os_error(9)).is_none()); // EBADF
    }

    #[test]
    fn outbox_orders_legacy_seqs() {
        let ob = Outbox::new();
        ob.push_seq(2, vec![2]);
        ob.push_seq(0, vec![0]);
        // seq 1 still missing: only seq 0 may be released.
        assert_eq!(ob.pop().unwrap(), vec![0]);
        ob.push_seq(1, vec![1]);
        assert_eq!(ob.pop().unwrap(), vec![1]);
        assert_eq!(ob.pop().unwrap(), vec![2]);
        ob.close();
        assert!(ob.pop().is_none());
    }

    #[test]
    fn outbox_ready_fifo_then_close_drains() {
        let ob = Outbox::new();
        ob.push_ready(vec![1]);
        ob.push_ready(vec![2]);
        ob.close();
        // Close lets queued frames drain first...
        assert_eq!(ob.pop().unwrap(), vec![1]);
        assert_eq!(ob.pop().unwrap(), vec![2]);
        assert!(ob.pop().is_none());
        // ...but drops anything pushed after.
        ob.push_ready(vec![3]);
        assert!(ob.pop().is_none());
    }

    #[test]
    fn retag_v2_inserts_id_after_opcode() {
        let f = Writer::new(OP_OK_EVICT).u8(1).frame();
        let t = retag_v2(f, 0xABCD);
        let len = u32::from_le_bytes(t[..4].try_into().unwrap()) as usize;
        assert_eq!(len, t.len() - 4);
        let mut r = Reader::new(&t[4..]);
        assert_eq!(r.u8().unwrap(), OP_OK_EVICT);
        assert_eq!(r.u64().unwrap(), 0xABCD);
        assert_eq!(r.u8().unwrap(), 1);
        assert!(r.finished());
    }

    #[test]
    fn frontend_config_floor() {
        // serve_with clamps to at least one reader; the default is >= 1
        // whatever SNSOLVE_READERS says (non-numeric / zero are ignored).
        assert!(FrontendConfig::default().readers >= 1);
    }
}
