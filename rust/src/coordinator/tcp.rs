//! TCP front-end: a thread-per-connection server speaking the
//! length-prefixed binary protocol, plus a blocking client for tests,
//! examples and the CLI.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::protocol::*;
use crate::coordinator::registry::MatrixId;
use crate::coordinator::service::Service;
use crate::coordinator::{ServiceError, SolveRequest, SolverChoice};
use crate::linalg::{DenseMatrix, Matrix};

/// Read one frame (payload including opcode) from a stream.
fn read_frame(stream: &mut TcpStream) -> std::io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok(Some(payload))
}

fn write_frame(stream: &mut TcpStream, frame: &[u8]) -> std::io::Result<()> {
    stream.write_all(frame)?;
    stream.flush()
}

fn error_frame(msg: &str) -> Vec<u8> {
    Writer::new(OP_ERROR).utf8(msg).frame()
}

/// A running TCP server.
pub struct TcpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn serve(service: Arc<Service>, addr: impl ToSocketAddrs) -> std::io::Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("sns-tcp-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            let svc = service.clone();
                            // Detached: a connection thread lives exactly as
                            // long as its client keeps the socket open, so
                            // joining here would deadlock stop() whenever a
                            // client is still connected.
                            let _ = std::thread::Builder::new()
                                .name("sns-tcp-conn".into())
                                .spawn(move || connection_loop(&mut stream, svc))
                                .expect("spawn conn thread");
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(TcpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting; existing connections finish on client disconnect.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn connection_loop(stream: &mut TcpStream, service: Arc<Service>) {
    loop {
        let payload = match read_frame(stream) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean EOF
            Err(_) => return,
        };
        let resp = handle_frame(&payload, &service);
        if write_frame(stream, &resp).is_err() {
            return;
        }
    }
}

fn handle_frame(payload: &[u8], service: &Arc<Service>) -> Vec<u8> {
    let mut r = Reader::new(payload);
    let op = match r.u8() {
        Ok(op) => op,
        Err(e) => return error_frame(&e.to_string()),
    };
    match op {
        OP_REGISTER_DENSE => match decode_register(&mut r) {
            Ok(matrix) => {
                let id = service.register_matrix(matrix);
                Writer::new(OP_OK_REGISTER).u64(id.0).frame()
            }
            Err(e) => error_frame(&e.to_string()),
        },
        OP_SOLVE => match decode_solve(&mut r) {
            Ok(req) => match service.solve_blocking(req) {
                Ok(resp) => match resp.result {
                    Ok(sol) => Writer::new(OP_OK_SOLVE)
                        .u32(sol.x.len() as u32)
                        .f64_slice(&sol.x)
                        .u32(sol.iterations as u32)
                        .f64(sol.resnorm)
                        .u8(sol.converged as u8)
                        .u64(resp.queue_us)
                        .u64(resp.solve_us)
                        .frame(),
                    Err(e) => error_frame(&e.to_string()),
                },
                Err(e) => error_frame(&e.to_string()),
            },
            Err(e) => error_frame(&e.to_string()),
        },
        OP_METRICS => Writer::new(OP_OK_METRICS).utf8(&service.metrics().report()).frame(),
        OP_EVICT => match r.u64() {
            Ok(id) => {
                let existed = service.registry().evict(MatrixId(id));
                Writer::new(OP_OK_EVICT).u8(existed as u8).frame()
            }
            Err(e) => error_frame(&e.to_string()),
        },
        other => error_frame(&format!("unknown opcode {other}")),
    }
}

fn decode_register(r: &mut Reader) -> Result<Matrix, DecodeError> {
    let m = r.u32()? as usize;
    let n = r.u32()? as usize;
    if m == 0 || n == 0 || m.checked_mul(n).is_none() {
        return Err(DecodeError(format!("bad dims {m}x{n}")));
    }
    let data = r.f64_vec(m * n)?;
    let dm = DenseMatrix::from_vec(m, n, data)
        .map_err(|e| DecodeError(e.to_string()))?;
    Ok(Matrix::Dense(dm))
}

fn decode_solve(r: &mut Reader) -> Result<SolveRequest, DecodeError> {
    let matrix = MatrixId(r.u64()?);
    let solver = solver_from_u8(r.u8()?)?;
    let tol = r.f64()?;
    let deadline_us = r.u64()?;
    let m = r.u32()? as usize;
    let rhs = r.f64_vec(m)?;
    Ok(SolveRequest { matrix, rhs, solver, tol, deadline_us })
}

// ----------------------------------------------------------------------
// Client
// ----------------------------------------------------------------------

/// Blocking client for the TCP front-end.
pub struct Client {
    stream: TcpStream,
}

#[derive(Debug)]
pub enum ClientError {
    Io(std::io::Error),
    Decode(DecodeError),
    Server(String),
    UnexpectedOpcode(u8),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Decode(e) => write!(f, "decode: {e}"),
            ClientError::Server(m) => write!(f, "server error: {m}"),
            ClientError::UnexpectedOpcode(op) => write!(f, "unexpected opcode {op}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Decode(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<DecodeError> for ClientError {
    fn from(e: DecodeError) -> Self {
        ClientError::Decode(e)
    }
}

/// A solve result over the wire.
#[derive(Debug, Clone)]
pub struct WireSolution {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub resnorm: f64,
    pub converged: bool,
    pub queue_us: u64,
    pub solve_us: u64,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    fn call(&mut self, frame: Vec<u8>) -> Result<Vec<u8>, ClientError> {
        write_frame(&mut self.stream, &frame)?;
        match read_frame(&mut self.stream)? {
            Some(p) => Ok(p),
            None => Err(ClientError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed",
            ))),
        }
    }

    fn expect(&mut self, frame: Vec<u8>, opcode: u8) -> Result<Vec<u8>, ClientError> {
        let p = self.call(frame)?;
        let mut r = Reader::new(&p);
        let op = r.u8()?;
        if op == OP_ERROR {
            return Err(ClientError::Server(r.rest_utf8()?));
        }
        if op != opcode {
            return Err(ClientError::UnexpectedOpcode(op));
        }
        Ok(p[1..].to_vec())
    }

    /// Register a dense matrix; returns the server-side id.
    pub fn register_dense(&mut self, a: &DenseMatrix) -> Result<u64, ClientError> {
        let frame = Writer::new(OP_REGISTER_DENSE)
            .u32(a.rows() as u32)
            .u32(a.cols() as u32)
            .f64_slice(a.data())
            .frame();
        let body = self.expect(frame, OP_OK_REGISTER)?;
        Ok(Reader::new(&body).u64()?)
    }

    /// Solve against a registered matrix.
    pub fn solve(
        &mut self,
        matrix_id: u64,
        rhs: &[f64],
        solver: SolverChoice,
        tol: f64,
    ) -> Result<WireSolution, ClientError> {
        let frame = Writer::new(OP_SOLVE)
            .u64(matrix_id)
            .u8(solver_to_u8(solver))
            .f64(tol)
            .u64(0)
            .u32(rhs.len() as u32)
            .f64_slice(rhs)
            .frame();
        let body = self.expect(frame, OP_OK_SOLVE)?;
        let mut r = Reader::new(&body);
        let n = r.u32()? as usize;
        let x = r.f64_vec(n)?;
        let iterations = r.u32()? as usize;
        let resnorm = r.f64()?;
        let converged = r.u8()? != 0;
        let queue_us = r.u64()?;
        let solve_us = r.u64()?;
        Ok(WireSolution { x, iterations, resnorm, converged, queue_us, solve_us })
    }

    /// Fetch the metrics report.
    pub fn metrics(&mut self) -> Result<String, ClientError> {
        let body = self.expect(Writer::new(OP_METRICS).frame(), OP_OK_METRICS)?;
        Ok(Reader::new(&body).rest_utf8()?)
    }

    /// Evict a matrix; true if it existed.
    pub fn evict(&mut self, matrix_id: u64) -> Result<bool, ClientError> {
        let body =
            self.expect(Writer::new(OP_EVICT).u64(matrix_id).frame(), OP_OK_EVICT)?;
        Ok(Reader::new(&body).u8()? != 0)
    }
}

impl From<ServiceError> for ClientError {
    fn from(e: ServiceError) -> Self {
        ClientError::Server(e.to_string())
    }
}
