//! Bounded MPMC queue with blocking push/pop, timeouts and close semantics —
//! the backpressure primitive (no crossbeam/tokio offline; Mutex+Condvar).
//!
//! # Memory-ordering audit (loom-style)
//!
//! There are **no raw atomics here** — every field (`items`, `closed`)
//! lives under the single `inner` mutex, so the protocol is sequentially
//! consistent by construction: lock acquisition/release provides all
//! happens-before edges, and TSan/Miri have nothing unordered to observe.
//! The properties worth auditing are the condvar protocol, not orderings:
//!
//! * **No lost wakeups.** Every state transition that can unblock a
//!   waiter signals the matching condvar *after* the guard is dropped
//!   (push → `not_empty`, pop/drain → `not_full`, close → both,
//!   `notify_all`). Signalling outside the lock is sound because waiters
//!   re-check their predicate (`items` length / `closed`) under the lock
//!   in a loop — spurious and stolen wakeups are absorbed by the re-check.
//! * **Deadline, not duration.** Waits recompute `deadline − now` each
//!   lap, so a spurious wakeup never extends the total timeout.
//! * **Close is sticky and drains.** `closed = true` is only ever set
//!   (never cleared) under the lock; pops keep returning queued items
//!   until empty, then report `Closed` — consumers that exit only on
//!   `Closed` therefore see every pushed item exactly once (asserted by
//!   `mpmc_stress`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push failed.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue stayed full for the whole timeout (backpressure signal).
    Full(T),
    /// Queue was closed.
    Closed(T),
}

/// Why a pop returned nothing.
#[derive(Debug, PartialEq, Eq)]
pub enum PopError {
    TimedOut,
    /// Closed *and* drained.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded blocking queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(capacity), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Push, waiting up to `timeout` for space.
    pub fn push_timeout(&self, item: T, timeout: Duration) -> Result<(), PushError<T>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed(item));
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(PushError::Full(item));
            }
            let (g2, res) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = g2;
            if res.timed_out() && g.items.len() >= self.capacity {
                return if g.closed {
                    Err(PushError::Closed(item))
                } else {
                    Err(PushError::Full(item))
                };
            }
        }
    }

    /// Pop, waiting up to `timeout` for an item.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<T, PopError> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Ok(item);
            }
            if g.closed {
                return Err(PopError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(PopError::TimedOut);
            }
            let (g2, _res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }

    /// Drain up to `max` items without blocking (batcher fast path).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let k = max.min(g.items.len());
        let out: Vec<T> = g.items.drain(..k).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Close: pushes fail immediately; pops drain then report Closed.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), i);
        }
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Err(PopError::TimedOut));
    }

    #[test]
    fn capacity_enforced() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        assert_eq!(q.len(), 2);
        assert_eq!(
            q.push_timeout(3, Duration::from_millis(5)),
            Err(PushError::Full(3))
        );
    }

    #[test]
    fn close_semantics() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(q.try_push(2), Err(PushError::Closed(2)));
        // drain remaining then Closed
        assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), 1);
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Err(PopError::Closed));
    }

    #[test]
    fn blocking_push_unblocks_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(0).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push_timeout(1, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_timeout(Duration::from_millis(100)).unwrap(), 0);
        h.join().unwrap().unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(100)).unwrap(), 1);
    }

    #[test]
    fn drain_up_to() {
        let q = BoundedQueue::new(10);
        for i in 0..7 {
            q.try_push(i).unwrap();
        }
        let batch = q.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 3);
        let rest = q.drain_up_to(100);
        assert_eq!(rest, vec![4, 5, 6]);
        assert!(q.drain_up_to(5).is_empty());
    }

    #[test]
    fn mpmc_stress() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..250 {
                        q.push_timeout(p * 1000 + i, Duration::from_secs(5)).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    // Keep popping until Closed: close() lets pops drain
                    // whatever is queued first, so exiting only on Closed
                    // (never on TimedOut) makes the count deterministic.
                    loop {
                        match q.pop_timeout(Duration::from_millis(100)) {
                            Ok(v) => got.push(v),
                            Err(PopError::TimedOut) => continue,
                            Err(PopError::Closed) => return got,
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        // Every item is in the queue (or already popped) once the producers
        // have joined; close-after-join + drain-then-Closed pops account for
        // all 1000 without any sleep-based race.
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap().len()).sum();
        assert_eq!(total, 1000);
    }
}
