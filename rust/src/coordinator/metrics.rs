//! Service metrics: atomic counters + lock-free log₂-bucketed latency
//! histograms with percentile estimation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ buckets: bucket i covers [2^i, 2^{i+1}) microseconds;
/// 48 buckets ≈ 8.9 years — effectively unbounded.
const BUCKETS: usize = 48;

/// A log₂-bucketed histogram of microsecond latencies.
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Percentile estimate (upper bucket bound), q in [0, 1].
    pub fn percentile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1); // upper bound of the bucket
            }
        }
        self.max_us()
    }

    /// (count, mean, p50, p99, max) snapshot.
    pub fn snapshot(&self) -> (u64, f64, u64, u64, u64) {
        (
            self.count(),
            self.mean_us(),
            self.percentile_us(0.5),
            self.percentile_us(0.99),
            self.max_us(),
        )
    }
}

/// All service counters.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub rejected_overload: AtomicU64,
    pub deadline_missed: AtomicU64,
    pub pjrt_dispatches: AtomicU64,
    pub native_dispatches: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Batches a worker solved as one blocked multi-RHS LSQR.
    pub blocked_batches: AtomicU64,
    /// Right-hand sides solved through the blocked path (per-RHS count).
    pub blocked_rhs: AtomicU64,
    pub factor_cache_hits: AtomicU64,
    pub factor_cache_misses: AtomicU64,
    /// TCP front-end: `accept()` errors survived (transient kinds retried
    /// with backoff instead of killing the accept loop).
    pub accept_errors: AtomicU64,
    /// TCP front-end: connections accepted / fully retired.
    pub conns_opened: AtomicU64,
    pub conns_closed: AtomicU64,
    /// TCP front-end: solve requests currently in flight (decoded and
    /// submitted, response not yet queued for write) — a gauge.
    pub frontend_inflight: AtomicU64,
    /// High-water mark of `frontend_inflight` (pipelining depth actually
    /// sustained by clients).
    pub frontend_peak_inflight: AtomicU64,
    /// Stable-solver ladder: right-hand sides finally answered by each
    /// stage (sketch-and-solve / preconditioned LSQR / refinement sweeps /
    /// dense QR).
    pub ladder_sas: AtomicU64,
    pub ladder_lsqr: AtomicU64,
    pub ladder_refine: AtomicU64,
    pub ladder_dense: AtomicU64,
    /// Stable-solver ladder: stage escalations (stage entries beyond the
    /// first, summed over right-hand sides).
    pub ladder_escalations: AtomicU64,
    /// Worker batches whose solve panicked and was contained by
    /// `catch_unwind` (each turned into per-request error responses).
    pub worker_panics: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub solve_latency: LatencyHistogram,
    pub e2e_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Decrement a gauge (callers pair this with a prior `inc`).
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Increment the in-flight gauge and fold the new depth into its peak.
    pub fn gauge_enter(gauge: &AtomicU64, peak: &AtomicU64) {
        let now = gauge.fetch_add(1, Ordering::Relaxed) + 1;
        peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Mean requests per batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = Self::get(&self.batches);
        if b == 0 {
            return 0.0;
        }
        Self::get(&self.batched_requests) as f64 / b as f64
    }

    /// Human-readable dump. Includes a kernel worker-pool line (the
    /// process-wide scheduler counters from [`crate::parallel::pool_stats`])
    /// so the OP_METRICS protocol frame surfaces steal rates to clients.
    pub fn report(&self) -> String {
        let (qc, qm, qp50, qp99, qmax) = self.queue_latency.snapshot();
        let (_sc, sm, sp50, sp99, smax) = self.solve_latency.snapshot();
        let (_ec, em, ep50, ep99, emax) = self.e2e_latency.snapshot();
        let pool = crate::parallel::pool_stats();
        format!(
            "submitted={} completed={} failed={} rejected={} deadline_missed={}\n\
             dispatch: pjrt={} native={} | batches={} mean_batch={:.2} \
             blocked_batches={} blocked_rhs={} factor_cache hit={} miss={}\n\
             frontend: conns_opened={} conns_closed={} accept_errors={} \
             inflight={} peak_inflight={}\n\
             ladder: sas={} lsqr={} refine={} dense={} escalations={} \
             worker_panics={}\n\
             queue_us:  n={} mean={:.0} p50={} p99={} max={}\n\
             solve_us:  mean={:.0} p50={} p99={} max={}\n\
             e2e_us:    mean={:.0} p50={} p99={} max={}\n\
             pool: schedule={} regions={} units={} stolen={} \
             steal_rate={:.3} max_depth={}",
            Self::get(&self.submitted),
            Self::get(&self.completed),
            Self::get(&self.failed),
            Self::get(&self.rejected_overload),
            Self::get(&self.deadline_missed),
            Self::get(&self.pjrt_dispatches),
            Self::get(&self.native_dispatches),
            Self::get(&self.batches),
            self.mean_batch_size(),
            Self::get(&self.blocked_batches),
            Self::get(&self.blocked_rhs),
            Self::get(&self.factor_cache_hits),
            Self::get(&self.factor_cache_misses),
            Self::get(&self.conns_opened),
            Self::get(&self.conns_closed),
            Self::get(&self.accept_errors),
            Self::get(&self.frontend_inflight),
            Self::get(&self.frontend_peak_inflight),
            Self::get(&self.ladder_sas),
            Self::get(&self.ladder_lsqr),
            Self::get(&self.ladder_refine),
            Self::get(&self.ladder_dense),
            Self::get(&self.ladder_escalations),
            Self::get(&self.worker_panics),
            qc,
            qm,
            qp50,
            qp99,
            qmax,
            sm,
            sp50,
            sp99,
            smax,
            em,
            ep50,
            ep99,
            emax,
            crate::parallel::active_schedule().name(),
            pool.regions,
            pool.executed,
            pool.stolen,
            pool.steal_rate(),
            pool.max_depth,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_estimates() {
        let h = LatencyHistogram::new();
        for us in [1u64, 2, 3, 10, 100, 1000, 1000, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 1000);
        // p50 of mostly-small values is small; p99 covers the 1000s.
        assert!(h.percentile_us(0.5) <= 128);
        assert!(h.percentile_us(0.99) >= 1000);
        assert!(h.percentile_us(0.99) <= 2048);
    }

    #[test]
    fn histogram_zero_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
        h.record(0); // clamps to bucket 0
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn metrics_counters() {
        let m = Metrics::new();
        Metrics::inc(&m.submitted);
        Metrics::add(&m.batched_requests, 6);
        Metrics::add(&m.batches, 2);
        assert_eq!(Metrics::get(&m.submitted), 1);
        assert_eq!(m.mean_batch_size(), 3.0);
        let rep = m.report();
        assert!(rep.contains("submitted=1"));
        // Scheduler counters ride along in every report (and therefore in
        // the OP_METRICS protocol frame).
        assert!(rep.contains("pool: schedule="));
        assert!(rep.contains("steal_rate="));
        // So do the front-end counters.
        assert!(rep.contains("accept_errors=0"));
        assert!(rep.contains("peak_inflight=0"));
        // And the stable-solver ladder counters.
        Metrics::inc(&m.ladder_refine);
        Metrics::add(&m.ladder_escalations, 2);
        Metrics::inc(&m.worker_panics);
        let rep = m.report();
        assert!(rep.contains("ladder: sas=0 lsqr=0 refine=1 dense=0 escalations=2"));
        assert!(rep.contains("worker_panics=1"));
    }

    #[test]
    fn inflight_gauge_tracks_peak() {
        let m = Metrics::new();
        Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
        Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
        Metrics::dec(&m.frontend_inflight);
        Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
        assert_eq!(Metrics::get(&m.frontend_inflight), 2);
        assert_eq!(Metrics::get(&m.frontend_peak_inflight), 2);
        Metrics::dec(&m.frontend_inflight);
        Metrics::dec(&m.frontend_inflight);
        assert_eq!(Metrics::get(&m.frontend_inflight), 0);
        assert_eq!(Metrics::get(&m.frontend_peak_inflight), 2);
    }

    #[test]
    fn histogram_concurrent() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 1..=1000u64 {
                        h.record(i);
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
