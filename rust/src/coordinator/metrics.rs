//! Service metrics: atomic counters + lock-free log₂-bucketed latency
//! histograms with percentile estimation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ buckets: bucket i covers [2^i, 2^{i+1}) microseconds;
/// 48 buckets ≈ 8.9 years — effectively unbounded.
const BUCKETS: usize = 48;

/// A log₂-bucketed histogram of microsecond latencies.
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Percentile estimate (upper bucket bound), q in [0, 1].
    pub fn percentile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1); // upper bound of the bucket
            }
        }
        self.max_us()
    }

    /// (count, mean, p50, p99, max) snapshot.
    pub fn snapshot(&self) -> (u64, f64, u64, u64, u64) {
        (
            self.count(),
            self.mean_us(),
            self.percentile_us(0.5),
            self.percentile_us(0.99),
            self.max_us(),
        )
    }
}

/// All service counters.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub rejected_overload: AtomicU64,
    pub deadline_missed: AtomicU64,
    pub pjrt_dispatches: AtomicU64,
    pub native_dispatches: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Batches a worker solved as one blocked multi-RHS LSQR.
    pub blocked_batches: AtomicU64,
    /// Right-hand sides solved through the blocked path (per-RHS count).
    pub blocked_rhs: AtomicU64,
    pub factor_cache_hits: AtomicU64,
    pub factor_cache_misses: AtomicU64,
    /// TCP front-end: `accept()` errors survived (transient kinds retried
    /// with backoff instead of killing the accept loop).
    pub accept_errors: AtomicU64,
    /// TCP front-end: connections accepted / fully retired.
    pub conns_opened: AtomicU64,
    pub conns_closed: AtomicU64,
    /// TCP front-end: solve requests currently in flight (decoded and
    /// submitted, response not yet queued for write) — a gauge.
    pub frontend_inflight: AtomicU64,
    /// High-water mark of `frontend_inflight` (pipelining depth actually
    /// sustained by clients).
    pub frontend_peak_inflight: AtomicU64,
    /// Stable-solver ladder: right-hand sides finally answered by each
    /// stage (sketch-and-solve / preconditioned LSQR / refinement sweeps /
    /// dense QR).
    pub ladder_sas: AtomicU64,
    pub ladder_lsqr: AtomicU64,
    pub ladder_refine: AtomicU64,
    pub ladder_dense: AtomicU64,
    /// Stable-solver ladder: stage escalations (stage entries beyond the
    /// first, summed over right-hand sides).
    pub ladder_escalations: AtomicU64,
    /// Worker batches whose solve panicked and was contained by
    /// `catch_unwind` (each turned into per-request error responses).
    pub worker_panics: AtomicU64,
    /// Shard router: per-shard attempts beyond the first for one request
    /// (same-shard resends after a transient failure).
    pub router_retries: AtomicU64,
    /// Shard router: requests that switched to a replica after exhausting
    /// the owning shard.
    pub router_failovers: AtomicU64,
    /// Shard router: matrices re-registered onto new owners during
    /// rebalance/handoff (membership-change repair traffic).
    pub router_rebalanced: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub solve_latency: LatencyHistogram,
    pub e2e_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Decrement a gauge (callers pair this with a prior `inc`).
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Increment the in-flight gauge and fold the new depth into its peak.
    pub fn gauge_enter(gauge: &AtomicU64, peak: &AtomicU64) {
        let now = gauge.fetch_add(1, Ordering::Relaxed) + 1;
        peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Mean requests per batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = Self::get(&self.batches);
        if b == 0 {
            return 0.0;
        }
        Self::get(&self.batched_requests) as f64 / b as f64
    }

    /// Human-readable dump. Includes a kernel worker-pool line (the
    /// process-wide scheduler counters from [`crate::parallel::pool_stats`])
    /// so the OP_METRICS protocol frame surfaces steal rates to clients.
    pub fn report(&self) -> String {
        let (qc, qm, qp50, qp99, qmax) = self.queue_latency.snapshot();
        let (_sc, sm, sp50, sp99, smax) = self.solve_latency.snapshot();
        let (_ec, em, ep50, ep99, emax) = self.e2e_latency.snapshot();
        let pool = crate::parallel::pool_stats();
        format!(
            "submitted={} completed={} failed={} rejected={} deadline_missed={}\n\
             dispatch: pjrt={} native={} | batches={} mean_batch={:.2} \
             blocked_batches={} blocked_rhs={} factor_cache hit={} miss={}\n\
             frontend: conns_opened={} conns_closed={} accept_errors={} \
             inflight={} peak_inflight={}\n\
             ladder: sas={} lsqr={} refine={} dense={} escalations={} \
             worker_panics={}\n\
             queue_us:  n={} mean={:.0} p50={} p99={} max={}\n\
             solve_us:  mean={:.0} p50={} p99={} max={}\n\
             e2e_us:    mean={:.0} p50={} p99={} max={}\n\
             pool: schedule={} regions={} units={} stolen={} \
             steal_rate={:.3} max_depth={}",
            Self::get(&self.submitted),
            Self::get(&self.completed),
            Self::get(&self.failed),
            Self::get(&self.rejected_overload),
            Self::get(&self.deadline_missed),
            Self::get(&self.pjrt_dispatches),
            Self::get(&self.native_dispatches),
            Self::get(&self.batches),
            self.mean_batch_size(),
            Self::get(&self.blocked_batches),
            Self::get(&self.blocked_rhs),
            Self::get(&self.factor_cache_hits),
            Self::get(&self.factor_cache_misses),
            Self::get(&self.conns_opened),
            Self::get(&self.conns_closed),
            Self::get(&self.accept_errors),
            Self::get(&self.frontend_inflight),
            Self::get(&self.frontend_peak_inflight),
            Self::get(&self.ladder_sas),
            Self::get(&self.ladder_lsqr),
            Self::get(&self.ladder_refine),
            Self::get(&self.ladder_dense),
            Self::get(&self.ladder_escalations),
            Self::get(&self.worker_panics),
            qc,
            qm,
            qp50,
            qp99,
            qmax,
            sm,
            sp50,
            sp99,
            smax,
            em,
            ep50,
            ep99,
            emax,
            crate::parallel::active_schedule().name(),
            pool.regions,
            pool.executed,
            pool.stolen,
            pool.steal_rate(),
            pool.max_depth,
        )
    }
}

/// Aggregate several per-shard [`Metrics::report`] strings into one
/// cluster-wide view (the router's `OP_METRICS` response body).
///
/// Token-aligned combination: every `key=<u64>` token is **summed** across
/// reports, except on the latency lines (`queue_us:`/`solve_us:`/`e2e_us:`)
/// where the **max** is taken — summing percentiles across shards would
/// fabricate latencies nobody observed, while the worst shard's tail is a
/// meaningful cluster number. Non-integer tokens (means, rates, schedule
/// names) are taken from the first report verbatim. Reports whose line
/// shape diverges (e.g. mixed server versions) fall back to verbatim
/// concatenation rather than misaligned sums.
pub fn aggregate_reports(reports: &[String]) -> String {
    let Some(first) = reports.first() else {
        return String::new();
    };
    if reports.len() == 1 {
        return first.clone();
    }
    let lines: Vec<Vec<&str>> = reports.iter().map(|r| r.lines().collect()).collect();
    if lines.iter().any(|l| l.len() != lines[0].len()) {
        return reports.join("\n---\n");
    }
    let mut out = Vec::with_capacity(lines[0].len());
    for li in 0..lines[0].len() {
        let toks: Vec<Vec<&str>> =
            lines.iter().map(|l| l[li].split_whitespace().collect()).collect();
        if toks.iter().any(|t| t.len() != toks[0].len()) {
            out.push(lines[0][li].to_string());
            continue;
        }
        let take_max = matches!(toks[0].first(), Some(&"queue_us:" | &"solve_us:" | &"e2e_us:"));
        let mut line = Vec::with_capacity(toks[0].len());
        for tj in 0..toks[0].len() {
            line.push(combine_token(&toks, tj, take_max));
        }
        out.push(line.join(" "));
    }
    out.join("\n")
}

/// Combine token `tj` across every report's tokenized line: summed (or
/// maxed) when every report has `key=<u64>` with the same key, otherwise
/// the first report's token verbatim.
fn combine_token(toks: &[Vec<&str>], tj: usize, take_max: bool) -> String {
    let template = toks[0][tj];
    let Some((key, _)) = template.split_once('=') else {
        return template.to_string();
    };
    let mut acc: u64 = 0;
    for t in toks {
        let Some((k, v)) = t[tj].split_once('=') else {
            return template.to_string();
        };
        let Ok(v) = v.parse::<u64>() else {
            return template.to_string();
        };
        if k != key {
            return template.to_string();
        }
        acc = if take_max { acc.max(v) } else { acc + v };
    }
    format!("{key}={acc}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_estimates() {
        let h = LatencyHistogram::new();
        for us in [1u64, 2, 3, 10, 100, 1000, 1000, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 1000);
        // p50 of mostly-small values is small; p99 covers the 1000s.
        assert!(h.percentile_us(0.5) <= 128);
        assert!(h.percentile_us(0.99) >= 1000);
        assert!(h.percentile_us(0.99) <= 2048);
    }

    #[test]
    fn histogram_zero_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
        h.record(0); // clamps to bucket 0
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn metrics_counters() {
        let m = Metrics::new();
        Metrics::inc(&m.submitted);
        Metrics::add(&m.batched_requests, 6);
        Metrics::add(&m.batches, 2);
        assert_eq!(Metrics::get(&m.submitted), 1);
        assert_eq!(m.mean_batch_size(), 3.0);
        let rep = m.report();
        assert!(rep.contains("submitted=1"));
        // Scheduler counters ride along in every report (and therefore in
        // the OP_METRICS protocol frame).
        assert!(rep.contains("pool: schedule="));
        assert!(rep.contains("steal_rate="));
        // So do the front-end counters.
        assert!(rep.contains("accept_errors=0"));
        assert!(rep.contains("peak_inflight=0"));
        // And the stable-solver ladder counters.
        Metrics::inc(&m.ladder_refine);
        Metrics::add(&m.ladder_escalations, 2);
        Metrics::inc(&m.worker_panics);
        let rep = m.report();
        assert!(rep.contains("ladder: sas=0 lsqr=0 refine=1 dense=0 escalations=2"));
        assert!(rep.contains("worker_panics=1"));
    }

    #[test]
    fn aggregate_sums_counters_and_maxes_latencies() {
        let a = Metrics::new();
        Metrics::add(&a.submitted, 3);
        Metrics::add(&a.completed, 3);
        a.queue_latency.record(100);
        let b = Metrics::new();
        Metrics::add(&b.submitted, 4);
        Metrics::add(&b.completed, 2);
        b.queue_latency.record(4000);
        let agg = aggregate_reports(&[a.report(), b.report()]);
        // Counters sum across shards.
        assert!(agg.contains("submitted=7"), "bad aggregate:\n{agg}");
        assert!(agg.contains("completed=5"));
        // Latency tokens take the worst shard, not the sum: both shards
        // recorded one sample, so n=1 must survive (a sum would say 2).
        let qline = agg.lines().find(|l| l.starts_with("queue_us:")).unwrap();
        assert!(qline.contains("n=1"), "latency n must be maxed: {qline}");
        // Max latency comes from shard b's 4000us sample.
        assert!(qline.contains("max=4000"), "{qline}");
        // Non-integer tokens survive from the first report.
        assert!(agg.contains("pool: schedule="));
        // Degenerate shapes: empty and singleton. (Snapshot the report
        // once — the pool counters inside are process-global and move as
        // other tests run.)
        assert_eq!(aggregate_reports(&[]), "");
        let ra = a.report();
        assert_eq!(aggregate_reports(&[ra.clone()]), ra);
        // Shape mismatch falls back to concatenation, never misaligned sums.
        let odd = aggregate_reports(&[ra, "just one line".to_string()]);
        assert!(odd.contains("---"));
        assert!(odd.contains("just one line"));
    }

    #[test]
    fn router_counters_present() {
        let m = Metrics::new();
        Metrics::inc(&m.router_retries);
        Metrics::inc(&m.router_failovers);
        Metrics::add(&m.router_rebalanced, 3);
        assert_eq!(Metrics::get(&m.router_retries), 1);
        assert_eq!(Metrics::get(&m.router_failovers), 1);
        assert_eq!(Metrics::get(&m.router_rebalanced), 3);
    }

    #[test]
    fn inflight_gauge_tracks_peak() {
        let m = Metrics::new();
        Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
        Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
        Metrics::dec(&m.frontend_inflight);
        Metrics::gauge_enter(&m.frontend_inflight, &m.frontend_peak_inflight);
        assert_eq!(Metrics::get(&m.frontend_inflight), 2);
        assert_eq!(Metrics::get(&m.frontend_peak_inflight), 2);
        Metrics::dec(&m.frontend_inflight);
        Metrics::dec(&m.frontend_inflight);
        assert_eq!(Metrics::get(&m.frontend_inflight), 0);
        assert_eq!(Metrics::get(&m.frontend_peak_inflight), 2);
    }

    #[test]
    fn histogram_concurrent() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 1..=1000u64 {
                        h.record(i);
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
