//! Compressed sparse row (CSR) matrices.
//!
//! The Figure-3 workload (m up to 2²⁰, n = 1000) is infeasible dense
//! (≈ 33 GB); the paper uses "sparsified" matrices. CSR is the layout the
//! LSQR inner loop wants: `A·v` streams rows, `Aᵀ·u` scatters per-row, both
//! one pass over the nonzeros.

use super::dense::DenseMatrix;
use super::{LinalgError, Result};

/// Coordinate-format builder; finalize into [`CsrMatrix`].
#[derive(Debug, Clone, Default)]
pub struct CooBuilder {
    rows: usize,
    cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl CooBuilder {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, entries: Vec::new() }
    }

    pub fn with_capacity(rows: usize, cols: usize, nnz: usize) -> Self {
        Self { rows, cols, entries: Vec::with_capacity(nnz) }
    }

    /// Add `value` at `(i, j)`; duplicates are summed on finalize.
    pub fn push(&mut self, i: usize, j: usize, value: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        if value != 0.0 {
            self.entries.push((i as u32, j as u32, value));
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Sort, merge duplicates, compress to CSR.
    pub fn build(mut self) -> CsrMatrix {
        self.entries
            .sort_unstable_by_key(|&(i, j, _)| ((i as u64) << 32) | j as u64);
        let mut indices: Vec<u32> = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut row_counts = vec![0u64; self.rows];
        let mut last: Option<(u32, u32)> = None;
        for &(i, j, v) in &self.entries {
            if last == Some((i, j)) {
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(j);
                values.push(v);
                row_counts[i as usize] += 1;
                last = Some((i, j));
            }
        }
        let mut indptr = vec![0u64; self.rows + 1];
        for r in 0..self.rows {
            indptr[r + 1] = indptr[r] + row_counts[r];
        }
        CsrMatrix { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

/// Compressed sparse row matrix, f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<u64>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Construct from raw CSR arrays, validating the invariants.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<u64>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Self> {
        if indptr.len() != rows + 1 {
            return Err(LinalgError::InvalidArgument(format!(
                "indptr len {} != rows+1 {}",
                indptr.len(),
                rows + 1
            )));
        }
        if indptr[0] != 0 || *indptr.last().unwrap() as usize != indices.len() {
            return Err(LinalgError::InvalidArgument("indptr endpoints invalid".into()));
        }
        if indices.len() != values.len() {
            return Err(LinalgError::InvalidArgument("indices/values length mismatch".into()));
        }
        for w in indptr.windows(2) {
            if w[1] < w[0] {
                return Err(LinalgError::InvalidArgument("indptr not monotone".into()));
            }
        }
        if indices.iter().any(|&j| j as usize >= cols) {
            return Err(LinalgError::InvalidArgument("column index out of range".into()));
        }
        Ok(Self { rows, cols, indptr, indices, values })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density = nnz / (rows*cols).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// `(column indices, values)` of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.indptr[i] as usize;
        let hi = self.indptr[i + 1] as usize;
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "csr matvec: x len {} != cols {}", x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = A x` into a caller buffer (no allocation — LSQR hot loop).
    ///
    /// Parallel: y's entries shard into contiguous row blocks behind an
    /// nnz-sized [`crate::parallel::PAR_MIN_ELEMS`] gate. Each entry is
    /// one row's scalar accumulation in index order, so every entry is
    /// **bitwise identical** to the serial loop at any thread count and
    /// under either scheduler. Row *counts* split evenly but row *costs*
    /// need not (skewed nnz profiles) — exactly the imbalance the steal
    /// scheduler exists for (`benches/micro_linalg.rs` pool sweep).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(y.len(), self.rows);
        let threads = if self.nnz() < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(self.rows, 8)
        };
        if threads <= 1 {
            for i in 0..self.rows {
                let (idx, vals) = self.row(i);
                let mut s = 0.0;
                for (&j, &v) in idx.iter().zip(vals.iter()) {
                    s += v * x[j as usize];
                }
                y[i] = s;
            }
            return;
        }
        crate::parallel::for_each_row_block(y, self.rows, 1, threads, |_, rows, yblock| {
            for (local, i) in rows.enumerate() {
                let (idx, vals) = self.row(i);
                let mut s = 0.0;
                for (&j, &v) in idx.iter().zip(vals.iter()) {
                    s += v * x[j as usize];
                }
                yblock[local] = s;
            }
        });
    }

    /// `y = Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "csr matvec_t: x len {} != rows {}", x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// `y = Aᵀ x` into a caller buffer.
    ///
    /// Zero coefficients are **not** skipped: `0 · NaN`/`0 · Inf` stored in
    /// A must reach y (same IEEE contract as the dense `matvec_t`), so
    /// non-finite propagation does not depend on the sparsity of x.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            let lo = self.indptr[i] as usize;
            let hi = self.indptr[i + 1] as usize;
            for k in lo..hi {
                y[self.indices[k] as usize] += self.values[k] * xi;
            }
        }
    }

    /// Dense materialization (tests / small problems only).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            for (&j, &v) in idx.iter().zip(vals.iter()) {
                d[(i, j as usize)] += v;
            }
        }
        d
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        super::norms::nrm2(&self.values)
    }

    /// Scale all values in place.
    pub fn scale(&mut self, alpha: f64) {
        for v in self.values.iter_mut() {
            *v *= alpha;
        }
    }

    /// Dense `B = A · X` where `X` is (cols × k) dense — used when sketching
    /// sparse matrices against dense test inputs.
    pub fn matmul_dense(&self, x: &DenseMatrix) -> Result<DenseMatrix> {
        if self.cols != x.rows() {
            return Err(LinalgError::DimensionMismatch(format!(
                "csr matmul_dense: ({}x{}) · ({}x{})",
                self.rows,
                self.cols,
                x.rows(),
                x.cols()
            )));
        }
        let k = x.cols();
        let mut out = DenseMatrix::zeros(self.rows, k);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (&j, &v) in idx.iter().zip(vals.iter()) {
                let xrow = x.row(j as usize);
                super::gemm::axpy(v, xrow, orow);
            }
        }
        Ok(out)
    }

    /// Column 2-norms (for scaling/diagnostics).
    pub fn col_norms(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.cols];
        for (&j, &v) in self.indices.iter().zip(self.values.iter()) {
            s[j as usize] += v * v;
        }
        for v in s.iter_mut() {
            *v = v.sqrt();
        }
        s
    }

    pub fn indptr(&self) -> &[u64] {
        &self.indptr
    }

    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, RngCore, Xoshiro256pp};

    fn small() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        let mut b = CooBuilder::new(3, 3);
        b.push(0, 0, 1.0);
        b.push(0, 2, 2.0);
        b.push(2, 0, 3.0);
        b.push(2, 1, 4.0);
        b.build()
    }

    #[test]
    fn build_and_shape() {
        let a = small();
        assert_eq!(a.shape(), (3, 3));
        assert_eq!(a.nnz(), 4);
        let (idx, vals) = a.row(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(vals, &[1.0, 2.0]);
        let (idx1, _) = a.row(1);
        assert!(idx1.is_empty());
    }

    #[test]
    fn duplicates_are_summed() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 1, 1.5);
        b.push(0, 1, 2.5);
        b.push(1, 0, 1.0);
        let a = b.build();
        assert_eq!(a.to_dense()[(0, 1)], 4.0);
        assert_eq!(a.to_dense()[(1, 0)], 1.0);
    }

    #[test]
    fn matvec_known() {
        let a = small();
        let y = a.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 0.0, 7.0]);
        let yt = a.matvec_t(&[1.0, 1.0, 1.0]);
        assert_eq!(yt, vec![4.0, 4.0, 2.0]);
    }

    #[test]
    fn matvec_matches_dense_random() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let (m, n) = (64, 37);
        let mut b = CooBuilder::new(m, n);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(12));
        for _ in 0..400 {
            let i = rng.next_bounded(m as u64) as usize;
            let j = rng.next_bounded(n as u64) as usize;
            b.push(i, j, g.next_gaussian());
        }
        let a = b.build();
        let d = a.to_dense();
        let x = g.gaussian_vec(n);
        let u = g.gaussian_vec(m);
        let y_s = a.matvec(&x);
        let y_d = d.matvec(&x);
        for (s, dd) in y_s.iter().zip(y_d.iter()) {
            assert!((s - dd).abs() < 1e-12);
        }
        let z_s = a.matvec_t(&u);
        let z_d = d.matvec_t(&u);
        for (s, dd) in z_s.iter().zip(z_d.iter()) {
            assert!((s - dd).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_dense_matches() {
        let a = small();
        let x = DenseMatrix::from_fn(3, 2, |i, j| (i + j) as f64);
        let c = a.matmul_dense(&x).unwrap();
        let c_ref = a.to_dense().matmul(&x).unwrap();
        assert!(c.fro_distance(&c_ref) < 1e-13);
    }

    #[test]
    fn from_raw_validation() {
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).is_ok());
        // bad indptr length
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        // non-monotone
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 1, 0], vec![0], vec![1.0]).is_err());
        // column out of range
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        // len mismatch
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 1], vec![0], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn col_norms_and_scale() {
        let mut a = small();
        let n = a.col_norms();
        assert!((n[0] - (1.0f64 + 9.0).sqrt()).abs() < 1e-14);
        assert!((n[1] - 4.0).abs() < 1e-14);
        a.scale(2.0);
        assert_eq!(a.to_dense()[(2, 1)], 8.0);
    }

    #[test]
    fn density() {
        let a = small();
        assert!((a.density() - 4.0 / 9.0).abs() < 1e-15);
    }
}
