//! The `LinearOperator` abstraction LSQR iterates against.
//!
//! LSQR only ever needs `u ← Av` and `v ← Aᵀu`; abstracting them lets one
//! solver implementation run over dense matrices, CSR matrices, the
//! implicitly preconditioned operator `Y = A R⁻¹` (never materialized for
//! sparse A), and the perturbed operator `Ã = A + σG/√m`.

use super::dense::DenseMatrix;
use super::sparse::CsrMatrix;
use super::triangular;

/// A (possibly implicit) m×n linear map with transpose action.
///
/// `Sync` is a supertrait so the blocked multi-RHS paths ([`apply_mat`],
/// [`apply_transpose_mat`]) can shard a block of vectors across the scoped
/// worker pool; every operator in the crate is plain data or shared
/// references, so the bound costs nothing.
///
/// [`apply_mat`]: LinearOperator::apply_mat
/// [`apply_transpose_mat`]: LinearOperator::apply_transpose_mat
pub trait LinearOperator: Sync {
    /// `(m, n)`.
    fn shape(&self) -> (usize, usize);

    /// `y = A x` (y has length m, pre-allocated).
    fn apply(&self, x: &[f64], y: &mut [f64]);

    /// `y = Aᵀ x` (y has length n, pre-allocated).
    fn apply_transpose(&self, x: &[f64], y: &mut [f64]);

    /// Convenience allocating forms.
    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.shape().0];
        self.apply(x, &mut y);
        y
    }

    fn apply_transpose_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.shape().1];
        self.apply_transpose(x, &mut y);
        y
    }

    /// Blocked forward apply: `y[r, :] = A x[r, :]` for a row-stored block
    /// of k vectors (`x` is k×n, `y` is k×m — row r holds vector r).
    ///
    /// Contract: row r is **bitwise identical** to `apply(x.row(r), ..)` at
    /// any thread count — the blocked LSQR path relies on this to stay
    /// per-RHS equivalent to the single-vector path. The default shards the
    /// k rows across the pool, each computed by the serial vector kernel.
    fn apply_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        let (m, n) = self.shape();
        let k = x.rows();
        assert_eq!(x.cols(), n, "apply_mat: x block has {} cols, A has {n}", x.cols());
        assert_eq!(y.shape(), (k, m), "apply_mat: y block is {:?}, need ({k}, {m})", y.shape());
        let work = k.saturating_mul(m.saturating_mul(n.max(1)));
        let threads = if work < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(k, 1)
        };
        crate::parallel::for_each_row_block(y.data_mut(), k, m, threads, |_, rows, block| {
            for (local, r) in rows.enumerate() {
                self.apply(x.row(r), &mut block[local * m..(local + 1) * m]);
            }
        });
    }

    /// Blocked transpose apply: `y[r, :] = Aᵀ x[r, :]` (`x` is k×m, `y` is
    /// k×n). Same bitwise-per-row contract as [`apply_mat`].
    ///
    /// [`apply_mat`]: LinearOperator::apply_mat
    fn apply_transpose_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        let (m, n) = self.shape();
        let k = x.rows();
        assert_eq!(x.cols(), m, "apply_transpose_mat: block has {} cols, A has {m} rows", x.cols());
        assert_eq!(
            y.shape(),
            (k, n),
            "apply_transpose_mat: y block is {:?}, need ({k}, {n})",
            y.shape()
        );
        let work = k.saturating_mul(m.saturating_mul(n.max(1)));
        let threads = if work < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(k, 1)
        };
        crate::parallel::for_each_row_block(y.data_mut(), k, n, threads, |_, rows, block| {
            for (local, r) in rows.enumerate() {
                self.apply_transpose(x.row(r), &mut block[local * n..(local + 1) * n]);
            }
        });
    }
}

impl LinearOperator for DenseMatrix {
    fn shape(&self) -> (usize, usize) {
        DenseMatrix::shape(self)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        super::gemm::matvec_into(self, x, y, 0.0);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        let out = super::gemm::matvec_t(self, x);
        y.copy_from_slice(&out);
    }

    /// GEMM-shaped block apply: the outer loop streams each row of A
    /// exactly once and dots it against all k (cache-resident) input rows —
    /// k× less memory traffic than k independent matvecs, which is where
    /// the blocked multi-RHS LSQR win comes from on memory-bound sizes.
    /// Each output element is the same `dot(A.row(i), x_r)` the serial
    /// matvec computes, so every column stays bitwise identical to
    /// [`LinearOperator::apply`] at any thread count.
    fn apply_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        let (m, n) = DenseMatrix::shape(self);
        let k = x.rows();
        assert_eq!(x.cols(), n, "apply_mat: x block has {} cols, A has {n}", x.cols());
        assert_eq!(y.shape(), (k, m), "apply_mat: y block is {:?}, need ({k}, {m})", y.shape());
        let work = k.saturating_mul(m.saturating_mul(n.max(1)));
        let threads = if work < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(m, 64)
        };
        if threads <= 1 {
            for i in 0..m {
                let arow = self.row(i);
                for r in 0..k {
                    y[(r, i)] = super::gemm::dot(arow, x.row(r));
                }
            }
            return;
        }
        // Shard A's rows (= output columns); the k-strided writes are
        // disjoint per element, expressed through the raw-pointer escape
        // hatch the FWHT column bands use.
        let yptr = crate::parallel::SendMutPtr(y.data_mut().as_mut_ptr());
        crate::parallel::run_partitioned(m, threads, |_, range| {
            for i in range {
                let arow = self.row(i);
                for r in 0..k {
                    let v = super::gemm::dot(arow, x.row(r));
                    // SAFETY: (r, i) pairs are disjoint across partitions
                    // (each worker owns a distinct i-range) and the buffer
                    // outlives the scoped threads.
                    unsafe {
                        *yptr.0.add(r * m + i) = v;
                    }
                }
            }
        });
    }

    /// Blocked transpose apply with a shared pass over A: for each input
    /// row i, `y[r, :] += x[r, i] · A[i, :]` for every r in the worker's
    /// row shard — the per-row accumulation order (i ascending, zero
    /// coefficients **not** skipped, same IEEE contract as `matvec_t`)
    /// matches `matvec_t` exactly, so each row is bitwise identical to
    /// [`LinearOperator::apply_transpose`].
    fn apply_transpose_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        let (m, n) = DenseMatrix::shape(self);
        let k = x.rows();
        assert_eq!(x.cols(), m, "apply_transpose_mat: block has {} cols, A has {m} rows", x.cols());
        assert_eq!(
            y.shape(),
            (k, n),
            "apply_transpose_mat: y block is {:?}, need ({k}, {n})",
            y.shape()
        );
        let work = k.saturating_mul(m.saturating_mul(n.max(1)));
        let threads = if work < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(k, 1)
        };
        // Hoisted once per pass: this axpy runs m·k times per apply, so the
        // per-call dispatch (atomic load + vtable) would sit in the
        // innermost loop. Same kernel object `matvec_t` resolves, so the
        // bitwise-per-row contract is unaffected.
        let kern = crate::simd::kernels();
        crate::parallel::for_each_row_block(y.data_mut(), k, n, threads, |_, rows, block| {
            block.fill(0.0);
            for i in 0..m {
                let arow = self.row(i);
                for (local, r) in rows.clone().enumerate() {
                    let xi = x[(r, i)];
                    kern.axpy(xi, arow, &mut block[local * n..(local + 1) * n]);
                }
            }
        });
    }
}

impl LinearOperator for CsrMatrix {
    fn shape(&self) -> (usize, usize) {
        CsrMatrix::shape(self)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_t_into(x, y);
    }
}

/// The right-preconditioned operator `Y = A R⁻¹` without materializing Y —
/// essential for sparse A (Y would be dense m×n).
///
/// `Y v = A (R⁻¹ v)` and `Yᵀ u = R⁻ᵀ (Aᵀ u)`.
pub struct PreconditionedOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    r: &'a DenseMatrix,
}

impl<'a, Op: LinearOperator + ?Sized> PreconditionedOperator<'a, Op> {
    /// `r` must be n×n upper triangular and nonsingular.
    pub fn new(a: &'a Op, r: &'a DenseMatrix) -> Self {
        debug_assert_eq!(a.shape().1, r.rows());
        debug_assert_eq!(r.rows(), r.cols());
        Self { a, r }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for PreconditionedOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let w = triangular::solve_upper(self.r, x).expect("R singular in preconditioned apply");
        self.a.apply(&w, y);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        let w = self.a.apply_transpose_vec(x);
        let z = triangular::solve_upper_transpose(self.r, &w)
            .expect("R singular in preconditioned apply_transpose");
        y.copy_from_slice(&z);
    }

    /// Blocked `Y X = A (R⁻¹ X)`: one row-parallel triangular solve over
    /// the block, then the inner operator's blocked apply (the dense fast
    /// path when A is dense). Row r stays bitwise identical to `apply`.
    fn apply_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        let w = triangular::solve_upper_block(self.r, x)
            .expect("R singular in preconditioned apply_mat");
        self.a.apply_mat(&w, y);
    }

    fn apply_transpose_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        self.a.apply_transpose_mat(x, y);
        let z = triangular::solve_upper_transpose_block(self.r, y)
            .expect("R singular in preconditioned apply_transpose_mat");
        y.data_mut().copy_from_slice(z.data());
    }
}

/// The perturbed operator `Ã = A + (σ/√m) G` from Algorithm 1 line 11,
/// applied implicitly (G is a dense Gaussian held separately so the original
/// A — possibly sparse — is untouched).
pub struct PerturbedOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    g: &'a DenseMatrix,
    scale: f64,
}

impl<'a, Op: LinearOperator + ?Sized> PerturbedOperator<'a, Op> {
    pub fn new(a: &'a Op, g: &'a DenseMatrix, sigma: f64) -> Self {
        debug_assert_eq!(a.shape(), g.shape());
        let m = a.shape().0;
        Self { a, g, scale: sigma / (m as f64).sqrt() }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for PerturbedOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply(x, y);
        let gy = self.g.matvec(x);
        for (yi, gi) in y.iter_mut().zip(gy.iter()) {
            *yi += self.scale * gi;
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply_transpose(x, y);
        let gy = self.g.matvec_t(x);
        for (yi, gi) in y.iter_mut().zip(gy.iter()) {
            *yi += self.scale * gi;
        }
    }
}

/// Scaled identity-augmented operator for damped least squares
/// `min ‖Ax−b‖² + λ²‖x‖²` — exposed for completeness/testing of LSQR's
/// damping path.
pub struct ScaledOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    alpha: f64,
}

impl<'a, Op: LinearOperator + ?Sized> ScaledOperator<'a, Op> {
    pub fn new(a: &'a Op, alpha: f64) -> Self {
        Self { a, alpha }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for ScaledOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply(x, y);
        for v in y.iter_mut() {
            *v *= self.alpha;
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply_transpose(x, y);
        for v in y.iter_mut() {
            *v *= self.alpha;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::qr;
    use crate::linalg::sparse::CooBuilder;
    use crate::rng::{GaussianSource, RngCore, Xoshiro256pp};

    #[test]
    fn dense_operator_matches_methods() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(51));
        let a = DenseMatrix::gaussian(13, 7, &mut g);
        let x = g.gaussian_vec(7);
        let u = g.gaussian_vec(13);
        assert_eq!(LinearOperator::shape(&a), (13, 7));
        assert_eq!(a.apply_vec(&x), a.matvec(&x));
        assert_eq!(a.apply_transpose_vec(&u), a.matvec_t(&u));
    }

    #[test]
    fn csr_operator_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(53));
        let mut b = CooBuilder::new(20, 9);
        for _ in 0..60 {
            b.push(
                rng.next_bounded(20) as usize,
                rng.next_bounded(9) as usize,
                g.next_gaussian(),
            );
        }
        let s = b.build();
        let d = s.to_dense();
        let x = g.gaussian_vec(9);
        let u = g.gaussian_vec(20);
        let ys = s.apply_vec(&x);
        let yd = d.apply_vec(&x);
        for (a, b) in ys.iter().zip(yd.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
        let zs = s.apply_transpose_vec(&u);
        let zd = d.apply_transpose_vec(&u);
        for (a, b) in zs.iter().zip(zd.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn preconditioned_operator_is_a_rinv() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(54));
        let a = DenseMatrix::gaussian(30, 8, &mut g);
        let f = qr(&a).unwrap();
        let op = PreconditionedOperator::new(&a, &f.r);
        // Explicit Y = A R^{-1}.
        let y = crate::linalg::triangular::right_solve_upper(&a, &f.r).unwrap();
        let x = g.gaussian_vec(8);
        let u = g.gaussian_vec(30);
        let y1 = op.apply_vec(&x);
        let y2 = y.matvec(&x);
        for (p, q) in y1.iter().zip(y2.iter()) {
            assert!((p - q).abs() < 1e-10);
        }
        let z1 = op.apply_transpose_vec(&u);
        let z2 = y.matvec_t(&u);
        for (p, q) in z1.iter().zip(z2.iter()) {
            assert!((p - q).abs() < 1e-10);
        }
    }

    #[test]
    fn perturbed_operator_matches_explicit_sum() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(55));
        let a = DenseMatrix::gaussian(16, 5, &mut g);
        let gm = DenseMatrix::gaussian(16, 5, &mut g);
        let sigma = 0.3;
        let op = PerturbedOperator::new(&a, &gm, sigma);
        let mut explicit = a.clone();
        explicit.axpy(sigma / 4.0, &gm).unwrap(); // sqrt(16) = 4
        let x = g.gaussian_vec(5);
        let u = g.gaussian_vec(16);
        let y1 = op.apply_vec(&x);
        let y2 = explicit.matvec(&x);
        for (p, q) in y1.iter().zip(y2.iter()) {
            assert!((p - q).abs() < 1e-12);
        }
        let z1 = op.apply_transpose_vec(&u);
        let z2 = explicit.matvec_t(&u);
        for (p, q) in z1.iter().zip(z2.iter()) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn scaled_operator() {
        let a = DenseMatrix::eye(3);
        let op = ScaledOperator::new(&a, 2.5);
        assert_eq!(op.apply_vec(&[1.0, 2.0, 0.0]), vec![2.5, 5.0, 0.0]);
        assert_eq!(op.apply_transpose_vec(&[1.0, 0.0, 2.0]), vec![2.5, 0.0, 5.0]);
    }

    /// The contract every blocked path relies on: row r of the block apply
    /// is bitwise the single-vector apply of row r.
    fn assert_block_matches_rows<Op: LinearOperator + ?Sized>(op: &Op, k: usize, seed: u64) {
        let (m, n) = op.shape();
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let x = DenseMatrix::gaussian(k, n, &mut g);
        let u = DenseMatrix::gaussian(k, m, &mut g);
        let mut y = DenseMatrix::zeros(k, m);
        op.apply_mat(&x, &mut y);
        let mut v = DenseMatrix::zeros(k, n);
        op.apply_transpose_mat(&u, &mut v);
        for r in 0..k {
            assert_eq!(y.row(r), &op.apply_vec(x.row(r))[..], "apply row {r}");
            assert_eq!(v.row(r), &op.apply_transpose_vec(u.row(r))[..], "transpose row {r}");
        }
    }

    #[test]
    fn dense_block_apply_matches_per_row_bitwise() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(56));
        let a = DenseMatrix::gaussian(37, 9, &mut g);
        for k in [1usize, 2, 5, 16] {
            assert_block_matches_rows(&a, k, 57 + k as u64);
        }
        // Degenerate empty block.
        let x = DenseMatrix::zeros(0, 9);
        let mut y = DenseMatrix::zeros(0, 37);
        a.apply_mat(&x, &mut y);
    }

    #[test]
    fn csr_block_apply_matches_per_row_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(58);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(59));
        let mut b = CooBuilder::new(40, 7);
        for _ in 0..120 {
            b.push(
                rng.next_bounded(40) as usize,
                rng.next_bounded(7) as usize,
                g.next_gaussian(),
            );
        }
        let s = b.build();
        assert_block_matches_rows(&s, 4, 60);
    }

    #[test]
    fn preconditioned_block_apply_matches_per_row_bitwise() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(61));
        let a = DenseMatrix::gaussian(50, 8, &mut g);
        let f = qr(&a).unwrap();
        let op = PreconditionedOperator::new(&a, &f.r);
        assert_block_matches_rows(&op, 5, 62);
    }
}
