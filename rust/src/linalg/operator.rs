//! The `LinearOperator` abstraction LSQR iterates against.
//!
//! LSQR only ever needs `u ← Av` and `v ← Aᵀu`; abstracting them lets one
//! solver implementation run over dense matrices, CSR matrices, the
//! implicitly preconditioned operator `Y = A R⁻¹` (never materialized for
//! sparse A), and the perturbed operator `Ã = A + σG/√m`.

use super::dense::DenseMatrix;
use super::sparse::CsrMatrix;
use super::triangular;

/// A (possibly implicit) m×n linear map with transpose action.
pub trait LinearOperator {
    /// `(m, n)`.
    fn shape(&self) -> (usize, usize);

    /// `y = A x` (y has length m, pre-allocated).
    fn apply(&self, x: &[f64], y: &mut [f64]);

    /// `y = Aᵀ x` (y has length n, pre-allocated).
    fn apply_transpose(&self, x: &[f64], y: &mut [f64]);

    /// Convenience allocating forms.
    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.shape().0];
        self.apply(x, &mut y);
        y
    }

    fn apply_transpose_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.shape().1];
        self.apply_transpose(x, &mut y);
        y
    }
}

impl LinearOperator for DenseMatrix {
    fn shape(&self) -> (usize, usize) {
        DenseMatrix::shape(self)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        super::gemm::matvec_into(self, x, y, 0.0);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        let out = super::gemm::matvec_t(self, x);
        y.copy_from_slice(&out);
    }
}

impl LinearOperator for CsrMatrix {
    fn shape(&self) -> (usize, usize) {
        CsrMatrix::shape(self)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_t_into(x, y);
    }
}

/// The right-preconditioned operator `Y = A R⁻¹` without materializing Y —
/// essential for sparse A (Y would be dense m×n).
///
/// `Y v = A (R⁻¹ v)` and `Yᵀ u = R⁻ᵀ (Aᵀ u)`.
pub struct PreconditionedOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    r: &'a DenseMatrix,
}

impl<'a, Op: LinearOperator + ?Sized> PreconditionedOperator<'a, Op> {
    /// `r` must be n×n upper triangular and nonsingular.
    pub fn new(a: &'a Op, r: &'a DenseMatrix) -> Self {
        debug_assert_eq!(a.shape().1, r.rows());
        debug_assert_eq!(r.rows(), r.cols());
        Self { a, r }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for PreconditionedOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let w = triangular::solve_upper(self.r, x).expect("R singular in preconditioned apply");
        self.a.apply(&w, y);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        let w = self.a.apply_transpose_vec(x);
        let z = triangular::solve_upper_transpose(self.r, &w)
            .expect("R singular in preconditioned apply_transpose");
        y.copy_from_slice(&z);
    }
}

/// The perturbed operator `Ã = A + (σ/√m) G` from Algorithm 1 line 11,
/// applied implicitly (G is a dense Gaussian held separately so the original
/// A — possibly sparse — is untouched).
pub struct PerturbedOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    g: &'a DenseMatrix,
    scale: f64,
}

impl<'a, Op: LinearOperator + ?Sized> PerturbedOperator<'a, Op> {
    pub fn new(a: &'a Op, g: &'a DenseMatrix, sigma: f64) -> Self {
        debug_assert_eq!(a.shape(), g.shape());
        let m = a.shape().0;
        Self { a, g, scale: sigma / (m as f64).sqrt() }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for PerturbedOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply(x, y);
        let gy = self.g.matvec(x);
        for (yi, gi) in y.iter_mut().zip(gy.iter()) {
            *yi += self.scale * gi;
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply_transpose(x, y);
        let gy = self.g.matvec_t(x);
        for (yi, gi) in y.iter_mut().zip(gy.iter()) {
            *yi += self.scale * gi;
        }
    }
}

/// Scaled identity-augmented operator for damped least squares
/// `min ‖Ax−b‖² + λ²‖x‖²` — exposed for completeness/testing of LSQR's
/// damping path.
pub struct ScaledOperator<'a, Op: LinearOperator + ?Sized> {
    a: &'a Op,
    alpha: f64,
}

impl<'a, Op: LinearOperator + ?Sized> ScaledOperator<'a, Op> {
    pub fn new(a: &'a Op, alpha: f64) -> Self {
        Self { a, alpha }
    }
}

impl<Op: LinearOperator + ?Sized> LinearOperator for ScaledOperator<'_, Op> {
    fn shape(&self) -> (usize, usize) {
        self.a.shape()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply(x, y);
        for v in y.iter_mut() {
            *v *= self.alpha;
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.a.apply_transpose(x, y);
        for v in y.iter_mut() {
            *v *= self.alpha;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::qr;
    use crate::linalg::sparse::CooBuilder;
    use crate::rng::{GaussianSource, RngCore, Xoshiro256pp};

    #[test]
    fn dense_operator_matches_methods() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(51));
        let a = DenseMatrix::gaussian(13, 7, &mut g);
        let x = g.gaussian_vec(7);
        let u = g.gaussian_vec(13);
        assert_eq!(LinearOperator::shape(&a), (13, 7));
        assert_eq!(a.apply_vec(&x), a.matvec(&x));
        assert_eq!(a.apply_transpose_vec(&u), a.matvec_t(&u));
    }

    #[test]
    fn csr_operator_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(53));
        let mut b = CooBuilder::new(20, 9);
        for _ in 0..60 {
            b.push(
                rng.next_bounded(20) as usize,
                rng.next_bounded(9) as usize,
                g.next_gaussian(),
            );
        }
        let s = b.build();
        let d = s.to_dense();
        let x = g.gaussian_vec(9);
        let u = g.gaussian_vec(20);
        let ys = s.apply_vec(&x);
        let yd = d.apply_vec(&x);
        for (a, b) in ys.iter().zip(yd.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
        let zs = s.apply_transpose_vec(&u);
        let zd = d.apply_transpose_vec(&u);
        for (a, b) in zs.iter().zip(zd.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn preconditioned_operator_is_a_rinv() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(54));
        let a = DenseMatrix::gaussian(30, 8, &mut g);
        let f = qr(&a).unwrap();
        let op = PreconditionedOperator::new(&a, &f.r);
        // Explicit Y = A R^{-1}.
        let y = crate::linalg::triangular::right_solve_upper(&a, &f.r).unwrap();
        let x = g.gaussian_vec(8);
        let u = g.gaussian_vec(30);
        let y1 = op.apply_vec(&x);
        let y2 = y.matvec(&x);
        for (p, q) in y1.iter().zip(y2.iter()) {
            assert!((p - q).abs() < 1e-10);
        }
        let z1 = op.apply_transpose_vec(&u);
        let z2 = y.matvec_t(&u);
        for (p, q) in z1.iter().zip(z2.iter()) {
            assert!((p - q).abs() < 1e-10);
        }
    }

    #[test]
    fn perturbed_operator_matches_explicit_sum() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(55));
        let a = DenseMatrix::gaussian(16, 5, &mut g);
        let gm = DenseMatrix::gaussian(16, 5, &mut g);
        let sigma = 0.3;
        let op = PerturbedOperator::new(&a, &gm, sigma);
        let mut explicit = a.clone();
        explicit.axpy(sigma / 4.0, &gm).unwrap(); // sqrt(16) = 4
        let x = g.gaussian_vec(5);
        let u = g.gaussian_vec(16);
        let y1 = op.apply_vec(&x);
        let y2 = explicit.matvec(&x);
        for (p, q) in y1.iter().zip(y2.iter()) {
            assert!((p - q).abs() < 1e-12);
        }
        let z1 = op.apply_transpose_vec(&u);
        let z2 = explicit.matvec_t(&u);
        for (p, q) in z1.iter().zip(z2.iter()) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn scaled_operator() {
        let a = DenseMatrix::eye(3);
        let op = ScaledOperator::new(&a, 2.5);
        assert_eq!(op.apply_vec(&[1.0, 2.0, 0.0]), vec![2.5, 5.0, 0.0]);
        assert_eq!(op.apply_transpose_vec(&[1.0, 0.0, 2.0]), vec![2.5, 0.0, 5.0]);
    }
}
