//! Vector/matrix norms and spectral-norm estimation.

use super::operator::LinearOperator;
use crate::rng::{GaussianSource, Xoshiro256pp};

/// Euclidean norm with overflow-safe scaling (LAPACK dnrm2 style).
///
/// NaN/Inf audit: the `v != 0.0` shortcut does **not** swallow NaN — IEEE
/// comparison makes `NaN != 0.0` true, so NaN enters the scaled update and
/// poisons `ssq` (and `0.0 * sqrt(NaN)` at the end is still NaN even when
/// `scale` never left zero). Infinities take the `hypot` convention: any
/// ±∞ entry makes the norm +∞ — even alongside NaN, and without the
/// `Inf/Inf = NaN` artifact a second infinite entry would feed the scaled
/// update. Pinned by `nan_and_inf_propagate` below and
/// `tests/nan_propagation.rs`.
pub fn nrm2(x: &[f64]) -> f64 {
    let mut scale = 0.0f64;
    let mut ssq = 1.0f64;
    let mut inf = false;
    for &v in x {
        if v != 0.0 {
            let a = v.abs();
            if a.is_infinite() {
                inf = true;
            } else if scale < a {
                let r = scale / a;
                ssq = 1.0 + ssq * r * r;
                scale = a;
            } else {
                let r = a / scale;
                ssq += r * r;
            }
        }
    }
    if inf {
        return f64::INFINITY;
    }
    scale * ssq.sqrt()
}

/// `||x - y||₂`.
pub fn nrm2_diff(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for (&a, &b) in x.iter().zip(y.iter()) {
        let d = a - b;
        s += d * d;
    }
    s.sqrt()
}

/// ∞-norm. NaN propagates: folding with `f64::max` would silently drop it
/// (`f64::max(x, NaN) == x`), so a vector of NaNs reported ∞-norm 0.0 and
/// a diverged solve could be mistaken for a converged one.
pub fn norm_inf(x: &[f64]) -> f64 {
    let mut m = 0.0f64;
    for &v in x {
        let a = v.abs();
        if a.is_nan() {
            return f64::NAN;
        }
        if a > m {
            m = a;
        }
    }
    m
}

/// 1-norm.
pub fn norm_1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Normalize in place; returns the original norm (0 leaves x untouched).
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = nrm2(x);
    if n > 0.0 {
        let inv = 1.0 / n;
        for v in x.iter_mut() {
            *v *= inv;
        }
    }
    n
}

/// Power-iteration estimate of the spectral norm ‖A‖₂ of a linear operator,
/// via the symmetric iteration `v ← AᵀA v`. Used for Algorithm 1's
/// perturbation scale σ = 10‖A‖₂·u and for condition diagnostics.
///
/// Converges geometrically in (σ₂/σ₁)²; `iters` ≈ 30 is plenty for the
/// 4-digit accuracy σ needs.
pub fn spectral_norm_est<Op: LinearOperator + ?Sized>(a: &Op, iters: usize, seed: u64) -> f64 {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        return 0.0;
    }
    let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
    let mut v = g.gaussian_vec(n);
    normalize(&mut v);
    let mut u = vec![0.0; m];
    let mut sigma = 0.0;
    for _ in 0..iters {
        a.apply(&v, &mut u);
        let un = nrm2(&u);
        if un == 0.0 {
            return 0.0; // v in null space; A ≈ 0 on this subspace
        }
        a.apply_transpose(&u, &mut v);
        sigma = nrm2(&v) / un; // Rayleigh-style estimate of σ₁
        let vn = normalize(&mut v);
        if vn == 0.0 {
            break;
        }
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    #[test]
    fn nrm2_basics() {
        assert_eq!(nrm2(&[]), 0.0);
        assert_eq!(nrm2(&[0.0, 0.0]), 0.0);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn nrm2_overflow_safe() {
        let big = 1e200;
        let v = [big, big];
        assert!((nrm2(&v) - big * 2f64.sqrt()).abs() / (big * 2f64.sqrt()) < 1e-15);
        let small = 1e-200;
        let w = [small, small];
        assert!((nrm2(&w) - small * 2f64.sqrt()).abs() / (small * 2f64.sqrt()) < 1e-15);
    }

    #[test]
    fn other_norms() {
        let v = [1.0, -2.0, 3.0];
        assert_eq!(norm_inf(&v), 3.0);
        assert_eq!(norm_1(&v), 6.0);
        assert!((nrm2_diff(&v, &[1.0, -2.0, 0.0]) - 3.0).abs() < 1e-15);
    }

    #[test]
    fn nan_and_inf_propagate() {
        // norm_inf: NaN anywhere (even alongside a larger finite value or
        // after Inf) must surface, not be max-folded away.
        assert!(norm_inf(&[f64::NAN]).is_nan());
        assert!(norm_inf(&[f64::NAN; 4]).is_nan());
        assert!(norm_inf(&[1.0, f64::NAN, 3.0]).is_nan());
        assert!(norm_inf(&[f64::INFINITY, f64::NAN]).is_nan());
        assert_eq!(norm_inf(&[1.0, f64::NEG_INFINITY]), f64::INFINITY);
        // nrm2: the zero-skip must not swallow non-finite entries either.
        assert!(nrm2(&[f64::NAN]).is_nan());
        assert!(nrm2(&[0.0, f64::NAN, 1.0]).is_nan());
        assert!(nrm2(&[2.0, f64::NAN]).is_nan());
        // hypot convention: ±∞ dominates — even repeated (no Inf/Inf = NaN
        // artifact) and even alongside NaN.
        assert_eq!(nrm2(&[1.0, f64::INFINITY]), f64::INFINITY);
        assert_eq!(nrm2(&[f64::INFINITY, f64::INFINITY]), f64::INFINITY);
        assert_eq!(nrm2(&[f64::NEG_INFINITY, 2.0]), f64::INFINITY);
        assert_eq!(nrm2(&[f64::INFINITY, f64::NAN]), f64::INFINITY);
        // norm_1 inherits propagation from `+`.
        assert!(norm_1(&[1.0, f64::NAN]).is_nan());
    }

    #[test]
    fn normalize_works() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-15);
        assert!((nrm2(&v) - 1.0).abs() < 1e-15);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let d = DenseMatrix::from_diag(&[1.0, 5.0, 2.0, 0.1]);
        let est = spectral_norm_est(&d, 50, 7);
        assert!((est - 5.0).abs() < 1e-6, "est={est}");
    }

    #[test]
    fn spectral_norm_close_to_fro_bound() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(41));
        let a = DenseMatrix::gaussian(60, 20, &mut g);
        let est = spectral_norm_est(&a, 60, 8);
        let fro = a.fro_norm();
        assert!(est <= fro * (1.0 + 1e-9));
        assert!(est >= fro / (20f64).sqrt() * 0.99);
    }

    #[test]
    fn spectral_norm_orthogonal_is_one() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(42));
        let a = DenseMatrix::gaussian(40, 10, &mut g);
        let q = crate::linalg::qr::orthonormal_columns(&a).unwrap();
        let est = spectral_norm_est(&q, 60, 9);
        assert!((est - 1.0).abs() < 1e-8, "est={est}");
    }
}
