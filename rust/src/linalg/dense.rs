//! Row-major dense `f64` matrix.

use super::{LinalgError, Result};
use crate::rng::{GaussianSource, RngCore};

/// A dense, row-major, `f64` matrix.
///
/// Row-major is the right layout for this codebase: the hot consumers are
/// (a) streaming row-accumulation sketches (CountSketch reads whole rows),
/// (b) GEMM with an explicitly blocked kernel, and (c) Householder QR on
/// tall-thin panels.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(LinalgError::DimensionMismatch(format!(
                "from_vec: buffer has {} elements, expected {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Self { rows, cols, data })
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// i.i.d. standard-Gaussian matrix.
    pub fn gaussian<R: RngCore>(rows: usize, cols: usize, g: &mut GaussianSource<R>) -> Self {
        let mut m = Self::zeros(rows, cols);
        g.fill_gaussian(&mut m.data);
        m
    }

    /// Diagonal matrix from entries.
    pub fn from_diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the row-major buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Column copy (row-major storage: strided gather).
    pub fn col_copy(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                let imax = (ib + B).min(self.rows);
                let jmax = (jb + B).min(self.cols);
                for i in ib..imax {
                    for j in jb..jmax {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Extract rows `[r0, r1)` as a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> DenseMatrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        DenseMatrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Extract columns `[c0, c1)` as a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> DenseMatrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = DenseMatrix::zeros(self.rows, w);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        super::norms::nrm2(&self.data)
    }

    /// `self += alpha * other` (same shape).
    pub fn axpy(&mut self, alpha: f64, other: &DenseMatrix) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(LinalgError::DimensionMismatch(format!(
                "axpy: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Scale in place.
    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// `||self - other||_F`.
    pub fn fro_distance(&self, other: &DenseMatrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Dense matvec `y = A x` (delegates to the blocked kernel).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        super::gemm::matvec(self, x)
    }

    /// Transposed matvec `y = Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        super::gemm::matvec_t(self, x)
    }

    /// Dense matmul `C = A B` (blocked kernel).
    pub fn matmul(&self, b: &DenseMatrix) -> Result<DenseMatrix> {
        super::gemm::matmul(self, b)
    }
}

impl std::ops::Index<(usize, usize)> for DenseMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn construct_and_index() {
        let mut m = DenseMatrix::zeros(3, 2);
        m[(0, 0)] = 1.0;
        m[(2, 1)] = 5.0;
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(2, 1)], 5.0);
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.row(2), &[0.0, 5.0]);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn eye_and_diag() {
        let i3 = DenseMatrix::eye(3);
        assert_eq!(i3[(1, 1)], 1.0);
        assert_eq!(i3[(0, 1)], 0.0);
        let d = DenseMatrix::from_diag(&[2.0, 3.0]);
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(1, 1)], 3.0);
        assert_eq!(d[(1, 0)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(1));
        let a = DenseMatrix::gaussian(37, 53, &mut g);
        let att = a.transpose().transpose();
        assert_eq!(a, att);
        let t = a.transpose();
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(a[(i, j)], t[(j, i)]);
            }
        }
    }

    #[test]
    fn slices() {
        let a = DenseMatrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let r = a.slice_rows(1, 3);
        assert_eq!(r.shape(), (2, 3));
        assert_eq!(r[(0, 0)], 3.0);
        let c = a.slice_cols(1, 3);
        assert_eq!(c.shape(), (4, 2));
        assert_eq!(c[(0, 0)], 1.0);
        assert_eq!(c[(3, 1)], 11.0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = DenseMatrix::from_fn(2, 2, |i, j| (i + j) as f64);
        let b = DenseMatrix::eye(2);
        a.axpy(2.0, &b).unwrap();
        assert_eq!(a[(0, 0)], 2.0);
        assert_eq!(a[(1, 1)], 4.0);
        a.scale(0.5);
        assert_eq!(a[(1, 1)], 2.0);
        let c = DenseMatrix::zeros(3, 2);
        assert!(a.axpy(1.0, &c).is_err());
    }

    #[test]
    fn col_copy_matches() {
        let a = DenseMatrix::from_fn(5, 4, |i, j| (10 * i + j) as f64);
        let c2 = a.col_copy(2);
        assert_eq!(c2, vec![2.0, 12.0, 22.0, 32.0, 42.0]);
    }

    #[test]
    fn fro_norm_known() {
        let a = DenseMatrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]).unwrap();
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
    }
}
