//! Householder QR factorization (HHQR — Algorithm 1 step 3).
//!
//! Tall-thin economy QR: `B (s×n) = Q (s×n) · R (n×n)`, s ≥ n. This runs
//! on the *sketched* matrix, so s is a small multiple of n — exactly the
//! regime where Murray et al. (2023) observe RandNLA speedups are realized
//! or lost in the BLAS-3 fraction. The factorization is therefore
//! **blocked compact-WY**: NB-column panels are factored with the BLAS-2
//! reflector sweep (dispatched SIMD `dot`/`axpy`, hoisted once per sweep —
//! see [`crate::simd`]), the triangular T factor of `Q_panel = I − V·T·Vᵀ`
//! is accumulated LAPACK-`larft` style, and the trailing update
//! `A ← A − V·Tᵀ·(Vᵀ·A)` runs as two packed GEMMs through
//! [`super::gemm::matmul_into`] — sharded across the worker pool with the
//! same MR-aligned bitwise-thread-determinism contract GEMM already
//! honors. Panel width: [`set_panel_nb`] → `SNSOLVE_QR_NB` → 32;
//! [`qr_compact_unblocked`] (the seed sweep, identical to a single
//! full-width panel) is kept as the reference/baseline path.
//!
//! Reflector norms are computed with LAPACK-style scaling (`dlassq`
//! spirit): columns with entries beyond ~1e±140 are rescaled by their max
//! before the dispatched `dot`, so ill-scaled columns factor accurately
//! instead of overflowing to `inf`/underflowing to a spurious zero
//! reflector (Epperly's forward-stability bar, arXiv 2311.04362).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::dense::DenseMatrix;
use super::{LinalgError, Result};
use crate::simd::SimdKernels;

/// Default compact-WY panel width: wide enough that the trailing GEMMs
/// dominate, narrow enough that a panel of reflectors stays cache-resident
/// during the BLAS-2 sweep.
const DEFAULT_NB: usize = 32;

/// Configured panel width (0 = unset → env → default).
static NB_CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// Configure the blocked-QR panel width for this process. `0` restores the
/// ambient resolution (`SNSOLVE_QR_NB` env var, then 32). Wired from
/// [`crate::config::SolveConfig`], the `--qr-nb` CLI flag and the
/// `[parallel] qr_nb` config key.
pub fn set_panel_nb(nb: usize) {
    NB_CONFIGURED.store(nb, Ordering::SeqCst);
}

fn env_nb() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        // snsolve-lint: allow(env-reads-behind-config) — designated
        // knob-resolution site: OnceLock-cached SNSOLVE_QR_NB fallback
        // behind set_panel_nb() (CLI/config take precedence).
        std::env::var("SNSOLVE_QR_NB")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or(0)
    })
}

/// The panel width [`qr_compact`] uses right now: configured → env → 32.
pub fn panel_nb() -> usize {
    let c = NB_CONFIGURED.load(Ordering::SeqCst);
    let c = if c == 0 { env_nb() } else { c };
    if c == 0 {
        DEFAULT_NB
    } else {
        c
    }
}

/// Economy QR factorization `A = Q R`.
#[derive(Debug, Clone)]
pub struct QrFactors {
    /// s×n orthonormal columns.
    pub q: DenseMatrix,
    /// n×n upper triangular.
    pub r: DenseMatrix,
}

/// Compact (factored) Householder QR: `A = Q R` with Q implicit in the
/// reflectors. Use [`QrCompact::q_transpose_vec`] / [`QrCompact::q_vec`] to
/// apply `Qᵀ`/`Q` without materializing Q (what Algorithm 1 needs for
/// `z₀ = Qᵀ c`).
///
/// Storage is the **transpose** of the LAPACK layout: `vrt` is n×s
/// row-major, so row j holds reflector v_j (contiguous!) past the diagonal
/// and R's row... — see `qr_compact` for why.
#[derive(Debug, Clone, PartialEq)]
pub struct QrCompact {
    /// n×s; row j holds R[j, ..] in positions ≤ j transposed — precisely:
    /// `vrt[(j, i)]` = element (i, j) of the classic compact factor, i.e.
    /// R on/above the diagonal (i ≤ j) and reflector v_j below (i > j).
    vrt: DenseMatrix,
    /// Householder scalars tau_j.
    tau: Vec<f64>,
}

/// Factor `a` (s×n, s ≥ n) by Householder reflections, in compact form —
/// blocked compact-WY with the configured panel width ([`panel_nb`]).
///
/// §Perf-L3 (EXPERIMENTS.md): the textbook in-place sweep walks *columns*
/// of a row-major buffer — every access strided by n, ~0.1 GFLOP/s at
/// n = 1000 (109 s on Figure 3's sketched QR). Factoring the transpose
/// turns both inner loops (w = vᵀa_k and a_k ← a_k − τw·v) into contiguous
/// `dot`/`axpy` over rows; blocking then moves the O(s·n²) trailing bulk
/// from those BLAS-1 streams into packed BLAS-3 GEMMs.
pub fn qr_compact(a: &DenseMatrix) -> Result<QrCompact> {
    qr_compact_blocked(a, panel_nb())
}

/// The seed unblocked sweep — identical to a single full-width panel (the
/// trailing update never runs), kept as the reference/baseline path for
/// the equivalence tests and the `micro_linalg` bench.
pub fn qr_compact_unblocked(a: &DenseMatrix) -> Result<QrCompact> {
    qr_compact_blocked(a, a.cols().max(1))
}

/// Blocked compact-WY factorization with an explicit panel width `nb`
/// (clamped to ≥ 1). `nb ≥ n` degenerates to the unblocked sweep bit for
/// bit; any `nb` agrees with any other within ~1e-12 (the trailing GEMM
/// re-rounds but never re-associates a single reflector application).
pub fn qr_compact_blocked(a: &DenseMatrix, nb: usize) -> Result<QrCompact> {
    let (s, n) = a.shape();
    if s < n {
        return Err(LinalgError::InvalidArgument(format!(
            "qr: need rows >= cols, got {s}x{n}"
        )));
    }
    let nb = nb.max(1);
    // at[(k, i)] = a[(i, k)]: row k of `at` is column k of A, contiguous.
    let mut at = a.transpose();
    let mut tau = vec![0.0; n];
    // Hoisted: dot/axpy run O(n·nb) times per panel; per-call dispatch
    // would sit in the inner loop.
    let kern = crate::simd::kernels();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + nb).min(n);
        factor_panel(&mut at, &mut tau, s, j0, j1, kern);
        if j1 < n {
            apply_panel_to_trailing(&mut at, &tau, s, j0, j1, n, kern)?;
        }
        j0 = j1;
    }
    Ok(QrCompact { vrt: at, tau })
}

/// BLAS-2 Householder sweep over panel columns `[j0, j1)` of `at`,
/// applying each reflector to the remaining columns **within the panel**
/// only (the trailing columns get the blocked WY update afterwards).
fn factor_panel(
    at: &mut DenseMatrix,
    tau: &mut [f64],
    s: usize,
    j0: usize,
    j1: usize,
    kern: &'static dyn SimdKernels,
) {
    for j in j0..j1 {
        // Reflector from column j (= row j of at), entries j..s.
        let row_j = at.row(j);
        let alpha = row_j[j];
        let xnorm = tail_norm_scaled(kern, &row_j[j + 1..s]);
        if xnorm == 0.0 && alpha >= 0.0 {
            tau[j] = 0.0;
            continue;
        }
        // hypot never overflows alpha² + xnorm² the way the naive square
        // sum did for entries beyond ~1e154.
        let beta = -(alpha.signum_nonzero()) * alpha.hypot(xnorm);
        let tau_j = (beta - alpha) / beta;
        // Divide by (alpha − beta) rather than multiplying by its
        // reciprocal: for subnormal columns the reciprocal overflows to
        // Inf while the per-element quotient is well-scaled (|v| ≤
        // |alpha − beta| here).
        let denom = alpha - beta;
        {
            let row_j = at.row_mut(j);
            for v in row_j[j + 1..s].iter_mut() {
                *v /= denom;
            }
            row_j[j] = beta; // R diagonal
        }
        tau[j] = tau_j;
        // Apply H_j to the rest of the panel (rows j < k < j1 of `at`):
        //   w = a_k[j] + v·a_k[j+1..]; a_k[j] -= τw; a_k[j+1..] -= τw·v.
        // Split borrows: row j (the reflector) vs rows k > j.
        let (head, tail) = at.data_mut().split_at_mut((j + 1) * s);
        let v_j = &head[j * s + j + 1..j * s + s];
        for k in j + 1..j1 {
            let row_k = &mut tail[(k - j - 1) * s..(k - j - 1) * s + s];
            let w = row_k[j] + kern.dot(v_j, &row_k[j + 1..s]);
            let tw = tau_j * w;
            row_k[j] -= tw;
            kern.axpy(-tw, v_j, &mut row_k[j + 1..s]);
        }
    }
}

/// `‖x‖₂` via the dispatched `dot` kernel with LAPACK-style scaling: in
/// the wide safe band the plain square sum is exact enough; outside it the
/// tail is rescaled by its max first, so entries at 1e±160 neither
/// overflow to `inf` nor underflow to a spurious zero norm. NaN/Inf
/// entries propagate (max tracking keeps NaN sticky).
fn tail_norm_scaled(kern: &dyn SimdKernels, x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let mut amax = 0.0f64;
    for &v in x {
        let a = v.abs();
        if a > amax || a.is_nan() {
            amax = a;
        }
    }
    if amax == 0.0 {
        return 0.0;
    }
    if !amax.is_finite() {
        return amax; // Inf → Inf, NaN → NaN
    }
    if (1e-140..=1e140).contains(&amax) {
        kern.dot(x, x).sqrt()
    } else {
        // Divide rather than multiply by the reciprocal: 1.0/amax
        // overflows to Inf for subnormal amax, which would poison the
        // factorization this branch exists to protect.
        let scaled: Vec<f64> = x.iter().map(|&v| v / amax).collect();
        amax * kern.dot(&scaled, &scaled).sqrt()
    }
}

/// Apply the compact-WY form of panel `[j0, j1)` to the trailing columns
/// `[j1, n)`: with `V` the unit-lower-trapezoidal reflector block and `T`
/// the LAPACK-`larft` triangular factor of `Q_panel = H_{j0}···H_{j1-1} =
/// I − V·T·Vᵀ`, the update is `A_trail ← Q_panelᵀ A_trail = A_trail −
/// V·Tᵀ·(Vᵀ·A_trail)`. In the transposed storage (`at` rows are columns of
/// A) that is `Ct ← Ct − (Ct·V)·T·Vᵀ` — two rectangular GEMMs over the
/// packed-panel path, row-sharded across the worker pool with GEMM's
/// MR-aligned bitwise thread-determinism contract.
#[allow(clippy::too_many_arguments)]
fn apply_panel_to_trailing(
    at: &mut DenseMatrix,
    tau: &[f64],
    s: usize,
    j0: usize,
    j1: usize,
    n: usize,
    kern: &'static dyn SimdKernels,
) -> Result<()> {
    let pnb = j1 - j0;
    let l = s - j0;
    let m2 = n - j1;
    // V restricted to rows j0..s, as pnb×l row-major: row i = v_{j0+i}
    // (zeros before position i, implicit 1 on it, stored tail after).
    let mut vmat = DenseMatrix::zeros(pnb, l);
    for i in 0..pnb {
        let src = at.row(j0 + i);
        let dst = vmat.row_mut(i);
        dst[i] = 1.0;
        dst[i + 1..].copy_from_slice(&src[j0 + i + 1..s]);
    }
    // T (pnb×pnb upper triangular), forward columnwise accumulation:
    // T[i,i] = τ_i, T[0..i, i] = −τ_i · T[0..i, 0..i] · (V[:, 0..i]ᵀ v_i).
    // v_p ᵀ v_i only overlaps from position i on, where v_p is the stored
    // tail and v_i is (1, tail) — exactly rows p and i of vmat from
    // column i.
    let mut t = DenseMatrix::zeros(pnb, pnb);
    let mut h = vec![0.0; pnb];
    for i in 0..pnb {
        let ti = tau[j0 + i];
        if ti != 0.0 {
            for p in 0..i {
                h[p] = kern.dot(&vmat.row(p)[i..], &vmat.row(i)[i..]);
            }
            for p in 0..i {
                let acc = kern.dot(&t.row(p)[p..i], &h[p..i]);
                t[(p, i)] = -ti * acc;
            }
        }
        t[(i, i)] = ti;
    }
    // Vᵀ as l×pnb for the first GEMM.
    let mut vt = DenseMatrix::zeros(l, pnb);
    for i in 0..pnb {
        for (c, &v) in vmat.row(i).iter().enumerate().skip(i) {
            vt[(c, i)] = v;
        }
    }
    // Trailing block in transposed storage: ctrail row r = column j1+r of
    // A restricted to rows j0..s (contiguous copies both ways — the GEMMs
    // then run on plain full-width row-major operands).
    let mut ctrail = DenseMatrix::zeros(m2, l);
    for r in 0..m2 {
        ctrail.row_mut(r).copy_from_slice(&at.row(j1 + r)[j0..s]);
    }
    let mut wt = DenseMatrix::zeros(m2, pnb);
    super::gemm::matmul_into(&ctrail, &vt, &mut wt)?;
    let mut y = DenseMatrix::zeros(m2, pnb);
    super::gemm::matmul_into(&wt, &t, &mut y)?;
    y.scale(-1.0); // exact sign flip: Ct += (−Y)·Vᵀ is the subtraction
    super::gemm::matmul_into(&y, &vmat, &mut ctrail)?;
    for r in 0..m2 {
        at.row_mut(j1 + r)[j0..s].copy_from_slice(ctrail.row(r));
    }
    Ok(())
}

trait SignumNonzero {
    fn signum_nonzero(self) -> f64;
}

impl SignumNonzero for f64 {
    /// signum with sign(0) = +1 (LAPACK convention for Householder).
    #[inline]
    fn signum_nonzero(self) -> f64 {
        if self >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }
}

impl QrCompact {
    /// (s, n) of the factored matrix.
    pub fn shape(&self) -> (usize, usize) {
        let (n, s) = self.vrt.shape();
        (s, n)
    }

    /// The n×n upper-triangular factor R.
    pub fn r(&self) -> DenseMatrix {
        let (n, _) = self.vrt.shape();
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = self.vrt[(j, i)];
            }
        }
        r
    }

    /// Apply `Qᵀ` to a length-s vector, returning the first n entries
    /// (the economy part — exactly `z₀ = Qᵀc` in Algorithm 1 step 5).
    pub fn q_transpose_vec(&self, c: &[f64]) -> Vec<f64> {
        let (n, s) = self.vrt.shape();
        assert_eq!(c.len(), s, "q_transpose_vec: len {} != rows {s}", c.len());
        let mut y = c.to_vec();
        let kern = crate::simd::kernels();
        // Qᵀ = H_{n-1} ... H_1 H_0 applied left-to-right; reflector v_j is
        // the contiguous tail of row j of vrt.
        for j in 0..n {
            let tau_j = self.tau[j];
            if tau_j == 0.0 {
                continue;
            }
            let v_j = &self.vrt.row(j)[j + 1..s];
            let w = y[j] + kern.dot(v_j, &y[j + 1..s]);
            let tw = tau_j * w;
            y[j] -= tw;
            kern.axpy(-tw, v_j, &mut y[j + 1..s]);
        }
        y.truncate(n);
        y
    }

    /// Apply `Qᵀ` to a row-stored block of k length-s vectors (`c` is k×s),
    /// returning the k×n block of economy parts — the batched
    /// `z₀ = Qᵀc` of Algorithm 1 step 5, one row per right-hand side.
    ///
    /// Rows shard across the worker pool; row r is bitwise identical to
    /// [`QrCompact::q_transpose_vec`]`(c.row(r))` at any thread count,
    /// which keeps the blocked serving path per-RHS equivalent to the
    /// single-vector path.
    pub fn q_transpose_mat(&self, c: &DenseMatrix) -> DenseMatrix {
        let (n, s) = self.vrt.shape();
        assert_eq!(c.cols(), s, "q_transpose_mat: block has {} cols, need {s}", c.cols());
        let k = c.rows();
        let mut out = DenseMatrix::zeros(k, n);
        if k == 0 || n == 0 {
            return out;
        }
        let work = k.saturating_mul(s.saturating_mul(n));
        let threads = if work < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(k, 1)
        };
        crate::parallel::for_each_row_block(out.data_mut(), k, n, threads, |_, rows, block| {
            for (local, r) in rows.enumerate() {
                let z = self.q_transpose_vec(c.row(r));
                block[local * n..(local + 1) * n].copy_from_slice(&z);
            }
        });
        out
    }

    /// Apply `Q` to a length-n vector, returning length s (`Q z`).
    pub fn q_vec(&self, z: &[f64]) -> Vec<f64> {
        let (n, s) = self.vrt.shape();
        assert_eq!(z.len(), n, "q_vec: len {} != cols {n}", z.len());
        let mut y = vec![0.0; s];
        y[..n].copy_from_slice(z);
        let kern = crate::simd::kernels();
        // Q = H_0 H_1 ... H_{n-1} applied right-to-left.
        for j in (0..n).rev() {
            let tau_j = self.tau[j];
            if tau_j == 0.0 {
                continue;
            }
            let v_j = &self.vrt.row(j)[j + 1..s];
            let w = y[j] + kern.dot(v_j, &y[j + 1..s]);
            let tw = tau_j * w;
            y[j] -= tw;
            kern.axpy(-tw, v_j, &mut y[j + 1..s]);
        }
        y
    }

    /// Materialize the economy Q (s×n). O(s n²) — fine at sketch scale.
    pub fn q(&self) -> DenseMatrix {
        let (s, n) = self.shape();
        let mut q = DenseMatrix::zeros(s, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e.fill(0.0);
            e[j] = 1.0;
            let col = self.q_vec(&e);
            for i in 0..s {
                q[(i, j)] = col[i];
            }
        }
        q
    }
}

/// Economy QR with materialized factors.
pub fn qr(a: &DenseMatrix) -> Result<QrFactors> {
    let compact = qr_compact(a)?;
    Ok(QrFactors { q: compact.q(), r: compact.r() })
}

/// Orthonormalize the columns of `a` (thin Q) — Haar sampling helper.
pub fn orthonormal_columns(a: &DenseMatrix) -> Result<DenseMatrix> {
    Ok(qr_compact(a)?.q())
}

/// Modified Gram–Schmidt QR — an independent second implementation used by
/// tests to cross-check Householder, and by callers that want Q with
/// slightly better row-access locality.
pub fn qr_mgs(a: &DenseMatrix) -> Result<QrFactors> {
    let (s, n) = a.shape();
    if s < n {
        return Err(LinalgError::InvalidArgument(format!(
            "qr_mgs: need rows >= cols, got {s}x{n}"
        )));
    }
    // Work column-major.
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col_copy(j)).collect();
    let mut r = DenseMatrix::zeros(n, n);
    let kern = crate::simd::kernels();
    for j in 0..n {
        // Re-orthogonalize once ("twice is enough", Giraud et al.) for
        // numerical robustness at high condition numbers.
        for _pass in 0..2 {
            for i in 0..j {
                let (head, tail) = cols.split_at_mut(j);
                let rij = kern.dot(&head[i], &tail[0]);
                r[(i, j)] += rij;
                kern.axpy(-rij, &head[i], &mut tail[0]);
            }
        }
        let norm = super::norms::nrm2(&cols[j]);
        if norm == 0.0 {
            return Err(LinalgError::Singular(format!("qr_mgs: column {j} is dependent")));
        }
        r[(j, j)] = norm;
        let inv = 1.0 / norm;
        for v in cols[j].iter_mut() {
            *v *= inv;
        }
    }
    let mut q = DenseMatrix::zeros(s, n);
    for (j, col) in cols.iter().enumerate() {
        for i in 0..s {
            q[(i, j)] = col[i];
        }
    }
    Ok(QrFactors { q, r })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn rand_matrix(s: usize, n: usize, seed: u64) -> DenseMatrix {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        DenseMatrix::gaussian(s, n, &mut g)
    }

    fn check_qr(a: &DenseMatrix, q: &DenseMatrix, r: &DenseMatrix, tol: f64) {
        let (s, n) = a.shape();
        assert_eq!(q.shape(), (s, n));
        assert_eq!(r.shape(), (n, n));
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert!(
                    r[(i, j)].abs() < tol,
                    "R not triangular at ({i},{j}): {}",
                    r[(i, j)]
                );
            }
        }
        // QᵀQ = I
        let qtq = q.transpose().matmul(q).unwrap();
        let i_n = DenseMatrix::eye(n);
        assert!(qtq.fro_distance(&i_n) < tol * (n as f64), "QtQ err {}", qtq.fro_distance(&i_n));
        // QR = A
        let qr_prod = q.matmul(r).unwrap();
        let rel = qr_prod.fro_distance(a) / a.fro_norm();
        assert!(rel < tol, "QR != A, rel err {rel}");
    }

    #[test]
    fn householder_qr_random_shapes() {
        for (s, n, seed) in [(5, 3, 1u64), (20, 20, 2), (64, 16, 3), (257, 63, 4)] {
            let a = rand_matrix(s, n, seed);
            let f = qr(&a).unwrap();
            check_qr(&a, &f.q, &f.r, 1e-12);
        }
    }

    #[test]
    fn mgs_qr_matches_invariants() {
        for (s, n, seed) in [(5, 3, 5u64), (64, 16, 6), (130, 40, 7)] {
            let a = rand_matrix(s, n, seed);
            let f = qr_mgs(&a).unwrap();
            check_qr(&a, &f.q, &f.r, 1e-12);
        }
    }

    #[test]
    fn householder_vs_mgs_same_r_up_to_signs() {
        let a = rand_matrix(40, 10, 8);
        let h = qr(&a).unwrap();
        let m = qr_mgs(&a).unwrap();
        // R factors agree up to row signs; compare |R|.
        for i in 0..10 {
            for j in i..10 {
                assert!(
                    (h.r[(i, j)].abs() - m.r[(i, j)].abs()).abs() < 1e-10,
                    "({i},{j}): {} vs {}",
                    h.r[(i, j)],
                    m.r[(i, j)]
                );
            }
        }
    }

    #[test]
    fn q_transpose_vec_matches_materialized() {
        let a = rand_matrix(33, 9, 9);
        let c = {
            let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(10));
            g.gaussian_vec(33)
        };
        let compact = qr_compact(&a).unwrap();
        let z_fast = compact.q_transpose_vec(&c);
        let q = compact.q();
        let z_ref = q.matvec_t(&c);
        for (u, v) in z_fast.iter().zip(z_ref.iter()) {
            assert!((u - v).abs() < 1e-11, "{u} vs {v}");
        }
    }

    #[test]
    fn q_vec_matches_materialized() {
        let a = rand_matrix(25, 7, 11);
        let z = {
            let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(12));
            g.gaussian_vec(7)
        };
        let compact = qr_compact(&a).unwrap();
        let y_fast = compact.q_vec(&z);
        let q = compact.q();
        let y_ref = q.matvec(&z);
        for (u, v) in y_fast.iter().zip(y_ref.iter()) {
            assert!((u - v).abs() < 1e-11);
        }
    }

    #[test]
    fn q_transpose_mat_matches_per_row_bitwise() {
        let a = rand_matrix(48, 11, 15);
        let compact = qr_compact(&a).unwrap();
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(16));
        let c = DenseMatrix::gaussian(5, 48, &mut g);
        let z = compact.q_transpose_mat(&c);
        assert_eq!(z.shape(), (5, 11));
        for r in 0..5 {
            assert_eq!(z.row(r), &compact.q_transpose_vec(c.row(r))[..], "row {r}");
        }
        let empty = DenseMatrix::zeros(0, 48);
        assert_eq!(compact.q_transpose_mat(&empty).rows(), 0);
    }

    #[test]
    fn wide_matrix_rejected() {
        let a = DenseMatrix::zeros(3, 5);
        assert!(qr(&a).is_err());
        assert!(qr_mgs(&a).is_err());
    }

    #[test]
    fn orthonormal_columns_haar_helper() {
        let a = rand_matrix(100, 20, 13);
        let q = orthonormal_columns(&a).unwrap();
        let qtq = q.transpose().matmul(&q).unwrap();
        assert!(qtq.fro_distance(&DenseMatrix::eye(20)) < 1e-11);
    }

    #[test]
    fn qr_on_illconditioned() {
        // Columns with widely varying scales — QR must remain accurate.
        let mut a = rand_matrix(50, 8, 14);
        for j in 0..8 {
            let scale = 10f64.powi(-(2 * j as i32));
            for i in 0..50 {
                a[(i, j)] *= scale;
            }
        }
        let f = qr(&a).unwrap();
        let rel = f.q.matmul(&f.r).unwrap().fro_distance(&a) / a.fro_norm();
        assert!(rel < 1e-12, "rel {rel}");
    }

    /// Regression for the reflector-norm overflow/underflow: the naive
    /// `Σ x²` is `inf` for entries beyond ~1e154 (poisoning the whole
    /// factorization with NaN) and `0` below ~1e-162 (silently treating a
    /// nonzero column as already triangular). The scaled norm must factor
    /// columns at 1e±160 accurately — and a fully subnormal column
    /// (1e-310) must survive too, which additionally requires the
    /// reflector scaling and the norm rescale to divide rather than
    /// multiply by a reciprocal (the reciprocal of a subnormal is Inf).
    #[test]
    fn extreme_column_scales_factor_accurately() {
        let mut a = rand_matrix(60, 6, 17);
        let scales = [1e160, 1e-160, 1.0, 1e155, 1e-155, 1e-310];
        for (j, &sc) in scales.iter().enumerate() {
            for i in 0..60 {
                a[(i, j)] *= sc;
            }
        }
        let compact = qr_compact(&a).unwrap();
        let q = compact.q();
        let r = compact.r();
        // Q stays orthonormal...
        let qtq = q.transpose().matmul(&q).unwrap();
        let dev = qtq.fro_distance(&DenseMatrix::eye(6));
        assert!(dev < 1e-12, "QtQ dev {dev}");
        // ...and every column reconstructs at its own scale. The squares
        // are taken in units of the column scale — the raw squares
        // over/underflow by design here.
        let qr_prod = q.matmul(&r).unwrap();
        for (j, &sc) in scales.iter().enumerate() {
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for i in 0..60 {
                let d = (qr_prod[(i, j)] - a[(i, j)]) / sc;
                let v = a[(i, j)] / sc;
                num += d * d;
                den += v * v;
            }
            // 1e-11 (not 1e-12): the 1e-310 column's entries are stored
            // subnormal, so the data itself carries ~1e-14 representation
            // error before the factorization sees it.
            let rel = num.sqrt() / den.sqrt().max(1e-300);
            assert!(rel.is_finite() && rel < 1e-11, "col {j}: rel {rel}");
        }
        // The unblocked sweep shares the scaled norm. The `.max(1e-300)`
        // keeps the tolerance representable for the subnormal diagonal
        // (1e-12 of 1e-310 would sit below the subnormal ulp).
        let unb = qr_compact_unblocked(&a).unwrap();
        for j in 0..scales.len() {
            let d = (unb.r()[(j, j)].abs() - r[(j, j)].abs()).abs();
            assert!(
                d <= (1e-11 * r[(j, j)].abs()).max(1e-320),
                "diag {j}: {} vs {}",
                unb.r()[(j, j)],
                r[(j, j)]
            );
        }
    }

    /// The `set_panel_nb` knob rebinds the default `qr_compact` to an
    /// explicit panel width, and `nb ≥ n` is bit-for-bit the unblocked
    /// sweep.
    #[test]
    fn panel_nb_knob_and_full_panel_degeneracy() {
        let a = rand_matrix(70, 20, 18);
        set_panel_nb(8);
        let via_knob = qr_compact(&a).unwrap();
        set_panel_nb(0);
        assert_eq!(via_knob, qr_compact_blocked(&a, 8).unwrap());
        assert_eq!(qr_compact_blocked(&a, 20).unwrap(), qr_compact_unblocked(&a).unwrap());
        assert_eq!(qr_compact_blocked(&a, 99).unwrap(), qr_compact_unblocked(&a).unwrap());
        assert!(panel_nb() >= 1);
    }
}
