//! Householder QR factorization (HHQR — Algorithm 1 step 3).
//!
//! Tall-thin economy QR: `B (s×n) = Q (s×n) · R (n×n)`, s ≥ n. This runs on
//! the *sketched* matrix, so s is a small multiple of n and an unblocked
//! column-at-a-time Householder sweep is already BLAS-2-bound on matrices
//! that fit in cache; the inner streams run on the dispatched SIMD
//! `dot`/`axpy` kernels (hoisted once per sweep — see [`crate::simd`]).

use super::dense::DenseMatrix;
use super::{LinalgError, Result};

/// Economy QR factorization `A = Q R`.
#[derive(Debug, Clone)]
pub struct QrFactors {
    /// s×n orthonormal columns.
    pub q: DenseMatrix,
    /// n×n upper triangular.
    pub r: DenseMatrix,
}

/// Compact (factored) Householder QR: `A = Q R` with Q implicit in the
/// reflectors. Use [`QrCompact::q_transpose_vec`] / [`QrCompact::q_vec`] to
/// apply `Qᵀ`/`Q` without materializing Q (what Algorithm 1 needs for
/// `z₀ = Qᵀ c`).
///
/// Storage is the **transpose** of the LAPACK layout: `vrt` is n×s
/// row-major, so row j holds reflector v_j (contiguous!) past the diagonal
/// and R's row... — see `qr_compact` for why.
#[derive(Debug, Clone)]
pub struct QrCompact {
    /// n×s; row j holds R[j, ..] in positions ≤ j transposed — precisely:
    /// `vrt[(j, i)]` = element (i, j) of the classic compact factor, i.e.
    /// R on/above the diagonal (i ≤ j) and reflector v_j below (i > j).
    vrt: DenseMatrix,
    /// Householder scalars tau_j.
    tau: Vec<f64>,
}

/// Factor `a` (s×n, s ≥ n) by Householder reflections, in compact form.
///
/// §Perf-L3 (EXPERIMENTS.md): the textbook in-place sweep walks *columns*
/// of a row-major buffer — every access strided by n, ~0.1 GFLOP/s at
/// n = 1000 (109 s on Figure 3's sketched QR). Factoring the transpose
/// turns both inner loops (w = vᵀa_k and a_k ← a_k − τw·v) into contiguous
/// `dot`/`axpy` over rows — the whole factorization is two BLAS-1 streams
/// per (j, k) pair. 30–40× faster at Figure-3 scale.
pub fn qr_compact(a: &DenseMatrix) -> Result<QrCompact> {
    let (s, n) = a.shape();
    if s < n {
        return Err(LinalgError::InvalidArgument(format!(
            "qr: need rows >= cols, got {s}x{n}"
        )));
    }
    // at[(k, i)] = a[(i, k)]: row k of `at` is column k of A, contiguous.
    let mut at = a.transpose();
    let mut tau = vec![0.0; n];
    // Hoisted: dot/axpy run O(n^2) times below; per-call dispatch would sit
    // in the inner loop.
    let kern = crate::simd::kernels();
    for j in 0..n {
        // Reflector from column j (= row j of at), entries j..s.
        let row_j = at.row(j);
        let alpha = row_j[j];
        let xnorm2: f64 = row_j[j + 1..s].iter().map(|&x| x * x).sum();
        if xnorm2 == 0.0 && alpha >= 0.0 {
            tau[j] = 0.0;
            continue;
        }
        let beta = -(alpha.signum_nonzero()) * (alpha * alpha + xnorm2).sqrt();
        let tau_j = (beta - alpha) / beta;
        let scale = 1.0 / (alpha - beta);
        {
            let row_j = at.row_mut(j);
            for v in row_j[j + 1..s].iter_mut() {
                *v *= scale;
            }
            row_j[j] = beta; // R diagonal
        }
        tau[j] = tau_j;
        // Apply H_j to trailing columns (rows k > j of `at`):
        //   w = a_k[j] + v·a_k[j+1..]; a_k[j] -= τw; a_k[j+1..] -= τw·v.
        // Split borrows: row j (the reflector) vs rows k > j.
        let (head, tail) = at.data_mut().split_at_mut((j + 1) * s);
        let v_j = &head[j * s + j + 1..j * s + s];
        for k in j + 1..n {
            let row_k = &mut tail[(k - j - 1) * s..(k - j - 1) * s + s];
            let w = row_k[j] + kern.dot(v_j, &row_k[j + 1..s]);
            let tw = tau_j * w;
            row_k[j] -= tw;
            kern.axpy(-tw, v_j, &mut row_k[j + 1..s]);
        }
    }
    Ok(QrCompact { vrt: at, tau })
}

trait SignumNonzero {
    fn signum_nonzero(self) -> f64;
}

impl SignumNonzero for f64 {
    /// signum with sign(0) = +1 (LAPACK convention for Householder).
    #[inline]
    fn signum_nonzero(self) -> f64 {
        if self >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }
}

impl QrCompact {
    /// (s, n) of the factored matrix.
    pub fn shape(&self) -> (usize, usize) {
        let (n, s) = self.vrt.shape();
        (s, n)
    }

    /// The n×n upper-triangular factor R.
    pub fn r(&self) -> DenseMatrix {
        let (n, _) = self.vrt.shape();
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = self.vrt[(j, i)];
            }
        }
        r
    }

    /// Apply `Qᵀ` to a length-s vector, returning the first n entries
    /// (the economy part — exactly `z₀ = Qᵀc` in Algorithm 1 step 5).
    pub fn q_transpose_vec(&self, c: &[f64]) -> Vec<f64> {
        let (n, s) = self.vrt.shape();
        assert_eq!(c.len(), s, "q_transpose_vec: len {} != rows {s}", c.len());
        let mut y = c.to_vec();
        let kern = crate::simd::kernels();
        // Qᵀ = H_{n-1} ... H_1 H_0 applied left-to-right; reflector v_j is
        // the contiguous tail of row j of vrt.
        for j in 0..n {
            let tau_j = self.tau[j];
            if tau_j == 0.0 {
                continue;
            }
            let v_j = &self.vrt.row(j)[j + 1..s];
            let w = y[j] + kern.dot(v_j, &y[j + 1..s]);
            let tw = tau_j * w;
            y[j] -= tw;
            kern.axpy(-tw, v_j, &mut y[j + 1..s]);
        }
        y.truncate(n);
        y
    }

    /// Apply `Qᵀ` to a row-stored block of k length-s vectors (`c` is k×s),
    /// returning the k×n block of economy parts — the batched
    /// `z₀ = Qᵀc` of Algorithm 1 step 5, one row per right-hand side.
    ///
    /// Rows shard across the worker pool; row r is bitwise identical to
    /// [`QrCompact::q_transpose_vec`]`(c.row(r))` at any thread count,
    /// which keeps the blocked serving path per-RHS equivalent to the
    /// single-vector path.
    pub fn q_transpose_mat(&self, c: &DenseMatrix) -> DenseMatrix {
        let (n, s) = self.vrt.shape();
        assert_eq!(c.cols(), s, "q_transpose_mat: block has {} cols, need {s}", c.cols());
        let k = c.rows();
        let mut out = DenseMatrix::zeros(k, n);
        if k == 0 || n == 0 {
            return out;
        }
        let work = k.saturating_mul(s.saturating_mul(n));
        let threads = if work < crate::parallel::PAR_MIN_ELEMS {
            1
        } else {
            crate::parallel::threads_for(k, 1)
        };
        crate::parallel::for_each_row_block(out.data_mut(), k, n, threads, |_, rows, block| {
            for (local, r) in rows.enumerate() {
                let z = self.q_transpose_vec(c.row(r));
                block[local * n..(local + 1) * n].copy_from_slice(&z);
            }
        });
        out
    }

    /// Apply `Q` to a length-n vector, returning length s (`Q z`).
    pub fn q_vec(&self, z: &[f64]) -> Vec<f64> {
        let (n, s) = self.vrt.shape();
        assert_eq!(z.len(), n, "q_vec: len {} != cols {n}", z.len());
        let mut y = vec![0.0; s];
        y[..n].copy_from_slice(z);
        let kern = crate::simd::kernels();
        // Q = H_0 H_1 ... H_{n-1} applied right-to-left.
        for j in (0..n).rev() {
            let tau_j = self.tau[j];
            if tau_j == 0.0 {
                continue;
            }
            let v_j = &self.vrt.row(j)[j + 1..s];
            let w = y[j] + kern.dot(v_j, &y[j + 1..s]);
            let tw = tau_j * w;
            y[j] -= tw;
            kern.axpy(-tw, v_j, &mut y[j + 1..s]);
        }
        y
    }

    /// Materialize the economy Q (s×n). O(s n²) — fine at sketch scale.
    pub fn q(&self) -> DenseMatrix {
        let (s, n) = self.shape();
        let mut q = DenseMatrix::zeros(s, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e.fill(0.0);
            e[j] = 1.0;
            let col = self.q_vec(&e);
            for i in 0..s {
                q[(i, j)] = col[i];
            }
        }
        q
    }
}

/// Economy QR with materialized factors.
pub fn qr(a: &DenseMatrix) -> Result<QrFactors> {
    let compact = qr_compact(a)?;
    Ok(QrFactors { q: compact.q(), r: compact.r() })
}

/// Orthonormalize the columns of `a` (thin Q) — Haar sampling helper.
pub fn orthonormal_columns(a: &DenseMatrix) -> Result<DenseMatrix> {
    Ok(qr_compact(a)?.q())
}

/// Modified Gram–Schmidt QR — an independent second implementation used by
/// tests to cross-check Householder, and by callers that want Q with
/// slightly better row-access locality.
pub fn qr_mgs(a: &DenseMatrix) -> Result<QrFactors> {
    let (s, n) = a.shape();
    if s < n {
        return Err(LinalgError::InvalidArgument(format!(
            "qr_mgs: need rows >= cols, got {s}x{n}"
        )));
    }
    // Work column-major.
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col_copy(j)).collect();
    let mut r = DenseMatrix::zeros(n, n);
    let kern = crate::simd::kernels();
    for j in 0..n {
        // Re-orthogonalize once ("twice is enough", Giraud et al.) for
        // numerical robustness at high condition numbers.
        for _pass in 0..2 {
            for i in 0..j {
                let (head, tail) = cols.split_at_mut(j);
                let rij = kern.dot(&head[i], &tail[0]);
                r[(i, j)] += rij;
                kern.axpy(-rij, &head[i], &mut tail[0]);
            }
        }
        let norm = super::norms::nrm2(&cols[j]);
        if norm == 0.0 {
            return Err(LinalgError::Singular(format!("qr_mgs: column {j} is dependent")));
        }
        r[(j, j)] = norm;
        let inv = 1.0 / norm;
        for v in cols[j].iter_mut() {
            *v *= inv;
        }
    }
    let mut q = DenseMatrix::zeros(s, n);
    for (j, col) in cols.iter().enumerate() {
        for i in 0..s {
            q[(i, j)] = col[i];
        }
    }
    Ok(QrFactors { q, r })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn rand_matrix(s: usize, n: usize, seed: u64) -> DenseMatrix {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        DenseMatrix::gaussian(s, n, &mut g)
    }

    fn check_qr(a: &DenseMatrix, q: &DenseMatrix, r: &DenseMatrix, tol: f64) {
        let (s, n) = a.shape();
        assert_eq!(q.shape(), (s, n));
        assert_eq!(r.shape(), (n, n));
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert!(
                    r[(i, j)].abs() < tol,
                    "R not triangular at ({i},{j}): {}",
                    r[(i, j)]
                );
            }
        }
        // QᵀQ = I
        let qtq = q.transpose().matmul(q).unwrap();
        let i_n = DenseMatrix::eye(n);
        assert!(qtq.fro_distance(&i_n) < tol * (n as f64), "QtQ err {}", qtq.fro_distance(&i_n));
        // QR = A
        let qr_prod = q.matmul(r).unwrap();
        let rel = qr_prod.fro_distance(a) / a.fro_norm();
        assert!(rel < tol, "QR != A, rel err {rel}");
    }

    #[test]
    fn householder_qr_random_shapes() {
        for (s, n, seed) in [(5, 3, 1u64), (20, 20, 2), (64, 16, 3), (257, 63, 4)] {
            let a = rand_matrix(s, n, seed);
            let f = qr(&a).unwrap();
            check_qr(&a, &f.q, &f.r, 1e-12);
        }
    }

    #[test]
    fn mgs_qr_matches_invariants() {
        for (s, n, seed) in [(5, 3, 5u64), (64, 16, 6), (130, 40, 7)] {
            let a = rand_matrix(s, n, seed);
            let f = qr_mgs(&a).unwrap();
            check_qr(&a, &f.q, &f.r, 1e-12);
        }
    }

    #[test]
    fn householder_vs_mgs_same_r_up_to_signs() {
        let a = rand_matrix(40, 10, 8);
        let h = qr(&a).unwrap();
        let m = qr_mgs(&a).unwrap();
        // R factors agree up to row signs; compare |R|.
        for i in 0..10 {
            for j in i..10 {
                assert!(
                    (h.r[(i, j)].abs() - m.r[(i, j)].abs()).abs() < 1e-10,
                    "({i},{j}): {} vs {}",
                    h.r[(i, j)],
                    m.r[(i, j)]
                );
            }
        }
    }

    #[test]
    fn q_transpose_vec_matches_materialized() {
        let a = rand_matrix(33, 9, 9);
        let c = {
            let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(10));
            g.gaussian_vec(33)
        };
        let compact = qr_compact(&a).unwrap();
        let z_fast = compact.q_transpose_vec(&c);
        let q = compact.q();
        let z_ref = q.matvec_t(&c);
        for (u, v) in z_fast.iter().zip(z_ref.iter()) {
            assert!((u - v).abs() < 1e-11, "{u} vs {v}");
        }
    }

    #[test]
    fn q_vec_matches_materialized() {
        let a = rand_matrix(25, 7, 11);
        let z = {
            let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(12));
            g.gaussian_vec(7)
        };
        let compact = qr_compact(&a).unwrap();
        let y_fast = compact.q_vec(&z);
        let q = compact.q();
        let y_ref = q.matvec(&z);
        for (u, v) in y_fast.iter().zip(y_ref.iter()) {
            assert!((u - v).abs() < 1e-11);
        }
    }

    #[test]
    fn q_transpose_mat_matches_per_row_bitwise() {
        let a = rand_matrix(48, 11, 15);
        let compact = qr_compact(&a).unwrap();
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(16));
        let c = DenseMatrix::gaussian(5, 48, &mut g);
        let z = compact.q_transpose_mat(&c);
        assert_eq!(z.shape(), (5, 11));
        for r in 0..5 {
            assert_eq!(z.row(r), &compact.q_transpose_vec(c.row(r))[..], "row {r}");
        }
        let empty = DenseMatrix::zeros(0, 48);
        assert_eq!(compact.q_transpose_mat(&empty).rows(), 0);
    }

    #[test]
    fn wide_matrix_rejected() {
        let a = DenseMatrix::zeros(3, 5);
        assert!(qr(&a).is_err());
        assert!(qr_mgs(&a).is_err());
    }

    #[test]
    fn orthonormal_columns_haar_helper() {
        let a = rand_matrix(100, 20, 13);
        let q = orthonormal_columns(&a).unwrap();
        let qtq = q.transpose().matmul(&q).unwrap();
        assert!(qtq.fro_distance(&DenseMatrix::eye(20)) < 1e-11);
    }

    #[test]
    fn qr_on_illconditioned() {
        // Columns with widely varying scales — QR must remain accurate.
        let mut a = rand_matrix(50, 8, 14);
        for j in 0..8 {
            let scale = 10f64.powi(-(2 * j as i32));
            for i in 0..50 {
                a[(i, j)] *= scale;
            }
        }
        let f = qr(&a).unwrap();
        let rel = f.q.matmul(&f.r).unwrap().fro_distance(&a) / a.fro_norm();
        assert!(rel < 1e-12, "rel {rel}");
    }
}
