//! Dense and sparse linear-algebra substrate.
//!
//! Everything the sketch-and-solve stack needs and the offline environment
//! does not provide: a row-major dense matrix, blocked GEMM, CSR sparse
//! matrices, Householder QR, triangular solves, the fast Walsh–Hadamard
//! transform, norms and a power-iteration 2-norm estimator.
//!
//! Scalar type is `f64` throughout the native path (the paper's experiments
//! are NumPy/SciPy f64); the AOT/PJRT path runs f32 and is cross-checked in
//! integration tests.

pub mod dense;
pub mod gemm;
pub mod hadamard;
pub mod norms;
pub mod operator;
pub mod qr;
pub mod sparse;
pub mod triangular;

pub use dense::DenseMatrix;
pub use operator::LinearOperator;
pub use sparse::CsrMatrix;

/// A dense-or-sparse matrix — the input type of the solver and service
/// layers (dispatches sketching and matvec paths without generics).
#[derive(Debug, Clone)]
pub enum Matrix {
    Dense(DenseMatrix),
    Csr(CsrMatrix),
}

impl Matrix {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Matrix::Dense(a) => a.shape(),
            Matrix::Csr(a) => a.shape(),
        }
    }

    pub fn rows(&self) -> usize {
        self.shape().0
    }

    pub fn cols(&self) -> usize {
        self.shape().1
    }

    /// Number of stored nonzeros (dense: all entries).
    pub fn nnz(&self) -> usize {
        match self {
            Matrix::Dense(a) => a.rows() * a.cols(),
            Matrix::Csr(a) => a.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Matrix::Csr(_))
    }

    pub fn as_operator(&self) -> &dyn LinearOperator {
        match self {
            Matrix::Dense(a) => a,
            Matrix::Csr(a) => a,
        }
    }

    /// Dense materialization (small matrices / tests).
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            Matrix::Dense(a) => a.clone(),
            Matrix::Csr(a) => a.to_dense(),
        }
    }
}

impl LinearOperator for Matrix {
    fn shape(&self) -> (usize, usize) {
        Matrix::shape(self)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.as_operator().apply(x, y)
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.as_operator().apply_transpose(x, y)
    }

    fn apply_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        self.as_operator().apply_mat(x, y)
    }

    fn apply_transpose_mat(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        self.as_operator().apply_transpose_mat(x, y)
    }
}

impl From<DenseMatrix> for Matrix {
    fn from(a: DenseMatrix) -> Self {
        Matrix::Dense(a)
    }
}

impl From<CsrMatrix> for Matrix {
    fn from(a: CsrMatrix) -> Self {
        Matrix::Csr(a)
    }
}

/// Errors surfaced by the linear-algebra layer.
#[derive(Debug)]
pub enum LinalgError {
    DimensionMismatch(String),
    Singular(String),
    InvalidArgument(String),
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::DimensionMismatch(m) => write!(f, "dimension mismatch: {m}"),
            LinalgError::Singular(m) => {
                write!(f, "matrix is singular to working precision: {m}")
            }
            LinalgError::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
        }
    }
}

impl std::error::Error for LinalgError {}

pub type Result<T> = std::result::Result<T, LinalgError>;

/// `true` iff `n` is a power of two (FHT precondition).
#[inline]
pub fn is_power_of_two(n: usize) -> bool {
    n != 0 && (n & (n - 1)) == 0
}

/// Smallest power of two `>= n`.
#[inline]
pub fn next_power_of_two(n: usize) -> usize {
    n.next_power_of_two()
}
