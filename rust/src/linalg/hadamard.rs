//! Fast Walsh–Hadamard transform (FWHT) — the core of the SRHT
//! (subsampled randomized Hadamard transform) dense sketching operator.
//!
//! `fwht_inplace` applies the *unnormalized* H_n (entries ±1) in
//! O(n log n); SRHT composes `P · H · D` with D a random sign flip and P a
//! row subsample, normalized by 1/√n (Hadamard orthogonality) and √(n/s)
//! (subsample variance correction).
//!
//! **Blocked, stage-fused engine.** The textbook FWHT makes `log₂ m̃` full
//! passes over the buffer (one per butterfly stage), so at Figure-3 scale
//! (m̃ = 2²⁰) it is pure DRAM traffic. The engine here instead:
//!
//! * **tiles** the row dimension into L2-resident blocks and runs every
//!   stage with stride < tile inside the tile (one trip through DRAM for
//!   all `log₂ tile` early stages), then
//! * **fuses** the remaining cross-tile stages into radix-4/radix-8 passes
//!   ([`crate::simd::SimdKernels::butterfly4`]/[`butterfly8`]) — three
//!   butterfly stages per trip instead of one.
//!
//! Every fused radix-R kernel computes exactly the adds/subs of the
//! cascaded radix-2 stages, in the same per-element order, and tiling only
//! reorders *independent* (element, stage) work — so the blocked engine is
//! **bitwise identical** to the stage-per-pass baseline at every radix, on
//! every backend, at every thread count (pinned by
//! `tests/sketch_engine_equivalence.rs`).
//!
//! The max fused radix is a knob: [`set_fwht_radix`] (wired from
//! [`crate::config::SolveConfig`], `--fwht-radix`, `[parallel] fwht_radix`)
//! → `SNSOLVE_FWHT_RADIX` env var → default 8. Radix **1** selects the
//! stage-per-pass baseline path, kept as the bench reference
//! (`sketch_ablation` → `BENCH_sketch_apply`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::{is_power_of_two, LinalgError, Result};
use crate::simd::SimdKernels;

/// Radix knob (process-wide). 0 = unset (fall through to the env var).
static RADIX_CONFIGURED: AtomicU8 = AtomicU8::new(0);

/// Valid `--fwht-radix` / `SNSOLVE_FWHT_RADIX` / `[parallel] fwht_radix`
/// values: 1 (stage-per-pass baseline), 2, 4, 8 (blocked engine, max fused
/// radix).
pub fn is_valid_fwht_radix(r: usize) -> bool {
    matches!(r, 1 | 2 | 4 | 8)
}

/// Configure the FWHT engine radix for this process (`None` restores the
/// ambient resolution: `SNSOLVE_FWHT_RADIX`, then 8). Panics on values
/// outside {1, 2, 4, 8}; the CLI/config layers validate before calling.
pub fn set_fwht_radix(radix: Option<usize>) {
    let v = match radix {
        None => 0u8,
        Some(r) => {
            assert!(is_valid_fwht_radix(r), "fwht radix must be 1, 2, 4 or 8 (got {r})");
            r as u8
        }
    };
    RADIX_CONFIGURED.store(v, Ordering::SeqCst);
}

fn env_radix() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        // snsolve-lint: allow(env-reads-behind-config) — designated
        // knob-resolution site: OnceLock-cached SNSOLVE_FWHT_RADIX fallback
        // behind set_fwht_radix() (CLI/config take precedence).
        std::env::var("SNSOLVE_FWHT_RADIX")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&r| is_valid_fwht_radix(r))
            .unwrap_or(8)
    })
}

/// The radix the FWHT engine resolves to right now:
/// [`set_fwht_radix`] → `SNSOLVE_FWHT_RADIX` → 8.
pub fn fwht_radix_in_use() -> usize {
    match RADIX_CONFIGURED.load(Ordering::SeqCst) {
        0 => env_radix(),
        v => v as usize,
    }
}

/// ~256 KB of f64 per L2-resident row tile.
const TILE_ELEMS: usize = 32 * 1024;

/// Largest power-of-two row tile with `tile · width ≤ TILE_ELEMS` (clamped
/// to `[1, rows]`). The tile size only affects *which order* independent
/// (element, stage) updates run in — never the arithmetic — so it is free
/// to depend on the band width without breaking bitwise determinism.
fn tile_rows(rows: usize, width: usize) -> usize {
    let w = width.max(1);
    let mut t = 1usize;
    while t < rows && 2 * t * w <= TILE_ELEMS {
        t *= 2;
    }
    t
}

/// Next fused radix for a pass at stride `h` when `h_end / h` stages remain
/// (both powers of two): the largest of {8, 4, 2} allowed by the knob that
/// still divides the remaining span.
fn next_radix(h: usize, h_end: usize, radix: usize) -> usize {
    let rem = h_end / h;
    if radix >= 8 && rem >= 8 {
        8
    } else if radix >= 4 && rem >= 4 {
        4
    } else {
        2
    }
}

// ---------------------------------------------------------------------------
// Vector engine (contiguous layout)
// ---------------------------------------------------------------------------

/// In-place unnormalized FWHT of a power-of-two-length vector, through the
/// blocked stage-fused engine at the ambient radix ([`fwht_radix_in_use`]).
/// Bitwise identical to the stage-per-pass baseline at every radix.
pub fn fwht_inplace(x: &mut [f64]) -> Result<()> {
    fwht_with_radix(x, fwht_radix_in_use())
}

/// [`fwht_inplace`] with an explicit radix (1 = stage-per-pass baseline;
/// 2/4/8 = blocked engine with that max fused radix). Exposed for the
/// equivalence tests and the bench baseline.
pub fn fwht_with_radix(x: &mut [f64], radix: usize) -> Result<()> {
    let n = x.len();
    if !is_power_of_two(n) {
        return Err(LinalgError::InvalidArgument(format!(
            "fwht: length {n} is not a power of two"
        )));
    }
    if !is_valid_fwht_radix(radix) {
        return Err(LinalgError::InvalidArgument(format!(
            "fwht: radix {radix} not in {{1, 2, 4, 8}}"
        )));
    }
    if n <= 1 {
        return Ok(());
    }
    if radix == 1 {
        fwht_vec_stagewise(x);
        return Ok(());
    }
    let tile = tile_rows(n, 1);
    if tile > 1 {
        for t0 in (0..n).step_by(tile) {
            fused_stages_vec(x, t0, t0 + tile, 1, tile, radix);
        }
    }
    fused_stages_vec(x, 0, n, tile, n, radix);
    Ok(())
}

/// Stage-per-pass baseline on a contiguous vector (the seed
/// implementation, kept as the bench/equivalence reference).
fn fwht_vec_stagewise(x: &mut [f64]) {
    let n = x.len();
    let kern = crate::simd::kernels();
    let mut h = 1;
    while h < n {
        // The early stages (h < 8) stay inline: one dispatched call per
        // 1-4-element half would cost more than the adds it performs, and
        // the inline loop is bitwise identical to every backend's
        // butterfly anyway.
        if h < 8 {
            for block in (0..n).step_by(2 * h) {
                for i in block..block + h {
                    bf2_scalar(x, i, h);
                }
            }
        } else {
            for block in (0..n).step_by(2 * h) {
                let (lo, hi) = x[block..block + 2 * h].split_at_mut(h);
                kern.butterfly(lo, hi);
            }
        }
        h *= 2;
    }
}

/// All butterfly stages with strides in `[h0, h_end)` over elements
/// `[r0, r1)` of a contiguous vector, fused into radix passes. `r1 − r0`
/// must be a multiple of `h_end`, and `h0`/`h_end` powers of two.
fn fused_stages_vec(x: &mut [f64], r0: usize, r1: usize, h0: usize, h_end: usize, radix: usize) {
    let kern = crate::simd::kernels();
    let mut h = h0;
    while h < h_end {
        let r = next_radix(h, h_end, radix);
        fused_pass_vec(kern, x, r0, r1, h, r);
        h *= r;
    }
}

/// One fused radix-`r` pass at stride `h` over `[r0, r1)` (contiguous
/// layout: the stride-`h` row slices are `h`-element chunks). Small-`h`
/// passes stay inline-scalar (bitwise identical to the kernels).
fn fused_pass_vec(
    kern: &'static dyn SimdKernels,
    x: &mut [f64],
    r0: usize,
    r1: usize,
    h: usize,
    r: usize,
) {
    match r {
        8 => {
            for block in (r0..r1).step_by(8 * h) {
                if h < 8 {
                    for i in block..block + h {
                        bf8_scalar(x, i, h);
                    }
                } else {
                    let (s0, rest) = x[block..block + 8 * h].split_at_mut(h);
                    let (s1, rest) = rest.split_at_mut(h);
                    let (s2, rest) = rest.split_at_mut(h);
                    let (s3, rest) = rest.split_at_mut(h);
                    let (s4, rest) = rest.split_at_mut(h);
                    let (s5, rest) = rest.split_at_mut(h);
                    let (s6, s7) = rest.split_at_mut(h);
                    kern.butterfly8([s0, s1, s2, s3, s4, s5, s6, s7]);
                }
            }
        }
        4 => {
            for block in (r0..r1).step_by(4 * h) {
                if h < 8 {
                    for i in block..block + h {
                        bf4_scalar(x, i, h);
                    }
                } else {
                    let (s0, rest) = x[block..block + 4 * h].split_at_mut(h);
                    let (s1, rest) = rest.split_at_mut(h);
                    let (s2, s3) = rest.split_at_mut(h);
                    kern.butterfly4(s0, s1, s2, s3);
                }
            }
        }
        _ => {
            for block in (r0..r1).step_by(2 * h) {
                if h < 8 {
                    for i in block..block + h {
                        bf2_scalar(x, i, h);
                    }
                } else {
                    let (lo, hi) = x[block..block + 2 * h].split_at_mut(h);
                    kern.butterfly(lo, hi);
                }
            }
        }
    }
}

/// Inline radix-2 butterfly on elements `(i, i+h)` — the seed loop body.
#[inline(always)]
fn bf2_scalar(x: &mut [f64], i: usize, h: usize) {
    let a = x[i];
    let b = x[i + h];
    x[i] = a + b;
    x[i + h] = a - b;
}

/// Inline radix-4 butterfly on elements `i + {0, h, 2h, 3h}` — routed
/// through [`crate::simd::butterfly4_lane`], the single source of the
/// cascade every backend shares (so the inline path cannot drift from the
/// dispatched kernels).
#[inline(always)]
fn bf4_scalar(x: &mut [f64], i: usize, h: usize) {
    let (o0, o1, o2, o3) =
        crate::simd::butterfly4_lane(x[i], x[i + h], x[i + 2 * h], x[i + 3 * h]);
    x[i] = o0;
    x[i + h] = o1;
    x[i + 2 * h] = o2;
    x[i + 3 * h] = o3;
}

/// Inline radix-8 butterfly on elements `i + {0, h, .., 7h}` — routed
/// through [`crate::simd::butterfly8_lane`] (see [`bf4_scalar`]).
#[inline(always)]
fn bf8_scalar(x: &mut [f64], i: usize, h: usize) {
    let mut v = [0.0f64; 8];
    for (l, vl) in v.iter_mut().enumerate() {
        *vl = x[i + l * h];
    }
    let o = crate::simd::butterfly8_lane(v);
    for (l, &ol) in o.iter().enumerate() {
        x[i + l * h] = ol;
    }
}

// ---------------------------------------------------------------------------
// Column engine (row-major rows × cols, transform along rows per column)
// ---------------------------------------------------------------------------

/// FWHT each *column* of a row-major (rows × cols) buffer, where `rows` is a
/// power of two, through the blocked stage-fused engine at the ambient
/// radix. This is the operation SRHT applies to a tall matrix: mix along
/// the sample (row) dimension, independently per feature column.
///
/// Implementation note: rather than transposing, the butterfly runs with
/// row-strided accesses but processes all columns of a row group
/// contiguously — each fused pass is a sweep of length-`cols` vector
/// adds/subs, bandwidth-optimal for row-major data — and the row dimension
/// is tiled so the `log₂ tile` early stages complete inside L2.
///
/// Parallel: columns are independent, so the buffer is split into disjoint
/// column *bands*, one scoped worker per band. Every column runs exactly
/// the serial stage cascade, so the result is **bitwise identical** at any
/// thread count, radix, and backend.
pub fn fwht_columns_inplace(data: &mut [f64], rows: usize, cols: usize) -> Result<()> {
    fwht_columns_with_radix(data, rows, cols, fwht_radix_in_use())
}

/// [`fwht_columns_inplace`] with an explicit radix (1 = stage-per-pass
/// baseline; 2/4/8 = blocked engine with that max fused radix). Exposed
/// for the equivalence tests and the bench baseline.
pub fn fwht_columns_with_radix(
    data: &mut [f64],
    rows: usize,
    cols: usize,
    radix: usize,
) -> Result<()> {
    if data.len() != rows * cols {
        return Err(LinalgError::DimensionMismatch(format!(
            "fwht_columns: buffer {} != {rows}x{cols}",
            data.len()
        )));
    }
    if !is_power_of_two(rows) {
        return Err(LinalgError::InvalidArgument(format!(
            "fwht_columns: rows {rows} not a power of two"
        )));
    }
    if !is_valid_fwht_radix(radix) {
        return Err(LinalgError::InvalidArgument(format!(
            "fwht_columns: radix {radix} not in {{1, 2, 4, 8}}"
        )));
    }
    if rows <= 1 || cols == 0 {
        return Ok(());
    }
    let kern = crate::simd::kernels();
    let threads = if rows * cols < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(cols, 8)
    };
    if threads <= 1 {
        // SAFETY: exclusive access to the whole buffer via &mut.
        unsafe { fwht_band(kern, data.as_mut_ptr(), rows, cols, 0, cols, radix) };
        return Ok(());
    }
    let ptr = crate::parallel::SendMutPtr(data.as_mut_ptr());
    crate::parallel::run_partitioned(cols, threads, |_, band| {
        // SAFETY: bands partition the column index space, so workers touch
        // disjoint elements of `data`, which outlives the scoped threads.
        unsafe { fwht_band(kern, ptr.0, rows, cols, band.start, band.end, radix) };
    });
    Ok(())
}

/// Full transform of columns `[j0, j1)`: L2 row tiles through the early
/// stages, fused radix passes across tiles, or the stage-per-pass baseline
/// when `radix == 1`.
///
/// # Safety
/// `base` must point at a live `rows × cols` buffer and no other thread may
/// touch columns `[j0, j1)` while this runs.
unsafe fn fwht_band(
    kern: &'static dyn SimdKernels,
    base: *mut f64,
    rows: usize,
    cols: usize,
    j0: usize,
    j1: usize,
    radix: usize,
) {
    if radix == 1 {
        // SAFETY: forwards the function-level contract unchanged.
        unsafe { fwht_band_stagewise(kern, base, rows, cols, j0, j1) };
        return;
    }
    let w = j1 - j0;
    let tile = tile_rows(rows, w);
    if tile > 1 {
        let mut t0 = 0;
        while t0 < rows {
            // SAFETY: forwards the function-level contract; row tiles
            // partition [0, rows) so each early-stage pass is in-bounds.
            unsafe { fused_stages_band(kern, base, cols, j0, w, t0, t0 + tile, 1, tile, radix) };
            t0 += tile;
        }
    }
    // SAFETY: forwards the function-level contract (late stages sweep the
    // whole band once the per-tile stages are done).
    unsafe { fused_stages_band(kern, base, cols, j0, w, 0, rows, tile, rows, radix) };
}

/// Stage-per-pass baseline restricted to columns `[j0, j1)` (the seed
/// implementation: one full sweep per butterfly stage).
///
/// # Safety
/// Same contract as [`fwht_band`].
unsafe fn fwht_band_stagewise(
    kern: &'static dyn SimdKernels,
    base: *mut f64,
    rows: usize,
    cols: usize,
    j0: usize,
    j1: usize,
) {
    let w = j1 - j0;
    let mut h = 1;
    while h < rows {
        for block in (0..rows).step_by(2 * h) {
            for i in block..block + h {
                // SAFETY: function contract — this thread owns columns
                // [j0, j1) of the live rows×cols buffer; rows `i` and
                // `i + h` are distinct, so the two slices never alias.
                let (a, b) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(base.add(i * cols + j0), w),
                        std::slice::from_raw_parts_mut(base.add((i + h) * cols + j0), w),
                    )
                };
                kern.butterfly(a, b);
            }
        }
        h *= 2;
    }
}

/// All butterfly stages with strides in `[h0, h_end)` over rows `[r0, r1)`
/// of the column band, fused into radix passes. `r1 − r0` must be a
/// multiple of `h_end`.
///
/// # Safety
/// Same contract as [`fwht_band`].
#[allow(clippy::too_many_arguments)]
unsafe fn fused_stages_band(
    kern: &'static dyn SimdKernels,
    base: *mut f64,
    cols: usize,
    j0: usize,
    w: usize,
    r0: usize,
    r1: usize,
    h0: usize,
    h_end: usize,
    radix: usize,
) {
    let mut h = h0;
    while h < h_end {
        let r = next_radix(h, h_end, radix);
        // SAFETY: forwards the function-level contract for one fused pass.
        unsafe { fused_pass_band(kern, base, cols, j0, w, r0, r1, h, r) };
        h *= r;
    }
}

/// One fused radix-`r` pass at row stride `h` over rows `[r0, r1)` of the
/// column band `[j0, j0+w)`.
///
/// # Safety
/// Same contract as [`fwht_band`]; the row octets/quartets/pairs handed to
/// the fused kernels are disjoint by construction.
#[allow(clippy::too_many_arguments)]
unsafe fn fused_pass_band(
    kern: &'static dyn SimdKernels,
    base: *mut f64,
    cols: usize,
    j0: usize,
    w: usize,
    r0: usize,
    r1: usize,
    h: usize,
    r: usize,
) {
    let row = |i: usize| {
        // SAFETY: delegated to the function-level contract; each index maps
        // to a distinct row of the band.
        unsafe { std::slice::from_raw_parts_mut(base.add(i * cols + j0), w) }
    };
    match r {
        8 => {
            for block in (r0..r1).step_by(8 * h) {
                for i in block..block + h {
                    kern.butterfly8([
                        row(i),
                        row(i + h),
                        row(i + 2 * h),
                        row(i + 3 * h),
                        row(i + 4 * h),
                        row(i + 5 * h),
                        row(i + 6 * h),
                        row(i + 7 * h),
                    ]);
                }
            }
        }
        4 => {
            for block in (r0..r1).step_by(4 * h) {
                for i in block..block + h {
                    kern.butterfly4(row(i), row(i + h), row(i + 2 * h), row(i + 3 * h));
                }
            }
        }
        _ => {
            for block in (r0..r1).step_by(2 * h) {
                for i in block..block + h {
                    kern.butterfly(row(i), row(i + h));
                }
            }
        }
    }
}

/// Reference O(n²) Walsh–Hadamard for tests: `y[k] = Σ_i (-1)^{popcount(i&k)} x[i]`.
pub fn wht_reference(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut y = vec![0.0; n];
    for (k, yk) in y.iter_mut().enumerate() {
        let mut s = 0.0;
        for (i, &xi) in x.iter().enumerate() {
            let sign = if ((i & k).count_ones() & 1) == 0 { 1.0 } else { -1.0 };
            s += sign * xi;
        }
        *yk = s;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    #[test]
    fn fwht_matches_reference() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(31));
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = g.gaussian_vec(n);
            let mut y = x.clone();
            fwht_inplace(&mut y).unwrap();
            let y_ref = wht_reference(&x);
            for (u, v) in y.iter().zip(y_ref.iter()) {
                assert!((u - v).abs() < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn fwht_involution() {
        // H (H x) = n x.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(32));
        let x = g.gaussian_vec(128);
        let mut y = x.clone();
        fwht_inplace(&mut y).unwrap();
        fwht_inplace(&mut y).unwrap();
        for (u, v) in y.iter().zip(x.iter()) {
            assert!((u - 128.0 * v).abs() < 1e-9);
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        // Parseval: ||Hx||² = n ||x||².
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(33));
        let x = g.gaussian_vec(512);
        let e0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y).unwrap();
        let e1: f64 = y.iter().map(|v| v * v).sum();
        assert!((e1 - 512.0 * e0).abs() / (512.0 * e0) < 1e-12);
    }

    #[test]
    fn fwht_rejects_non_pow2_and_bad_radix() {
        let mut x = vec![0.0; 6];
        assert!(fwht_inplace(&mut x).is_err());
        let mut d = vec![0.0; 12];
        assert!(fwht_columns_inplace(&mut d, 6, 2).is_err());
        assert!(fwht_columns_inplace(&mut d, 4, 2).is_err()); // wrong buffer size
        let mut ok = vec![0.0; 8];
        assert!(fwht_with_radix(&mut ok, 3).is_err());
        assert!(fwht_with_radix(&mut ok, 16).is_err());
        let mut okc = vec![0.0; 16];
        assert!(fwht_columns_with_radix(&mut okc, 8, 2, 0).is_err());
    }

    #[test]
    fn fwht_columns_matches_per_column() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(34));
        let (rows, cols) = (64usize, 7usize);
        let data: Vec<f64> = g.gaussian_vec(rows * cols);
        let mut block = data.clone();
        fwht_columns_inplace(&mut block, rows, cols).unwrap();
        for j in 0..cols {
            let mut col: Vec<f64> = (0..rows).map(|i| data[i * cols + j]).collect();
            fwht_inplace(&mut col).unwrap();
            for i in 0..rows {
                assert!((block[i * cols + j] - col[i]).abs() < 1e-10);
            }
        }
    }

    /// The blocked stage-fused engine is bitwise identical to the
    /// stage-per-pass baseline at every radix — the structural guarantee
    /// the whole sketch engine rides on (swept across backends and thread
    /// counts in `tests/sketch_engine_equivalence.rs`).
    #[test]
    fn fused_radices_bitwise_match_stagewise() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(35));
        for rows in [2usize, 8, 32, 256, 1024] {
            // Vector engine.
            let x = g.gaussian_vec(rows);
            let mut base = x.clone();
            fwht_with_radix(&mut base, 1).unwrap();
            for radix in [2usize, 4, 8] {
                let mut y = x.clone();
                fwht_with_radix(&mut y, radix).unwrap();
                assert_eq!(y, base, "vector rows={rows} radix={radix}");
            }
            // Column engine (odd width exercises ragged vector tails).
            let cols = 5usize;
            let data = g.gaussian_vec(rows * cols);
            let mut cbase = data.clone();
            fwht_columns_with_radix(&mut cbase, rows, cols, 1).unwrap();
            for radix in [2usize, 4, 8] {
                let mut d = data.clone();
                fwht_columns_with_radix(&mut d, rows, cols, radix).unwrap();
                assert_eq!(d, cbase, "columns rows={rows} radix={radix}");
            }
        }
    }

    #[test]
    fn radix_knob_resolution() {
        assert!(is_valid_fwht_radix(1) && is_valid_fwht_radix(8));
        assert!(!is_valid_fwht_radix(0) && !is_valid_fwht_radix(3) && !is_valid_fwht_radix(16));
        // NOTE: no set_fwht_radix here — the knob is process-global and
        // unit tests run concurrently (same rule as the simd choice).
        assert!(is_valid_fwht_radix(fwht_radix_in_use()));
    }

    #[test]
    fn tile_rows_clamped_power_of_two() {
        assert_eq!(tile_rows(1 << 20, 1), TILE_ELEMS);
        assert_eq!(tile_rows(16, 1), 16);
        assert_eq!(tile_rows(1 << 20, TILE_ELEMS), 1);
        let t = tile_rows(1 << 20, 100);
        assert!(is_power_of_two(t) && t * 100 <= TILE_ELEMS && 2 * t * 100 > TILE_ELEMS);
    }
}
