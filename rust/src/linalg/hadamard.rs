//! Fast Walsh–Hadamard transform (FWHT) — the core of the SRHT
//! (subsampled randomized Hadamard transform) dense sketching operator.
//!
//! `fwht_inplace` applies the *unnormalized* H_n (entries ±1) in
//! O(n log n); SRHT composes `P · H · D` with D a random sign flip and P a
//! row subsample, normalized by 1/√n (Hadamard orthogonality) and √(n/s)
//! (subsample variance correction).

use super::{is_power_of_two, LinalgError, Result};

/// In-place unnormalized FWHT of a power-of-two-length vector.
///
/// Each stage's block halves are contiguous, so the whole butterfly runs
/// through the dispatched SIMD add/sub pass. The pass is adds/subs only —
/// bitwise identical on every backend.
pub fn fwht_inplace(x: &mut [f64]) -> Result<()> {
    let n = x.len();
    if !is_power_of_two(n) {
        return Err(LinalgError::InvalidArgument(format!(
            "fwht: length {n} is not a power of two"
        )));
    }
    let kern = crate::simd::kernels();
    let mut h = 1;
    while h < n {
        // Butterfly stage at stride h; blocks of 2h. The early stages
        // (h < 8) stay inline: one dispatched call per 1-4-element half
        // would cost more than the adds it performs, and the inline loop
        // is bitwise identical to every backend's butterfly anyway.
        if h < 8 {
            for block in (0..n).step_by(2 * h) {
                for i in block..block + h {
                    let a = x[i];
                    let b = x[i + h];
                    x[i] = a + b;
                    x[i + h] = a - b;
                }
            }
        } else {
            for block in (0..n).step_by(2 * h) {
                let (lo, hi) = x[block..block + 2 * h].split_at_mut(h);
                kern.butterfly(lo, hi);
            }
        }
        h *= 2;
    }
    Ok(())
}

/// FWHT each *column* of a row-major (rows × cols) buffer, where `rows` is a
/// power of two. This is the operation SRHT applies to a tall matrix: mix
/// along the sample (row) dimension, independently per feature column.
///
/// Implementation note: rather than transposing, we run the butterfly with
/// row-strided accesses but process all columns of a row pair contiguously —
/// each stage is a pass of length-`cols` vector adds/subs, which is
/// bandwidth-optimal for row-major data.
///
/// Parallel: columns are independent, so the buffer is split into disjoint
/// column *bands*, one scoped worker per band. Every column runs exactly
/// the serial butterfly, so the result is **bitwise identical** at any
/// thread count.
pub fn fwht_columns_inplace(data: &mut [f64], rows: usize, cols: usize) -> Result<()> {
    if data.len() != rows * cols {
        return Err(LinalgError::DimensionMismatch(format!(
            "fwht_columns: buffer {} != {rows}x{cols}",
            data.len()
        )));
    }
    if !is_power_of_two(rows) {
        return Err(LinalgError::InvalidArgument(format!(
            "fwht_columns: rows {rows} not a power of two"
        )));
    }
    if rows <= 1 {
        return Ok(());
    }
    let threads = if rows * cols < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(cols, 8)
    };
    if threads <= 1 {
        fwht_columns_serial(data, rows, cols);
        return Ok(());
    }
    let ptr = crate::parallel::SendMutPtr(data.as_mut_ptr());
    crate::parallel::run_partitioned(cols, threads, |_, band| {
        // SAFETY: bands partition the column index space, so workers write
        // disjoint elements of `data`, which outlives the scoped threads.
        unsafe { fwht_column_band(ptr, rows, cols, band.start, band.end) };
    });
    Ok(())
}

/// Serial full-width butterfly (all columns at once), each row pair through
/// the dispatched SIMD add/sub pass.
fn fwht_columns_serial(data: &mut [f64], rows: usize, cols: usize) {
    let kern = crate::simd::kernels();
    let mut h = 1;
    while h < rows {
        for block in (0..rows).step_by(2 * h) {
            for i in block..block + h {
                let (top, bot) = data.split_at_mut((i + h) * cols);
                kern.butterfly(&mut top[i * cols..i * cols + cols], &mut bot[..cols]);
            }
        }
        h *= 2;
    }
}

/// Butterfly restricted to columns `[j0, j1)` of the row-major buffer.
///
/// # Safety
/// `ptr` must point at a live `rows × cols` buffer and no other thread may
/// touch columns `[j0, j1)` while this runs.
unsafe fn fwht_column_band(
    ptr: crate::parallel::SendMutPtr,
    rows: usize,
    cols: usize,
    j0: usize,
    j1: usize,
) {
    let base = ptr.0;
    let w = j1 - j0;
    let kern = crate::simd::kernels();
    let mut h = 1;
    while h < rows {
        for block in (0..rows).step_by(2 * h) {
            for i in block..block + h {
                let a = std::slice::from_raw_parts_mut(base.add(i * cols + j0), w);
                let b = std::slice::from_raw_parts_mut(base.add((i + h) * cols + j0), w);
                kern.butterfly(a, b);
            }
        }
        h *= 2;
    }
}

/// Reference O(n²) Walsh–Hadamard for tests: `y[k] = Σ_i (-1)^{popcount(i&k)} x[i]`.
pub fn wht_reference(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut y = vec![0.0; n];
    for (k, yk) in y.iter_mut().enumerate() {
        let mut s = 0.0;
        for (i, &xi) in x.iter().enumerate() {
            let sign = if ((i & k).count_ones() & 1) == 0 { 1.0 } else { -1.0 };
            s += sign * xi;
        }
        *yk = s;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    #[test]
    fn fwht_matches_reference() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(31));
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = g.gaussian_vec(n);
            let mut y = x.clone();
            fwht_inplace(&mut y).unwrap();
            let y_ref = wht_reference(&x);
            for (u, v) in y.iter().zip(y_ref.iter()) {
                assert!((u - v).abs() < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn fwht_involution() {
        // H (H x) = n x.
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(32));
        let x = g.gaussian_vec(128);
        let mut y = x.clone();
        fwht_inplace(&mut y).unwrap();
        fwht_inplace(&mut y).unwrap();
        for (u, v) in y.iter().zip(x.iter()) {
            assert!((u - 128.0 * v).abs() < 1e-9);
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        // Parseval: ||Hx||² = n ||x||².
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(33));
        let x = g.gaussian_vec(512);
        let e0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y).unwrap();
        let e1: f64 = y.iter().map(|v| v * v).sum();
        assert!((e1 - 512.0 * e0).abs() / (512.0 * e0) < 1e-12);
    }

    #[test]
    fn fwht_rejects_non_pow2() {
        let mut x = vec![0.0; 6];
        assert!(fwht_inplace(&mut x).is_err());
        let mut d = vec![0.0; 12];
        assert!(fwht_columns_inplace(&mut d, 6, 2).is_err());
        assert!(fwht_columns_inplace(&mut d, 4, 2).is_err()); // wrong buffer size
    }

    #[test]
    fn fwht_columns_matches_per_column() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(34));
        let (rows, cols) = (64usize, 7usize);
        let data: Vec<f64> = g.gaussian_vec(rows * cols);
        let mut block = data.clone();
        fwht_columns_inplace(&mut block, rows, cols).unwrap();
        for j in 0..cols {
            let mut col: Vec<f64> = (0..rows).map(|i| data[i * cols + j]).collect();
            fwht_inplace(&mut col).unwrap();
            for i in 0..rows {
                assert!((block[i * cols + j] - col[i]).abs() < 1e-10);
            }
        }
    }
}
