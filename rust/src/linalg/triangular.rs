//! Triangular solves: forward/back substitution (Algorithm 1 steps 4 & 8)
//! and the right-multiplication `Y = A R⁻¹` used to precondition LSQR.

use super::dense::DenseMatrix;
use super::{LinalgError, Result};

/// Relative pivot threshold below which we declare R singular.
const SINGULAR_RTOL: f64 = 1e-300;

/// Solve `R x = b` with `R` upper triangular (back substitution).
pub fn solve_upper(r: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_square(r)?;
    if b.len() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_upper: R is {n}x{n}, b has {}",
            b.len()
        )));
    }
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        let row = r.row(i);
        for j in i + 1..n {
            s -= row[j] * x[j];
        }
        let d = row[i];
        if d.abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_upper: R[{i},{i}] = {d}")));
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Solve `L x = b` with `L` lower triangular (forward substitution).
pub fn solve_lower(l: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_square(l)?;
    if b.len() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_lower: L is {n}x{n}, b has {}",
            b.len()
        )));
    }
    let mut x = b.to_vec();
    for i in 0..n {
        let row = l.row(i);
        let mut s = x[i];
        for j in 0..i {
            s -= row[j] * x[j];
        }
        let d = row[i];
        if d.abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_lower: L[{i},{i}] = {d}")));
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Solve `Rᵀ x = b` with `R` upper triangular (i.e. a lower-triangular solve
/// against R's transpose, without forming it).
pub fn solve_upper_transpose(r: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_square(r)?;
    if b.len() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_upper_transpose: R is {n}x{n}, b has {}",
            b.len()
        )));
    }
    let mut x = b.to_vec();
    for i in 0..n {
        let d = r[(i, i)];
        if d.abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_upper_transpose: R[{i},{i}] = {d}")));
        }
        x[i] /= d;
        let xi = x[i];
        // Rᵀ is lower; eliminate column i of Rᵀ = row i of R beyond diag.
        let row = r.row(i);
        for j in i + 1..n {
            x[j] -= row[j] * xi;
        }
    }
    Ok(x)
}

/// Compute `Y = A R⁻¹` for tall dense `A` (m×n) and upper-triangular `R`
/// (n×n) — "forward substitution" in the paper's Algorithm 1 step 4
/// (each *row* of Y solves `Rᵀ yᵢᵀ = aᵢᵀ`).
///
/// Row-major A makes this embarrassingly row-parallel and cache-perfect:
/// each row of A is transformed independently against cache-resident R.
pub fn right_solve_upper(a: &DenseMatrix, r: &DenseMatrix) -> Result<DenseMatrix> {
    let n = check_square(r)?;
    if a.cols() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "right_solve_upper: A is {}x{}, R is {n}x{n}",
            a.rows(),
            a.cols()
        )));
    }
    for i in 0..n {
        if r[(i, i)].abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("right_solve_upper: R[{i},{i}] = 0")));
        }
    }
    let mut y = a.clone();
    right_solve_upper_inplace(&mut y, r);
    Ok(y)
}

/// In-place version of [`right_solve_upper`] (A is overwritten with Y).
pub fn right_solve_upper_inplace(a: &mut DenseMatrix, r: &DenseMatrix) {
    let n = r.rows();
    debug_assert_eq!(a.cols(), n);
    let m = a.rows();
    // y_row Rᵀ-solve: y[j] = (a[j] - sum_{k<j} y[k] R[k,j]) / R[j,j]
    // Process column j in increasing order; vectorize over rows in blocks.
    let inv_diag: Vec<f64> = (0..n).map(|j| 1.0 / r[(j, j)]).collect();
    for bi in (0..m).step_by(64) {
        let bend = (bi + 64).min(m);
        for j in 0..n {
            // gather R column j above diagonal once
            for i in bi..bend {
                let row = a.row_mut(i);
                let mut s = row[j];
                for k in 0..j {
                    s -= row[k] * r[(k, j)];
                }
                row[j] = s * inv_diag[j];
            }
        }
    }
}

fn check_square(m: &DenseMatrix) -> Result<usize> {
    let (r, c) = m.shape();
    if r != c {
        return Err(LinalgError::InvalidArgument(format!("expected square, got {r}x{c}")));
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::qr;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn rand_upper(n: usize, seed: u64) -> DenseMatrix {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = g.next_gaussian();
            }
            // keep diagonal away from zero
            r[(i, i)] += 3.0 * r[(i, i)].signum();
            if r[(i, i)] == 0.0 {
                r[(i, i)] = 3.0;
            }
        }
        r
    }

    #[test]
    fn upper_solve_roundtrip() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(21));
        for n in [1usize, 2, 5, 33, 100] {
            let r = rand_upper(n, n as u64);
            let x_true = g.gaussian_vec(n);
            let b = r.matvec(&x_true);
            let x = solve_upper(&r, &b).unwrap();
            for (u, v) in x.iter().zip(x_true.iter()) {
                assert!((u - v).abs() < 1e-9 * (1.0 + v.abs()), "n={n}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn lower_solve_roundtrip() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(22));
        for n in [1usize, 3, 17, 64] {
            let l = rand_upper(n, 100 + n as u64).transpose();
            let x_true = g.gaussian_vec(n);
            let b = l.matvec(&x_true);
            let x = solve_lower(&l, &b).unwrap();
            for (u, v) in x.iter().zip(x_true.iter()) {
                assert!((u - v).abs() < 1e-9 * (1.0 + v.abs()));
            }
        }
    }

    #[test]
    fn upper_transpose_solve_matches_explicit() {
        let n = 20;
        let r = rand_upper(n, 23);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(24));
        let b = g.gaussian_vec(n);
        let x1 = solve_upper_transpose(&r, &b).unwrap();
        let x2 = solve_lower(&r.transpose(), &b).unwrap();
        for (u, v) in x1.iter().zip(x2.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn right_solve_matches_per_row() {
        let (m, n) = (47, 12);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(25));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let r = rand_upper(n, 26);
        let y = right_solve_upper(&a, &r).unwrap();
        // Check Y R = A.
        let yr = y.matmul(&r).unwrap();
        let rel = yr.fro_distance(&a) / a.fro_norm();
        assert!(rel < 1e-11, "rel {rel}");
    }

    #[test]
    fn right_solve_preconditions_qr() {
        // Y = A R⁻¹ where R comes from QR(A) must have orthonormal columns.
        let (m, n) = (120, 15);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(27));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let f = qr(&a).unwrap();
        let y = right_solve_upper(&a, &f.r).unwrap();
        let yty = y.transpose().matmul(&y).unwrap();
        assert!(yty.fro_distance(&DenseMatrix::eye(n)) < 1e-10);
    }

    #[test]
    fn singular_detected() {
        let mut r = DenseMatrix::eye(3);
        r[(1, 1)] = 0.0;
        assert!(matches!(solve_upper(&r, &[1.0, 1.0, 1.0]), Err(LinalgError::Singular(_))));
        assert!(matches!(
            solve_upper_transpose(&r, &[1.0, 1.0, 1.0]),
            Err(LinalgError::Singular(_))
        ));
        let a = DenseMatrix::zeros(4, 3);
        assert!(matches!(right_solve_upper(&a, &r), Err(LinalgError::Singular(_))));
    }

    #[test]
    fn dimension_checks() {
        let r = DenseMatrix::eye(3);
        assert!(solve_upper(&r, &[1.0, 2.0]).is_err());
        assert!(solve_lower(&r, &[1.0, 2.0]).is_err());
        let a = DenseMatrix::zeros(5, 4);
        assert!(right_solve_upper(&a, &r).is_err());
        let ns = DenseMatrix::zeros(3, 4);
        assert!(solve_upper(&ns, &[1.0, 2.0, 3.0]).is_err());
    }
}
