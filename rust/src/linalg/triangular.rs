//! Triangular solves: forward/back substitution (Algorithm 1 steps 4 & 8)
//! and the right-multiplication `Y = A R⁻¹` used to precondition LSQR.

use super::dense::DenseMatrix;
use super::{LinalgError, Result};

/// Relative pivot threshold below which we declare R singular.
const SINGULAR_RTOL: f64 = 1e-300;

/// Solve `R x = b` with `R` upper triangular (back substitution).
pub fn solve_upper(r: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_square(r)?;
    if b.len() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_upper: R is {n}x{n}, b has {}",
            b.len()
        )));
    }
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        let row = r.row(i);
        for j in i + 1..n {
            s -= row[j] * x[j];
        }
        let d = row[i];
        if d.abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_upper: R[{i},{i}] = {d}")));
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Solve `L x = b` with `L` lower triangular (forward substitution).
pub fn solve_lower(l: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_square(l)?;
    if b.len() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_lower: L is {n}x{n}, b has {}",
            b.len()
        )));
    }
    let mut x = b.to_vec();
    for i in 0..n {
        let row = l.row(i);
        let mut s = x[i];
        for j in 0..i {
            s -= row[j] * x[j];
        }
        let d = row[i];
        if d.abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_lower: L[{i},{i}] = {d}")));
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Solve `Rᵀ x = b` with `R` upper triangular (i.e. a lower-triangular solve
/// against R's transpose, without forming it).
pub fn solve_upper_transpose(r: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_square(r)?;
    if b.len() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_upper_transpose: R is {n}x{n}, b has {}",
            b.len()
        )));
    }
    let mut x = b.to_vec();
    for i in 0..n {
        let d = r[(i, i)];
        if d.abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_upper_transpose: R[{i},{i}] = {d}")));
        }
        x[i] /= d;
        let xi = x[i];
        // Rᵀ is lower; eliminate column i of Rᵀ = row i of R beyond diag.
        let row = r.row(i);
        for j in i + 1..n {
            x[j] -= row[j] * xi;
        }
    }
    Ok(x)
}

/// Compute `Y = A R⁻¹` for tall dense `A` (m×n) and upper-triangular `R`
/// (n×n) — "forward substitution" in the paper's Algorithm 1 step 4
/// (each *row* of Y solves `Rᵀ yᵢᵀ = aᵢᵀ`).
///
/// Row-major A makes this embarrassingly row-parallel and cache-perfect:
/// each row of A is transformed independently against cache-resident R.
pub fn right_solve_upper(a: &DenseMatrix, r: &DenseMatrix) -> Result<DenseMatrix> {
    let n = check_square(r)?;
    if a.cols() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "right_solve_upper: A is {}x{}, R is {n}x{n}",
            a.rows(),
            a.cols()
        )));
    }
    for i in 0..n {
        if r[(i, i)].abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("right_solve_upper: R[{i},{i}] = 0")));
        }
    }
    let mut y = a.clone();
    right_solve_upper_inplace(&mut y, r);
    Ok(y)
}

/// In-place version of [`right_solve_upper`] (A is overwritten with Y).
pub fn right_solve_upper_inplace(a: &mut DenseMatrix, r: &DenseMatrix) {
    let n = r.rows();
    debug_assert_eq!(a.cols(), n);
    let m = a.rows();
    let inv_diag: Vec<f64> = (0..n).map(|j| 1.0 / r[(j, j)]).collect();
    right_solve_rows(a.data_mut(), m, r, &inv_diag);
}

/// The serial kernel shared by [`right_solve_upper_inplace`] and
/// [`right_solve_upper_multi`]: transform `rows` contiguous rows of a
/// row-major block. Each row is independent, so any row partitioning is
/// bitwise identical to the full serial pass.
fn right_solve_rows(block: &mut [f64], rows: usize, r: &DenseMatrix, inv_diag: &[f64]) {
    let n = r.rows();
    debug_assert_eq!(block.len(), rows * n);
    // y_row Rᵀ-solve: y[j] = (a[j] - sum_{k<j} y[k] R[k,j]) / R[j,j]
    // Process column j in increasing order; vectorize over rows in blocks.
    for bi in (0..rows).step_by(64) {
        let bend = (bi + 64).min(rows);
        for j in 0..n {
            // gather R column j above diagonal once
            for i in bi..bend {
                let row = &mut block[i * n..(i + 1) * n];
                let mut s = row[j];
                for k in 0..j {
                    s -= row[k] * r[(k, j)];
                }
                row[j] = s * inv_diag[j];
            }
        }
    }
}

/// Row-parallel `Y = A R⁻¹` — the multithreaded version of
/// [`right_solve_upper`] (the "solve half" of the ROADMAP's parallel QR +
/// right-solve item). Rows of A are independent, so sharding them across
/// the pool at 64-row (cache-block) aligned boundaries is **bitwise
/// identical** to the serial path at any thread count.
pub fn right_solve_upper_multi(a: &DenseMatrix, r: &DenseMatrix) -> Result<DenseMatrix> {
    let n = check_square(r)?;
    if a.cols() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "right_solve_upper_multi: A is {}x{}, R is {n}x{n}",
            a.rows(),
            a.cols()
        )));
    }
    for i in 0..n {
        if r[(i, i)].abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("right_solve_upper_multi: R[{i},{i}] = 0")));
        }
    }
    let m = a.rows();
    let mut y = a.clone();
    let inv_diag: Vec<f64> = (0..n).map(|j| 1.0 / r[(j, j)]).collect();
    let work = m.saturating_mul(n.saturating_mul(n));
    let threads = if work < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(m, 64)
    };
    let ranges = crate::parallel::partition_aligned(m, threads, 64);
    crate::parallel::for_each_row_range(y.data_mut(), n, &ranges, 64, |_, rows, block| {
        right_solve_rows(block, rows.len(), r, &inv_diag);
    });
    Ok(y)
}

/// Solve `R xᵣ = bᵣ` for a row-stored block of k right-hand sides (`b` is
/// k×n; row r holds RHS r) — back substitution, row-parallel over the k
/// independent systems. Row r is bitwise identical to
/// [`solve_upper`]`(r, b.row(r))` at any thread count.
pub fn solve_upper_block(r: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let n = check_square(r)?;
    if b.cols() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_upper_block: R is {n}x{n}, block has {} cols",
            b.cols()
        )));
    }
    for i in 0..n {
        if r[(i, i)].abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!("solve_upper_block: R[{i},{i}] = 0")));
        }
    }
    let k = b.rows();
    let mut x = b.clone();
    if k == 0 || n == 0 {
        return Ok(x);
    }
    let work = k.saturating_mul(n.saturating_mul(n));
    let threads = if work < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(k, 1)
    };
    crate::parallel::for_each_row_block(x.data_mut(), k, n, threads, |_, _rows, block| {
        for xr in block.chunks_mut(n) {
            for i in (0..n).rev() {
                let mut s = xr[i];
                let row = r.row(i);
                for j in i + 1..n {
                    s -= row[j] * xr[j];
                }
                xr[i] = s / row[i];
            }
        }
    });
    Ok(x)
}

/// Solve `Rᵀ xᵣ = bᵣ` for a row-stored block of k right-hand sides —
/// forward substitution against R's transpose, row-parallel. Row r is
/// bitwise identical to [`solve_upper_transpose`]`(r, b.row(r))`.
pub fn solve_upper_transpose_block(r: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let n = check_square(r)?;
    if b.cols() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "solve_upper_transpose_block: R is {n}x{n}, block has {} cols",
            b.cols()
        )));
    }
    for i in 0..n {
        if r[(i, i)].abs() <= SINGULAR_RTOL {
            return Err(LinalgError::Singular(format!(
                "solve_upper_transpose_block: R[{i},{i}] = 0"
            )));
        }
    }
    let k = b.rows();
    let mut x = b.clone();
    if k == 0 || n == 0 {
        return Ok(x);
    }
    let work = k.saturating_mul(n.saturating_mul(n));
    let threads = if work < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(k, 1)
    };
    crate::parallel::for_each_row_block(x.data_mut(), k, n, threads, |_, _rows, block| {
        for xr in block.chunks_mut(n) {
            for i in 0..n {
                let row = r.row(i);
                xr[i] /= row[i];
                let xi = xr[i];
                for j in i + 1..n {
                    xr[j] -= row[j] * xi;
                }
            }
        }
    });
    Ok(x)
}

fn check_square(m: &DenseMatrix) -> Result<usize> {
    let (r, c) = m.shape();
    if r != c {
        return Err(LinalgError::InvalidArgument(format!("expected square, got {r}x{c}")));
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::qr;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn rand_upper(n: usize, seed: u64) -> DenseMatrix {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(seed));
        let mut r = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = g.next_gaussian();
            }
            // keep diagonal away from zero
            r[(i, i)] += 3.0 * r[(i, i)].signum();
            if r[(i, i)] == 0.0 {
                r[(i, i)] = 3.0;
            }
        }
        r
    }

    #[test]
    fn upper_solve_roundtrip() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(21));
        for n in [1usize, 2, 5, 33, 100] {
            let r = rand_upper(n, n as u64);
            let x_true = g.gaussian_vec(n);
            let b = r.matvec(&x_true);
            let x = solve_upper(&r, &b).unwrap();
            for (u, v) in x.iter().zip(x_true.iter()) {
                assert!((u - v).abs() < 1e-9 * (1.0 + v.abs()), "n={n}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn lower_solve_roundtrip() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(22));
        for n in [1usize, 3, 17, 64] {
            let l = rand_upper(n, 100 + n as u64).transpose();
            let x_true = g.gaussian_vec(n);
            let b = l.matvec(&x_true);
            let x = solve_lower(&l, &b).unwrap();
            for (u, v) in x.iter().zip(x_true.iter()) {
                assert!((u - v).abs() < 1e-9 * (1.0 + v.abs()));
            }
        }
    }

    #[test]
    fn upper_transpose_solve_matches_explicit() {
        let n = 20;
        let r = rand_upper(n, 23);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(24));
        let b = g.gaussian_vec(n);
        let x1 = solve_upper_transpose(&r, &b).unwrap();
        let x2 = solve_lower(&r.transpose(), &b).unwrap();
        for (u, v) in x1.iter().zip(x2.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn right_solve_matches_per_row() {
        let (m, n) = (47, 12);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(25));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let r = rand_upper(n, 26);
        let y = right_solve_upper(&a, &r).unwrap();
        // Check Y R = A.
        let yr = y.matmul(&r).unwrap();
        let rel = yr.fro_distance(&a) / a.fro_norm();
        assert!(rel < 1e-11, "rel {rel}");
    }

    #[test]
    fn right_solve_preconditions_qr() {
        // Y = A R⁻¹ where R comes from QR(A) must have orthonormal columns.
        let (m, n) = (120, 15);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(27));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let f = qr(&a).unwrap();
        let y = right_solve_upper(&a, &f.r).unwrap();
        let yty = y.transpose().matmul(&y).unwrap();
        assert!(yty.fro_distance(&DenseMatrix::eye(n)) < 1e-10);
    }

    #[test]
    fn singular_detected() {
        let mut r = DenseMatrix::eye(3);
        r[(1, 1)] = 0.0;
        assert!(matches!(solve_upper(&r, &[1.0, 1.0, 1.0]), Err(LinalgError::Singular(_))));
        assert!(matches!(
            solve_upper_transpose(&r, &[1.0, 1.0, 1.0]),
            Err(LinalgError::Singular(_))
        ));
        let a = DenseMatrix::zeros(4, 3);
        assert!(matches!(right_solve_upper(&a, &r), Err(LinalgError::Singular(_))));
    }

    #[test]
    fn dimension_checks() {
        let r = DenseMatrix::eye(3);
        assert!(solve_upper(&r, &[1.0, 2.0]).is_err());
        assert!(solve_lower(&r, &[1.0, 2.0]).is_err());
        let a = DenseMatrix::zeros(5, 4);
        assert!(right_solve_upper(&a, &r).is_err());
        let ns = DenseMatrix::zeros(3, 4);
        assert!(solve_upper(&ns, &[1.0, 2.0, 3.0]).is_err());
    }

    #[test]
    fn right_solve_multi_matches_serial_bitwise() {
        // The parallel path must be bit-for-bit the serial one (the factor
        // cache shares results across workers at different pool sizes).
        let (m, n) = (331, 24);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(28));
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let r = rand_upper(n, 29);
        let serial = right_solve_upper(&a, &r).unwrap();
        let multi = right_solve_upper_multi(&a, &r).unwrap();
        assert_eq!(serial, multi);
    }

    #[test]
    fn solve_upper_block_matches_per_row_bitwise() {
        let (k, n) = (7, 19);
        let r = rand_upper(n, 30);
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(31));
        let b = DenseMatrix::gaussian(k, n, &mut g);
        let x = solve_upper_block(&r, &b).unwrap();
        let xt = solve_upper_transpose_block(&r, &b).unwrap();
        for j in 0..k {
            assert_eq!(x.row(j), &solve_upper(&r, b.row(j)).unwrap()[..], "row {j}");
            assert_eq!(
                xt.row(j),
                &solve_upper_transpose(&r, b.row(j)).unwrap()[..],
                "transpose row {j}"
            );
        }
        // Empty block is a no-op, not a panic.
        let empty = DenseMatrix::zeros(0, n);
        assert_eq!(solve_upper_block(&r, &empty).unwrap().rows(), 0);
    }

    #[test]
    fn block_solvers_reject_singular_and_mismatch() {
        let mut r = DenseMatrix::eye(3);
        r[(1, 1)] = 0.0;
        let b = DenseMatrix::zeros(2, 3);
        assert!(matches!(solve_upper_block(&r, &b), Err(LinalgError::Singular(_))));
        assert!(matches!(solve_upper_transpose_block(&r, &b), Err(LinalgError::Singular(_))));
        assert!(matches!(right_solve_upper_multi(&b, &r), Err(LinalgError::Singular(_))));
        let ok = DenseMatrix::eye(3);
        let wide = DenseMatrix::zeros(2, 4);
        assert!(solve_upper_block(&ok, &wide).is_err());
        assert!(solve_upper_transpose_block(&ok, &wide).is_err());
        assert!(right_solve_upper_multi(&wide, &ok).is_err());
    }
}
