//! Blocked dense GEMM / GEMV kernels.
//!
//! Row-major `C = A·B` with BLIS-style packed-panel blocking and a
//! register-tile microkernel dispatched through [`crate::simd`] (scalar /
//! AVX2+FMA / AVX-512 / NEON, selected at runtime). This is the CPU
//! stand-in for the MXU-tiled Pallas kernel at Layer 1 — same tiling idea
//! (stream panels of B through a register-resident accumulator), different
//! hardware target.
//!
//! **Packing.** The interior loop no longer reads A/B straight out of the
//! row-major buffers: each (jc, pc) iteration packs the B block into
//! NR-column panels (once — shared read-only across the row-panel
//! workers) and each (ic, pc) iteration packs the A block into MR-row
//! strips (per-worker, cache-line-aligned scratch), so the microkernel
//! streams contiguous, zero-padded operands — edge tiles are padded in
//! the pack and the ragged scalar kernel disappears from the packed
//! interior. `SNSOLVE_GEMM_PACK=0` / [`set_packing`] / the
//! `[parallel] pack` config key / `--pack false` restore the direct
//! (unpacked) nest, which the `micro_linalg` bench uses as its baseline.
//!
//! **IEEE contract:** no kernel on this path skips zero operands, so
//! `0·NaN = 0·Inf = NaN` reaches C identically whether an element lands in
//! a full register tile, a zero-padded packed edge tile, or an unpacked
//! ragged edge tile (see `tests/nan_propagation.rs`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::dense::DenseMatrix;
use super::{LinalgError, Result};
use crate::simd::{self, SimdKernels};

// Cache blocking parameters. MC*KC*8B ≈ 512 KB fits comfortably in L2;
// KC*NC panels of B stream through L3/memory; the MR x NR register tile
// (backend-dependent: 4x8 scalar/NEON, 4x12 AVX2+FMA, 8x8 AVX-512) keeps
// the accumulators live in vector registers.
const MC: usize = 256;
const KC: usize = 256;
const NC: usize = 1024;

/// Below this many MACs the pack copies cost more than they save (tiny
/// service matmuls, the blocked-QR T products); the nest reads the
/// row-major buffers directly instead. Decided once per `matmul_into` on
/// the **full** problem shape, so serial and row-sharded runs always take
/// the same path (a per-panel decision would break the bitwise
/// thread-count contract at the edge tiles, where the two paths round
/// differently).
const PACK_MIN_FLOPS: usize = 1 << 15;

/// Packing knob tri-state (process-wide).
const PACK_UNSET: u8 = 0;
const PACK_ON: u8 = 1;
const PACK_OFF: u8 = 2;

static PACK_CONFIGURED: AtomicU8 = AtomicU8::new(PACK_UNSET);

/// Force the packed-panel GEMM path on/off for this process (`None`
/// restores the ambient resolution: `SNSOLVE_GEMM_PACK` env var, then the
/// default **on**). Wired from [`crate::config::SolveConfig`], the
/// `--pack` CLI flag and the `[parallel] pack` config key; benches flip it
/// to measure packed vs unpacked throughput.
pub fn set_packing(on: Option<bool>) {
    let v = match on {
        None => PACK_UNSET,
        Some(true) => PACK_ON,
        Some(false) => PACK_OFF,
    };
    PACK_CONFIGURED.store(v, Ordering::SeqCst);
}

fn env_packing() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        // Case-insensitive like SNSOLVE_SIMD, so OFF/False/0 all disable.
        // snsolve-lint: allow(env-reads-behind-config) — designated
        // knob-resolution site: OnceLock-cached SNSOLVE_GEMM_PACK fallback
        // behind set_packing() (CLI/config take precedence).
        let v = std::env::var("SNSOLVE_GEMM_PACK")
            .map(|s| s.trim().to_ascii_lowercase())
            .unwrap_or_default();
        !matches!(v.as_str(), "0" | "false" | "off")
    })
}

/// Whether large GEMMs currently take the packed-panel path:
/// [`set_packing`] → `SNSOLVE_GEMM_PACK` → on.
pub fn packing_enabled() -> bool {
    match PACK_CONFIGURED.load(Ordering::SeqCst) {
        PACK_ON => true,
        PACK_OFF => false,
        _ => env_packing(),
    }
}

/// Heap scratch for the pack buffers, nudged to a 64-byte (cache-line /
/// zmm) boundary. Alignment is a throughput nicety, not a correctness
/// requirement — the microkernels use unaligned loads — so the clamp on
/// `align_offset`'s escape value is harmless.
struct PackBuf {
    raw: Vec<f64>,
    off: usize,
}

impl PackBuf {
    fn new(len: usize) -> PackBuf {
        let raw = vec![0.0f64; len + 7];
        let off = raw.as_ptr().align_offset(64).min(7);
        PackBuf { raw, off }
    }

    fn buf_mut(&mut self) -> &mut [f64] {
        &mut self.raw[self.off..]
    }
}

/// `C = A · B`.
pub fn matmul(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    if a.cols() != b.rows() {
        return Err(LinalgError::DimensionMismatch(format!(
            "matmul: ({}x{}) · ({}x{})",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let mut c = DenseMatrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c)?;
    Ok(c)
}

/// `C += A · B` into an existing (zeroed or accumulating) output.
///
/// Parallel: C's rows are sharded into contiguous panels, one scoped worker
/// per panel (each also owning the matching rows of A; B is shared
/// read-only). Panel boundaries are aligned to the active SIMD backend's
/// register-tile height, and every C element accumulates over `pc` in the
/// same order as the serial nest, so for a fixed backend the result is
/// **bitwise identical** at any thread count.
pub fn matmul_into(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    if k != kb || c.shape() != (m, n) {
        return Err(LinalgError::DimensionMismatch(format!(
            "matmul_into: A {}x{}, B {}x{}, C {:?}",
            m, k, kb, n, c.shape()
        )));
    }
    let adata = a.data();
    let bdata = b.data();
    let cdata = c.data_mut();
    let kern = simd::kernels();

    let flops = m.saturating_mul(k).saturating_mul(n);
    // Path and thread decisions are made on the FULL shape, never per
    // panel: packed and unpacked edge tiles round differently, so a
    // per-panel choice would break bitwise identity across thread counts.
    let packed = packing_enabled() && flops >= PACK_MIN_FLOPS;
    let threads = if flops < 4 * crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(m, kern.mr())
    };
    if threads <= 1 {
        gemm_nest(adata, bdata, cdata, m, k, n, kern, packed);
    } else if packed {
        gemm_packed_nest(adata, bdata, cdata, m, k, n, kern, threads);
    } else {
        // MR-aligned panel boundaries keep the register-tile layout (and
        // hence every rounding) identical to the serial nest.
        let panels = crate::parallel::partition_aligned(m, threads, kern.mr());
        crate::parallel::for_each_row_range(cdata, n, &panels, kern.mr(), |_, rows, cblock| {
            let ablock = &adata[rows.start * k..rows.end * k];
            gemm_nest(ablock, bdata, cblock, rows.len(), k, n, kern, packed);
        });
    }
    Ok(())
}

/// The packed nest, serial and threaded alike (`threads = 1` runs the
/// whole matrix as one panel on the calling thread): B is packed **once**
/// per (jc, pc) block on the calling thread and shared read-only across
/// the row-panel workers (a per-worker B pack would multiply the copy
/// bandwidth on the shared operand by the thread count); each worker packs
/// only its own A rows. Row-panel boundaries stay MR-aligned and every C
/// element accumulates in the exact same order at every panel split
/// (ascending `pc`, one packed tile per block), so the result is bitwise
/// identical at any thread count — one copy of this loop nest serves both
/// paths precisely so that contract can't drift.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_nest(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    kern: &dyn SimdKernels,
    threads: usize,
) {
    let tnr = kern.nr();
    let panels = crate::parallel::partition_aligned(m, threads, kern.mr());
    let nc_step = (NC - NC % tnr).max(tnr);
    let mut bpack = PackBuf::new(KC * nc_step.min(n).div_ceil(tnr) * tnr);
    for jc in (0..n).step_by(nc_step) {
        let nc = nc_step.min(n - jc);
        let npanels = nc.div_ceil(tnr);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            let bbuf = &mut bpack.buf_mut()[..npanels * tnr * kc];
            kern.pack_b(b, n, pc, jc, kc, nc, bbuf);
            let bbuf: &[f64] = bbuf;
            crate::parallel::for_each_row_range(c, n, &panels, kern.mr(), |_, rows, cblock| {
                let ablock = &a[rows.start * k..rows.end * k];
                packed_block_rows(ablock, bbuf, cblock, rows.len(), k, n, jc, pc, kc, nc, kern);
            });
        }
    }
}

/// The blocked loop nest over an `m`-row panel of A/C, on the calling
/// thread.
///
/// Loop nest: jc (NC cols of B) -> pc (KC depth) -> ic (MC rows of A) ->
/// microkernel over MR x NR register tiles. `packed` selects between the
/// packed-panel path (a one-panel [`gemm_packed_nest`]) and the direct
/// (seed) nest; it must be decided by the caller on the full problem
/// shape.
#[allow(clippy::too_many_arguments)]
fn gemm_nest(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    kern: &dyn SimdKernels,
    packed: bool,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    if packed {
        gemm_packed_nest(a, b, c, m, k, n, kern, 1);
    } else {
        gemm_nest_unpacked(a, b, c, m, k, n, kern);
    }
}

/// One (jc, pc) block over an `m`-row panel of A/C against an
/// already-packed B block: pack A per MC sub-block (into this worker's own
/// scratch) and run the packed microkernel over every strip × panel tile —
/// every interior AND edge tile goes through the branch-free packed
/// microkernel (edges are zero-padded in the pack; the pad rows/columns
/// are computed but masked out of the write-back).
///
/// The A scratch is allocated per call: the scoped pool spawns fresh OS
/// threads per fan-out anyway, so a worker-persistent buffer has nowhere
/// to live, and the ≤ 512 KB allocation is the same order as the thread
/// spawn it accompanies.
#[allow(clippy::too_many_arguments)]
fn packed_block_rows(
    a: &[f64],
    bbuf: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    jc: usize,
    pc: usize,
    kc: usize,
    nc: usize,
    kern: &dyn SimdKernels,
) {
    let (tmr, tnr) = (kern.mr(), kern.nr());
    let npanels = nc.div_ceil(tnr);
    let mut apack = PackBuf::new(MC.min(m).div_ceil(tmr) * tmr * kc);
    for ic in (0..m).step_by(MC) {
        let mc = MC.min(m - ic);
        let nstrips = mc.div_ceil(tmr);
        let abuf = &mut apack.buf_mut()[..nstrips * tmr * kc];
        kern.pack_a(a, k, ic, pc, mc, kc, abuf);
        for si in 0..nstrips {
            let ir = si * tmr;
            let mr = tmr.min(mc - ir);
            let astrip = &abuf[si * tmr * kc..(si + 1) * tmr * kc];
            for pj in 0..npanels {
                let jr = pj * tnr;
                let nr = tnr.min(nc - jr);
                let bpanel = &bbuf[pj * tnr * kc..(pj + 1) * tnr * kc];
                kern.gemm_tile_packed(astrip, bpanel, c, n, ic + ir, jc + jr, kc, mr, nr);
            }
        }
    }
}

/// Direct (unpacked) nest — the pre-packing seed path, kept as the bench
/// baseline and for small problems where packing doesn't pay.
fn gemm_nest_unpacked(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    kern: &dyn SimdKernels,
) {
    // Column blocks rounded down to a multiple of the backend's tile width
    // (1024 for NR=8, 1020 for the AVX2 NR=12) — otherwise every interior
    // jc block would end in a permanent ragged strip served by the scalar
    // edge kernel. Per-element accumulation order is unaffected (each C
    // element lives in exactly one jr tile per pc step), so the per-backend
    // bitwise thread-determinism contract is untouched.
    let nc_step = (NC - NC % kern.nr()).max(kern.nr());
    for jc in (0..n).step_by(nc_step) {
        let nc = nc_step.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                block_kernel(a, b, c, k, n, ic, jc, pc, mc, nc, kc, kern);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn block_kernel(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    k: usize,
    n: usize,
    ic: usize,
    jc: usize,
    pc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    kern: &dyn SimdKernels,
) {
    let (tmr, tnr) = (kern.mr(), kern.nr());
    let mut ir = 0;
    while ir < mc {
        let mr = tmr.min(mc - ir);
        let mut jr = 0;
        while jr < nc {
            let nr = tnr.min(nc - jr);
            if mr == tmr && nr == tnr {
                kern.gemm_tile(a, b, c, k, n, ic + ir, jc + jr, pc, kc);
            } else {
                micro_edge(a, b, c, k, n, ic + ir, jc + jr, pc, mr, nr, kc);
            }
            jr += tnr;
        }
        ir += tmr;
    }
}

/// Scalar edge microkernel for ragged tiles (shared by every backend).
///
/// No `av == 0.0` shortcut: skipping would drop `0·NaN`/`0·Inf`, making
/// C's non-finite propagation depend on which tile an element lands in.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_edge(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    kc: usize,
) {
    for r in 0..mr {
        let arow = (i0 + r) * k + pc;
        let crow = (i0 + r) * n + j0;
        for p in 0..kc {
            let av = a[arow + p];
            let bp = (pc + p) * n + j0;
            for s in 0..nr {
                c[crow + s] += av * b[bp + s];
            }
        }
    }
}

/// `y = A x` — row-major matvec; each row is a contiguous dot product.
pub fn matvec(a: &DenseMatrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(
        a.cols(),
        x.len(),
        "matvec: A is {}x{}, x has {}",
        a.rows(),
        a.cols(),
        x.len()
    );
    let mut y = vec![0.0; a.rows()];
    matvec_into(a, x, &mut y, 0.0);
    y
}

/// `y = beta*y + A x`.
///
/// Parallel: y's entries (= A's rows) shard into contiguous blocks across
/// the worker pool behind the usual [`crate::parallel::PAR_MIN_ELEMS`]
/// gate. Each entry is one full-row `dot`, so every entry is **bitwise
/// identical** to the serial loop at any thread count (same per-row
/// contract as the blocked `apply_mat` paths).
pub fn matvec_into(a: &DenseMatrix, x: &[f64], y: &mut [f64], beta: f64) {
    let (m, n) = a.shape();
    debug_assert_eq!(y.len(), m);
    let kern = simd::kernels();
    let adata = a.data();
    let work = m.saturating_mul(n);
    let threads = if work < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(m, 8)
    };
    if threads <= 1 {
        for (i, yi) in y.iter_mut().enumerate() {
            let row = &adata[i * n..(i + 1) * n];
            *yi = beta * *yi + kern.dot(row, x);
        }
    } else {
        crate::parallel::for_each_row_block(y, m, 1, threads, |_, rows, yblock| {
            for (local, i) in rows.enumerate() {
                let row = &adata[i * n..(i + 1) * n];
                yblock[local] = beta * yblock[local] + kern.dot(row, x);
            }
        });
    }
}

/// Column-stripe alignment for the parallel [`matvec_t`]: stripe
/// boundaries must be a multiple of every backend's `axpy` vector-body
/// chunk (scalar 4, NEON 4, AVX2 8, AVX-512 16) so that element `j` takes
/// the same code path (vector body vs scalar tail — which round
/// differently under FMA) inside a stripe as in the full-row serial call.
/// That positional invariance is what keeps the sharded result bitwise
/// identical to the serial accumulation chain; `gemm::tests::
/// axpy_stripes_match_full_row_bitwise` pins it per backend.
const MATVEC_T_COL_ALIGN: usize = 16;

/// `y = Aᵀ x` — accumulate x[i]-scaled rows; streams A once, writes y
/// repeatedly (y is short: n entries, cache-resident).
///
/// Parallel: y shards into contiguous **column stripes** (each worker
/// streams all of A but only its column range), because sharding A's rows
/// would turn the sum into a thread-count-dependent reduction. Stripe
/// boundaries are [`MATVEC_T_COL_ALIGN`]-aligned, so each y entry
/// accumulates in exactly the serial order and the result is **bitwise
/// identical** at any thread count — the same per-element contract the
/// blocked `apply_transpose_mat` relies on.
///
/// Zero coefficients are **not** skipped: `0 · row` must still propagate
/// NaN/Inf from A into y (same IEEE contract as the GEMM tiles).
pub fn matvec_t(a: &DenseMatrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(
        a.rows(),
        x.len(),
        "matvec_t: A is {}x{}, x has {}",
        a.rows(),
        a.cols(),
        x.len()
    );
    let (m, n) = a.shape();
    let mut y = vec![0.0; n];
    let kern = simd::kernels();
    let adata = a.data();
    let work = m.saturating_mul(n);
    let threads = if work < crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(n.div_ceil(MATVEC_T_COL_ALIGN), 1)
    };
    let stripes = crate::parallel::partition_aligned(n, threads, MATVEC_T_COL_ALIGN);
    crate::parallel::for_each_row_range(&mut y, 1, &stripes, MATVEC_T_COL_ALIGN, |_, cols, yblock| {
        for (i, &xi) in x.iter().enumerate() {
            let row = &adata[i * n + cols.start..i * n + cols.end];
            kern.axpy(xi, row, yblock);
        }
    });
    y
}

/// Unrolled dot product (dispatched to the active SIMD backend).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    simd::kernels().dot(a, b)
}

/// `y += alpha * x` (dispatched to the active SIMD backend).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::kernels().axpy(alpha, x, y)
}

/// `x *= alpha` (dispatched to the active SIMD backend).
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    simd::kernels().scal(alpha, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn naive_matmul(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = DenseMatrix::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let av = a[(i, p)];
                for j in 0..n {
                    c[(i, j)] += av * b[(p, j)];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(3));
        for (m, k, n) in [
            (1, 1, 1),
            (3, 4, 5),
            (4, 8, 8),
            (5, 7, 9),
            (4, 8, 12),
            (5, 9, 13),
            (17, 33, 29),
            (64, 64, 64),
            (100, 37, 258),
            (260, 270, 1030), // crosses all block boundaries
        ] {
            let a = DenseMatrix::gaussian(m, k, &mut g);
            let b = DenseMatrix::gaussian(k, n, &mut g);
            let c = matmul(&a, &b).unwrap();
            let c_ref = naive_matmul(&a, &b);
            let err = c.fro_distance(&c_ref) / c_ref.fro_norm().max(1e-300);
            assert!(err < 1e-13, "({m},{k},{n}): rel err {err}");
        }
    }

    #[test]
    fn matmul_dim_mismatch() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(4, 2);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn matmul_identity() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(4));
        let a = DenseMatrix::gaussian(20, 20, &mut g);
        let i = DenseMatrix::eye(20);
        let c = matmul(&a, &i).unwrap();
        assert!(a.fro_distance(&c) < 1e-14);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(5));
        let a = DenseMatrix::gaussian(23, 17, &mut g);
        let x = g.gaussian_vec(17);
        let y = matvec(&a, &x);
        let xm = DenseMatrix::from_vec(17, 1, x.clone()).unwrap();
        let ym = matmul(&a, &xm).unwrap();
        for i in 0..23 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(6));
        let a = DenseMatrix::gaussian(31, 13, &mut g);
        let x = g.gaussian_vec(31);
        let y1 = matvec_t(&a, &x);
        let y2 = matvec(&a.transpose(), &x);
        for (u, v) in y1.iter().zip(y2.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_and_axpy() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = [1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0, 9.0, 11.0]);
        let mut z = [2.0, 4.0];
        scal(0.5, &mut z);
        assert_eq!(z, [1.0, 2.0]);
    }

    #[test]
    fn matvec_into_beta() {
        let a = DenseMatrix::eye(3);
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0, 10.0];
        matvec_into(&a, &x, &mut y, 1.0);
        assert_eq!(y, vec![11.0, 12.0, 13.0]);
    }

    #[test]
    fn matmul_into_accumulates() {
        let a = DenseMatrix::eye(2);
        let b = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut c = b.clone();
        matmul_into(&a, &b, &mut c).unwrap();
        assert_eq!(c[(0, 0)], 2.0);
        assert_eq!(c[(1, 1)], 8.0);
    }

    /// One test (not several) because the packing knob is process-global
    /// and unit tests run concurrently: the knob flips and the comparison
    /// happen back-to-back here, and every *other* test's matmul assertion
    /// is tolerance-based, so a mid-flight flip elsewhere is harmless.
    #[test]
    fn packing_knob_and_packed_vs_unpacked_agree() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(7));
        // Ragged in every dimension for all tile shapes, above
        // PACK_MIN_FLOPS so the packed path actually engages.
        let (m, k, n) = (37usize, 41, 33);
        assert!(m * k * n >= PACK_MIN_FLOPS);
        let a = DenseMatrix::gaussian(m, k, &mut g);
        let b = DenseMatrix::gaussian(k, n, &mut g);
        set_packing(Some(false));
        assert!(!packing_enabled());
        let unpacked = matmul(&a, &b).unwrap();
        set_packing(Some(true));
        assert!(packing_enabled());
        let packed = matmul(&a, &b).unwrap();
        set_packing(None);
        let scale = unpacked.max_abs().max(1.0);
        for (u, p) in unpacked.data().iter().zip(packed.data().iter()) {
            assert!((u - p).abs() <= 1e-12 * scale, "packed {p} vs unpacked {u}");
        }
    }

    /// The alignment contract behind the parallel `matvec_t`: an axpy run
    /// over [`MATVEC_T_COL_ALIGN`]-aligned stripes is bitwise identical to
    /// the full-slice call on every backend (element `j` keeps its
    /// vector-body vs scalar-tail role across the split).
    #[test]
    fn axpy_stripes_match_full_row_bitwise() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(8));
        for backend in crate::simd::available() {
            let kern = crate::simd::backend_kernels(backend);
            for n in [16usize, 23, 48, 67, 100] {
                let x = g.gaussian_vec(n);
                let mut full = g.gaussian_vec(n);
                let mut striped = full.clone();
                kern.axpy(0.73, &x, &mut full);
                let mut j0 = 0;
                while j0 < n {
                    let j1 = (j0 + MATVEC_T_COL_ALIGN).min(n);
                    kern.axpy(0.73, &x[j0..j1], &mut striped[j0..j1]);
                    j0 = j1;
                }
                assert_eq!(striped, full, "{} n={n}", backend.name());
            }
        }
    }

    /// Parallel matvec/matvec_t (sizes above the pool gate, ambient thread
    /// count) are bitwise identical to the serial accumulation chain.
    #[test]
    fn parallel_matvec_paths_match_serial_chain_bitwise() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(9));
        let (m, n) = (600usize, 130usize); // m·n ≥ PAR_MIN_ELEMS
        assert!(m * n >= crate::parallel::PAR_MIN_ELEMS);
        let a = DenseMatrix::gaussian(m, n, &mut g);
        let x = g.gaussian_vec(n);
        let u = g.gaussian_vec(m);
        let kern = simd::kernels();

        let y = matvec(&a, &x);
        let mut y_ref = vec![0.0; m];
        for (i, yi) in y_ref.iter_mut().enumerate() {
            *yi = kern.dot(a.row(i), &x);
        }
        assert_eq!(y, y_ref, "matvec");

        let z = matvec_t(&a, &u);
        let mut z_ref = vec![0.0; n];
        for (i, &ui) in u.iter().enumerate() {
            kern.axpy(ui, a.row(i), &mut z_ref);
        }
        assert_eq!(z, z_ref, "matvec_t");

        // beta path too.
        let mut yb = u.clone();
        matvec_into(&a, &x, &mut yb, 0.5);
        let mut yb_ref = u.clone();
        for (i, yi) in yb_ref.iter_mut().enumerate() {
            *yi = 0.5 * *yi + kern.dot(a.row(i), &x);
        }
        assert_eq!(yb, yb_ref, "matvec_into beta");
    }
}
