//! Blocked dense GEMM / GEMV kernels.
//!
//! Row-major `C = A·B` with L1/L2-aware blocking and a register-tile
//! microkernel dispatched through [`crate::simd`] (scalar / AVX2+FMA /
//! NEON, selected at runtime). This is the CPU stand-in for the MXU-tiled
//! Pallas kernel at Layer 1 — same tiling idea (stream panels of B through
//! a register-resident accumulator), different hardware target.
//!
//! **IEEE contract:** no kernel on this path skips zero operands, so
//! `0·NaN = 0·Inf = NaN` reaches C identically whether an element lands in
//! a full register tile or a ragged edge tile (see
//! `tests/nan_propagation.rs`).

use super::dense::DenseMatrix;
use super::{LinalgError, Result};
use crate::simd::{self, SimdKernels};

// Cache blocking parameters. MC*KC*8B ≈ 512 KB fits comfortably in L2;
// KC*NC panels of B stream through L3/memory; the MR x NR register tile
// (backend-dependent: 4x8 scalar/NEON, 4x12 AVX2+FMA) keeps the
// accumulators live in vector registers.
const MC: usize = 256;
const KC: usize = 256;
const NC: usize = 1024;

/// `C = A · B`.
pub fn matmul(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    if a.cols() != b.rows() {
        return Err(LinalgError::DimensionMismatch(format!(
            "matmul: ({}x{}) · ({}x{})",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let mut c = DenseMatrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c)?;
    Ok(c)
}

/// `C += A · B` into an existing (zeroed or accumulating) output.
///
/// Parallel: C's rows are sharded into contiguous panels, one scoped worker
/// per panel (each also owning the matching rows of A; B is shared
/// read-only). Panel boundaries are aligned to the active SIMD backend's
/// register-tile height, and every C element accumulates over `pc` in the
/// same order as the serial nest, so for a fixed backend the result is
/// **bitwise identical** at any thread count.
pub fn matmul_into(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    if k != kb || c.shape() != (m, n) {
        return Err(LinalgError::DimensionMismatch(format!(
            "matmul_into: A {}x{}, B {}x{}, C {:?}",
            m, k, kb, n, c.shape()
        )));
    }
    let adata = a.data();
    let bdata = b.data();
    let cdata = c.data_mut();
    let kern = simd::kernels();

    let flops = m.saturating_mul(k).saturating_mul(n);
    let threads = if flops < 4 * crate::parallel::PAR_MIN_ELEMS {
        1
    } else {
        crate::parallel::threads_for(m, kern.mr())
    };
    if threads <= 1 {
        gemm_nest(adata, bdata, cdata, m, k, n, kern);
    } else {
        // MR-aligned panel boundaries keep the register-tile layout (and
        // hence every rounding) identical to the serial nest.
        let panels = crate::parallel::partition_aligned(m, threads, kern.mr());
        crate::parallel::for_each_row_range(cdata, n, &panels, |_, rows, cblock| {
            let ablock = &adata[rows.start * k..rows.end * k];
            gemm_nest(ablock, bdata, cblock, rows.len(), k, n, kern);
        });
    }
    Ok(())
}

/// The serial blocked loop nest over an `m`-row panel of A/C.
///
/// Loop nest: jc (NC cols of B) -> pc (KC depth) -> ic (MC rows of A)
/// -> microkernel over MR x NR register tiles.
#[allow(clippy::too_many_arguments)]
fn gemm_nest(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    kern: &dyn SimdKernels,
) {
    // Column blocks rounded down to a multiple of the backend's tile width
    // (1024 for NR=8, 1020 for the AVX2 NR=12) — otherwise every interior
    // jc block would end in a permanent ragged strip served by the scalar
    // edge kernel. Per-element accumulation order is unaffected (each C
    // element lives in exactly one jr tile per pc step), so the per-backend
    // bitwise thread-determinism contract is untouched.
    let nc_step = (NC - NC % kern.nr()).max(kern.nr());
    for jc in (0..n).step_by(nc_step) {
        let nc = nc_step.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                block_kernel(a, b, c, k, n, ic, jc, pc, mc, nc, kc, kern);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn block_kernel(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    k: usize,
    n: usize,
    ic: usize,
    jc: usize,
    pc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    kern: &dyn SimdKernels,
) {
    let (tmr, tnr) = (kern.mr(), kern.nr());
    let mut ir = 0;
    while ir < mc {
        let mr = tmr.min(mc - ir);
        let mut jr = 0;
        while jr < nc {
            let nr = tnr.min(nc - jr);
            if mr == tmr && nr == tnr {
                kern.gemm_tile(a, b, c, k, n, ic + ir, jc + jr, pc, kc);
            } else {
                micro_edge(a, b, c, k, n, ic + ir, jc + jr, pc, mr, nr, kc);
            }
            jr += tnr;
        }
        ir += tmr;
    }
}

/// Scalar edge microkernel for ragged tiles (shared by every backend).
///
/// No `av == 0.0` shortcut: skipping would drop `0·NaN`/`0·Inf`, making
/// C's non-finite propagation depend on which tile an element lands in.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_edge(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    kc: usize,
) {
    for r in 0..mr {
        let arow = (i0 + r) * k + pc;
        let crow = (i0 + r) * n + j0;
        for p in 0..kc {
            let av = a[arow + p];
            let bp = (pc + p) * n + j0;
            for s in 0..nr {
                c[crow + s] += av * b[bp + s];
            }
        }
    }
}

/// `y = A x` — row-major matvec; each row is a contiguous dot product.
pub fn matvec(a: &DenseMatrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(
        a.cols(),
        x.len(),
        "matvec: A is {}x{}, x has {}",
        a.rows(),
        a.cols(),
        x.len()
    );
    let mut y = vec![0.0; a.rows()];
    matvec_into(a, x, &mut y, 0.0);
    y
}

/// `y = beta*y + A x`.
pub fn matvec_into(a: &DenseMatrix, x: &[f64], y: &mut [f64], beta: f64) {
    let n = a.cols();
    let kern = simd::kernels();
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a.data()[i * n..(i + 1) * n];
        *yi = beta * *yi + kern.dot(row, x);
    }
}

/// `y = Aᵀ x` — accumulate x[i]-scaled rows; streams A once, writes y
/// repeatedly (y is short: n entries, cache-resident).
///
/// Zero coefficients are **not** skipped: `0 · row` must still propagate
/// NaN/Inf from A into y (same IEEE contract as the GEMM tiles), and the
/// blocked `apply_transpose_mat` path stays bitwise identical per row.
pub fn matvec_t(a: &DenseMatrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(
        a.rows(),
        x.len(),
        "matvec_t: A is {}x{}, x has {}",
        a.rows(),
        a.cols(),
        x.len()
    );
    let n = a.cols();
    let mut y = vec![0.0; n];
    let kern = simd::kernels();
    for (i, &xi) in x.iter().enumerate() {
        let row = &a.data()[i * n..(i + 1) * n];
        kern.axpy(xi, row, &mut y);
    }
    y
}

/// Unrolled dot product (dispatched to the active SIMD backend).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    simd::kernels().dot(a, b)
}

/// `y += alpha * x` (dispatched to the active SIMD backend).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::kernels().axpy(alpha, x, y)
}

/// `x *= alpha` (dispatched to the active SIMD backend).
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    simd::kernels().scal(alpha, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256pp};

    fn naive_matmul(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = DenseMatrix::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let av = a[(i, p)];
                for j in 0..n {
                    c[(i, j)] += av * b[(p, j)];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(3));
        for (m, k, n) in [
            (1, 1, 1),
            (3, 4, 5),
            (4, 8, 8),
            (5, 7, 9),
            (4, 8, 12),
            (5, 9, 13),
            (17, 33, 29),
            (64, 64, 64),
            (100, 37, 258),
            (260, 270, 1030), // crosses all block boundaries
        ] {
            let a = DenseMatrix::gaussian(m, k, &mut g);
            let b = DenseMatrix::gaussian(k, n, &mut g);
            let c = matmul(&a, &b).unwrap();
            let c_ref = naive_matmul(&a, &b);
            let err = c.fro_distance(&c_ref) / c_ref.fro_norm().max(1e-300);
            assert!(err < 1e-13, "({m},{k},{n}): rel err {err}");
        }
    }

    #[test]
    fn matmul_dim_mismatch() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(4, 2);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn matmul_identity() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(4));
        let a = DenseMatrix::gaussian(20, 20, &mut g);
        let i = DenseMatrix::eye(20);
        let c = matmul(&a, &i).unwrap();
        assert!(a.fro_distance(&c) < 1e-14);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(5));
        let a = DenseMatrix::gaussian(23, 17, &mut g);
        let x = g.gaussian_vec(17);
        let y = matvec(&a, &x);
        let xm = DenseMatrix::from_vec(17, 1, x.clone()).unwrap();
        let ym = matmul(&a, &xm).unwrap();
        for i in 0..23 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let mut g = GaussianSource::new(Xoshiro256pp::seed_from_u64(6));
        let a = DenseMatrix::gaussian(31, 13, &mut g);
        let x = g.gaussian_vec(31);
        let y1 = matvec_t(&a, &x);
        let y2 = matvec(&a.transpose(), &x);
        for (u, v) in y1.iter().zip(y2.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_and_axpy() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = [1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0, 9.0, 11.0]);
        let mut z = [2.0, 4.0];
        scal(0.5, &mut z);
        assert_eq!(z, [1.0, 2.0]);
    }

    #[test]
    fn matvec_into_beta() {
        let a = DenseMatrix::eye(3);
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0, 10.0];
        matvec_into(&a, &x, &mut y, 1.0);
        assert_eq!(y, vec![11.0, 12.0, 13.0]);
    }

    #[test]
    fn matmul_into_accumulates() {
        let a = DenseMatrix::eye(2);
        let b = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut c = b.clone();
        matmul_into(&a, &b, &mut c).unwrap();
        assert_eq!(c[(0, 0)], 2.0);
        assert_eq!(c[(1, 1)], 8.0);
    }
}
